//! Shared fixtures for the integration-test targets (each test target
//! compiles this module separately via `mod common;` — cargo never
//! builds it as its own target because `autotests = false`).

use std::collections::BTreeMap;

use sira_finn::graph::{Graph, Node, Op, RoundMode};
use sira_finn::sira::SiRange;
use sira_finn::tensor::Tensor;

/// A quant → integer MatMul graph whose worst-case partial-sum bound
/// sits just inside the engine's i32 headroom limit (4 × 100 × 5e6 =
/// 2.0e9 < 2.147e9), so the SIRA-proven extremes drive the accumulator
/// to the very sums the width selection certified. Shared by the
/// accumulator-edge cases in `kernel_properties.rs` (engine tiled vs
/// scalar vs executor) and `sira_soundness.rs` (bound tightness): one
/// copy, so the near-limit arithmetic cannot drift between the two.
#[allow(dead_code)]
pub fn near_limit_graph() -> (Graph, BTreeMap<String, SiRange>) {
    let mut g = Graph::new("edge-mm");
    g.add_input("x", &[1, 4]);
    g.add_initializer("one", Tensor::scalar(1.0));
    g.add_initializer("z", Tensor::scalar(0.0));
    g.add_initializer("bits", Tensor::scalar(8.0));
    g.add_node(Node::new(
        "q",
        Op::Quant {
            signed: true,
            narrow: false,
            rounding: RoundMode::RoundEven,
        },
        &["x", "one", "z", "bits"],
        &["xq"],
    ));
    g.add_initializer(
        "W",
        Tensor::new(
            &[4, 3],
            vec![
                5_000_000.0, -5_000_000.0, 2_500_000.0, //
                5_000_000.0, 5_000_000.0, -2_500_000.0, //
                5_000_000.0, -5_000_000.0, 2_500_000.0, //
                5_000_000.0, 5_000_000.0, -2_500_000.0,
            ],
        )
        .unwrap(),
    );
    g.add_node(Node::new("mm", Op::MatMul, &["xq", "W"], &["y"]));
    g.outputs.push("y".into());
    sira_finn::graph::shapes::infer_shapes(&mut g).unwrap();
    let mut inputs = BTreeMap::new();
    inputs.insert("x".to_string(), SiRange::scalar(-100.0, 100.0));
    (g, inputs)
}
