//! Tuning-file persistence contract: a corrupt, truncated, wrong-kind,
//! or stale-version tuning JSON must degrade to the default
//! [`TilingScheme`] with a clean warning — never an error out of
//! `engine::compile`, and never a wrong-answer plan. The scheme only
//! steers MAC loop order (proven result-invariant before it may
//! engage), so even a *maliciously* wrong tuning file cannot change
//! results; this suite locks the degrade-cleanly half of that contract.
//!
//! Everything lives in ONE test fn on purpose: [`tune::global`] reads
//! `SIRA_TUNING_FILE` exactly once per process, so the env var must be
//! set before the first `engine::compile` in this binary and must not
//! race another test.

use sira_finn::engine;
use sira_finn::engine::tune::{self, TilingScheme, TuneEntry, TuningTable};
use sira_finn::executor::Executor;
use sira_finn::models;
use sira_finn::sira::analyze;
use sira_finn::tensor::Tensor;

#[test]
fn corrupt_tuning_files_never_poison_plans() {
    let dir = std::env::temp_dir().join(format!("sira_tune_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // load/parse level: every malformed document is a clean Err
    let cases: &[(&str, &str)] = &[
        ("binary garbage", "\x00\x01\x02 not json"),
        (
            "truncated",
            "{\"tuning\":\"sira-tiling\",\"version\":1,\"entr",
        ),
        (
            "wrong kind",
            "{\"tuning\":\"something-else\",\"version\":1,\"entries\":{}}",
        ),
        (
            "stale version",
            "{\"tuning\":\"sira-tiling\",\"version\":99,\"entries\":{}}",
        ),
        (
            "insane scheme",
            "{\"tuning\":\"sira-tiling\",\"version\":1,\"entries\":\
             {\"k8n8\":{\"mr\":0,\"nr_panels\":1,\"kc\":0,\"ns\":1}}}",
        ),
        (
            "missing scheme fields",
            "{\"tuning\":\"sira-tiling\",\"version\":1,\"entries\":{\"k8n8\":{\"mr\":4}}}",
        ),
    ];
    for (label, text) in cases {
        let p = dir.join("bad.json");
        std::fs::write(&p, text).unwrap();
        assert!(TuningTable::load(&p).is_err(), "{label} must fail the load");
    }

    // a missing file is the untuned-machine case, not an error
    assert!(matches!(TuningTable::load(&dir.join("absent.json")), Ok(None)));

    // and a valid file round-trips exactly
    let mut good = TuningTable::default();
    let scheme = TilingScheme {
        mr: 8,
        nr_panels: 2,
        kc: 256,
    };
    good.entries
        .insert(tune::shape_key(784, 256), TuneEntry { scheme, ns: 123.0 });
    let gp = dir.join("good.json");
    good.save(&gp).unwrap();
    let back = TuningTable::load(&gp).unwrap().unwrap();
    assert_eq!(back.scheme_for(784, 256), scheme);
    assert_eq!(back.scheme_for(1, 1), TilingScheme::default());

    // process level: point the global table at a stale-version file,
    // then compile + run. global() must warn and degrade to the default
    // table; the compiled plan must stay bit-exact vs the interpreter.
    let bad = dir.join("poisoned.json");
    std::fs::write(
        &bad,
        "{\"tuning\":\"sira-tiling\",\"version\":99,\"entries\":{}}",
    )
    .unwrap();
    std::env::set_var("SIRA_TUNING_FILE", &bad);
    assert_eq!(tune::default_path(), bad);
    assert!(
        tune::global().entries.is_empty(),
        "corrupt tuning file must degrade to the default table"
    );

    let m = models::tfc_w2a2().unwrap();
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    let mut plan = engine::compile(&m.graph, &analysis)
        .expect("a corrupt tuning file must never fail compilation");
    let mut exec = Executor::new(&m.graph).unwrap();
    let shape = m.input_shape.clone();
    let numel: usize = shape.iter().product();
    let xs: Vec<Tensor> = (0..3)
        .map(|i| {
            Tensor::new(
                &shape,
                (0..numel).map(|e| ((e * 7 + i * 31) % 256) as f64).collect(),
            )
            .unwrap()
        })
        .collect();
    let ys = plan.run_batch(&xs).unwrap();
    for (x, y) in xs.iter().zip(&ys) {
        let want = exec.run_single(x).unwrap().remove(0);
        assert_eq!(
            want.data(),
            y.data(),
            "plan compiled under a corrupt tuning file diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
