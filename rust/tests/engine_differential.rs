//! Differential test harness locking down the pool-backed parallel
//! engine runtime: for ≥50 seeded random QNN graphs (random layer
//! stacks, widths, signs and per-channel scales from `models::builder`),
//! the plan-compiled engine must agree **element-exactly** with the
//! interpretive executor — compiled both ways (raw graph, and
//! streamlined via `engine::prepare_streamlined`), across batch sizes
//! {1, 3, 8} and thread counts {1, 2, 4, 8}, monolithic *and* segmented
//! (`SegmentedPlan`, the pipelined coordinator's compute path).
//! `min_kernel_work = 0` forces every sharded code path (pool sample
//! sharding at batch > 1, row/column/channel work items at batch 1)
//! even on these tiny graphs, and the **tiled-vs-scalar axis** runs the
//! register-blocked MAC cores (`min_tile_work = 0`) under every thread
//! count, the scalar oracle (`min_tile_work = usize::MAX`) at threads
//! {1, 4}, and the default gate (threshold-crossing shapes: large
//! kernels tile, small ones stay scalar within one plan) at threads 2.
//! A plan-reuse loop additionally locks the persistent pool's
//! determinism across consecutive `run_batch` calls, and a subset of
//! graphs goes through the full pipelined coordinator request path —
//! both on the tiled kernels.
//!
//! The base seed is fixed (reproducible by construction); `scripts/
//! verify.sh` pins it explicitly via `SIRA_DIFF_SEED` when running the
//! suite as part of tier-1.

use std::collections::BTreeMap;
use std::time::Duration;

use sira_finn::coordinator::{BatchPolicy, Coordinator};
use sira_finn::engine;
use sira_finn::executor::Executor;
use sira_finn::graph::Graph;
use sira_finn::models::{Granularity, QnnBuilder};
use sira_finn::sira::{analyze, Analysis, SiRange};
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

/// Fixed default; override (e.g. from CI) with SIRA_DIFF_SEED.
fn base_seed() -> u64 {
    std::env::var("SIRA_DIFF_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1FF)
}

/// Random small QNN: random input rank, layer kinds, widths, bitwidths,
/// signedness, activation/weight granularities (per-channel scales
/// included), pooling, optional depthwise convs, and (from a separate
/// seed stream) fan-out constructs — a residual skip whose tap tensor
/// has two consumers crossing a quantizer, a self-add `Add(t, t)`, and a
/// graph output that is also consumed downstream — so the streamline
/// single-use gate and fuse's multi-consumer/output chain boundaries get
/// randomized coverage, not just the zoo's fixed shapes.
///
/// `streamline_safe` keeps activation quantizers unsigned + per-tensor —
/// the envelope the streamlining passes are specified over (weight
/// granularity stays random, per-channel included; the signed shared-
/// scale pre-add quantizers of the residual construct are the rn8/rn12
/// pattern, which is inside that envelope). Raw-graph cases use the full
/// variety: the engine's generic fallback must swallow anything the
/// executor runs.
fn random_qnn(seed: u64, streamline_safe: bool) -> (Graph, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let conv_input = rng.chance(0.5);
    let mut b = QnnBuilder::new("diff", seed ^ 0xD1FF);
    let in_shape: Vec<usize> = if conv_input {
        let hw = *rng.choose(&[4usize, 6, 8]);
        vec![1, *rng.choose(&[1usize, 2, 3]), hw, hw]
    } else {
        vec![1, *rng.choose(&[4usize, 8, 12])]
    };
    b.input("x", &in_shape);
    let act_gran = |rng: &mut Rng| {
        if !streamline_safe && rng.chance(0.3) {
            Granularity::PerChannel
        } else {
            Granularity::PerTensor
        }
    };
    let g0 = act_gran(&mut rng);
    b.quant_act(8, !streamline_safe && rng.chance(0.3), g0, 255.0);
    let layers = rng.int_in(1, 3);
    for li in 0..layers {
        let wbits = rng.int_in(2, 6) as u32;
        let abits = rng.int_in(2, 5) as u32;
        let wgran = if rng.chance(0.5) {
            Granularity::PerChannel
        } else {
            Granularity::PerTensor
        };
        let agran = act_gran(&mut rng);
        if b.current_shape().len() == 4 {
            let ch = *rng.choose(&[2usize, 4, 6]);
            let depthwise = rng.chance(0.25);
            let stride = if rng.chance(0.3) { 2 } else { 1 };
            // pad 0 (the stuck-elision-eligible shape) only when the
            // spatial extent still covers the 3x3 kernel
            let pad = if rng.chance(0.5) && b.current_shape()[2] >= 3 { 0 } else { 1 };
            b.conv(ch, 3, stride, pad, wbits, wgran, depthwise);
            b.batchnorm();
            b.relu();
            b.quant_act(abits, !streamline_safe && rng.chance(0.3), agran, 8.0);
            if rng.chance(0.3) && b.current_shape()[2] >= 2 && b.current_shape()[2] % 2 == 0 {
                b.maxpool(2);
            }
            if li == layers - 1 {
                b.global_avgpool();
                b.flatten();
            }
        } else {
            b.linear(*rng.choose(&[4usize, 8, 10]), wbits, wgran, rng.chance(0.5));
            b.batchnorm();
            b.relu();
            b.quant_act(abits, false, agran, 8.0);
        }
    }
    // Fan-out constructs, drawn from a *separate* stream so the
    // layer-stack draws above replay identically for existing pinned
    // seeds — only graphs where a construct fires gain new structure.
    let mut fan = Rng::new(seed ^ 0xFA00);
    if fan.chance(0.35) {
        // Residual skip in FC-land: `tap` (a quantizer output) feeds
        // both the main linear and a skip requantizer — a multi-consumer
        // tensor crossing a quantizer, the shape the streamline
        // single-use gate and fuse's consumer checks guard.
        let tap = b.current().to_string();
        let tap_shape = b.current_shape().to_vec();
        let f = tap_shape[1];
        b.linear(f, fan.int_in(2, 6) as u32, Granularity::PerTensor, false);
        b.batchnorm();
        b.quant_act(3, true, Granularity::PerTensor, 8.0);
        let main = b.current().to_string();
        let main_shape = b.current_shape().to_vec();
        b.seek(&tap, &tap_shape);
        b.quant_act(3, true, Granularity::PerTensor, 8.0);
        let skip = b.current().to_string();
        b.seek(&main, &main_shape);
        b.add_residual(&skip);
        b.relu();
        b.quant_act(3, false, Granularity::PerTensor, 8.0);
    }
    if fan.chance(0.25) {
        // Self-add `Add(t, t)`: one consuming node but two input-
        // position uses of the same tensor — the shape that exposed the
        // node-counting single_use bug in residual factoring.
        let t = b.current().to_string();
        b.add_residual(&t);
        b.relu();
        b.quant_act(3, false, Granularity::PerTensor, 8.0);
    }
    let output_mid = fan.chance(0.3);
    let pre_tail = b.current().to_string();
    b.linear(5, 8, Granularity::PerTensor, true);
    let mut g = b.finish().unwrap();
    if output_mid {
        // Graph output that is also consumed downstream: keep the
        // classifier tail as live consumer nodes of the output tensor,
        // but make the pre-tail tensor the graph's single output —
        // exercising fuse's chain break at graph outputs and the
        // arena's output-slot pinning while later steps still run.
        g.outputs = vec![pre_tail];
    }
    (g, in_shape)
}

fn uint8_input_ranges() -> BTreeMap<String, SiRange> {
    let mut m = BTreeMap::new();
    m.insert("x".to_string(), SiRange::scalar(0.0, 255.0));
    m
}

/// Engine (every thread count and batch split, monolithic and
/// segmented) vs executor, exact.
fn assert_differential(g: &Graph, analysis: &Analysis, seed: u64, label: &str) {
    let in_shape = g.shapes[&g.inputs[0]].clone();
    let numel: usize = in_shape.iter().product();
    let mut rng = Rng::new(seed ^ 0xE11E);
    let xs: Vec<Tensor> = (0..8)
        .map(|_| {
            Tensor::new(
                &in_shape,
                (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
            )
            .unwrap()
        })
        .collect();
    let mut exec = Executor::new(g).unwrap();
    let want: Vec<Tensor> = xs
        .iter()
        .map(|x| exec.run_single(x).unwrap().remove(0))
        .collect();
    // (threads, min_tile_work): the tiled register-blocked kernels
    // (forced via 0) under every thread count; the scalar oracle
    // (usize::MAX) at {1, 4}; the default gate at threads 2, where
    // threshold-crossing shapes mix both MAC cores within one plan.
    let axis: [(usize, Option<usize>); 7] = [
        (1, Some(0)),
        (2, Some(0)),
        (4, Some(0)),
        (8, Some(0)),
        (1, Some(usize::MAX)),
        (4, Some(usize::MAX)),
        (2, None),
    ];
    for (threads, tile_work) in axis {
        let mut plan = engine::compile(g, analysis)
            .unwrap_or_else(|e| panic!("{label} seed {seed}: compile failed: {e:#}"));
        plan.set_threads(threads);
        plan.set_min_kernel_work(0); // force the sharded paths
        if let Some(tw) = tile_work {
            plan.set_min_tile_work(tw);
        }
        let mode = match tile_work {
            Some(0) => "tiled",
            Some(_) => "scalar",
            None => "mixed",
        };
        for bsz in [1usize, 3, 8] {
            let ys = plan.run_batch(&xs[..bsz]).unwrap_or_else(|e| {
                panic!("{label} seed {seed} t={threads} {mode} b={bsz}: run failed: {e:#}")
            });
            assert_eq!(ys.len(), bsz);
            for (i, (w, y)) in want[..bsz].iter().zip(&ys).enumerate() {
                assert_eq!(
                    w.shape(),
                    y.shape(),
                    "{label} seed {seed} t={threads} {mode} b={bsz}: shape at sample {i}"
                );
                assert_eq!(
                    w.data(),
                    y.data(),
                    "{label} seed {seed} t={threads} {mode} b={bsz}: not element-exact at \
                     sample {i}"
                );
            }
        }
    }
    // segmented execution — the pipelined coordinator's compute path:
    // same steps and buffers, run segment by segment with staged state
    // (tiled kernels forced, so the staged path exercises them too)
    let mut plan = engine::compile(g, analysis).unwrap();
    plan.set_threads(2);
    plan.set_min_kernel_work(0);
    plan.set_min_tile_work(0);
    let mut sp = engine::SegmentedPlan::new(plan, 3);
    for bsz in [1usize, 3, 8] {
        let ys = sp.run_batch(&xs[..bsz]).unwrap_or_else(|e| {
            panic!("{label} seed {seed} segmented b={bsz}: run failed: {e:#}")
        });
        for (i, (w, y)) in want[..bsz].iter().zip(&ys).enumerate() {
            assert_eq!(
                w.data(),
                y.data(),
                "{label} seed {seed} segmented b={bsz}: not element-exact at sample {i}"
            );
        }
    }
}

fn raw_cases(range: std::ops::Range<u64>) {
    let base = base_seed();
    for case in range {
        let seed = base.wrapping_add(case);
        let (g, _) = random_qnn(seed, false);
        let analysis = analyze(&g, &uint8_input_ranges())
            .unwrap_or_else(|e| panic!("raw seed {seed}: analyze failed: {e:#}"));
        assert_differential(&g, &analysis, seed, "raw");
    }
}

fn streamlined_cases(range: std::ops::Range<u64>) {
    let base = base_seed();
    for case in range {
        let seed = base.wrapping_add(case);
        let (mut g, _) = random_qnn(seed, true);
        let analysis = engine::prepare_streamlined(&mut g, &uint8_input_ranges())
            .unwrap_or_else(|e| panic!("streamlined seed {seed}: prepare failed: {e:#}"));
        assert_differential(&g, &analysis, seed, "streamlined");
    }
}

// 50 graph cases, each compiled both ways (raw + streamlined) = 100
// engine/executor comparisons, split into four #[test]s so the harness
// runs them in parallel.

#[test]
fn differential_raw_first_half() {
    raw_cases(0..25);
}

#[test]
fn differential_raw_second_half() {
    raw_cases(25..50);
}

#[test]
fn differential_streamlined_first_half() {
    streamlined_cases(0..25);
}

#[test]
fn differential_streamlined_second_half() {
    streamlined_cases(25..50);
}

/// Pool-backed plan reuse: one `Plan`, 10 consecutive `run_batch` calls
/// through the persistent pool — bit-exact against the executor every
/// round, with the pool's parked-state count bounded by its executor
/// count (no state leak across calls).
#[test]
fn plan_reuse_through_the_pool_is_deterministic_and_leak_free() {
    let base = base_seed();
    let (g, _) = random_qnn(base, false);
    let analysis = analyze(&g, &uint8_input_ranges()).unwrap();
    let in_shape = g.shapes[&g.inputs[0]].clone();
    let numel: usize = in_shape.iter().product();
    let mut rng = Rng::new(base ^ 0xAB);
    let xs: Vec<Tensor> = (0..8)
        .map(|_| {
            Tensor::new(
                &in_shape,
                (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
            )
            .unwrap()
        })
        .collect();
    let mut exec = Executor::new(&g).unwrap();
    let want: Vec<Tensor> = xs
        .iter()
        .map(|x| exec.run_single(x).unwrap().remove(0))
        .collect();
    let mut plan = engine::compile(&g, &analysis)
        .unwrap()
        .with_min_kernel_work(0)
        .with_min_tile_work(0);
    plan.set_threads(4);
    for round in 0..10 {
        let ys = plan.run_batch(&xs).unwrap();
        for (i, (w, y)) in want.iter().zip(&ys).enumerate() {
            assert_eq!(
                w.data(),
                y.data(),
                "plan reuse diverged at round {round}, sample {i}"
            );
        }
    }
    let pool = plan.pool().expect("threads > 1 attaches a pool");
    assert!(
        pool.tasks_executed() > 0,
        "sharded paths never engaged through the pool"
    );
    assert!(
        pool.pooled_states() <= 4,
        "worker states leaked across runs: {} parked",
        pool.pooled_states()
    );
}

/// The full pipelined-coordinator request path (drain, pack, staged
/// segments, carry hand-off between stage threads, extract, reply) on a
/// subset of the harness graphs, threads {1, 2}.
#[test]
fn differential_pipelined_coordinator() {
    let base = base_seed();
    for case in 0..6u64 {
        let seed = base.wrapping_add(case);
        let (mut g, _) = random_qnn(seed, true);
        let analysis = engine::prepare_streamlined(&mut g, &uint8_input_ranges())
            .unwrap_or_else(|e| panic!("pipelined seed {seed}: prepare failed: {e:#}"));
        let in_shape = g.shapes[&g.inputs[0]].clone();
        let numel: usize = in_shape.iter().product();
        let mut rng = Rng::new(seed ^ 0x919E);
        let xs: Vec<Tensor> = (0..8)
            .map(|_| {
                Tensor::new(
                    &in_shape,
                    (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
                )
                .unwrap()
            })
            .collect();
        let mut exec = Executor::new(&g).unwrap();
        let want: Vec<Tensor> = xs
            .iter()
            .map(|x| exec.run_single(x).unwrap().remove(0))
            .collect();
        for threads in [1usize, 2] {
            let mut plan = engine::compile(&g, &analysis).unwrap();
            plan.set_threads(threads);
            plan.set_min_kernel_work(0);
            plan.set_min_tile_work(0);
            let sp = engine::SegmentedPlan::new(plan, 3);
            let coord = Coordinator::start_pipelined(
                sp,
                BatchPolicy {
                    max_batch: 3,
                    max_wait: Duration::from_millis(2),
                },
            );
            let handles: Vec<_> = xs.iter().map(|x| coord.submit(x.clone()).unwrap()).collect();
            for (i, (w, h)) in want.iter().zip(handles).enumerate() {
                let y = h.recv().unwrap().unwrap_or_else(|e| {
                    panic!("pipelined seed {seed} t={threads} sample {i}: {e:#}")
                });
                assert_eq!(
                    w.data(),
                    y.data(),
                    "pipelined seed {seed} t={threads}: not element-exact at sample {i}"
                );
            }
            coord.shutdown();
        }
    }
}
