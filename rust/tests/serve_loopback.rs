//! Loopback integration tests for the network serving subsystem
//! (`sira_finn::serve`): a real server on `127.0.0.1:0`, real TCP
//! clients, and the full contract from ISSUE 5 —
//!
//! * concurrent clients × {tfc, cnv, vgg12, rn12, dws} × mixed batch
//!   sizes get responses **bit-exact** against a direct
//!   [`Plan::run_batch`] on the same inputs (f64 values survive the
//!   JSON round trip exactly);
//! * overload yields 503 load-shed without wedging the server;
//! * deadline-expired requests fail with the timeout error (504) before
//!   any engine runs them;
//! * graceful shutdown drains in-flight work, and post-shutdown
//!   requests fail cleanly.

use std::time::{Duration, Instant};

use sira_finn::coordinator::BatchPolicy;
use sira_finn::engine;
use sira_finn::models;
use sira_finn::serve::http::Client;
use sira_finn::serve::{ModelSpec, Server, ServerConfig};
use sira_finn::sira::analyze;
use sira_finn::tensor::Tensor;
use sira_finn::util::json::Json;
use sira_finn::util::rng::Rng;

/// A server on an ephemeral loopback port serving the given models on
/// the engine backend.
fn start_server(names: &[&str], threads: usize, max_pending: usize) -> Server {
    let specs: Vec<ModelSpec> = names
        .iter()
        .map(|n| ModelSpec {
            threads,
            ..ModelSpec::engine_default(n)
        })
        .collect();
    let cfg = ServerConfig {
        specs,
        max_pending,
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
        ..Default::default()
    };
    Server::start(cfg).unwrap()
}

/// A reference plan compiled exactly like the server's (raw graph,
/// engine backend) — thread count is irrelevant to the bits.
fn reference_plan(name: &str) -> engine::Plan {
    let m = models::by_name(name).unwrap();
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    engine::compile(&m.graph, &analysis).unwrap()
}

fn random_samples(rng: &mut Rng, numel: usize, batch: usize) -> Vec<Vec<f64>> {
    (0..batch)
        .map(|_| (0..numel).map(|_| rng.int_in(0, 255) as f64).collect())
        .collect()
}

fn infer_body(samples: &[Vec<f64>]) -> Json {
    Json::obj(vec![(
        "inputs",
        Json::Arr(samples.iter().map(|s| Json::nums(s)).collect()),
    )])
}

/// N concurrent client threads × five zoo models × mixed batch sizes,
/// every response compared element-exact against `Plan::run_batch`.
#[test]
fn loopback_is_bit_exact_vs_run_batch() {
    let server = start_server(&["tfc", "cnv", "vgg12", "rn12", "dws"], 2, 1024);
    let addr = server.addr().to_string();
    let shapes = [
        ("tfc", 784usize),
        ("cnv", 3 * 32 * 32),
        ("vgg12", 3 * 32 * 32),
        ("rn12", 3 * 32 * 32),
        ("dws", 32 * 32),
    ];
    let batch_sizes = [1usize, 3, 8];

    type Recorded = (String, Vec<Vec<f64>>, Vec<Vec<f64>>);
    let recorded: Vec<Recorded> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..3usize {
            for (model, numel) in shapes {
                let addr = addr.clone();
                handles.push(s.spawn(move || {
                    let mut rng = Rng::new(0x5EEF + t as u64 * 131 + numel as u64);
                    let mut client = Client::connect(&addr).unwrap();
                    let path = format!("/v1/models/{model}/infer");
                    let mut out: Vec<Recorded> = Vec::new();
                    for round in 0..3usize {
                        let b = batch_sizes[(t + round) % batch_sizes.len()];
                        let samples = random_samples(&mut rng, numel, b);
                        let (status, reply) =
                            client.post_json(&path, &[], &infer_body(&samples)).unwrap();
                        assert_eq!(status, 200, "{reply}");
                        let outputs: Vec<Vec<f64>> = reply
                            .get("outputs")
                            .unwrap()
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|o| o.as_f64_vec().unwrap())
                            .collect();
                        assert_eq!(outputs.len(), b);
                        out.push((model.to_string(), samples, outputs));
                    }
                    out
                }));
            }
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });

    // replay every request against a local plan: element-exact
    let mut plans = std::collections::BTreeMap::new();
    for (model, _) in shapes {
        let plan = reference_plan(model);
        let shape = plan.input_shape().to_vec();
        plans.insert(model.to_string(), (plan, shape));
    }
    let mut total_samples = 0usize;
    for (model, samples, outputs) in &recorded {
        let (plan, shape) = plans.get_mut(model).unwrap();
        let shape = shape.clone();
        let xs: Vec<Tensor> = samples
            .iter()
            .map(|s| Tensor::new(&shape, s.clone()).unwrap())
            .collect();
        let want = plan.run_batch(&xs).unwrap();
        assert_eq!(want.len(), outputs.len());
        for (w, got) in want.iter().zip(outputs) {
            assert_eq!(
                w.data(),
                got.as_slice(),
                "served output differs from Plan::run_batch for {model}"
            );
        }
        total_samples += samples.len();
    }

    // the server-side metrics saw exactly that many samples
    let mut c = Client::connect(&addr).unwrap();
    let (status, body) = c.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let models_j = v.get("models").unwrap();
    let completed: usize = ["tfc", "cnv", "vgg12", "rn12", "dws"]
        .iter()
        .map(|m| {
            models_j
                .get(m)
                .unwrap()
                .get("completed")
                .unwrap()
                .as_usize()
                .unwrap()
        })
        .sum();
    assert_eq!(completed, total_samples);
    assert_eq!(
        v.get("admission").unwrap().get("shed").unwrap().as_usize().unwrap(),
        0,
        "no load-shed expected at this pending bound"
    );
    assert!(server.shutdown(), "drain must complete");
}

/// Replicated serving (ISSUE 7 tentpole): N replicas behind the HTTP
/// front end are coordinators over clones of **one** trimmed plan —
/// responses stay bit-exact against a direct [`Plan::run_batch`],
/// least-loaded routing spreads overlapping traffic beyond replica 0,
/// and the aggregated per-model metrics account for every sample
/// exactly once (summed counters + a per-replica report array).
#[test]
fn replicated_serving_is_bit_exact_and_spreads_load() {
    let cfg = ServerConfig {
        specs: vec![ModelSpec {
            replicas: 3,
            ..ModelSpec::engine_default("cnv")
        }],
        max_pending: 1024,
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let addr = server.addr().to_string();
    let numel = 3 * 32 * 32;

    // the replicas serve clones of ONE serve-trimmed plan: flat oracle
    // dropped, packed weights the whole (shared) footprint
    {
        let entry = server.registry().get("cnv").unwrap();
        assert_eq!(entry.replicas.len(), 3);
        let stats = entry.plan_stats.as_ref().unwrap();
        assert!(stats.packed_weight_elems > 0, "{stats}");
        assert_eq!(stats.flat_weight_elems, 0, "{stats}");
    }

    // 6 clients post overlapping batch-8 requests; the barrier releases
    // the first round's writes together, so the slow CNV batches overlap
    // and routing sees nonzero pending depths
    let barrier = std::sync::Barrier::new(6);
    type Recorded = (Vec<Vec<f64>>, Vec<Vec<f64>>);
    let recorded: Vec<Recorded> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..6usize {
            let addr = addr.clone();
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(0x5CA1E + t as u64 * 97);
                let mut client = Client::connect(&addr).unwrap();
                let mut out: Vec<Recorded> = Vec::new();
                for round in 0..2usize {
                    let samples = random_samples(&mut rng, numel, 8);
                    if round == 0 {
                        barrier.wait();
                    }
                    let (status, reply) = client
                        .post_json("/v1/models/cnv/infer", &[], &infer_body(&samples))
                        .unwrap();
                    assert_eq!(status, 200, "{reply}");
                    let outputs: Vec<Vec<f64>> = reply
                        .get("outputs")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|o| o.as_f64_vec().unwrap())
                        .collect();
                    assert_eq!(outputs.len(), 8);
                    out.push((samples, outputs));
                }
                out
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });

    // replay every request against a local plan: element-exact whichever
    // replica answered
    let mut plan = reference_plan("cnv");
    let shape = plan.input_shape().to_vec();
    for (samples, outputs) in &recorded {
        let xs: Vec<Tensor> = samples
            .iter()
            .map(|s| Tensor::new(&shape, s.clone()).unwrap())
            .collect();
        let want = plan.run_batch(&xs).unwrap();
        for (w, got) in want.iter().zip(outputs) {
            assert_eq!(
                w.data(),
                got.as_slice(),
                "replicated serving diverged from Plan::run_batch"
            );
        }
    }
    let total = (recorded.len() * 8) as u64;

    // every sample accounted for exactly once across the replicas, and
    // the overlapping burst reached beyond the first replica
    {
        use std::sync::atomic::Ordering;
        let entry = server.registry().get("cnv").unwrap();
        let per: Vec<u64> = entry
            .replicas
            .iter()
            .map(|c| c.metrics.completed.load(Ordering::Relaxed))
            .collect();
        assert_eq!(per.iter().sum::<u64>(), total, "{per:?}");
        assert!(
            per.iter().filter(|&&c| c > 0).count() >= 2,
            "least-loaded routing must spread overlapping traffic: {per:?}"
        );
    }

    // the /metrics report for the model sums the replicas and carries
    // their individual shared-schema reports
    let mut c = Client::connect(&addr).unwrap();
    let (status, body) = c.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let cnv = v.get("models").unwrap().get("cnv").unwrap();
    assert_eq!(cnv.get("completed").unwrap().as_usize().unwrap() as u64, total);
    assert_eq!(cnv.get("pending").unwrap().as_usize().unwrap(), 0);
    assert_eq!(cnv.get("replicas").unwrap().as_arr().unwrap().len(), 3);
    assert!(server.shutdown(), "drain must complete");
}

/// Overload: a tight admission bound sheds concurrent batch requests
/// with 503 (`cnv` batches are slow enough to overlap), and the server
/// keeps serving afterwards.
#[test]
fn overload_sheds_503_without_wedging() {
    // max_pending 4 < batch 8: an 8-sample request is only admitted
    // from idle, so any overlapping request is deterministically shed
    let server = start_server(&["cnv"], 1, 4);
    let addr = server.addr().to_string();
    let numel = 3 * 32 * 32;

    let (ok, shed): (usize, usize) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..6usize {
            let addr = addr.clone();
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(0xBAD + t as u64);
                let mut client = Client::connect(&addr).unwrap();
                let (mut ok, mut shed) = (0usize, 0usize);
                for _ in 0..2 {
                    let samples = random_samples(&mut rng, numel, 8);
                    let (status, reply) = client
                        .post_json("/v1/models/cnv/infer", &[], &infer_body(&samples))
                        .unwrap();
                    match status {
                        200 => ok += 1,
                        503 => {
                            assert!(
                                reply.get("error").unwrap().as_str().unwrap().contains("overload"),
                                "{reply}"
                            );
                            shed += 1;
                        }
                        other => panic!("unexpected status {other}: {reply}"),
                    }
                }
                (ok, shed)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |(a, b), (c, d)| (a + c, b + d))
    });
    assert_eq!(ok + shed, 12);
    assert!(ok >= 1, "at least the first arrival must be admitted");
    assert!(shed >= 1, "overlapping batch-8 requests must shed at cap 4");

    // not wedged: a fresh request succeeds once the burst is over
    let mut rng = Rng::new(0xAF7E);
    let mut client = Client::connect(&addr).unwrap();
    let samples = random_samples(&mut rng, numel, 1);
    let (status, reply) = client
        .post_json("/v1/models/cnv/infer", &[], &infer_body(&samples))
        .unwrap();
    assert_eq!(status, 200, "{reply}");
    // the shed counter made it into /metrics
    let (status, body) = client.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(
        v.get("admission").unwrap().get("shed").unwrap().as_usize().unwrap() >= shed,
        "shed counter must be visible in /metrics"
    );
    server.shutdown();
}

/// Deadline budgets: an already-expired budget (`x-deadline-ms: 0`)
/// fails with 504 and the deadline error before any engine runs; the
/// server keeps serving and counts the expiry.
#[test]
fn expired_deadlines_get_504_and_server_keeps_serving() {
    let server = start_server(&["tfc"], 1, 64);
    let addr = server.addr().to_string();
    let mut rng = Rng::new(0xDEAD);
    let mut client = Client::connect(&addr).unwrap();
    let samples = random_samples(&mut rng, 784, 2);
    let (status, reply) = client
        .post_json(
            "/v1/models/tfc/infer",
            &[("x-deadline-ms", "0")],
            &infer_body(&samples),
        )
        .unwrap();
    assert_eq!(status, 504, "{reply}");
    assert!(
        reply.get("error").unwrap().as_str().unwrap().contains("deadline exceeded"),
        "{reply}"
    );
    // a generous budget on the same connection still succeeds
    let (status, reply) = client
        .post_json(
            "/v1/models/tfc/infer",
            &[("x-deadline-ms", "60000")],
            &infer_body(&samples),
        )
        .unwrap();
    assert_eq!(status, 200, "{reply}");
    // expiries are visible in the model's metrics
    let (status, body) = client.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let tfc = v.get("models").unwrap().get("tfc").unwrap();
    assert_eq!(tfc.get("expired").unwrap().as_usize().unwrap(), 2);
    assert_eq!(tfc.get("completed").unwrap().as_usize().unwrap(), 2);
    server.shutdown();
}

/// Graceful shutdown: in-flight admitted work completes before the
/// coordinators drain; afterwards the port is closed.
#[test]
fn graceful_shutdown_drains_in_flight_work() {
    let server = start_server(&["cnv"], 1, 64);
    let addr = server.addr().to_string();
    let numel = 3 * 32 * 32;

    let client_thread = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut rng = Rng::new(0xD7A1);
            let mut client = Client::connect(&addr).unwrap();
            let samples = random_samples(&mut rng, numel, 8);
            client
                .post_json("/v1/models/cnv/infer", &[], &infer_body(&samples))
                .unwrap()
        })
    };
    // wait until that request is admitted (or already finished), then
    // begin the drain while it may still be in flight
    let t0 = Instant::now();
    while server.admission().admitted_total() == 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(server.shutdown(), "drain must complete within the timeout");
    let (status, reply) = client_thread.join().unwrap();
    assert_eq!(status, 200, "in-flight work must finish during drain: {reply}");
    // the listener is gone: new connections are refused
    assert!(
        std::net::TcpStream::connect(addr.as_str()).is_err(),
        "post-shutdown connections must fail"
    );
}

/// Observability contract over a real socket: a client-supplied
/// `x-request-id` echoes back on the response, a missing one is minted
/// server-side, and `GET /metrics?format=prom` serves a well-formed
/// Prometheus text exposition carrying the per-model serving series.
#[test]
fn request_ids_round_trip_and_prom_metrics_parse() {
    let server = start_server(&["tfc"], 1, 64);
    let addr = server.addr().to_string();
    let mut rng = Rng::new(0x0B5);
    let mut client = Client::connect(&addr).unwrap();
    let req_body = infer_body(&random_samples(&mut rng, 784, 2)).to_string();

    // client-supplied id echoes back verbatim
    let (status, headers, _) = client
        .request_full(
            "POST",
            "/v1/models/tfc/infer",
            &[("x-request-id", "loopback-42")],
            req_body.as_bytes(),
        )
        .unwrap();
    assert_eq!(status, 200);
    let echoed = headers
        .iter()
        .find(|(k, _)| k == "x-request-id")
        .map(|(_, v)| v.as_str());
    assert_eq!(echoed, Some("loopback-42"));

    // no id supplied: the server mints one
    let (status, headers, _) = client
        .request_full("POST", "/v1/models/tfc/infer", &[], req_body.as_bytes())
        .unwrap();
    assert_eq!(status, 200);
    let minted = headers
        .iter()
        .find(|(k, _)| k == "x-request-id")
        .map(|(_, v)| v.as_str())
        .unwrap();
    assert!(minted.starts_with("r-"), "{minted}");

    // the Prometheus exposition validates line by line and carries the
    // per-model serving series next to the latency histogram
    let (status, body) = client.get("/metrics?format=prom").unwrap();
    assert_eq!(status, 200);
    let text = std::str::from_utf8(&body).unwrap();
    let n = sira_finn::obs::validate_exposition(text).unwrap();
    assert!(n > 10, "expected a real exposition, got {n} samples:\n{text}");
    assert!(
        text.contains("sira_samples_completed_total{model=\"tfc\"}"),
        "{text}"
    );
    assert!(text.contains("sira_request_latency_microseconds_bucket"), "{text}");
    server.shutdown();
}

/// `POST /admin/shutdown` flips the drain flag and sheds new work with
/// the draining error while the server finishes what it admitted.
#[test]
fn admin_shutdown_begins_drain() {
    let server = start_server(&["tfc"], 1, 64);
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert!(!server.shutdown_requested());
    let (status, _) = client.request("POST", "/admin/shutdown", &[], b"").unwrap();
    assert_eq!(status, 200);
    assert!(server.shutdown_requested());
    // new work is shed while draining
    let mut rng = Rng::new(0x0FF);
    let samples = random_samples(&mut rng, 784, 1);
    let (status, reply) = client
        .post_json("/v1/models/tfc/infer", &[], &infer_body(&samples))
        .unwrap();
    assert_eq!(status, 503, "{reply}");
    assert!(
        reply.get("error").unwrap().as_str().unwrap().contains("draining"),
        "{reply}"
    );
    server.shutdown();
}
