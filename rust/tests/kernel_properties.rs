//! Kernel-level property/fuzz suite for the tiled MAC core: for seeded
//! random shapes — K = 0, N = 1, tile-boundary ±1 and
//! non-multiple-of-tile remainders included — the register-blocked
//! `tile::mac_rows_tiled` must agree **element-exactly** with the scalar
//! `MacElem::mac_row` oracle across all three accumulator widths
//! (f64 / i32 / i64), arbitrary column-range tilings must compose to the
//! full product, and the tiled unroll order must not be able to overflow
//! anywhere the scalar k-order could not (driven to the exact
//! `sira_int_bounds` extremes). The KC-blocked loop nest
//! (`tile::mac_rows_blocked`) gets the same treatment over a grid of
//! `(mr, nr_panels, kc)` schemes, plus accumulator-edge cases where the
//! SIRA absolute-value bound `Σ|a·w|` sits one term below the width
//! limit — the exact regime in which the dispatcher may legally engage
//! blocking, where every chunk partial is proven wrap-free and the
//! result must stay bit-identical. The overflow properties rely on
//! overflow *checks* being live — a reordering bug would wrap back to
//! the correct value under plain release — so the suite runs in the
//! default dev profile via `cargo test` and, pinned-seed in tier-1,
//! under the `relcheck` profile (release optimization +
//! `overflow-checks = true`, see Cargo.toml). This is the contract that
//! makes every future kernel rewrite safe: swap the implementation,
//! keep the suite green.
//!
//! The base seed is fixed; `scripts/verify.sh` pins it explicitly via
//! `SIRA_KERNEL_SEED` when running the suite as part of tier-1.

mod common;

use std::collections::BTreeMap;

use common::near_limit_graph;
use sira_finn::engine;
use sira_finn::engine::kernels::tile::{mac_rows_blocked, mac_rows_tiled, PackedWeights, MR, NR};
use sira_finn::engine::kernels::MacElem;
use sira_finn::executor::Executor;
use sira_finn::passes::accmin::sira_int_bounds;
use sira_finn::sira::{analyze, SiRange};
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

/// Fixed default; override (e.g. from CI) with SIRA_KERNEL_SEED.
fn base_seed() -> u64 {
    std::env::var("SIRA_KERNEL_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x711E)
}

/// The scalar oracle lifted to a row block: per activation row, the
/// plain `MacElem::mac_row` over the flat row-major matrix.
fn scalar_rows<T: MacElem>(
    a: &[T],
    rows: usize,
    k: usize,
    flat: &[T],
    n: usize,
    cols: core::ops::Range<usize>,
    acc: &mut [T],
) {
    let width = cols.len();
    for r in 0..rows {
        T::mac_row(
            &a[r * k..(r + 1) * k],
            flat,
            n,
            cols.clone(),
            &mut acc[r * width..(r + 1) * width],
        );
    }
}

/// Random small integers at width `T`, with explicit zeros sprinkled in
/// so the f64 zero-skip path is exercised.
fn fill<T: MacElem>(rng: &mut Rng, len: usize, amp: i64) -> Vec<T> {
    (0..len)
        .map(|_| {
            if rng.chance(0.2) {
                T::ZERO
            } else {
                T::from_i64(rng.int_in(-amp, amp))
            }
        })
        .collect()
}

/// Shapes straddling every tile boundary: K = 0, N = 1, exact NR / MR
/// multiples, ±1 around them, and ragged remainders.
fn boundary_shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = Vec::new();
    for rows in [1usize, 2, MR - 1, MR, MR + 1, 2 * MR + 1] {
        for k in [0usize, 1, 3, NR, NR + 1, 17] {
            for n in [1usize, NR - 1, NR, NR + 1, 2 * NR, 2 * NR + 1, 3 * NR - 1] {
                shapes.push((rows, k, n));
            }
        }
    }
    shapes
}

/// Tiled == scalar for one width over one shape, with random seeds in
/// the accumulator (the caller-seeding contract elision relies on).
fn check_shape<T: MacElem + PartialEq + std::fmt::Debug>(
    rng: &mut Rng,
    rows: usize,
    k: usize,
    n: usize,
) {
    let a: Vec<T> = fill(rng, rows * k, 9);
    let flat: Vec<T> = fill(rng, k * n, 9);
    let packed = PackedWeights::pack(&flat, k, n);
    let seed: Vec<T> = fill(rng, rows * n, 50);
    let mut want = seed.clone();
    scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
    let mut got = seed;
    mac_rows_tiled(&a, rows, &packed, 0..n, &mut got);
    assert_eq!(got, want, "rows={rows} k={k} n={n}");
}

#[test]
fn tiled_matches_scalar_across_widths_and_shapes() {
    let mut rng = Rng::new(base_seed());
    for (rows, k, n) in boundary_shapes() {
        check_shape::<f64>(&mut rng, rows, k, n);
        check_shape::<i32>(&mut rng, rows, k, n);
        check_shape::<i64>(&mut rng, rows, k, n);
    }
    // fuzz tail: fully random shapes
    for _ in 0..40 {
        let rows = rng.int_in(1, 11) as usize;
        let k = rng.int_in(0, 40) as usize;
        let n = rng.int_in(1, 40) as usize;
        check_shape::<f64>(&mut rng, rows, k, n);
        check_shape::<i32>(&mut rng, rows, k, n);
        check_shape::<i64>(&mut rng, rows, k, n);
    }
}

/// Arbitrary column-range tilings compose to the full product: cutting
/// `0..n` into random consecutive ranges and running each through the
/// tiled kernel reproduces the full-width result exactly — the invariant
/// the tile-aligned column/channel work items of the pool rely on (and
/// which must hold even for ranges *not* aligned to NR).
#[test]
fn column_range_tilings_compose_to_the_full_product() {
    let mut rng = Rng::new(base_seed() ^ 0xC0);
    for trial in 0..60 {
        let rows = rng.int_in(1, 6) as usize;
        let k = rng.int_in(0, 24) as usize;
        let n = rng.int_in(1, 36) as usize;
        let a: Vec<i64> = fill(&mut rng, rows * k, 9);
        let flat: Vec<i64> = fill(&mut rng, k * n, 9);
        let packed = PackedWeights::pack(&flat, k, n);
        let mut full = vec![0i64; rows * n];
        mac_rows_tiled(&a, rows, &packed, 0..n, &mut full);
        let mut want = vec![0i64; rows * n];
        scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
        assert_eq!(full, want, "trial {trial}: full-width tiled != scalar");
        // random consecutive tiling of 0..n
        let mut cuts = vec![0usize, n];
        for _ in 0..rng.int_in(0, 3) {
            cuts.push(rng.int_in(0, n as i64) as usize);
        }
        cuts.sort_unstable();
        cuts.dedup();
        let mut assembled = vec![0i64; rows * n];
        for w in cuts.windows(2) {
            let (j0, j1) = (w[0], w[1]);
            let width = j1 - j0;
            let mut piece = vec![0i64; rows * width];
            mac_rows_tiled(&a, rows, &packed, j0..j1, &mut piece);
            for r in 0..rows {
                assembled[r * n + j0..r * n + j1]
                    .copy_from_slice(&piece[r * width..(r + 1) * width]);
            }
        }
        assert_eq!(assembled, full, "trial {trial}: tiling {cuts:?} diverged");
    }
}

/// The three accumulator widths agree on common integer data (magnitudes
/// far from every overflow bound).
#[test]
fn widths_agree_on_small_integer_data() {
    let mut rng = Rng::new(base_seed() ^ 0x3D);
    for _ in 0..30 {
        let rows = rng.int_in(1, 5) as usize;
        let k = rng.int_in(0, 20) as usize;
        let n = rng.int_in(1, 20) as usize;
        let ints: Vec<i64> = (0..rows * k).map(|_| rng.int_in(-9, 9)).collect();
        let wints: Vec<i64> = (0..k * n).map(|_| rng.int_in(-9, 9)).collect();
        let run = |got: &mut Vec<f64>| {
            let a: Vec<f64> = ints.iter().map(|&v| v as f64).collect();
            let flat: Vec<f64> = wints.iter().map(|&v| v as f64).collect();
            let packed = PackedWeights::pack(&flat, k, n);
            got.resize(rows * n, 0.0);
            mac_rows_tiled(&a, rows, &packed, 0..n, got);
        };
        let mut f = Vec::new();
        run(&mut f);
        let a32: Vec<i32> = ints.iter().map(|&v| v as i32).collect();
        let w32: Vec<i32> = wints.iter().map(|&v| v as i32).collect();
        let mut g32 = vec![0i32; rows * n];
        mac_rows_tiled(&a32, rows, &PackedWeights::pack(&w32, k, n), 0..n, &mut g32);
        let a64: Vec<i64> = ints.clone();
        let mut g64 = vec![0i64; rows * n];
        mac_rows_tiled(&a64, rows, &PackedWeights::pack(&wints, k, n), 0..n, &mut g64);
        for i in 0..rows * n {
            assert_eq!(g32[i] as f64, f[i], "i32 vs f64 at {i}");
            assert_eq!(g64[i] as f64, f[i], "i64 vs f64 at {i}");
        }
    }
}

/// f64 zero-skip bit-exactness: activations containing +0.0 and -0.0
/// against negative/fractional weights must reproduce the scalar
/// kernel's skip decisions bit-for-bit (value equality would hide a
/// signed-zero drift).
#[test]
fn f64_signed_zero_skip_is_bit_exact() {
    let mut rng = Rng::new(base_seed() ^ 0xF0);
    for trial in 0..40 {
        let rows = rng.int_in(1, 6) as usize;
        let k = rng.int_in(1, 20) as usize;
        let n = rng.int_in(1, 3 * NR as i64) as usize;
        let a: Vec<f64> = (0..rows * k)
            .map(|_| match rng.int_in(0, 4) {
                0 => 0.0,
                1 => -0.0,
                v => (v as f64 - 3.0) * 1.5,
            })
            .collect();
        let flat: Vec<f64> = (0..k * n)
            .map(|_| (rng.int_in(-7, 7) as f64) * 0.25 - 0.125)
            .collect();
        let packed = PackedWeights::pack(&flat, k, n);
        let seed: Vec<f64> = (0..rows * n)
            .map(|_| if rng.chance(0.3) { -0.0 } else { rng.int_in(-5, 5) as f64 })
            .collect();
        let mut want = seed.clone();
        scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
        let mut got = seed;
        mac_rows_tiled(&a, rows, &packed, 0..n, &mut got);
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "trial {trial}: f64 bits diverged at {i} ({w} vs {g})"
            );
        }
    }
}

/// Accumulator-edge property, i32: alternating ±2^30 terms keep every
/// scalar k-order partial sum at |·| ≤ 2^30, while the absolute-value
/// sum (16 × 2^30) is far beyond i32::MAX — a kernel that reordered
/// terms *within* one output element (e.g. summing the positive half
/// first) would overflow and, under the overflow checks this test runs
/// with (dev profile locally, the `relcheck` profile in tier-1), panic.
/// The tiled unroll reorders only across elements, so it must match the
/// scalar oracle exactly.
#[test]
fn i32_tiled_order_cannot_overflow_where_scalar_did_not() {
    const M: i32 = 1 << 30;
    let k = 16usize;
    let n = NR + 3;
    let a = vec![1i32; k];
    let mut flat = vec![0i32; k * n];
    for kk in 0..k {
        let v = if kk % 2 == 0 { M } else { -M };
        for j in 0..n {
            flat[kk * n + j] = v;
        }
    }
    let packed = PackedWeights::pack(&flat, k, n);
    let mut want = vec![0i32; n];
    scalar_rows(&a, 1, k, &flat, n, 0..n, &mut want);
    let mut got = vec![0i32; n];
    mac_rows_tiled(&a, 1, &packed, 0..n, &mut got);
    assert_eq!(got, want);
    assert!(got.iter().all(|&v| v == 0));
    // seeds at the representable edge: seed + first term touches i32::MAX
    let seed = vec![i32::MAX - M; n];
    let mut want = seed.clone();
    scalar_rows(&a, 1, k, &flat, n, 0..n, &mut want);
    let mut got = seed;
    mac_rows_tiled(&a, 1, &packed, 0..n, &mut got);
    assert_eq!(got, want);
    assert!(got.iter().all(|&v| v == i32::MAX - M));
}

/// Blocked == scalar for one width, shape, and `(mr, nr_panels, kc)`
/// scheme, with random accumulator seeds (the same caller-seeding
/// contract as the single-pass kernel).
fn check_blocked<T: MacElem + PartialEq + std::fmt::Debug>(
    rng: &mut Rng,
    rows: usize,
    k: usize,
    n: usize,
    mr: usize,
    np: usize,
    kc: usize,
) {
    let a: Vec<T> = fill(rng, rows * k, 9);
    let flat: Vec<T> = fill(rng, k * n, 9);
    let packed = PackedWeights::pack(&flat, k, n);
    let seed: Vec<T> = fill(rng, rows * n, 50);
    let mut want = seed.clone();
    scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
    let mut got = seed;
    mac_rows_blocked(&a, rows, &packed, 0..n, mr, np, kc, &mut got);
    assert_eq!(got, want, "rows={rows} k={k} n={n} mr={mr} np={np} kc={kc}");
}

/// The KC-blocked loop nest must agree element-exactly with the scalar
/// oracle over every tile-boundary shape and a grid of schemes — row
/// blocks, panel-group widths and chunk depths that divide k evenly,
/// raggedly, and not at all. f64 rides along with integer-valued data
/// (where any summation order is exact); the engine never dispatches
/// f64 steps to the blocked kernel precisely because general f64 data
/// would round differently.
#[test]
fn blocked_matches_scalar_across_schemes_and_shapes() {
    let mut rng = Rng::new(base_seed() ^ 0xB1);
    let schemes = [
        (1usize, 1usize, 1usize),
        (3, 2, 5),
        (4, 1, 64),
        (8, 4, 0),
        (8, 2, 7),
    ];
    for (rows, k, n) in boundary_shapes() {
        for &(mr, np, kc) in &schemes {
            check_blocked::<i32>(&mut rng, rows, k, n, mr, np, kc);
            check_blocked::<i64>(&mut rng, rows, k, n, mr, np, kc);
            check_blocked::<f64>(&mut rng, rows, k, n, mr, np, kc);
        }
    }
    // fuzz tail: random shapes x random schemes
    for _ in 0..40 {
        let rows = rng.int_in(1, 11) as usize;
        let k = rng.int_in(0, 70) as usize;
        let n = rng.int_in(1, 40) as usize;
        let mr = rng.int_in(1, 8) as usize;
        let np = rng.int_in(1, 4) as usize;
        let kc = rng.int_in(0, 20) as usize;
        check_blocked::<i32>(&mut rng, rows, k, n, mr, np, kc);
        check_blocked::<i64>(&mut rng, rows, k, n, mr, np, kc);
    }
}

/// Accumulator-edge property for the blocked order, i32: terms sized so
/// the SIRA absolute-value bound `Σ_k |a_k·w_kj|` lands one term short
/// of `i32::MAX` — the exact precondition under which the dispatcher is
/// allowed to engage KC blocking. Every chunk partial and every spill
/// prefix is bounded by that sum, so under overflow checks (dev locally,
/// `relcheck` in tier-1) nothing may wrap in *any* chunking, and the
/// result must equal the scalar k-order exactly. Mixed signs make the
/// chunk partials genuinely different from the scalar prefixes, so an
/// association bug cannot cancel out.
#[test]
fn i32_blocked_is_exact_at_the_sira_absolute_bound() {
    let k = 16usize;
    let n = NR + 3;
    let a = vec![1i32; k];
    let step = i32::MAX / k as i32; // sum of |terms| = 16*step < i32::MAX
    let mut flat = vec![0i32; k * n];
    for kk in 0..k {
        let v = if kk % 3 == 0 { -step } else { step };
        for j in 0..n {
            flat[kk * n + j] = v;
        }
    }
    let packed = PackedWeights::pack(&flat, k, n);
    let mut want = vec![0i32; n];
    scalar_rows(&a, 1, k, &flat, n, 0..n, &mut want);
    for kc in [0usize, 1, 3, 5, 8, 16, 64] {
        let mut got = vec![0i32; n];
        mac_rows_blocked(&a, 1, &packed, 0..n, 4, 2, kc, &mut got);
        assert_eq!(got, want, "kc={kc}");
    }
}

/// The i64 twin of the blocked edge property.
#[test]
fn i64_blocked_is_exact_at_the_sira_absolute_bound() {
    let k = 16usize;
    let n = 2 * NR - 1;
    let a = vec![1i64; k];
    let step = i64::MAX / k as i64;
    let mut flat = vec![0i64; k * n];
    for kk in 0..k {
        let v = if kk % 3 == 0 { -step } else { step };
        for j in 0..n {
            flat[kk * n + j] = v;
        }
    }
    let packed = PackedWeights::pack(&flat, k, n);
    let mut want = vec![0i64; n];
    scalar_rows(&a, 1, k, &flat, n, 0..n, &mut want);
    for kc in [0usize, 1, 3, 5, 8, 16, 64] {
        let mut got = vec![0i64; n];
        mac_rows_blocked(&a, 1, &packed, 0..n, 4, 2, kc, &mut got);
        assert_eq!(got, want, "kc={kc}");
    }
}

/// The i64 twin of the edge property, at ±2^62.
#[test]
fn i64_tiled_order_cannot_overflow_where_scalar_did_not() {
    const M: i64 = 1 << 62;
    let k = 16usize;
    let n = 2 * NR - 1;
    let a = vec![1i64; k];
    let mut flat = vec![0i64; k * n];
    for kk in 0..k {
        let v = if kk % 2 == 0 { M } else { -M };
        for j in 0..n {
            flat[kk * n + j] = v;
        }
    }
    let packed = PackedWeights::pack(&flat, k, n);
    let mut want = vec![0i64; n];
    scalar_rows(&a, 1, k, &flat, n, 0..n, &mut want);
    let mut got = vec![0i64; n];
    mac_rows_tiled(&a, 1, &packed, 0..n, &mut got);
    assert_eq!(got, want);
    assert!(got.iter().all(|&v| v == 0));
}

/// Engine-level accumulator-edge case: inputs pinned to the exact
/// `sira_int_bounds` extremes (and one step inside) through the compiled
/// plan — tiled kernels forced, scalar oracle forced — must match the
/// executor element-exactly, with the i32 fast path engaged.
#[test]
fn engine_integer_mac_is_exact_at_sira_bound_extremes() {
    let (g, inputs) = near_limit_graph();
    let analysis = analyze(&g, &inputs).unwrap();
    let (lo, hi) = sira_int_bounds(&analysis, "xq").expect("quant output is pure-integer");
    let (lo, hi) = (lo as f64, hi as f64);
    let xs: Vec<Tensor> = [
        vec![hi; 4],
        vec![lo; 4],
        vec![hi - 1.0; 4],
        vec![lo + 1.0; 4],
        vec![hi, lo, hi, lo],
        vec![lo, hi, lo, hi],
    ]
    .into_iter()
    .map(|v| Tensor::new(&[1, 4], v).unwrap())
    .collect();
    let mut exec = Executor::new(&g).unwrap();
    let want: Vec<Tensor> = xs
        .iter()
        .map(|x| exec.run_single(x).unwrap().remove(0))
        .collect();
    let mut tiled = engine::compile(&g, &analysis).unwrap().with_min_tile_work(0);
    assert_eq!(tiled.stats().matmul_i32, 1, "{}", tiled.stats());
    let mut scalar = engine::compile(&g, &analysis)
        .unwrap()
        .with_min_tile_work(usize::MAX);
    let got_t = tiled.run_batch(&xs).unwrap();
    let got_s = scalar.run_batch(&xs).unwrap();
    for (i, w) in want.iter().enumerate() {
        assert_eq!(w.data(), got_t[i].data(), "tiled diverged at extreme {i}");
        assert_eq!(w.data(), got_s[i].data(), "scalar diverged at extreme {i}");
    }
}

/// Threshold-crossing shapes: the tiled-work gate is on `rows * k * n`
/// with `rows = batch × m`, so at batch 1 this QNN's first MatMul
/// (1 × 64 × 32 = 2048 MACs) clears the default `min_tile_work` gate
/// (1 << 10) while its second (1 × 32 × 4 = 128) does not — the
/// default-gate plan genuinely mixes tiled and scalar kernels in one
/// run. Batch 8 pushes the second layer over the gate too
/// (8 × 32 × 4 = 1024 ≥ 1 << 10), so sweeping batch sizes covers
/// mixed *and* all-tiled dispatch; every configuration must be
/// bit-exact against both forced modes and against the executor.
#[test]
fn default_tile_gate_mixes_paths_bit_exactly() {
    use sira_finn::models::{Granularity, QnnBuilder};

    let mut b = QnnBuilder::new("mix", 77);
    b.input("x", &[1, 64]);
    b.quant_act(8, false, Granularity::PerTensor, 255.0);
    b.linear(32, 3, Granularity::PerTensor, true);
    b.relu();
    b.quant_act(4, false, Granularity::PerTensor, 8.0);
    b.linear(4, 4, Granularity::PerTensor, true);
    let g = b.finish().unwrap();
    let mut inputs = BTreeMap::new();
    inputs.insert("x".to_string(), SiRange::scalar(0.0, 255.0));
    let analysis = analyze(&g, &inputs).unwrap();
    let mut rng = Rng::new(base_seed() ^ 0x5E);
    let xs: Vec<Tensor> = (0..8)
        .map(|_| {
            Tensor::new(&[1, 64], (0..64).map(|_| rng.int_in(0, 255) as f64).collect()).unwrap()
        })
        .collect();
    let mut exec = Executor::new(&g).unwrap();
    let want: Vec<Tensor> = xs
        .iter()
        .map(|x| exec.run_single(x).unwrap().remove(0))
        .collect();
    let mut forced = engine::compile(&g, &analysis).unwrap().with_min_tile_work(0);
    let mut scalar = engine::compile(&g, &analysis)
        .unwrap()
        .with_min_tile_work(usize::MAX);
    let mut mixed = engine::compile(&g, &analysis).unwrap(); // default gate
    // batch 1: mixed dispatch (layer 1 tiled, layer 2 scalar);
    // batch 3: still mixed (384 < 1024); batch 8: everything tiled
    for bsz in [1usize, 3, 8] {
        let got_f = forced.run_batch(&xs[..bsz]).unwrap();
        let got_s = scalar.run_batch(&xs[..bsz]).unwrap();
        let got_m = mixed.run_batch(&xs[..bsz]).unwrap();
        for (i, w) in want[..bsz].iter().enumerate() {
            assert_eq!(w.data(), got_f[i].data(), "b={bsz} tiled vs executor at {i}");
            assert_eq!(w.data(), got_s[i].data(), "b={bsz} scalar vs executor at {i}");
            assert_eq!(w.data(), got_m[i].data(), "b={bsz} mixed vs executor at {i}");
        }
    }
}
