//! Property tests (in-repo proptest substrate, seeded generation): SIRA
//! soundness. For randomly generated QNN graphs and randomly sampled
//! inputs within the declared input ranges, every executed intermediate
//! tensor must fall inside its analyzed range, and the affine
//! scale/bias invariant must hold for every scaled-integer range.

mod common;

use std::collections::BTreeMap;

use sira_finn::executor::Executor;
use sira_finn::graph::{Graph, Node, Op};
use sira_finn::models::{Granularity, QnnBuilder};
use sira_finn::sira::{analyze, SiRange};
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

/// Generate a random small QNN (random layer kinds / widths / bitwidths).
fn random_qnn(seed: u64) -> (Graph, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let conv_input = rng.chance(0.5);
    let mut b = QnnBuilder::new("prop", seed ^ 0x51AA);
    let in_shape: Vec<usize> = if conv_input {
        let hw = *rng.choose(&[4usize, 6, 8]);
        vec![1, *rng.choose(&[1usize, 2, 3]), hw, hw]
    } else {
        vec![1, *rng.choose(&[4usize, 8, 12])]
    };
    b.input("x", &in_shape);
    b.quant_act(8, rng.chance(0.5), Granularity::PerTensor, 255.0);
    let layers = rng.int_in(1, 3);
    for li in 0..layers {
        let wbits = rng.int_in(2, 6) as u32;
        let abits = rng.int_in(2, 5) as u32;
        let gran = if rng.chance(0.5) {
            Granularity::PerChannel
        } else {
            Granularity::PerTensor
        };
        if b.current_shape().len() == 4 {
            let ch = *rng.choose(&[2usize, 4, 6]);
            let depthwise = rng.chance(0.25);
            let stride = if rng.chance(0.3) { 2 } else { 1 };
            b.conv(ch, 3, stride, 1, wbits, gran, depthwise);
            b.batchnorm();
            b.relu();
            b.quant_act(abits, false, Granularity::PerTensor, 8.0);
            if rng.chance(0.3) && b.current_shape()[2] >= 2 && b.current_shape()[2] % 2 == 0 {
                b.maxpool(2);
            }
            if li == layers - 1 {
                b.global_avgpool();
                b.flatten();
            }
        } else {
            b.linear(*rng.choose(&[4usize, 8, 10]), wbits, gran, rng.chance(0.5));
            b.batchnorm();
            b.relu();
            b.quant_act(abits, false, Granularity::PerTensor, 8.0);
        }
    }
    b.linear(5, 8, Granularity::PerTensor, true);
    (b.finish().unwrap(), in_shape)
}

fn uint8_range() -> SiRange {
    SiRange::from_int(
        Tensor::scalar(0.0),
        Tensor::scalar(255.0),
        Tensor::scalar(1.0),
        Tensor::scalar(0.0),
        Default::default(),
        Default::default(),
    )
    .unwrap()
}

#[test]
fn sampled_executions_stay_within_analyzed_ranges() {
    for seed in 0..24u64 {
        let (g, in_shape) = random_qnn(seed);
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), uint8_range());
        let analysis = analyze(&g, &inputs).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));

        let mut rng = Rng::new(seed ^ 0xEEE);
        let numel: usize = in_shape.iter().product();
        let mut exec = Executor::new(&g).unwrap();
        for _ in 0..4 {
            let x = Tensor::new(
                &in_shape,
                (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
            )
            .unwrap();
            let mut m = BTreeMap::new();
            m.insert("x".to_string(), x);
            let env = exec.run_env(&m).unwrap();
            for (tensor, value) in &env {
                let Ok(r) = analysis.get(tensor) else { continue };
                // check every element against the (broadcast) range
                let lo = r.lo.broadcast_to(value.shape()).unwrap_or_else(|_| r.lo.clone());
                let hi = r.hi.broadcast_to(value.shape()).unwrap_or_else(|_| r.hi.clone());
                if lo.numel() == value.numel() {
                    for i in 0..value.numel() {
                        let v = value.data()[i];
                        assert!(
                            v >= lo.data()[i] - 1e-6 && v <= hi.data()[i] + 1e-6,
                            "seed {seed}, tensor {tensor}[{i}]: {v} outside [{}, {}]",
                            lo.data()[i],
                            hi.data()[i]
                        );
                    }
                } else {
                    let (rl, rh) = r.bounds();
                    assert!(
                        value.min() >= rl - 1e-6 && value.max() <= rh + 1e-6,
                        "seed {seed}, tensor {tensor}: [{}, {}] outside [{rl}, {rh}]",
                        value.min(),
                        value.max()
                    );
                }
            }
        }
    }
}

/// The integer-component soundness property stuck-channel elision and
/// accumulator narrowing both rest on: for inputs drawn inside the
/// declared input range, every observed value of a tensor whose SIRA
/// range carries a *pure-integer* component lies inside its
/// `sira_int_bounds` interval — and a point interval (`lo == hi`, a
/// stuck channel) is observed at exactly that constant. Checked on the
/// raw graphs and on their streamlined forms, since the engine elides
/// channels on both.
#[test]
fn observed_values_lie_within_sira_int_bounds_raw_and_streamlined() {
    use sira_finn::engine::prepare_streamlined;
    use sira_finn::passes::accmin::sira_int_bounds;

    let check = |g: &Graph, analysis: &sira_finn::sira::Analysis, seed: u64, label: &str| {
        let in_shape = g.shapes[&g.inputs[0]].clone();
        let numel: usize = in_shape.iter().product();
        let mut rng = Rng::new(seed ^ 0x1B0);
        let mut exec = Executor::new(g).unwrap();
        let mut checked = 0usize;
        for _ in 0..3 {
            let x = Tensor::new(
                &in_shape,
                (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
            )
            .unwrap();
            let mut m = BTreeMap::new();
            m.insert("x".to_string(), x);
            let env = exec.run_env(&m).unwrap();
            for (tensor, value) in &env {
                let Ok(r) = analysis.get(tensor) else { continue };
                let Some(ic) = &r.int else { continue };
                if !ic.is_pure_integer() {
                    continue;
                }
                let Some((lo, hi)) = sira_int_bounds(analysis, tensor) else {
                    continue;
                };
                for (i, &v) in value.data().iter().enumerate() {
                    assert!(
                        v >= lo as f64 - 1e-9 && v <= hi as f64 + 1e-9,
                        "{label} seed {seed}, {tensor}[{i}]: {v} outside int bounds [{lo}, {hi}]"
                    );
                }
                // per-element point intervals pin the observed value
                if let (Ok(elo), Ok(ehi)) = (
                    ic.lo.broadcast_to(value.shape()),
                    ic.hi.broadcast_to(value.shape()),
                ) {
                    if elo.numel() == value.numel() {
                        for (i, &v) in value.data().iter().enumerate() {
                            if elo.data()[i] == ehi.data()[i] {
                                assert!(
                                    (v - elo.data()[i]).abs() <= 1e-9,
                                    "{label} seed {seed}, {tensor}[{i}]: stuck element moved \
                                     ({v} != {})",
                                    elo.data()[i]
                                );
                            }
                        }
                    }
                }
                checked += 1;
            }
        }
        assert!(
            checked > 0,
            "{label} seed {seed}: no pure-integer tensors were checked"
        );
    };

    for seed in 40..56u64 {
        let (g, _) = random_qnn(seed);
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), uint8_range());
        let analysis = analyze(&g, &inputs).unwrap();
        check(&g, &analysis, seed, "raw");

        let mut sg = g.clone();
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), uint8_range());
        let s_analysis = prepare_streamlined(&mut sg, &inputs).unwrap();
        check(&sg, &s_analysis, seed, "streamlined");
    }
}

/// The same pure-integer-bounds property, run over the three zoo
/// additions (VGG12, RN12, DWS) raw and streamlined — real topologies
/// with residual fan-out, dense skips and depthwise stages rather than
/// the linear random stacks above. One sampled input per form keeps the
/// debug-profile runtime bounded; the elision-relevant property is
/// per-tensor, not per-sample.
#[test]
fn zoo_additions_respect_sira_int_bounds_raw_and_streamlined() {
    use sira_finn::engine::prepare_streamlined;
    use sira_finn::models;
    use sira_finn::passes::accmin::sira_int_bounds;

    let check = |g: &Graph, analysis: &sira_finn::sira::Analysis, name: &str, label: &str| {
        let in_shape = g.shapes[&g.inputs[0]].clone();
        let numel: usize = in_shape.iter().product();
        let mut rng = Rng::new(0x200A);
        let x = Tensor::new(
            &in_shape,
            (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
        )
        .unwrap();
        let mut m = BTreeMap::new();
        m.insert(g.inputs[0].clone(), x);
        let env = Executor::new(g).unwrap().run_env(&m).unwrap();
        let mut checked = 0usize;
        for (tensor, value) in &env {
            let Ok(r) = analysis.get(tensor) else { continue };
            let Some(ic) = &r.int else { continue };
            if !ic.is_pure_integer() {
                continue;
            }
            let Some((lo, hi)) = sira_int_bounds(analysis, tensor) else {
                continue;
            };
            for (i, &v) in value.data().iter().enumerate() {
                assert!(
                    v >= lo as f64 - 1e-9 && v <= hi as f64 + 1e-9,
                    "{name} ({label}), {tensor}[{i}]: {v} outside int bounds [{lo}, {hi}]"
                );
            }
            checked += 1;
        }
        assert!(
            checked > 0,
            "{name} ({label}): no pure-integer tensors were checked"
        );
    };

    for m in [
        models::vgg12_w2a2().unwrap(),
        models::rn12_w3a3().unwrap(),
        models::dws_w4a4().unwrap(),
    ] {
        let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
        check(&m.graph, &analysis, m.name, "raw");

        let mut sg = m.graph.clone();
        let s_analysis = prepare_streamlined(&mut sg, &m.input_ranges).unwrap();
        check(&sg, &s_analysis, m.name, "streamlined");
    }
}

/// Accumulator-edge case on the `common::near_limit_graph` fixture
/// (shared with `rust/tests/kernel_properties.rs`): a quant → integer
/// MatMul whose worst-case partial-sum bound (4 × 100 × 5e6 = 2.0e9)
/// sits just inside the engine's i32 headroom.
/// Inputs pinned to the exact `sira_int_bounds` extremes must drive the
/// observed outputs to the analyzed integer bounds *exactly* (tightness
/// — these are the sums the A2Q-style width selection certified), and
/// inputs one step inside must stay strictly inside; nothing may ever
/// escape the bounds.
#[test]
fn int_bounds_are_tight_and_sound_at_extreme_inputs() {
    use sira_finn::passes::accmin::sira_int_bounds;

    let (g, inputs) = common::near_limit_graph();
    let analysis = analyze(&g, &inputs).unwrap();

    let (xlo, xhi) = sira_int_bounds(&analysis, "xq").expect("quant output is pure-integer");
    let (ylo, yhi) = sira_int_bounds(&analysis, "y").expect("integer MAC output has int bounds");
    let (xlo, xhi) = (xlo as f64, xhi as f64);
    let mut exec = Executor::new(&g).unwrap();
    let mut run = |v: Vec<f64>| -> Vec<f64> {
        exec.run_single(&Tensor::new(&[1, 4], v).unwrap()).unwrap()[0]
            .data()
            .to_vec()
    };
    // column 0's weights are all positive: the all-hi / all-lo inputs
    // achieve the analyzed bound exactly
    let at_hi = run(vec![xhi; 4]);
    let at_lo = run(vec![xlo; 4]);
    assert_eq!(at_hi[0], yhi as f64, "upper int bound not achieved");
    assert_eq!(at_lo[0], ylo as f64, "lower int bound not achieved");
    // every extreme-pattern output stays inside the bounds
    let pats = [
        vec![xhi; 4],
        vec![xlo; 4],
        vec![xhi, xlo, xhi, xlo],
        vec![xlo, xhi, xlo, xhi],
    ];
    for p in pats {
        for &v in &run(p.clone()) {
            assert!(
                v >= ylo as f64 && v <= yhi as f64,
                "extreme input {p:?} escaped int bounds: {v} not in [{ylo}, {yhi}]"
            );
        }
    }
    // one step inside the extremes stays strictly inside the bounds
    for p in [vec![xhi - 1.0; 4], vec![xlo + 1.0; 4]] {
        for &v in &run(p.clone()) {
            assert!(
                v > ylo as f64 && v < yhi as f64,
                "near-extreme input {p:?} touched the bound: {v}"
            );
        }
    }
}

#[test]
fn all_analyzed_ranges_satisfy_affine_invariant() {
    for seed in 24..40u64 {
        let (g, _) = random_qnn(seed);
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), uint8_range());
        let analysis = analyze(&g, &inputs).unwrap();
        for (name, r) in &analysis.ranges {
            r.check_invariant()
                .unwrap_or_else(|e| panic!("seed {seed}, tensor {name}: {e}"));
        }
    }
}

#[test]
fn matmul_interval_bound_is_achievable_with_extreme_inputs() {
    // tightness property (§2.4.2): feeding the minimizing/maximizing
    // input vectors achieves the analyzed bound exactly for MatMul.
    for seed in 0..16u64 {
        let mut rng = Rng::new(seed ^ 0x7157);
        let (k, m) = (rng.int_in(1, 6) as usize, rng.int_in(1, 5) as usize);
        let w = Tensor::new(
            &[k, m],
            (0..k * m).map(|_| rng.int_in(-7, 7) as f64).collect(),
        )
        .unwrap();
        let (lo_v, hi_v) = (rng.int_in(-9, 0) as f64, rng.int_in(0, 9) as f64);
        let mut g = Graph::new("mm");
        g.add_input("x", &[1, k]);
        g.add_initializer("w", w.clone());
        g.add_node(Node::new("mm", Op::MatMul, &["x", "w"], &["y"]));
        g.outputs.push("y".into());
        sira_finn::graph::shapes::infer_shapes(&mut g).unwrap();

        let mut inputs = BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            SiRange::from_int(
                Tensor::scalar(lo_v),
                Tensor::scalar(hi_v),
                Tensor::scalar(1.0),
                Tensor::scalar(0.0),
                Default::default(),
                Default::default(),
            )
            .unwrap(),
        );
        let a = analyze(&g, &inputs).unwrap();
        let r = a.get("y").unwrap();
        // minimizing vector for output column 0
        let mut x_min = vec![0.0; k];
        let mut x_max = vec![0.0; k];
        for kk in 0..k {
            let wv = w.data()[kk * m];
            x_min[kk] = if wv >= 0.0 { lo_v } else { hi_v };
            x_max[kk] = if wv >= 0.0 { hi_v } else { lo_v };
        }
        let mut exec = Executor::new(&g).unwrap();
        let y_min = exec
            .run_single(&Tensor::new(&[1, k], x_min).unwrap())
            .unwrap()[0]
            .data()[0];
        let y_max = exec
            .run_single(&Tensor::new(&[1, k], x_max).unwrap())
            .unwrap()[0]
            .data()[0];
        let lo0 = r.lo.data()[0];
        let hi0 = r.hi.data()[0];
        assert_eq!(y_min, lo0, "seed {seed}: lower bound not tight");
        assert_eq!(y_max, hi0, "seed {seed}: upper bound not tight");
    }
}

#[test]
fn quant_output_never_escapes_datatype_bounds() {
    // property: analyzed Quant ranges always lie within the quantizer's
    // own representable interval
    for seed in 0..20u64 {
        let (g, _) = random_qnn(seed);
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), uint8_range());
        let a = analyze(&g, &inputs).unwrap();
        for node in &g.nodes {
            let Op::Quant { signed, narrow, .. } = node.op else {
                continue;
            };
            let bits = g.initializers[&node.inputs[3]].first() as u32;
            let (qmin, qmax) = sira_finn::sira::quant_bounds(bits, signed, narrow);
            let r = a.get(node.output()).unwrap();
            if let Some(ic) = &r.int {
                let (lo, hi) = ic.int_bounds();
                assert!(lo as f64 >= qmin && hi as f64 <= qmax, "{}", node.name);
            }
        }
    }
}
