//! The interchange contract (`models::onnx`): exporting any zoo
//! topology to ONNX bytes and importing it back must yield a graph that
//! is (a) **isomorphic** to the original — same nodes, ops, attributes,
//! wiring, and bit-identical initializers — and (b) **bit-exact** under
//! execution: the interpretive executor and the plan-compiled engine
//! (threads {1, 4}, raw and streamlined forms) produce the original
//! graph's exact output bits on seeded random batches.
//!
//! The streamlined leg round-trips the *streamlined* graph, which is
//! what exercises the ops the raw zoo never emits (`MultiThreshold`,
//! `Gemm`-lowered arithmetic chains, extracted scale `Mul`s) through
//! the exporter and importer.

use sira_finn::engine;
use sira_finn::executor::Executor;
use sira_finn::graph::Graph;
use sira_finn::models;
use sira_finn::sira::{analyze, Analysis};
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

fn random_batch(rng: &mut Rng, shape: &[usize], b: usize) -> Vec<Tensor> {
    let numel: usize = shape.iter().product();
    (0..b)
        .map(|_| {
            Tensor::new(shape, (0..numel).map(|_| rng.int_in(0, 255) as f64).collect()).unwrap()
        })
        .collect()
}

fn reimport(g: &Graph, label: &str) -> Graph {
    let bytes = models::export_model(g);
    models::import_model(&bytes)
        .unwrap_or_else(|e| panic!("{label}: import of exported bytes failed: {e:#}"))
}

/// Structural isomorphism: identical inputs/outputs/nodes (name, op —
/// including every embedded attribute — wiring) and bit-identical
/// initializers. Shapes are compared on the *live* tensors (inputs,
/// initializers, node outputs); passes may leave stale `shapes` entries
/// for tensors they removed, and those are not part of the graph.
/// `dtypes` annotations are advisory (engine compilation derives kernel
/// selection from the SIRA analysis, not from them) and are not carried
/// by the interchange format.
fn assert_isomorphic(a: &Graph, b: &Graph, label: &str) {
    assert_eq!(a.name, b.name, "{label}: graph name");
    assert_eq!(a.inputs, b.inputs, "{label}: inputs");
    assert_eq!(a.outputs, b.outputs, "{label}: outputs");
    assert_eq!(a.nodes.len(), b.nodes.len(), "{label}: node count");
    for (x, y) in a.nodes.iter().zip(&b.nodes) {
        assert_eq!(x.name, y.name, "{label}: node name");
        assert_eq!(x.op, y.op, "{label}: op of node '{}'", x.name);
        assert_eq!(x.inputs, y.inputs, "{label}: inputs of node '{}'", x.name);
        assert_eq!(x.outputs, y.outputs, "{label}: outputs of node '{}'", x.name);
    }
    assert_eq!(
        a.initializers.keys().collect::<Vec<_>>(),
        b.initializers.keys().collect::<Vec<_>>(),
        "{label}: initializer names"
    );
    for (k, t) in &a.initializers {
        assert_eq!(t, &b.initializers[k], "{label}: initializer '{k}' changed bits");
    }
    let live = a
        .inputs
        .iter()
        .chain(a.initializers.keys())
        .chain(a.nodes.iter().flat_map(|n| n.outputs.iter()));
    for name in live {
        assert_eq!(
            a.shapes.get(name),
            b.shapes.get(name),
            "{label}: shape of '{name}'"
        );
    }
}

/// Engine plans compiled from `g` (threads {1, 4}, `min_kernel_work` 0
/// so the sharded paths engage at batch 1) must reproduce the reference
/// executor on `g_ref` bit-for-bit.
fn assert_engine_matches_reference(
    g_ref: &Graph,
    g: &Graph,
    analysis: &Analysis,
    seed: u64,
    batches: &[usize],
    label: &str,
) {
    let mut exec = Executor::new(g_ref).unwrap();
    let in_shape = g_ref.shapes[&g_ref.inputs[0]].clone();
    for threads in [1usize, 4] {
        let mut plan = engine::compile(g, analysis)
            .unwrap_or_else(|e| panic!("{label}: engine compile failed: {e:#}"));
        plan.set_threads(threads);
        plan.set_min_kernel_work(0);
        let mut rng = Rng::new(seed);
        for &bsz in batches {
            let xs = random_batch(&mut rng, &in_shape, bsz);
            let ys = plan.run_batch(&xs).unwrap();
            for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
                let want = exec.run_single(x).unwrap().remove(0);
                assert_eq!(
                    want.data(),
                    y.data(),
                    "{label}: engine on imported graph not bit-exact at sample {i} \
                     (batch {bsz}, t={threads})"
                );
            }
        }
    }
}

/// The full acceptance matrix for one zoo model: structural round trip,
/// executor bit-exactness, engine bit-exactness at threads {1, 4} on
/// the raw import, then the same for the round-tripped *streamlined*
/// graph.
fn roundtrip_case(name: &str, seed: u64, batches: &[usize]) {
    let m = models::by_name(name).unwrap();
    let g0 = m.graph;
    let g1 = reimport(&g0, name);
    assert_isomorphic(&g0, &g1, name);

    let ranges = models::default_input_ranges(&g1).unwrap();
    let a1 = analyze(&g1, &ranges)
        .unwrap_or_else(|e| panic!("{name}: SIRA on imported graph failed: {e:#}"));

    // executor(imported) vs executor(original)
    let mut exec0 = Executor::new(&g0).unwrap();
    let mut exec1 = Executor::new(&g1).unwrap();
    let in_shape = g0.shapes[&g0.inputs[0]].clone();
    let mut rng = Rng::new(seed);
    for x in random_batch(&mut rng, &in_shape, batches[0]) {
        let want = exec0.run_single(&x).unwrap().remove(0);
        let got = exec1.run_single(&x).unwrap().remove(0);
        assert_eq!(want.shape(), got.shape(), "{name}: executor shape");
        assert_eq!(want.data(), got.data(), "{name}: executor on imported graph not bit-exact");
    }

    // engine(imported raw) vs executor(original)
    assert_engine_matches_reference(&g0, &g1, &a1, seed, batches, name);

    // streamline the original, round-trip the *streamlined* graph, and
    // hold the engine on the re-imported form to the same reference
    let mut gs0 = g0.clone();
    engine::prepare_streamlined(&mut gs0, &m.input_ranges)
        .unwrap_or_else(|e| panic!("{name}: streamline failed: {e:#}"));
    let label = format!("{name} (streamlined)");
    let gs1 = reimport(&gs0, &label);
    assert_isomorphic(&gs0, &gs1, &label);
    let as1 = analyze(&gs1, &ranges)
        .unwrap_or_else(|e| panic!("{label}: SIRA on imported graph failed: {e:#}"));
    assert_engine_matches_reference(&g0, &gs1, &as1, seed ^ 0x5, batches, &label);

    // streamlining the *imported* graph directly (the serve-registry
    // `--onnx --streamline` path) must land on the same bits too
    let mut gs2 = g1.clone();
    let as2 = engine::prepare_streamlined(&mut gs2, &ranges)
        .unwrap_or_else(|e| panic!("{name}: streamline of imported graph failed: {e:#}"));
    assert_engine_matches_reference(&g0, &gs2, &as2, seed ^ 0xA, &batches[..1], name);
}

#[test]
fn tfc_round_trips_bit_exact() {
    roundtrip_case("tfc", 0x07FC_0001, &[1, 4]);
}

#[test]
fn cnv_round_trips_bit_exact() {
    roundtrip_case("cnv", 0x0C27_0002, &[2]);
}

#[test]
fn vgg12_round_trips_bit_exact() {
    roundtrip_case("vgg12", 0x7612_0003, &[2]);
}

#[test]
fn rn8_round_trips_bit_exact() {
    roundtrip_case("rn8", 0x8380_0004, &[2]);
}

#[test]
fn rn12_round_trips_bit_exact() {
    roundtrip_case("rn12", 0x12E5_0005, &[1]);
}

#[test]
fn mnv1_round_trips_bit_exact() {
    // 56x56 serving resolution; batch 1 bounds the per-sample
    // interpreter cost, matching the equivalence suite's treatment
    roundtrip_case("mnv1", 0x1144_0006, &[1]);
}

#[test]
fn dws_round_trips_bit_exact() {
    roundtrip_case("dws", 0x0D25_0007, &[1, 4]);
}

#[test]
fn mnv1_full_round_trips_structurally_and_through_the_engine() {
    // Full 224x224 resolution: the interpreter reference is too slow for
    // the executor legs (it is excluded from the equivalence suite for
    // the same reason), so the original's own engine plan serves as the
    // reference — compiled from the same graph, it is bit-locked to the
    // executor by `engine_equivalence` on the scaled resolutions.
    let m = models::by_name("mnv1-full").unwrap();
    let g0 = m.graph;
    let g1 = reimport(&g0, "mnv1-full");
    assert_isomorphic(&g0, &g1, "mnv1-full");
    let a0 = analyze(&g0, &m.input_ranges).unwrap();
    let ranges = models::default_input_ranges(&g1).unwrap();
    let a1 = analyze(&g1, &ranges).unwrap();
    let mut plan0 = engine::compile(&g0, &a0).unwrap();
    let mut plan1 = engine::compile(&g1, &a1).unwrap();
    let in_shape = g0.shapes[&g0.inputs[0]].clone();
    let mut rng = Rng::new(0x224_0008);
    let xs = random_batch(&mut rng, &in_shape, 1);
    let want = plan0.run_batch(&xs).unwrap();
    let got = plan1.run_batch(&xs).unwrap();
    assert_eq!(want[0].data(), got[0].data(), "mnv1-full: imported engine bits diverged");
}

#[test]
fn export_is_deterministic_and_stable_across_a_round_trip() {
    // import(export(g)) is isomorphic to g, and export depends only on
    // the structures the isomorphism covers — so a second export must
    // reproduce the first byte stream exactly. This pins serialization
    // order (node order, BTreeMap initializer order, field order).
    let m = models::by_name("tfc").unwrap();
    let bytes0 = models::export_model(&m.graph);
    let bytes1 = models::export_model(&models::import_model(&bytes0).unwrap());
    assert_eq!(bytes0, bytes1, "export bytes changed across a round trip");
}
