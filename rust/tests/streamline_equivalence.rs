//! Property tests: the streamlining passes preserve quantized semantics.
//! Random QNNs are pushed through lowering, scale extraction,
//! aggregation and threshold conversion; predictions must match the
//! original graph on random inputs (quantized outputs agree exactly up
//! to float-association noise well below one quantization step).

use std::collections::BTreeMap;

use sira_finn::executor::Executor;
use sira_finn::models::{Granularity, QnnBuilder};
use sira_finn::passes::{fold, lower, streamline, thresholds};
use sira_finn::sira::SiRange;
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

fn random_qnn(seed: u64) -> (sira_finn::graph::Graph, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let mut b = QnnBuilder::new("prop", seed ^ 0xABCD);
    let conv = rng.chance(0.5);
    let in_shape: Vec<usize> = if conv {
        vec![1, 2, 6, 6]
    } else {
        vec![1, *rng.choose(&[6usize, 10])]
    };
    b.input("x", &in_shape);
    b.quant_act(8, false, Granularity::PerTensor, 255.0);
    for _ in 0..rng.int_in(1, 2) {
        let wbits = rng.int_in(2, 5) as u32;
        if b.current_shape().len() == 4 {
            b.conv(4, 3, 1, 1, wbits, Granularity::PerChannel, false);
            b.batchnorm();
            b.relu();
            b.quant_act(3, false, Granularity::PerTensor, 8.0);
        } else {
            b.linear(8, wbits, Granularity::PerTensor, rng.chance(0.5));
            b.batchnorm();
            b.relu();
            b.quant_act(3, false, Granularity::PerTensor, 8.0);
        }
    }
    if b.current_shape().len() == 4 {
        b.global_avgpool();
        b.flatten();
    }
    b.linear(4, 8, Granularity::PerTensor, true);
    (b.finish().unwrap(), in_shape)
}

fn sample_outputs(g: &sira_finn::graph::Graph, in_shape: &[usize], seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    let numel: usize = in_shape.iter().product();
    let mut exec = Executor::new(g).unwrap();
    (0..5)
        .map(|_| {
            let x = Tensor::new(
                in_shape,
                (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
            )
            .unwrap();
            exec.run_single(&x).unwrap()[0].data().to_vec()
        })
        .collect()
}

#[test]
fn streamlining_preserves_predictions() {
    for seed in 0..20u64 {
        let (g0, in_shape) = random_qnn(seed);
        let y0 = sample_outputs(&g0, &in_shape, seed ^ 1);

        let mut g1 = g0.clone();
        lower::lower_all(&mut g1).unwrap();
        fold::fold_constants(&mut g1, false).unwrap();
        streamline::extract_quant_scales(&mut g1).unwrap();
        fold::duplicate_shared_initializers(&mut g1).unwrap();
        streamline::streamline(&mut g1).unwrap();
        g1.check().unwrap();
        let y1 = sample_outputs(&g1, &in_shape, seed ^ 1);
        for (a, b) in y0.iter().flatten().zip(y1.iter().flatten()) {
            assert!(
                (a - b).abs() < 1e-6 * (1.0 + a.abs()),
                "seed {seed}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn threshold_conversion_preserves_predictions() {
    let mut converted_any = false;
    for seed in 20..40u64 {
        let (g0, in_shape) = random_qnn(seed);
        let y0 = sample_outputs(&g0, &in_shape, seed ^ 2);

        let mut g1 = g0.clone();
        lower::lower_all(&mut g1).unwrap();
        fold::fold_constants(&mut g1, false).unwrap();
        streamline::extract_quant_scales(&mut g1).unwrap();
        fold::duplicate_shared_initializers(&mut g1).unwrap();
        streamline::streamline(&mut g1).unwrap();
        let mut inputs = BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            SiRange::from_int(
                Tensor::scalar(0.0),
                Tensor::scalar(255.0),
                Tensor::scalar(1.0),
                Tensor::scalar(0.0),
                Default::default(),
                Default::default(),
            )
            .unwrap(),
        );
        let rep = thresholds::convert_to_thresholds(&mut g1, &inputs).unwrap();
        converted_any |= rep.converted > 0;
        g1.check().unwrap();
        let y1 = sample_outputs(&g1, &in_shape, seed ^ 2);
        for (a, b) in y0.iter().flatten().zip(y1.iter().flatten()) {
            assert!(
                (a - b).abs() < 1e-6 * (1.0 + a.abs()),
                "seed {seed}: {a} vs {b}"
            );
        }
    }
    assert!(converted_any, "no tails were ever converted");
}

#[test]
fn streamlined_graphs_reveal_integer_macs() {
    for seed in 40..52u64 {
        let (g0, _) = random_qnn(seed);
        let mut g1 = g0;
        lower::lower_all(&mut g1).unwrap();
        fold::fold_constants(&mut g1, false).unwrap();
        streamline::extract_quant_scales(&mut g1).unwrap();
        fold::duplicate_shared_initializers(&mut g1).unwrap();
        streamline::streamline(&mut g1).unwrap();
        for node in &g1.nodes {
            if node.op.is_mac() {
                let w = &g1.initializers[&node.inputs[1]];
                assert!(
                    w.is_integral(),
                    "seed {seed}: MAC '{}' weights not integer after streamlining",
                    node.name
                );
            }
        }
    }
}
