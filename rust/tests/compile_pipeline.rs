//! Integration tests over the full compile pipeline (frontend + backend)
//! plus failure-injection for accumulator overflow detection.

use std::collections::BTreeMap;

use sira_finn::accel::{compile_qnn, CompileOptions, TailStyle};
use sira_finn::executor::{ExecOptions, Executor};
use sira_finn::graph::DataType;
use sira_finn::hw::{EwDtype, ThresholdStyle};
use sira_finn::models;
use sira_finn::passes::accmin::AccPolicy;
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

fn opts(tail: TailStyle, acc: AccPolicy) -> CompileOptions {
    CompileOptions {
        tail_style: tail,
        acc_policy: acc,
        target_cycles: 1 << 14,
        ..Default::default()
    }
}

#[test]
fn all_zoo_models_compile_under_all_configs() {
    for m in [
        models::tfc_w2a2().unwrap(),
        models::cnv_w2a2().unwrap(),
        models::rn8_w3a3().unwrap(),
    ] {
        for tail in [
            TailStyle::Thresholding(ThresholdStyle::BinarySearch),
            TailStyle::Thresholding(ThresholdStyle::Parallel),
            TailStyle::Composite(EwDtype::Fixed(16, 8)),
            TailStyle::Composite(EwDtype::Float32),
        ] {
            for acc in [AccPolicy::Bound32, AccPolicy::Datatype, AccPolicy::Sira] {
                let c = compile_qnn(m.graph.clone(), &m.input_ranges, &opts(tail, acc))
                    .unwrap_or_else(|e| panic!("{} {tail:?} {acc:?}: {e:#}", m.name));
                assert!(c.fdna.total.lut > 0.0);
                assert!(c.fdna.perf.fps > 0.0);
                assert!(c.fdna.perf.ii_cycles <= (1 << 14) + 1);
            }
        }
    }
}

#[test]
fn parallel_thresholding_costs_more_compute_than_binary_search() {
    let m = models::tfc_w2a2().unwrap();
    let bin = compile_qnn(
        m.graph.clone(),
        &m.input_ranges,
        &opts(TailStyle::Thresholding(ThresholdStyle::BinarySearch), AccPolicy::Sira),
    )
    .unwrap();
    let m = models::tfc_w2a2().unwrap();
    let par = compile_qnn(
        m.graph,
        &m.input_ranges,
        &opts(TailStyle::Thresholding(ThresholdStyle::Parallel), AccPolicy::Sira),
    )
    .unwrap();
    assert!(
        par.fdna.non_mac.lut >= bin.fdna.non_mac.lut,
        "parallel {} < binary {}",
        par.fdna.non_mac.lut,
        bin.fdna.non_mac.lut
    );
}

#[test]
fn executor_validates_sira_accumulator_widths_on_real_traffic() {
    // annotate the streamlined TFC with SIRA widths and run with dtype
    // verification: no overflow may occur on any sampled input
    let m = models::tfc_w2a2().unwrap();
    let c = compile_qnn(
        m.graph,
        &m.input_ranges,
        &opts(TailStyle::Thresholding(ThresholdStyle::BinarySearch), AccPolicy::Sira),
    )
    .unwrap();
    let mut exec = Executor::with_options(
        &c.graph,
        ExecOptions {
            instrument: false,
            verify_dtypes: true,
        },
    )
    .unwrap();
    let mut rng = Rng::new(77);
    for _ in 0..6 {
        let x = Tensor::new(
            &[1, 784],
            (0..784).map(|_| rng.int_in(0, 255) as f64).collect(),
        )
        .unwrap();
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), x);
        exec.run_env(&inputs).unwrap(); // must not report overflow
    }
}

#[test]
fn failure_injection_undersized_accumulator_is_caught() {
    // shrink one MAC accumulator annotation below the SIRA bound and
    // drive the network with extreme inputs: verification must trip
    let m = models::tfc_w2a2().unwrap();
    let c = compile_qnn(
        m.graph,
        &m.input_ranges,
        &opts(TailStyle::Thresholding(ThresholdStyle::BinarySearch), AccPolicy::Sira),
    )
    .unwrap();
    let mut g = c.graph.clone();
    // find the first MAC output annotation and halve its width
    let mm_out = g
        .nodes
        .iter()
        .find(|n| n.op.is_mac())
        .map(|n| n.outputs[0].clone())
        .unwrap();
    let orig = g.dtypes[&mm_out];
    g.dtypes.insert(mm_out.clone(), DataType::Int(orig.bits() / 2));

    let mut exec = Executor::with_options(
        &g,
        ExecOptions {
            instrument: false,
            verify_dtypes: true,
        },
    )
    .unwrap();
    // extreme input: all 255s maximizes the first-layer accumulators
    let x = Tensor::full(&[1, 784], 255.0);
    let mut inputs = BTreeMap::new();
    inputs.insert("x".to_string(), x);
    let err = exec.run_env(&inputs).err().expect("undersized accumulator must be detected");
    assert!(err.to_string().contains("overflow"), "{err}");
}

#[test]
fn fps_is_invariant_across_optimizations() {
    // §7.2: "the degree of parallelization for each network stays
    // constant across optimizations, and we do not see differences in
    // throughput and latency"
    let mut fps = Vec::new();
    for (acc, thr) in [(false, false), (true, true)] {
        let m = models::cnv_w2a2().unwrap();
        let tail = if thr {
            TailStyle::Thresholding(ThresholdStyle::BinarySearch)
        } else {
            TailStyle::Composite(EwDtype::Fixed(16, 8))
        };
        let pol = if acc { AccPolicy::Sira } else { AccPolicy::Datatype };
        let c = compile_qnn(m.graph, &m.input_ranges, &opts(tail, pol)).unwrap();
        fps.push(c.fdna.perf.fps);
    }
    let ratio = fps[1] / fps[0];
    assert!((0.9..=1.6).contains(&ratio), "fps ratio {ratio}");
}

#[test]
fn sidecar_roundtrip_compiles_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/model_params.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let m = sira_finn::models::sidecar::load_sidecar_file("artifacts/model_params.json").unwrap();
    let c = compile_qnn(
        m.graph,
        &m.input_ranges,
        &opts(TailStyle::Thresholding(ThresholdStyle::BinarySearch), AccPolicy::Sira),
    )
    .unwrap();
    assert!(c.thr_report.unwrap().converted >= 2);
}
