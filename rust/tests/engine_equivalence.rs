//! The engine's contract: for every zoo workload (Table 5), the
//! plan-compiled [`sira_finn::engine`] backend must be **bit-exact**
//! against the interpretive [`sira_finn::executor`] on the same graph,
//! on seeded random batches — both on the raw QNN graphs (f64 kernels)
//! and on the streamlined pure-integer forms (i32/i64 kernels + fused
//! thresholds), where the integer fast paths are additionally asserted
//! to engage.

use sira_finn::engine;
use sira_finn::executor::Executor;
use sira_finn::graph::Graph;
use sira_finn::models::{self, ZooModel};
use sira_finn::sira::{analyze, Analysis};
use sira_finn::tensor::Tensor;
use sira_finn::util::rng::Rng;

fn random_batch(rng: &mut Rng, shape: &[usize], b: usize) -> Vec<Tensor> {
    let numel: usize = shape.iter().product();
    (0..b)
        .map(|_| {
            Tensor::new(shape, (0..numel).map(|_| rng.int_in(0, 255) as f64).collect()).unwrap()
        })
        .collect()
}

/// Engine vs executor on the same graph: identical shapes, identical bits.
fn assert_bit_exact(g: &Graph, analysis: &Analysis, seed: u64, batches: &[usize]) {
    let mut plan = engine::compile(g, analysis)
        .unwrap_or_else(|e| panic!("{}: engine compile failed: {e:#}", g.name));
    let mut exec = Executor::new(g).unwrap();
    let mut rng = Rng::new(seed);
    let in_shape = g.shapes[&g.inputs[0]].clone();
    for &bsz in batches {
        let xs = random_batch(&mut rng, &in_shape, bsz);
        let ys = plan.run_batch(&xs).unwrap();
        assert_eq!(ys.len(), xs.len());
        for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
            let want = exec.run_single(x).unwrap().remove(0);
            assert_eq!(want.shape(), y.shape(), "{}: shape at sample {i}", g.name);
            assert_eq!(
                want.data(),
                y.data(),
                "{}: engine not bit-exact at sample {i} (batch {bsz})",
                g.name
            );
        }
    }
}

/// Like [`assert_bit_exact`] but at explicit thread counts {1, 4} with
/// `min_kernel_work` forced to 0 so the sharded paths engage even at
/// batch 1 — the acceptance matrix for zoo additions.
fn assert_bit_exact_threads(g: &Graph, analysis: &Analysis, seed: u64, batches: &[usize]) {
    let mut exec = Executor::new(g).unwrap();
    let in_shape = g.shapes[&g.inputs[0]].clone();
    for threads in [1usize, 4] {
        let mut plan = engine::compile(g, analysis)
            .unwrap_or_else(|e| panic!("{}: engine compile failed: {e:#}", g.name));
        plan.set_threads(threads);
        plan.set_min_kernel_work(0);
        let mut rng = Rng::new(seed);
        for &bsz in batches {
            let xs = random_batch(&mut rng, &in_shape, bsz);
            let ys = plan.run_batch(&xs).unwrap();
            assert_eq!(ys.len(), xs.len());
            for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
                let want = exec.run_single(x).unwrap().remove(0);
                assert_eq!(
                    want.shape(),
                    y.shape(),
                    "{}: shape at sample {i} (t={threads})",
                    g.name
                );
                assert_eq!(
                    want.data(),
                    y.data(),
                    "{}: engine not bit-exact at sample {i} (batch {bsz}, t={threads})",
                    g.name
                );
            }
        }
    }
}

fn raw_case(m: ZooModel, seed: u64, batches: &[usize]) {
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    assert_bit_exact(&m.graph, &analysis, seed, batches);
}

fn raw_case_threads(m: ZooModel, seed: u64, batches: &[usize]) {
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    assert_bit_exact_threads(&m.graph, &analysis, seed, batches);
}

#[test]
fn tfc_w2a2_bit_exact() {
    raw_case(models::tfc_w2a2().unwrap(), 0x7FC0, &[1, 5]);
}

#[test]
fn cnv_w2a2_bit_exact() {
    raw_case(models::cnv_w2a2().unwrap(), 0xC270, &[2]);
}

#[test]
fn rn8_w3a3_bit_exact() {
    raw_case(models::rn8_w3a3().unwrap(), 0x8380, &[2]);
}

#[test]
fn vgg12_w2a2_bit_exact() {
    raw_case_threads(models::vgg12_w2a2().unwrap(), 0x7612, &[2]);
}

#[test]
fn rn12_w3a3_bit_exact() {
    raw_case_threads(models::rn12_w3a3().unwrap(), 0x12E5, &[2]);
}

#[test]
fn dws_w4a4_bit_exact() {
    raw_case_threads(models::dws_w4a4().unwrap(), 0x0D25, &[1, 4]);
}

#[test]
fn mnv1_w4a4_bit_exact() {
    // 28x28 resolution: identical graph structure/params to the paper
    // model, tractable for a per-sample interpreter comparison. The
    // serving resolution (by_name's 56x56) is covered separately below —
    // both resolutions are deliberate, not drift.
    raw_case(models::mnv1_w4a4_scaled(8).unwrap(), 0x1144, &[1]);
}

#[test]
fn mnv1_serving_resolution_bit_exact() {
    // by_name("mnv1") — the exact artifact the CLI, the serving registry
    // and the perf gate compile (56x56). Previously only 28x28 was
    // equivalence-tested while every other path ran 56x56.
    raw_case(models::by_name("mnv1").unwrap(), 0x1145, &[1]);
}

#[test]
fn streamlined_tfc_bit_exact_with_integer_macs() {
    let m = models::tfc_w2a2().unwrap();
    let mut g = m.graph.clone();
    let analysis = engine::prepare_streamlined(&mut g, &m.input_ranges).unwrap();
    let plan = engine::compile(&g, &analysis).unwrap();
    assert!(
        plan.stats().integer_macs() >= 1,
        "streamlined TFC produced no integer MACs: {}",
        plan.stats()
    );
    assert_bit_exact(&g, &analysis, 0x57FC, &[1, 4]);
}

#[test]
fn streamlined_cnv_bit_exact_with_fused_thresholds() {
    let m = models::cnv_w2a2().unwrap();
    let mut g = m.graph.clone();
    let analysis = engine::prepare_streamlined(&mut g, &m.input_ranges).unwrap();
    let plan = engine::compile(&g, &analysis).unwrap();
    assert!(
        plan.stats().integer_macs() >= 1,
        "streamlined CNV produced no integer MACs: {}",
        plan.stats()
    );
    assert!(
        plan.stats().fused_thresholds >= 1,
        "streamlined CNV fused no thresholds: {}",
        plan.stats()
    );
    assert_bit_exact(&g, &analysis, 0x5C27, &[2]);
}

#[test]
fn streamlined_vgg12_bit_exact_with_integer_macs() {
    let m = models::vgg12_w2a2().unwrap();
    let mut g = m.graph.clone();
    let analysis = engine::prepare_streamlined(&mut g, &m.input_ranges).unwrap();
    let plan = engine::compile(&g, &analysis).unwrap();
    assert!(
        plan.stats().integer_macs() >= 1,
        "streamlined VGG12 produced no integer MACs: {}",
        plan.stats()
    );
    assert!(
        plan.stats().fused_thresholds >= 1,
        "streamlined VGG12 fused no thresholds: {}",
        plan.stats()
    );
    assert_bit_exact_threads(&g, &analysis, 0x5762, &[2]);
}

#[test]
fn streamlined_rn12_bit_exact_with_integer_macs() {
    let m = models::rn12_w3a3().unwrap();
    let mut g = m.graph.clone();
    let analysis = engine::prepare_streamlined(&mut g, &m.input_ranges).unwrap();
    let plan = engine::compile(&g, &analysis).unwrap();
    assert!(
        plan.stats().integer_macs() >= 1,
        "streamlined RN12 produced no integer MACs: {}",
        plan.stats()
    );
    assert_bit_exact_threads(&g, &analysis, 0x52E5, &[2]);
}

#[test]
fn streamlined_dws_bit_exact_with_depthwise_steps() {
    let m = models::dws_w4a4().unwrap();
    let mut g = m.graph.clone();
    let analysis = engine::prepare_streamlined(&mut g, &m.input_ranges).unwrap();
    let plan = engine::compile(&g, &analysis).unwrap();
    assert!(
        plan.stats().integer_macs() >= 1,
        "streamlined DWS produced no integer MACs: {}",
        plan.stats()
    );
    assert!(
        plan.stats().depthwise >= 1,
        "streamlined DWS compiled no depthwise steps: {}",
        plan.stats()
    );
    assert_bit_exact_threads(&g, &analysis, 0x5D25, &[1, 4]);
}

/// Segmented execution on the zoo workloads: the pipelined serving
/// compute path must produce the monolithic runner's bits.
#[test]
fn segmented_zoo_models_bit_exact() {
    for (m, segs) in [
        (models::tfc_w2a2().unwrap(), 3usize),
        (models::cnv_w2a2().unwrap(), 4),
        (models::vgg12_w2a2().unwrap(), 5),
        (models::rn12_w3a3().unwrap(), 4),
        (models::dws_w4a4().unwrap(), 3),
    ] {
        let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
        let mut mono = engine::compile(&m.graph, &analysis).unwrap();
        let mut sp =
            engine::SegmentedPlan::new(engine::compile(&m.graph, &analysis).unwrap(), segs);
        let mut rng = Rng::new(0x5E69);
        let xs = random_batch(&mut rng, &m.input_shape, 3);
        let want = mono.run_batch(&xs).unwrap();
        let got = sp.run_batch(&xs).unwrap();
        for (w, y) in want.iter().zip(&got) {
            assert_eq!(w.data(), y.data(), "{}: segmented run diverged", m.name);
        }
    }
}

/// The persistent pool at a generous thread budget, reused across
/// consecutive calls, on a real conv workload.
#[test]
fn pooled_threads_zoo_bit_exact_across_calls() {
    let m = models::cnv_w2a2().unwrap();
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    let mut serial = engine::compile(&m.graph, &analysis).unwrap();
    let mut rng = Rng::new(0x9001);
    let xs = random_batch(&mut rng, &m.input_shape, 4);
    let want = serial.run_batch(&xs).unwrap();
    let mut pooled = engine::compile(&m.graph, &analysis)
        .unwrap()
        .with_min_kernel_work(0);
    pooled.set_threads(8);
    for round in 0..3 {
        let got = pooled.run_batch(&xs).unwrap();
        for (w, y) in want.iter().zip(&got) {
            assert_eq!(w.data(), y.data(), "pooled run diverged at round {round}");
        }
    }
}

/// Build (raw, streamlined) compiled plans for every zoo workload this
/// suite exercises, with the graph each was compiled from.
fn zoo_plans() -> Vec<(String, engine::Plan)> {
    let mut out = Vec::new();
    for m in [
        models::tfc_w2a2().unwrap(),
        models::cnv_w2a2().unwrap(),
        models::vgg12_w2a2().unwrap(),
        models::rn8_w3a3().unwrap(),
        models::rn12_w3a3().unwrap(),
        // by_name's 56x56 serving artifact, not the 28x28 test scale —
        // the snapshot/trim suites must cover what serve actually loads
        models::by_name("mnv1").unwrap(),
        models::dws_w4a4().unwrap(),
    ] {
        let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
        out.push((
            format!("{} (raw)", m.name),
            engine::compile(&m.graph, &analysis).unwrap(),
        ));
        let mut g = m.graph.clone();
        let analysis = engine::prepare_streamlined(&mut g, &m.input_ranges).unwrap();
        out.push((
            format!("{} (streamlined)", m.name),
            engine::compile(&g, &analysis).unwrap(),
        ));
    }
    out
}

/// Tentpole lock (ROADMAP item 5): a plan that went through the binary
/// snapshot format answers with the freshly compiled plan's bits — for
/// every zoo workload, raw and streamlined.
#[test]
fn snapshot_roundtrip_bit_exact_across_zoo() {
    for (label, mut fresh) in zoo_plans() {
        let bytes = engine::snapshot::to_bytes(&fresh);
        let mut loaded = engine::snapshot::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("{label}: snapshot decode failed: {e:#}"));
        assert_eq!(loaded.stats().steps, fresh.stats().steps, "{label}");
        assert_eq!(
            loaded.stats().integer_macs(),
            fresh.stats().integer_macs(),
            "{label}"
        );
        assert_eq!(
            loaded.stats().packed_weight_elems,
            fresh.stats().packed_weight_elems,
            "{label}"
        );
        let mut rng = Rng::new(0x54A9);
        let xs = random_batch(&mut rng, &fresh.input_shape().to_vec(), 2);
        let want = fresh.run_batch(&xs).unwrap();
        let got = loaded.run_batch(&xs).unwrap();
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.shape(), g.shape(), "{label}: shape at sample {i}");
            assert_eq!(
                w.data(),
                g.data(),
                "{label}: snapshot-loaded plan not bit-exact at sample {i}"
            );
        }
    }
}

/// A corrupted snapshot must be a clean error, never a wrong answer:
/// every single-byte flip — header, length, checksum, or payload — and
/// every truncation point is rejected at decode.
#[test]
fn snapshot_corruption_is_always_a_clean_error() {
    let m = models::tfc_w2a2().unwrap();
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    let plan = engine::compile(&m.graph, &analysis).unwrap();
    let good = engine::snapshot::to_bytes(&plan);
    assert!(engine::snapshot::from_bytes(&good).is_ok());
    for i in (0..good.len()).step_by(101) {
        let mut bad = good.clone();
        bad[i] ^= 0x40;
        assert!(
            engine::snapshot::from_bytes(&bad).is_err(),
            "flipped byte {i} of {} decoded anyway",
            good.len()
        );
    }
    for cut in [0, 7, 27, 28, good.len() / 3, good.len() - 1] {
        assert!(
            engine::snapshot::from_bytes(&good[..cut]).is_err(),
            "truncation at {cut} decoded anyway"
        );
    }
}

/// The fleet-memory claim, asserted at the allocation: N plan clones
/// (what N serving replicas hold) share ONE packed-weight allocation —
/// `Arc::strong_count` observed through `packed_share_count` rises and
/// falls with the clones instead of duplicating weights.
#[test]
fn plan_clones_share_one_packed_weight_allocation() {
    let m = models::tfc_w2a2().unwrap();
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    let plan = engine::compile(&m.graph, &analysis).unwrap();
    assert_eq!(plan.packed_share_count(), Some(1));
    let clones: Vec<_> = (0..7).map(|_| plan.clone()).collect();
    assert_eq!(
        plan.packed_share_count(),
        Some(8),
        "7 clones + the original must share one packed allocation"
    );
    drop(clones);
    assert_eq!(plan.packed_share_count(), Some(1));
}

/// Serve-time memory trim: after `drop_flat_oracles` the plan runs the
/// tiled kernels from packed storage only — and still answers with the
/// untrimmed plan's bits.
#[test]
fn dropped_flat_oracles_stay_bit_exact() {
    for (label, mut fresh) in zoo_plans() {
        let mut trimmed = fresh.clone();
        trimmed.drop_flat_oracles();
        assert_eq!(trimmed.stats().flat_weight_elems, 0, "{label}");
        let mut rng = Rng::new(0xD50F);
        let xs = random_batch(&mut rng, &fresh.input_shape().to_vec(), 2);
        let want = fresh.run_batch(&xs).unwrap();
        let got = trimmed.run_batch(&xs).unwrap();
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                w.data(),
                g.data(),
                "{label}: flat-dropped plan diverged at sample {i}"
            );
        }
    }
}

#[test]
fn engine_batching_is_order_preserving() {
    // outputs must correspond to inputs positionally, not just setwise
    let m = models::tfc_w2a2().unwrap();
    let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
    let mut plan = engine::compile(&m.graph, &analysis).unwrap();
    let mut rng = Rng::new(0x0DDB);
    let xs = random_batch(&mut rng, &m.input_shape, 6);
    let batched = plan.run_batch(&xs).unwrap();
    for (x, yb) in xs.iter().zip(&batched) {
        let y1 = plan.run_one(x).unwrap();
        assert_eq!(y1.data(), yb.data());
    }
}
