//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact sidecar interchange with the python compile path). `serde` is
//! unavailable offline, so this is a hand-rolled recursive-descent parser.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are kept as f64 (the sidecar only carries weights,
/// scales and small integers, all exactly representable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("expected non-negative integer, got {i}");
        }
        Ok(i as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Object field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Optional object field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Flatten a (possibly nested) numeric array into a vec of f64.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        fn walk(v: &Json, out: &mut Vec<f64>) -> Result<()> {
            match v {
                Json::Num(n) => out.push(*n),
                Json::Arr(a) => {
                    for x in a {
                        walk(x, out)?;
                    }
                }
                _ => bail!("expected numeric array, got {v:?}"),
            }
            Ok(())
        }
        walk(self, &mut out)?;
        Ok(out)
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn strs(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.to_string())).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).ok_or_else(|| anyhow!("bad codepoint"))?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let again = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, again);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn flatten_nested_numeric() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn display_escapes() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }
}
