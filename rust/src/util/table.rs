//! Aligned plain-text table rendering for bench harness output, matching
//! the row/column structure of the paper's tables.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i] - cells[i].len();
                // right-align numeric-looking cells, left-align text
                let numeric = cells[i]
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+' || c == '.')
                    .unwrap_or(false);
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(&cells[i]);
                } else {
                    line.push_str(&cells[i]);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format helpers used by bench output.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e4 || x.abs() < 1e-2 {
        format!("{x:.2e}")
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Network", "LUT", "rLUT"]);
        t.row(vec!["TFC-w2a2".into(), "42987".into(), "1.00".into()]);
        t.row(vec!["CNV-w2a2".into(), "124896".into(), "0.95".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows equal width
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[2].contains("42987"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn sci_formats() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(5.79e6), "5.79e6");
        assert_eq!(sci(0.2), "0.20");
    }
}
