//! Substrate utilities implemented in-repo because the offline registry
//! only carries `xla` and `anyhow`: JSON, seeded RNG, CLI parsing, table
//! formatting and lightweight timing.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// ceil(log2(x)) for x >= 1; bits needed so that 2^bits >= x.
pub fn ceil_log2(x: u64) -> u32 {
    assert!(x >= 1, "ceil_log2 of zero");
    64 - (x - 1).leading_zeros()
}

/// Number of bits of a two's complement integer type able to hold every
/// value in `[lo, hi]` (signed if lo < 0, otherwise unsigned).
pub fn bits_for_range(lo: i64, hi: i64) -> u32 {
    assert!(lo <= hi);
    if lo >= 0 {
        // unsigned
        if hi == 0 {
            1
        } else {
            ceil_log2(hi as u64 + 1)
        }
    } else {
        // signed: need bits so -2^(b-1) <= lo and hi <= 2^(b-1)-1
        let mag = (lo.unsigned_abs()).max(hi.unsigned_abs() + 1);
        ceil_log2(mag) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_basics() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn bits_for_unsigned_ranges() {
        assert_eq!(bits_for_range(0, 0), 1);
        assert_eq!(bits_for_range(0, 1), 1);
        assert_eq!(bits_for_range(0, 2), 2);
        assert_eq!(bits_for_range(0, 255), 8);
        assert_eq!(bits_for_range(0, 256), 9);
        assert_eq!(bits_for_range(3, 255), 8);
    }

    #[test]
    fn bits_for_signed_ranges() {
        assert_eq!(bits_for_range(-1, 0), 1);
        assert_eq!(bits_for_range(-2, 1), 2);
        assert_eq!(bits_for_range(-128, 127), 8);
        assert_eq!(bits_for_range(-129, 0), 9);
        assert_eq!(bits_for_range(-128, 128), 9);
        // paper §4.2 example: [-..., 96] requires 8 bits
        assert_eq!(bits_for_range(-96, 96), 8);
    }
}
