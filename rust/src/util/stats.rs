//! Small statistics helpers: summary statistics, histograms and ordinary
//! least-squares linear regression (used to fit the analytical cost models
//! of §5.4 against the structural synthesis estimator, mirroring the
//! paper's regression over Vivado out-of-context runs), plus the shared
//! percentile-summary JSON emitter used by every serving-metrics surface
//! (`/metrics`, `sira-finn loadgen`, `examples/serve.rs`).

use crate::util::json::Json;

/// 1-based rank of the p-percentile over `n` sorted samples: the index
/// formula `(n - 1) * p` (nearest-rank, the one `percentiles_u64` has
/// always used) plus one. Shared with the bucket-resolution estimator
/// in `obs::metrics::Histogram` so the two percentile surfaces agree on
/// which sample they are pointing at.
pub fn percentile_rank(n: u64, p: f64) -> u64 {
    if n == 0 {
        return 0;
    }
    ((n - 1) as f64 * p) as u64 + 1
}

/// (p50, p95, p99) of integer-valued samples (latency microseconds,
/// batch occupancies, ...). Sorts a copy; (0, 0, 0) when empty.
pub fn percentiles_u64(samples: &[u64]) -> (u64, u64, u64) {
    if samples.is_empty() {
        return (0, 0, 0);
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let pick = |p: f64| v[percentile_rank(v.len() as u64, p) as usize - 1];
    (pick(0.50), pick(0.95), pick(0.99))
}

/// The single percentile/occupancy JSON emitter shared by the HTTP
/// `/metrics` endpoint, the loopback load generator and the serve
/// example: `{count, mean, p50, p95, p99}` over integer samples. Every
/// machine-readable latency/occupancy report goes through here so the
/// schema cannot drift between surfaces.
pub fn percentile_json(samples: &[u64]) -> Json {
    let (p50, p95, p99) = percentiles_u64(samples);
    let mean = if samples.is_empty() {
        0.0
    } else {
        samples.iter().sum::<u64>() as f64 / samples.len() as f64
    };
    Json::obj(vec![
        ("count", Json::Num(samples.len() as f64)),
        ("mean", Json::Num(mean)),
        ("p50", Json::Num(p50 as f64)),
        ("p95", Json::Num(p95 as f64)),
        ("p99", Json::Num(p99 as f64)),
    ])
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n == 0 {
        f64::NAN
    } else if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean relative error |pred - obs| / obs, skipping zero observations.
/// This is the MRE metric the paper reports for Figs. 18 and 19.
pub fn mean_relative_error(pred: &[f64], obs: &[f64]) -> f64 {
    assert_eq!(pred.len(), obs.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (&p, &o) in pred.iter().zip(obs) {
        if o != 0.0 {
            total += ((p - o) / o).abs();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        total / n as f64
    }
}

/// Simple OLS fit y = alpha * x + beta. Returns (alpha, beta).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, sy / n);
    }
    let alpha = (n * sxy - sx * sy) / denom;
    let beta = (sy - alpha * sx) / n;
    (alpha, beta)
}

/// Histogram over integer-valued samples; returns (value, count) sorted.
pub fn int_histogram(xs: &[u32]) -> Vec<(u32, usize)> {
    let mut map = std::collections::BTreeMap::new();
    for &x in xs {
        *map.entry(x).or_insert(0usize) += 1;
    }
    map.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-9);
    }

    #[test]
    fn mre_basics() {
        let pred = [110.0, 95.0];
        let obs = [100.0, 100.0];
        assert!((mean_relative_error(&pred, &obs) - 0.075).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = int_histogram(&[8, 8, 10, 24]);
        assert_eq!(h, vec![(8, 2), (10, 1), (24, 1)]);
    }

    #[test]
    fn percentile_rank_matches_index_formula() {
        assert_eq!(percentile_rank(0, 0.5), 0);
        assert_eq!(percentile_rank(1, 0.99), 1);
        for n in [2u64, 8, 100, 1000] {
            for p in [0.5, 0.95, 0.99] {
                let rank = percentile_rank(n, p);
                assert_eq!(rank, ((n - 1) as f64 * p) as u64 + 1);
                assert!(rank >= 1 && rank <= n);
            }
        }
    }

    #[test]
    fn percentiles_ordering_and_empty() {
        assert_eq!(percentiles_u64(&[]), (0, 0, 0));
        let v: Vec<u64> = (1..=100).collect();
        let (p50, p95, p99) = percentiles_u64(&v);
        assert!(p50 <= p95 && p95 <= p99);
        assert_eq!(p50, 50);
        assert_eq!(p99, 99);
    }

    #[test]
    fn percentile_json_schema() {
        let j = percentile_json(&[10, 20, 30, 40]);
        assert_eq!(j.get("count").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("mean").unwrap().as_f64().unwrap(), 25.0);
        assert!(j.get("p50").unwrap().as_f64().unwrap() <= j.get("p99").unwrap().as_f64().unwrap());
    }
}
