//! Deterministic pseudo-random number generation (SplitMix64 +
//! xoshiro256**). Used for seeded model weights, synthetic datasets,
//! synthesis-noise modeling and property-test case generation. The `rand`
//! crate is unavailable offline.

/// SplitMix64: used to seed the main generator from a single u64.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian sample (Box-Muller generates pairs).
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (seeded via SplitMix64, per Blackman & Vigna's guidance).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire-style rejection-free-enough for our (non-crypto) uses.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn int_in_inclusive() {
        let mut r = Rng::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let v = r.gauss();
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
