//! Tiny argv parser (the `clap` crate is unavailable offline). Supports
//! `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    /// `flag_names` lists the options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(v) = iter.peek() {
                    if v.starts_with("--") {
                        bail!("option --{rest} expects a value");
                    }
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    bail!("option --{rest} expects a value");
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["compile", "--model", "cnv", "--pe=4", "out.json"], &[]);
        assert_eq!(a.positional, vec!["compile", "out.json"]);
        assert_eq!(a.get("model"), Some("cnv"));
        assert_eq!(a.get_usize("pe", 1).unwrap(), 4);
    }

    #[test]
    fn flags() {
        let a = parse(&["--verbose", "--model", "tfc"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("model"), Some("tfc"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(["--model".to_string()], &[]).is_err());
        assert!(Args::parse(["--a".to_string(), "--b".to_string(), "x".to_string()], &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("style", "thr"), "thr");
        assert_eq!(a.get_f64("freq", 200e6).unwrap(), 200e6);
    }
}
