//! Elementwise operation meta-kernel model (§5.2, after Berganski et
//! al.): a pipelined loop-nest applying one binary op per cycle per PE,
//! with multidirectional broadcasting and an embedded constant parameter
//! storage. Used to implement *composite* layer tails (Fig 14 option 1):
//! Mul → Add → Max(ReLU) → Mul → ToInt.

use crate::synth::{MemStyle, Resources, Synth};

use super::{HwKernel, KernelCategory};

/// The binary operation implemented by the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EwOp {
    Mul,
    Add,
    /// max(x, const) — covers ReLU
    Max,
    /// rounding/clipping conversion to integer (the quantizer step)
    ToInt,
}

/// Arithmetic implementation datatype for the op (§6.3: float32,
/// fixed16.8 or fixed32.16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EwDtype {
    Float32,
    /// fixed-point with total bits / integer bits
    Fixed(u32, u32),
    /// pure integer at given width
    Int(u32),
}

impl EwDtype {
    pub fn bits(&self) -> u32 {
        match self {
            EwDtype::Float32 => 32,
            EwDtype::Fixed(w, _) => *w,
            EwDtype::Int(w) => *w,
        }
    }
}

/// Elementwise meta-kernel instance.
#[derive(Clone, Debug)]
pub struct ElementwiseKernel {
    pub name: String,
    pub op: EwOp,
    /// dynamic input bits (n_i)
    pub in_bits: u32,
    /// constant parameter bits (n_p); 0 when the op has no parameter
    pub param_bits: u32,
    /// output bits
    pub out_bits: u32,
    /// arithmetic datatype
    pub dtype: EwDtype,
    /// channels (parameter storage depth when per-channel)
    pub channels: usize,
    /// per-channel parameters? (false = scalar constant)
    pub per_channel: bool,
    pub elems_per_frame: usize,
    pub pe: usize,
    /// force LUT implementation of arithmetic (the §6.4.1 microbenchmark
    /// setting); otherwise the "tool" may use DSPs for wide multiplies
    pub force_lut: bool,
    pub mem_style: MemStyle,
}

impl HwKernel for ElementwiseKernel {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::NonMac
    }

    fn resources(&self, synth: &Synth) -> Resources {
        let pe = self.pe as f64;
        let mut r = Resources::default();
        // compute element
        match self.dtype {
            EwDtype::Float32 => {
                let unit = match self.op {
                    EwOp::Mul => synth.fmul32(),
                    EwOp::Add | EwOp::Max => synth.fadd32(),
                    EwOp::ToInt => synth.fcvt32(),
                };
                r += unit * pe;
            }
            EwDtype::Fixed(..) | EwDtype::Int(_) => {
                let unit = match self.op {
                    EwOp::Mul => {
                        if self.force_lut || self.in_bits.max(self.param_bits) < 10 {
                            synth.multiplier_lut(self.in_bits, self.param_bits.max(1))
                        } else {
                            synth.multiplier_dsp(self.in_bits, self.param_bits.max(1))
                        }
                    }
                    EwOp::Add => synth.adder(self.in_bits.max(self.param_bits) + 1),
                    EwOp::Max => {
                        synth.comparator(self.in_bits) + synth.mux2(self.in_bits)
                    }
                    // round + clip: adder for the rounding increment plus
                    // saturation comparators
                    EwOp::ToInt => {
                        synth.adder(self.in_bits) + synth.comparator(self.in_bits) * 2.0
                            + synth.mux2(self.out_bits)
                    }
                };
                r += unit * pe;
            }
        }
        // constant parameter storage (per-channel only; scalar params fold
        // into the datapath)
        if self.per_channel && self.param_bits > 0 {
            let bits = self.channels as u64 * self.param_bits as u64;
            r += synth.memory(bits, self.param_bits * self.pe as u32, self.mem_style);
        }
        // broadcasting buffer index logic + loop-nest control (§5.2)
        r += Resources::lut_only(24.0 + 4.0 * pe);
        r
    }

    fn cycles_per_frame(&self) -> u64 {
        (self.elems_per_frame as u64).div_ceil(self.pe as u64)
    }

    fn latency(&self) -> u64 {
        match self.dtype {
            EwDtype::Float32 => 12,
            _ => 3,
        }
    }

    fn stream_widths(&self) -> (u64, u64) {
        (
            self.pe as u64 * self.in_bits as u64,
            self.pe as u64 * self.out_bits as u64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ew(op: EwOp, dtype: EwDtype, n_i: u32, n_p: u32, pe: usize) -> ElementwiseKernel {
        ElementwiseKernel {
            name: "ew".into(),
            op,
            in_bits: n_i,
            param_bits: n_p,
            out_bits: n_i,
            dtype,
            channels: 256,
            per_channel: true,
            elems_per_frame: 256,
            pe,
            force_lut: true,
            mem_style: MemStyle::Lut,
        }
    }

    #[test]
    fn mul_scales_multiplicatively() {
        let s = Synth::exact();
        let small = ew(EwOp::Mul, EwDtype::Fixed(16, 8), 8, 8, 1).resources(&s);
        let big = ew(EwOp::Mul, EwDtype::Fixed(16, 8), 16, 16, 1).resources(&s);
        // n_i*n_p grows 4x
        assert!(big.lut / small.lut > 2.0);
    }

    #[test]
    fn add_scales_linearly() {
        let s = Synth::exact();
        let a8 = ew(EwOp::Add, EwDtype::Fixed(16, 8), 8, 8, 1).resources(&s);
        let a16 = ew(EwOp::Add, EwDtype::Fixed(16, 8), 16, 16, 1).resources(&s);
        assert!(a16.lut < a8.lut * 2.5);
    }

    #[test]
    fn float32_is_an_order_of_magnitude_costlier() {
        let s = Synth::exact();
        let fx = ew(EwOp::Mul, EwDtype::Fixed(16, 8), 8, 8, 4).resources(&s);
        let fl = ew(EwOp::Mul, EwDtype::Float32, 8, 8, 4).resources(&s);
        assert!(fl.lut > fx.lut * 3.0, "float {} vs fixed {}", fl.lut, fx.lut);
    }

    #[test]
    fn pe_parallelism_multiplies_compute() {
        let s = Synth::exact();
        let p1 = ew(EwOp::Max, EwDtype::Int(16), 16, 0, 1).resources(&s);
        let p4 = ew(EwOp::Max, EwDtype::Int(16), 16, 0, 4).resources(&s);
        assert!(p4.lut > p1.lut * 2.5 && p4.lut < p1.lut * 4.5);
    }

    #[test]
    fn cycles_per_frame_by_pe() {
        assert_eq!(ew(EwOp::Mul, EwDtype::Int(8), 8, 8, 4).cycles_per_frame(), 64);
    }
}
