//! Matrix-Vector Unit (MVU) model — the FINN MAC engine [Alam et al.]:
//! PE × SIMD multiply-accumulate lanes, folded over a (MW × MH) weight
//! matrix. DSP packing is applied for 4-bit and 8-bit operands (§6.4.1:
//! "FINN RTL MVU with DSP packing optimizations for 4-bit and 8-bit
//! arithmetic, while MACs with other precisions are instantiated with
//! LUTs").

use crate::synth::{MemStyle, Resources, Synth};

use super::{HwKernel, KernelCategory};

/// MVU configuration.
#[derive(Clone, Debug)]
pub struct Mvu {
    pub name: String,
    /// matrix height = output channels (neurons)
    pub mh: usize,
    /// matrix width = dot-product length (synapses)
    pub mw: usize,
    pub pe: usize,
    pub simd: usize,
    /// weight bits
    pub wbits: u32,
    /// activation (input) bits
    pub abits: u32,
    /// accumulator bits (set by the accumulator-minimization policy; this
    /// is where §4.2 savings enter the datapath)
    pub acc_bits: u32,
    /// number of output vectors computed per frame (1 for FC; OH*OW for a
    /// convolution lowered onto the MVU)
    pub vectors_per_frame: usize,
    pub mem_style: MemStyle,
}

impl Mvu {
    /// cycles to compute one output vector
    pub fn cycles_per_vector(&self) -> u64 {
        ((self.mh + self.pe - 1) / self.pe) as u64 * ((self.mw + self.simd - 1) / self.simd) as u64
    }

    /// MACs per DSP slice achievable by operand packing. Per §6.4.1 the
    /// RTL MVU packs 4-bit and 8-bit *arithmetic* onto DSPs; packing
    /// requires both operands in the same precision class (a 2-bit-weight
    /// layer with 8-bit activations is cheaper in LUTs — this is why the
    /// paper's CNV-w2a2 reaches zero DSPs under full SIRA optimization).
    fn dsp_packing(&self) -> Option<f64> {
        let b = self.wbits.max(self.abits);
        let same_class = self.wbits.min(self.abits) * 2 >= b;
        match (same_class, b) {
            (true, 4) => Some(4.0), // int4 packing: 4 MACs per DSP48E2
            (true, 8) => Some(2.0), // int8 packing: 2 MACs per DSP48E2
            _ => None,            // other precisions: LUT multipliers
        }
    }
}

impl HwKernel for Mvu {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::Mac
    }

    fn resources(&self, synth: &Synth) -> Resources {
        let lanes = (self.pe * self.simd) as f64;
        let mut r = Resources::default();
        // multipliers: DSP-packed for 4/8-bit (per §6.4.1), LUTs otherwise
        match self.dsp_packing() {
            Some(macs_per_dsp) => {
                r.dsp += (lanes / macs_per_dsp).ceil();
                // packing glue
                r += Resources::lut_only(6.0 * lanes);
            }
            None => {
                r += synth.multiplier_lut(self.wbits, self.abits) * lanes;
            }
        }
        // adder tree per PE: SIMD-1 adders at product width, growing
        let prod_bits = self.wbits + self.abits;
        let tree_adders = (self.simd.saturating_sub(1)) as f64;
        r += synth.adder(prod_bits + 2) * (tree_adders * self.pe as f64);
        // accumulator per PE at acc_bits — the §4.2 lever
        r += synth.adder(self.acc_bits) * self.pe as f64;
        // weight memory: MH*MW*wbits bits, read pe*simd*wbits wide
        let wbits_total = (self.mh * self.mw) as u64 * self.wbits as u64;
        let read_width = (self.pe * self.simd) as u32 * self.wbits;
        r += synth.memory(wbits_total, read_width, self.mem_style);
        // control
        r += Resources::lut_only(120.0);
        r
    }

    fn cycles_per_frame(&self) -> u64 {
        self.cycles_per_vector() * self.vectors_per_frame as u64
    }

    fn latency(&self) -> u64 {
        self.cycles_per_vector() + 8
    }

    fn stream_widths(&self) -> (u64, u64) {
        (
            (self.simd as u64) * self.abits as u64,
            (self.pe as u64) * self.acc_bits as u64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mvu(pe: usize, simd: usize, wbits: u32, abits: u32, acc: u32) -> Mvu {
        Mvu {
            name: "mvu".into(),
            mh: 64,
            mw: 128,
            pe,
            simd,
            wbits,
            abits,
            acc_bits: acc,
            vectors_per_frame: 1,
            mem_style: MemStyle::Auto,
        }
    }

    #[test]
    fn folding_controls_cycles() {
        assert_eq!(mvu(1, 1, 2, 2, 16).cycles_per_frame(), 64 * 128);
        assert_eq!(mvu(8, 16, 2, 2, 16).cycles_per_frame(), 8 * 8);
        assert_eq!(mvu(64, 128, 2, 2, 16).cycles_per_frame(), 1);
    }

    #[test]
    fn dsp_packing_for_4_and_8_bit() {
        let s = Synth::exact();
        let m4 = mvu(4, 8, 4, 4, 16).resources(&s);
        assert_eq!(m4.dsp, 8.0); // 32 lanes / 4 per DSP
        let m8 = mvu(4, 8, 8, 8, 24).resources(&s);
        assert_eq!(m8.dsp, 16.0); // 32 lanes / 2 per DSP
        let m3 = mvu(4, 8, 3, 3, 14).resources(&s);
        assert_eq!(m3.dsp, 0.0); // LUT multipliers
        assert!(m3.lut > m4.lut);
    }

    #[test]
    fn accumulator_width_moves_luts() {
        let s = Synth::exact();
        let wide = mvu(8, 8, 3, 3, 32).resources(&s);
        let narrow = mvu(8, 8, 3, 3, 14).resources(&s);
        assert!(narrow.lut < wide.lut);
        // saving ~ pe * (32-14) LUTs
        let delta = wide.lut - narrow.lut;
        assert!((delta - 8.0 * 18.0).abs() < 16.0, "delta = {delta}");
    }

    #[test]
    fn parallelism_widens_streams() {
        let m = mvu(8, 16, 2, 2, 16);
        assert_eq!(m.stream_widths(), (32, 128));
    }
}
