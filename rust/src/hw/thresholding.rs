//! Thresholding kernel models (§5.3): the legacy parallel-comparator
//! implementation (Fig 16: 2^n - 1 comparators + adder tree) and the new
//! RTL binary-search implementation (Fig 17: n pipeline stages, one
//! comparator each, stage-local threshold storage).

use crate::synth::{MemStyle, Resources, Synth};

use super::{HwKernel, KernelCategory};

/// Implementation style for the multi-threshold operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThresholdStyle {
    /// Fig 16: N parallel comparators + popcount adder tree.
    Parallel,
    /// Fig 17: pipelined binary search over sorted thresholds.
    BinarySearch,
}

/// Thresholding kernel configuration.
#[derive(Clone, Debug)]
pub struct Thresholding {
    pub name: String,
    /// channels (threshold granularity: 1 = per-tensor)
    pub channels: usize,
    /// distinct threshold rows after compression (paper §9 future work:
    /// "threshold compression"); channels sharing an identical threshold
    /// vector share one memory bank plus an indirection entry.
    /// 0 = uncompressed (= channels).
    pub unique_rows: usize,
    /// data channels processed per frame element (frame elements =
    /// channels * spatial positions)
    pub elems_per_frame: usize,
    /// input bitwidth n_i (the accumulator width of the producer — the
    /// §4.2 coupling illustrated in Fig 12)
    pub in_bits: u32,
    /// output bitwidth n_o (N = 2^n_o - 1 thresholds)
    pub out_bits: u32,
    pub pe: usize,
    pub style: ThresholdStyle,
    pub mem_style: MemStyle,
}

impl Thresholding {
    /// number of thresholds per channel
    pub fn n_thresholds(&self) -> u64 {
        (1u64 << self.out_bits) - 1
    }

    /// total threshold memory bits: Sum_Θ * n_i (§5.4.3), reduced by row
    /// deduplication when compression found shared rows, plus the
    /// per-channel indirection table.
    pub fn mem_bits(&self) -> u64 {
        let rows = if self.unique_rows == 0 {
            self.channels.max(1)
        } else {
            self.unique_rows.max(1)
        } as u64;
        let table = self.n_thresholds() * rows * self.in_bits as u64;
        let indirection = if (rows as usize) < self.channels.max(1) {
            let idx_bits = crate::util::ceil_log2(rows.max(2)).max(1) as u64;
            self.channels as u64 * idx_bits
        } else {
            0
        };
        table + indirection
    }
}

impl HwKernel for Thresholding {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::NonMac
    }

    fn resources(&self, synth: &Synth) -> Resources {
        let mut r = Resources::default();
        let n = self.n_thresholds();
        match self.style {
            ThresholdStyle::Parallel => {
                // N comparators per PE + adder tree of n_o-bit counters
                r += synth.comparator(self.in_bits) * (n as f64 * self.pe as f64);
                r += synth.adder(self.out_bits) * ((n as f64 - 1.0).max(0.0) * self.pe as f64);
            }
            ThresholdStyle::BinarySearch => {
                // one comparator per tree level per PE + index extension
                r += synth.comparator(self.in_bits) * (self.out_bits as f64 * self.pe as f64);
                r += Resources::lut_only(4.0 * self.out_bits as f64 * self.pe as f64);
            }
        }
        // threshold parameter storage, partitioned into PE banks (each PE
        // serves a slice of the channels; total bits are constant)
        let read_width = self.in_bits * self.pe as u32;
        r += synth.memory(self.mem_bits(), read_width, self.mem_style);
        // control
        r += Resources::lut_only(40.0);
        r
    }

    fn cycles_per_frame(&self) -> u64 {
        (self.elems_per_frame as u64).div_ceil(self.pe as u64)
    }

    fn latency(&self) -> u64 {
        match self.style {
            ThresholdStyle::Parallel => 4,
            ThresholdStyle::BinarySearch => self.out_bits as u64 + 2,
        }
    }

    fn stream_widths(&self) -> (u64, u64) {
        (
            self.pe as u64 * self.in_bits as u64,
            self.pe as u64 * self.out_bits as u64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn thr(style: ThresholdStyle, in_bits: u32, out_bits: u32, c: usize, pe: usize) -> Thresholding {
        Thresholding {
            name: "thr".into(),
            channels: c,
            unique_rows: 0,
            elems_per_frame: c,
            in_bits,
            out_bits,
            pe,
            style,
            mem_style: MemStyle::Lut,
        }
    }

    #[test]
    fn binary_search_beats_parallel_compute() {
        let s = Synth::exact();
        // 8-bit output: 255 comparators vs 8
        let par = thr(ThresholdStyle::Parallel, 16, 8, 1, 1).resources(&s);
        let bin = thr(ThresholdStyle::BinarySearch, 16, 8, 1, 1).resources(&s);
        assert!(
            bin.lut < par.lut / 3.0,
            "binary {} vs parallel {}",
            bin.lut,
            par.lut
        );
    }

    #[test]
    fn memory_grows_exponentially_with_out_bits() {
        let t2 = thr(ThresholdStyle::BinarySearch, 16, 2, 256, 1);
        let t8 = thr(ThresholdStyle::BinarySearch, 16, 8, 256, 1);
        assert_eq!(t2.mem_bits(), 3 * 256 * 16);
        assert_eq!(t8.mem_bits(), 255 * 256 * 16);
        assert!(t8.mem_bits() / t2.mem_bits() == 85);
    }

    #[test]
    fn per_channel_costs_more_than_per_tensor() {
        let s = Synth::exact();
        let pt = thr(ThresholdStyle::BinarySearch, 24, 8, 1, 1).resources(&s);
        let pc = thr(ThresholdStyle::BinarySearch, 24, 8, 512, 1).resources(&s);
        assert!(pc.lut > pt.lut * 10.0);
    }

    #[test]
    fn cycles_follow_pe() {
        let t = thr(ThresholdStyle::BinarySearch, 8, 4, 256, 4);
        assert_eq!(t.cycles_per_frame(), 64);
    }

    #[test]
    fn bram_style_moves_memory_off_luts() {
        let s = Synth::exact();
        let mut t = thr(ThresholdStyle::BinarySearch, 24, 8, 512, 1);
        t.mem_style = MemStyle::Bram;
        let r = t.resources(&s);
        assert!(r.bram18 > 0.0);
        // only the comparators + control remain in LUTs
        assert!(r.lut < 350.0, "lut = {}", r.lut);
    }
}


#[cfg(test)]
mod compression_tests {
    use super::*;
    use crate::synth::{MemStyle, Synth};

    #[test]
    fn row_dedup_reduces_memory() {
        let base = Thresholding {
            name: "t".into(),
            channels: 256,
            unique_rows: 0,
            elems_per_frame: 256,
            in_bits: 16,
            out_bits: 4,
            pe: 1,
            style: ThresholdStyle::BinarySearch,
            mem_style: MemStyle::Lut,
        };
        let mut compressed = base.clone();
        compressed.unique_rows = 16;
        assert!(compressed.mem_bits() < base.mem_bits() / 4);
        let s = Synth::exact();
        assert!(compressed.resources(&s).lut < base.resources(&s).lut);
    }

    #[test]
    fn indirection_overhead_accounted() {
        let mut t = Thresholding {
            name: "t".into(),
            channels: 256,
            unique_rows: 2,
            elems_per_frame: 256,
            in_bits: 16,
            out_bits: 2,
            pe: 1,
            style: ThresholdStyle::BinarySearch,
            mem_style: MemStyle::Lut,
        };
        // 2 unique rows x 3 thresholds x 16 bits + 256 x 1-bit index
        assert_eq!(t.mem_bits(), 2 * 3 * 16 + 256);
        t.unique_rows = 256; // no sharing: no indirection table
        assert_eq!(t.mem_bits(), 256 * 3 * 16);
    }
}
