//! Hardware kernel library models (§5): the building blocks the FINN
//! backend instantiates into a streaming dataflow pipeline. Each kernel
//! models its FPGA resource cost (via the [`crate::synth`] structural
//! estimator) and its cycle behaviour (initiation interval + latency) for
//! the dataflow performance simulator.

pub mod elementwise;
pub mod mvu;
pub mod stream;
pub mod thresholding;

pub use elementwise::{EwDtype, EwOp, ElementwiseKernel};
pub use mvu::Mvu;
pub use stream::{Dwc, Fifo, PoolKernel, SlidingWindow};
pub use thresholding::{Thresholding, ThresholdStyle};

use crate::synth::{Resources, Synth};

/// Category for the Fig 21 MAC / non-MAC resource breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelCategory {
    Mac,
    NonMac,
}

/// A hardware kernel model.
pub trait HwKernel {
    fn name(&self) -> String;
    fn category(&self) -> KernelCategory;
    /// FPGA resources under a given synthesis context.
    fn resources(&self, synth: &Synth) -> Resources;
    /// Cycles to process one input frame (initiation interval at the
    /// frame level; streaming kernels overlap frames).
    fn cycles_per_frame(&self) -> u64;
    /// Pipeline latency in cycles from first input to first output.
    fn latency(&self) -> u64;
    /// Input and output stream widths in bits (checked against the
    /// 8192-bit Vitis ap_int limit, §6.2.2).
    fn stream_widths(&self) -> (u64, u64);
}

/// A placed kernel instance in the FDNA.
pub struct KernelInstance {
    pub kernel: Box<dyn HwKernel>,
    /// graph node this was generated from
    pub source_node: String,
}

/// The Vitis HLS arbitrary-precision integer stream-width limit.
pub const MAX_STREAM_BITS: u64 = 8192;
