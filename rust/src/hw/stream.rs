//! Streaming plumbing kernels: FIFOs, data width converters, the sliding
//! window unit (convolution input generator) and pooling kernels. These
//! are the "other components" of the paper's non-MAC category (Fig 21:
//! "FIFOs, data width converters, elementwise kernels, thresholding and
//! others") whose widths inherit from upstream accumulators — the channel
//! through which accumulator minimization (§4.2) propagates savings.

use crate::synth::{MemStyle, Resources, Synth};

use super::{HwKernel, KernelCategory};

/// Inter-kernel FIFO buffer.
#[derive(Clone, Debug)]
pub struct Fifo {
    pub name: String,
    pub width_bits: u64,
    pub depth: u64,
}

impl HwKernel for Fifo {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::NonMac
    }

    fn resources(&self, synth: &Synth) -> Resources {
        // shallow FIFOs map to SRL shift registers (32 bits/LUT), deep and
        // wide ones to BRAM
        let bits = self.width_bits * self.depth;
        if self.depth <= 32 {
            Resources::lut_only((self.width_bits as f64 * self.depth as f64) / 32.0 + 12.0)
        } else {
            synth.memory(bits, self.width_bits as u32, MemStyle::Auto)
                + Resources::lut_only(16.0)
        }
    }

    fn cycles_per_frame(&self) -> u64 {
        0 // transparent to throughput
    }

    fn latency(&self) -> u64 {
        1
    }

    fn stream_widths(&self) -> (u64, u64) {
        (self.width_bits, self.width_bits)
    }
}

/// Data width converter between mismatched stream widths.
#[derive(Clone, Debug)]
pub struct Dwc {
    pub name: String,
    pub in_bits: u64,
    pub out_bits: u64,
}

impl HwKernel for Dwc {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::NonMac
    }

    fn resources(&self, _synth: &Synth) -> Resources {
        // barrel shifter + holding register sized by the wider side
        let w = self.in_bits.max(self.out_bits) as f64;
        Resources {
            lut: w * 1.2 + 20.0,
            ff: w * 2.0,
            ..Default::default()
        }
    }

    fn cycles_per_frame(&self) -> u64 {
        0
    }

    fn latency(&self) -> u64 {
        2
    }

    fn stream_widths(&self) -> (u64, u64) {
        (self.in_bits, self.out_bits)
    }
}

/// Sliding window unit (convolution input generator): buffers K rows of
/// the input feature map and emits im2col-ordered windows for the MVU.
#[derive(Clone, Debug)]
pub struct SlidingWindow {
    pub name: String,
    pub channels: usize,
    pub kernel: usize,
    pub ifm_dim: usize,
    pub ofm_dim: usize,
    pub stride: usize,
    pub in_bits: u32,
    pub simd: usize,
    pub mem_style: MemStyle,
}

impl HwKernel for SlidingWindow {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::NonMac
    }

    fn resources(&self, synth: &Synth) -> Resources {
        // line buffer: K rows of the IFM
        let buf_bits =
            (self.kernel * self.ifm_dim * self.channels) as u64 * self.in_bits as u64;
        let read_width = (self.simd as u32) * self.in_bits;
        synth.memory(buf_bits, read_width, self.mem_style)
            + Resources::lut_only(150.0 + 2.0 * self.kernel as f64 * self.kernel as f64)
    }

    fn cycles_per_frame(&self) -> u64 {
        // emits OFM*OFM windows of K*K*C elements, SIMD at a time
        (self.ofm_dim * self.ofm_dim) as u64
            * ((self.kernel * self.kernel * self.channels) as u64).div_ceil(self.simd as u64)
    }

    fn latency(&self) -> u64 {
        (self.kernel * self.ifm_dim * self.channels / self.simd.max(1)) as u64
    }

    fn stream_widths(&self) -> (u64, u64) {
        let w = self.simd as u64 * self.in_bits as u64;
        (w, w)
    }
}

/// Max/average pooling kernel.
#[derive(Clone, Debug)]
pub struct PoolKernel {
    pub name: String,
    pub channels: usize,
    pub kernel: usize,
    pub ifm_dim: usize,
    pub in_bits: u32,
    pub pe: usize,
    pub is_max: bool,
}

impl HwKernel for PoolKernel {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn category(&self) -> KernelCategory {
        KernelCategory::NonMac
    }

    fn resources(&self, synth: &Synth) -> Resources {
        let unit = if self.is_max {
            synth.comparator(self.in_bits) + synth.mux2(self.in_bits)
        } else {
            synth.adder(self.in_bits + 4)
        };
        // line buffer for the pooling window
        let buf_bits = (self.kernel * self.ifm_dim * self.channels) as u64 * self.in_bits as u64;
        unit * self.pe as f64
            + synth.memory(buf_bits, self.in_bits * self.pe as u32, MemStyle::Auto)
            + Resources::lut_only(60.0)
    }

    fn cycles_per_frame(&self) -> u64 {
        let ofm = self.ifm_dim / self.kernel.max(1);
        (ofm * ofm * self.kernel * self.kernel) as u64
            * (self.channels as u64).div_ceil(self.pe as u64)
    }

    fn latency(&self) -> u64 {
        (self.kernel * self.ifm_dim) as u64
    }

    fn stream_widths(&self) -> (u64, u64) {
        let w = self.pe as u64 * self.in_bits as u64;
        (w, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shallow_fifo_is_srl() {
        let s = Synth::exact();
        let f = Fifo {
            name: "f".into(),
            width_bits: 64,
            depth: 16,
        };
        let r = f.resources(&s);
        assert_eq!(r.bram18, 0.0);
        assert!(r.lut < 60.0);
    }

    #[test]
    fn deep_fifo_uses_bram() {
        let s = Synth::exact();
        let f = Fifo {
            name: "f".into(),
            width_bits: 64,
            depth: 2048,
        };
        assert!(f.resources(&s).bram18 >= 4.0);
    }

    #[test]
    fn fifo_width_follows_accumulator_bits() {
        // the §4.2 propagation: narrower accumulator -> narrower FIFO
        let s = Synth::exact();
        let wide = Fifo { name: "w".into(), width_bits: 32 * 4, depth: 512 };
        let narrow = Fifo { name: "n".into(), width_bits: 14 * 4, depth: 512 };
        let (rw, rn) = (wide.resources(&s), narrow.resources(&s));
        assert!(rn.bram18 <= rw.bram18);
        assert!(rn.lut <= rw.lut + 1.0);
    }

    #[test]
    fn swu_cycles_match_im2col_volume() {
        let swu = SlidingWindow {
            name: "swu".into(),
            channels: 16,
            kernel: 3,
            ifm_dim: 32,
            ofm_dim: 32,
            stride: 1,
            in_bits: 4,
            simd: 16,
            mem_style: MemStyle::Auto,
        };
        assert_eq!(swu.cycles_per_frame(), 32 * 32 * 9);
    }

    #[test]
    fn pool_kernel_runs() {
        let s = Synth::exact();
        let p = PoolKernel {
            name: "p".into(),
            channels: 64,
            kernel: 2,
            ifm_dim: 32,
            in_bits: 4,
            pe: 2,
            is_max: true,
        };
        assert!(p.resources(&s).lut > 0.0);
        assert_eq!(p.cycles_per_frame(), 16 * 16 * 4 * 32);
    }
}
