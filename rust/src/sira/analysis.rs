//! The SIRA analysis driver: a node-by-node walk of the topologically
//! sorted graph (Listing 1 of the paper), maintaining a dictionary from
//! tensor name to [`SiRange`].

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::graph::{DataType, Graph};

use super::propagate::propagate_node;
use super::range::SiRange;

/// Result of a SIRA run: scaled-integer ranges for every tensor.
#[derive(Clone, Debug, Default)]
pub struct Analysis {
    pub ranges: BTreeMap<String, SiRange>,
}

impl Analysis {
    pub fn get(&self, tensor: &str) -> Result<&SiRange> {
        self.ranges
            .get(tensor)
            .with_context(|| format!("no analyzed range for tensor '{tensor}'"))
    }

    /// Tensors whose range is a point interval (candidates for stuck
    /// channel removal, §7.1, are per-channel points inside these).
    pub fn point_tensors(&self) -> Vec<&str> {
        self.ranges
            .iter()
            .filter(|(_, r)| r.is_point())
            .map(|(k, _)| k.as_str())
            .collect()
    }
}

/// Run SIRA over `g`. `input_ranges` must provide a range for every graph
/// input; initializers are automatically treated as point ranges. Graph
/// shapes must already be inferred ([`crate::graph::shapes::infer_shapes`]).
pub fn analyze(g: &Graph, input_ranges: &BTreeMap<String, SiRange>) -> Result<Analysis> {
    let mut ranges: BTreeMap<String, SiRange> = BTreeMap::new();
    for inp in &g.inputs {
        let r = input_ranges
            .get(inp)
            .with_context(|| format!("missing input range for '{inp}'"))?;
        ranges.insert(inp.clone(), r.clone());
    }
    for (name, t) in &g.initializers {
        ranges.insert(name.clone(), SiRange::point(t));
    }
    for node in g.topo_nodes()? {
        let ins: Vec<&SiRange> = node
            .inputs
            .iter()
            .map(|i| {
                ranges
                    .get(i)
                    .with_context(|| format!("node '{}' reads unanalyzed tensor '{i}'", node.name))
            })
            .collect::<Result<_>>()?;
        let outs = propagate_node(g, node, &ins)
            .with_context(|| format!("propagating node '{}' ({})", node.name, node.op.name()))?;
        for (o, r) in node.outputs.iter().zip(outs) {
            debug_assert!(r.check_invariant().is_ok(), "invariant violated at {o}");
            ranges.insert(o.clone(), r);
        }
    }
    Ok(Analysis { ranges })
}

/// Range implied by a datatype annotation (e.g. for UINT8 image inputs).
pub fn range_of_dtype(dt: DataType) -> SiRange {
    SiRange::scalar(dt.min_value(), dt.max_value())
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::graph::{Graph, Node, Op, RoundMode};
    use crate::sira::range::SiRange;
    use crate::tensor::Tensor;

    /// Build the lowered QNN layer of Fig. 7 with the Table 2 inputs.
    /// X --Quant(qs_X)--> X_q --MatMul(W_q)--> M_o --Add(B)--> A_o
    ///   --Mul(M)--> Mu_o --Add(N)--> N_o --Relu--> R_o --Quant(qs_Y)--> Y
    pub fn fig7_graph() -> (Graph, BTreeMap<String, SiRange>) {
        let mut g = Graph::new("fig7");
        g.add_input("X", &[1, 2]);
        // Quant params for X: per-tensor scale 0.7, signed 4-bit
        g.add_initializer("qs_X", Tensor::scalar(0.7));
        g.add_initializer("z0", Tensor::scalar(0.0));
        g.add_initializer("b4", Tensor::scalar(4.0));
        let q = |signed| Op::Quant {
            signed,
            narrow: false,
            rounding: RoundMode::RoundEven,
        };
        g.add_node(Node::new("QuantX", q(true), &["X", "qs_X", "z0", "b4"], &["X_q"]));
        // Weights W (2,3) quantized per-channel with scales (0.2, 0.3, 0.1)
        g.add_initializer(
            "W",
            Tensor::new(&[2, 3], vec![-2.1, 5.0, -1.3, 3.1, 0.0, -3.2]).unwrap(),
        );
        g.add_initializer("qs_W", Tensor::new(&[1, 3], vec![0.2, 0.3, 0.1]).unwrap());
        g.add_node(Node::new("QuantW", q(true), &["W", "qs_W", "z0", "b4"], &["W_q"]));
        g.add_node(Node::new("MatMul0", Op::MatMul, &["X_q", "W_q"], &["MM"]));
        // Gemm bias B, BatchNorm lowered to Mul(M) + Add(N)
        g.add_initializer("B", Tensor::new(&[1, 3], vec![-3.3, 1.1, 0.0]).unwrap());
        g.add_node(Node::new("AddB", Op::Add, &["MM", "B"], &["AB"]));
        g.add_initializer("M", Tensor::new(&[1, 3], vec![0.6, 0.2, 0.4]).unwrap());
        g.add_node(Node::new("MulM", Op::Mul, &["AB", "M"], &["MU"]));
        g.add_initializer("N", Tensor::new(&[1, 3], vec![-0.2, -0.4, 1.1]).unwrap());
        g.add_node(Node::new("AddN", Op::Add, &["MU", "N"], &["NO"]));
        g.add_node(Node::new("Relu0", Op::Relu, &["NO"], &["RO"]));
        g.add_initializer("qs_Y", Tensor::scalar(0.1));
        g.add_node(Node::new("QuantY", q(false), &["RO", "qs_Y", "z0", "b4"], &["Y"]));
        g.outputs.push("Y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();

        let mut inputs = BTreeMap::new();
        inputs.insert(
            "X".to_string(),
            SiRange::float(
                Tensor::new(&[1, 2], vec![-5.1, -3.8]).unwrap(),
                Tensor::new(&[1, 2], vec![5.1, 3.8]).unwrap(),
            )
            .unwrap(),
        );
        (g, inputs)
    }

    #[test]
    fn worked_example_quant_x() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        let xq = a.get("X_q").unwrap();
        let ic = xq.int.as_ref().unwrap();
        // round(-5.1/0.7) = -7, round(5.1/0.7) = 7; round(±3.8/0.7) = ±5
        assert_eq!(ic.lo.data(), &[-7.0, -5.0]);
        assert_eq!(ic.hi.data(), &[7.0, 5.0]);
        assert_eq!(ic.scale.data(), &[0.7]);
        assert!(ic.zero_bias());
        assert!(ic.scale_contribs.contains("qs_X"));
        // value range = 0.7 * int range
        assert!((xq.lo.data()[0] + 4.9).abs() < 1e-12);
        assert!((xq.hi.data()[1] - 3.5).abs() < 1e-12);
    }

    #[test]
    fn worked_example_quant_w_clips() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        let wq = a.get("W_q").unwrap();
        let ic = wq.int.as_ref().unwrap();
        assert!(wq.is_point());
        // -2.1/0.2 = -10.5 -> round-even -10 -> clip -8; 3.1/0.2 = 15.5 -> 16 -> clip 7
        assert_eq!(ic.lo.data(), &[-8.0, 7.0, -8.0, 7.0, 0.0, -8.0]);
    }

    #[test]
    fn worked_example_matmul() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        let mm = a.get("MM").unwrap();
        let ic = mm.int.as_ref().unwrap();
        // miv/mav over integer ranges: columns (±91, ±49, ±96)
        assert_eq!(ic.lo.data(), &[-91.0, -49.0, -96.0]);
        assert_eq!(ic.hi.data(), &[91.0, 49.0, 96.0]);
        // s_Y = s_X * s_W = (0.14, 0.21, 0.07)
        for (s, e) in ic.scale.data().iter().zip([0.14, 0.21, 0.07]) {
            assert!((s - e).abs() < 1e-12);
        }
        assert!(ic.zero_bias());
        // accumulator example of Fig. 12: max |..| = 96 -> 8 bits
        assert_eq!(crate::util::bits_for_range(-96, 96), 8);
    }

    #[test]
    fn worked_example_layer_tail_scale_bias() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        // After Add(B): bias = B; after Mul(M): scale = s*M, bias = B*M;
        // after Add(N): bias = B*M + N.
        let no = a.get("NO").unwrap();
        let ic = no.int.as_ref().unwrap();
        let exp_scale = [0.14 * 0.6, 0.21 * 0.2, 0.07 * 0.4];
        let exp_bias = [
            -3.3 * 0.6 - 0.2,
            1.1 * 0.2 - 0.4,
            0.0 * 0.4 + 1.1,
        ];
        for (s, e) in ic.scale.data().iter().zip(exp_scale) {
            assert!((s - e).abs() < 1e-12, "scale {s} vs {e}");
        }
        for (b, e) in ic.bias.data().iter().zip(exp_bias) {
            assert!((b - e).abs() < 1e-12, "bias {b} vs {e}");
        }
        // contribution history: scale fed by qs_X, qs_W, M; bias by B, M, N
        assert!(ic.scale_contribs.contains("qs_X"));
        assert!(ic.scale_contribs.contains("qs_W"));
        assert!(ic.scale_contribs.contains("M"));
        assert!(ic.bias_contribs.contains("B"));
        assert!(ic.bias_contribs.contains("M"));
        assert!(ic.bias_contribs.contains("N"));
        // integer range unchanged through the affine tail
        assert_eq!(ic.lo.data(), &[-91.0, -49.0, -96.0]);
    }

    #[test]
    fn worked_example_relu_drops_int() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        let ro = a.get("RO").unwrap();
        assert!(ro.int.is_none());
        assert!(ro.lo.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn worked_example_output_quant() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        let y = a.get("Y").unwrap();
        let ic = y.int.as_ref().unwrap();
        assert_eq!(ic.scale.data(), &[0.1]);
        assert!(ic.zero_bias());
        // unsigned 4-bit: q in [0, 15]
        assert!(ic.lo.data().iter().all(|&v| v >= 0.0));
        assert!(ic.hi.data().iter().all(|&v| v <= 15.0));
        // col0 pre-activation max 91*0.084 - 2.18 = 5.464 -> q = 15 (sat)
        assert_eq!(ic.hi.data()[0], 15.0);
        y.check_invariant().unwrap();
    }

    #[test]
    fn all_ranges_satisfy_invariant() {
        let (g, inputs) = fig7_graph();
        let a = analyze(&g, &inputs).unwrap();
        for (name, r) in &a.ranges {
            r.check_invariant().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn missing_input_range_errors() {
        let (g, _) = fig7_graph();
        assert!(analyze(&g, &BTreeMap::new()).is_err());
    }

    #[test]
    fn dtype_range_for_inputs() {
        let r = range_of_dtype(DataType::UInt(8));
        assert_eq!(r.bounds(), (0.0, 255.0));
    }
}
