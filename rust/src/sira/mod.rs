//! SIRA — scaled-integer range analysis (§3 of the paper).
//!
//! Applies interval arithmetic to a trained QNN graph, tracking for every
//! tensor (1) its possible value range, (2) the underlying integer
//! component's range with the affine scale/bias mapping, and (3) which
//! graph tensors contributed to the scale and bias (the contribution
//! history driving the aggregation pass of §4.1.2).

pub mod analysis;
pub mod propagate;
pub mod range;

pub use analysis::{analyze, range_of_dtype, Analysis};
pub use propagate::{propagate_node, quant_bounds};
pub use range::{IntComponent, SiRange};
