//! Per-operator scaled-integer range propagation handlers (§3.2).
//!
//! Each handler receives the input [`SiRange`]s of a node and produces the
//! output range(s), propagating the integer component (scale/bias) only
//! when the paper's conditions hold:
//!
//! * scales and biases only propagate in affine regions;
//! * non-linear operations drop the integer component (ReLU, Sigmoid);
//! * at least one dynamic input must be scaled-integer (Quant excepted —
//!   it always *creates* scaled-integer ranges);
//! * MatMul/Conv require per-output-channel weight scales with zero bias
//!   and per-tensor input scales (per-channel allowed for depthwise).

use std::collections::BTreeSet;

use anyhow::{bail, Context, Result};

use crate::graph::{Graph, Node, Op, RoundMode};
use crate::tensor::{round_half_even, Conv2dSpec, Tensor};

use super::range::{interval_mul, IntComponent, SiRange};

/// Propagate ranges through one node.
pub fn propagate_node(g: &Graph, node: &Node, ins: &[&SiRange]) -> Result<Vec<SiRange>> {
    let out = match &node.op {
        Op::Quant {
            signed,
            narrow,
            rounding,
        } => quant(node, ins, *signed, *narrow, *rounding)?,
        Op::Add => add_like(node, ins, false)?,
        Op::Sub => add_like(node, ins, true)?,
        Op::Mul => mul(node, ins)?,
        Op::Div => div(node, ins)?,
        Op::MatMul => matmul(g, node, ins)?,
        Op::Gemm => gemm(g, node, ins)?,
        Op::Conv { spec, group } => conv(g, node, ins, *spec, *group)?,
        Op::Relu => {
            let lo = ins[0].lo.relu();
            let hi = ins[0].hi.relu();
            SiRange::float(lo, hi)?
        }
        Op::Sigmoid => SiRange::float(ins[0].lo.sigmoid(), ins[0].hi.sigmoid())?,
        Op::Floor => SiRange::float(ins[0].lo.floor(), ins[0].hi.floor())?,
        Op::Clip { lo, hi } => SiRange::float(ins[0].lo.clip(*lo, *hi), ins[0].hi.clip(*lo, *hi))?,
        Op::BatchNorm { eps } => batchnorm(ins, *eps)?,
        Op::MaxPool { .. } => maxpool(ins)?,
        Op::AveragePool { spec } => avgpool(ins, spec.kernel.0 * spec.kernel.1, spec.pad)?,
        Op::GlobalAveragePool => {
            let shape = g
                .shapes
                .get(&node.inputs[0])
                .with_context(|| format!("no shape for {}", node.inputs[0]))?;
            avgpool(ins, shape[2] * shape[3], (0, 0))?
        }
        Op::Reshape { .. } | Op::Flatten { .. } | Op::Transpose { .. } | Op::Identity => {
            data_movement(g, node, ins)?
        }
        Op::Concat { axis } => concat(ins, *axis)?,
        Op::MultiThreshold {
            out_scale,
            out_bias,
        } => multithreshold(g, node, ins, *out_scale, *out_bias)?,
    };
    Ok(vec![out])
}

/// Quantization range bounds for the QONNX Quant operator.
pub fn quant_bounds(bits: u32, signed: bool, narrow: bool) -> (f64, f64) {
    if signed {
        let qmin = -(1i64 << (bits - 1)) + if narrow { 1 } else { 0 };
        let qmax = (1i64 << (bits - 1)) - 1;
        (qmin as f64, qmax as f64)
    } else {
        (0.0, ((1u64 << bits) - 1) as f64)
    }
}

/// §3.2.1 — quantization always creates a scaled-integer range:
/// `q = clip(round(x/s + z), qmin, qmax)`, value `= s*(q - z)`, so the
/// output has scale `s` and bias `-s*z`.
fn quant(node: &Node, ins: &[&SiRange], signed: bool, narrow: bool, rounding: RoundMode) -> Result<SiRange> {
    let x = &ins[0];
    let s = ins[1]
        .point_value()
        .with_context(|| format!("Quant '{}': scale must be constant", node.name))?
        .clone();
    let z = ins[2]
        .point_value()
        .with_context(|| format!("Quant '{}': zero_point must be constant", node.name))?
        .clone();
    let bits_t = ins[3]
        .point_value()
        .with_context(|| format!("Quant '{}': bitwidth must be constant", node.name))?;
    if !bits_t.is_scalar() {
        bail!("Quant '{}': bitwidth must be scalar", node.name);
    }
    let bits = bits_t.first() as u32;
    if bits == 0 || bits > 32 {
        bail!("Quant '{}': unsupported bitwidth {bits}", node.name);
    }
    if s.data().iter().any(|&v| v <= 0.0) {
        bail!("Quant '{}': scale must be positive", node.name);
    }
    if !z.is_integral() {
        bail!("Quant '{}': zero point must be integral", node.name);
    }
    let (qmin, qmax) = quant_bounds(bits, signed, narrow);
    // q = clip(round(x/s + z), qmin, qmax); monotone nondecreasing in x.
    // Fused single pass per bound (perf: the Quant handler dominates
    // whole-graph analysis time on weight tensors — see §Perf).
    let z0 = z.all_eq(0.0);
    let round1 = |v: f64| -> f64 {
        match rounding {
            RoundMode::RoundEven => round_half_even(v),
            RoundMode::Floor => v.floor(),
            RoundMode::Ceil => v.ceil(),
        }
    };
    let to_q = |v: &Tensor| -> Result<Tensor> {
        if z0 {
            v.zip(&s, |a, sv| round1(a / sv).clamp(qmin, qmax))
        } else {
            Ok(v
                .zip(&s, |a, sv| a / sv)?
                .zip(&z, |a, zv| round1(a + zv).clamp(qmin, qmax))?)
        }
    };
    let q_lo = to_q(&x.lo)?;
    // point ranges (constant weights): reuse the computed bound
    let q_hi = if x.lo == x.hi { q_lo.clone() } else { to_q(&x.hi)? };
    let bias = s.mul(&z)?.neg();
    let mut scale_contribs = BTreeSet::new();
    scale_contribs.insert(node.inputs[1].clone());
    let mut bias_contribs = BTreeSet::new();
    if !z.all_eq(0.0) {
        bias_contribs.insert(node.inputs[1].clone());
        bias_contribs.insert(node.inputs[2].clone());
    }
    SiRange::from_int(q_lo, q_hi, s, bias, scale_contribs, bias_contribs)
}

/// §3.2.2 — addition (and subtraction via negation of the second operand).
fn add_like(node: &Node, ins: &[&SiRange], is_sub: bool) -> Result<SiRange> {
    let (a, b) = (&ins[0], &ins[1]);
    // Full-precision range is always propagated.
    let (lo, hi) = if is_sub {
        (a.lo.sub(&b.hi)?, a.hi.sub(&b.lo)?)
    } else {
        (a.lo.add(&b.lo)?, a.hi.add(&b.hi)?)
    };
    let float = SiRange::float(lo, hi)?;

    let sign = if is_sub { -1.0 } else { 1.0 };
    // Case 1: one input scaled-integer, the other a constant — absorb the
    // constant into the bias.
    if let (Some(ic), Some(c)) = (&a.int, b.point_value()) {
        let c_eff = c.map(|v| sign * v);
        let mut bias_contribs = ic.bias_contribs.clone();
        bias_contribs.insert(node.inputs[1].clone());
        return SiRange::from_int(
            ic.lo.zip(&c_eff, |q, _| q)?, // broadcast q to output reduced shape
            ic.hi.zip(&c_eff, |q, _| q)?,
            ic.scale.clone(),
            ic.bias.add(&c_eff)?,
            ic.scale_contribs.clone(),
            bias_contribs,
        );
    }
    if let (Some(c), Some(ic)) = (a.point_value(), &b.int) {
        // c + s*q + b  or  c - (s*q + b) = (-s)*q + (c - b)
        let s = if is_sub { ic.scale.neg() } else { ic.scale.clone() };
        let bias = if is_sub { c.sub(&ic.bias)? } else { c.add(&ic.bias)? };
        let mut bias_contribs = ic.bias_contribs.clone();
        bias_contribs.insert(node.inputs[0].clone());
        return SiRange::from_int(
            ic.lo.zip(c, |q, _| q)?,
            ic.hi.zip(c, |q, _| q)?,
            s,
            bias,
            ic.scale_contribs.clone(),
            bias_contribs,
        );
    }
    // Case 2: both scaled-integer with integer scale ratio s_b = k * s_a.
    if let (Some(ia), Some(ib)) = (&a.int, &b.int) {
        if let Some(k) = integer_scale_ratio(&ia.scale, &ib.scale)? {
            let k_eff = sign * k;
            // q = q_a + k_eff * q_b (interval add with corner handling)
            let t1 = ib.lo.map(|v| k_eff * v);
            let t2 = ib.hi.map(|v| k_eff * v);
            let q_lo = ia.lo.add(&t1.minimum(&t2)?)?;
            let q_hi = ia.hi.add(&t1.maximum(&t2)?)?;
            let bias = if is_sub {
                ia.bias.sub(&ib.bias)?
            } else {
                ia.bias.add(&ib.bias)?
            };
            let mut sc = ia.scale_contribs.clone();
            sc.extend(ib.scale_contribs.iter().cloned());
            let mut bc = ia.bias_contribs.clone();
            bc.extend(ib.bias_contribs.iter().cloned());
            return SiRange::from_int(q_lo, q_hi, ia.scale.clone(), bias, sc, bc);
        }
    }
    Ok(float)
}

/// If `s_b = k * s_a` elementwise for a single integer k, return k.
fn integer_scale_ratio(sa: &Tensor, sb: &Tensor) -> Result<Option<f64>> {
    let ratio = sb.div(sa)?;
    let k = ratio.data()[0];
    if k.fract() != 0.0 || k == 0.0 {
        return Ok(None);
    }
    if ratio.data().iter().all(|&r| (r - k).abs() < 1e-12) {
        Ok(Some(k))
    } else {
        Ok(None)
    }
}

/// §3.2.3 — multiplication: scaled-integer propagates only when one input
/// is a constant (applied to scale and bias); the constant need not be an
/// integer and may be negative (handled by the range hull in `from_int`).
fn mul(node: &Node, ins: &[&SiRange]) -> Result<SiRange> {
    let (a, b) = (&ins[0], &ins[1]);
    // Full range: elementwise hull of the four corner products.
    let c1 = a.lo.mul(&b.lo)?;
    let c2 = a.lo.mul(&b.hi)?;
    let c3 = a.hi.mul(&b.lo)?;
    let c4 = a.hi.mul(&b.hi)?;
    let lo = c1.minimum(&c2)?.minimum(&c3)?.minimum(&c4)?;
    let hi = c1.maximum(&c2)?.maximum(&c3)?.maximum(&c4)?;
    let float = SiRange::float(lo, hi)?;

    let scaled = |ic: &IntComponent, c: &Tensor, c_name: &str| -> Result<SiRange> {
        let mut sc = ic.scale_contribs.clone();
        sc.insert(c_name.to_string());
        let mut bc = ic.bias_contribs.clone();
        if !ic.bias.all_eq(0.0) {
            bc.insert(c_name.to_string());
        }
        SiRange::from_int(
            ic.lo.zip(c, |q, _| q)?,
            ic.hi.zip(c, |q, _| q)?,
            ic.scale.mul(c)?,
            ic.bias.mul(c)?,
            sc,
            bc,
        )
    };
    if let (Some(ic), Some(c)) = (&a.int, b.point_value()) {
        if !a.is_point() {
            return scaled(ic, c, &node.inputs[1]);
        }
    }
    if let (Some(c), Some(ic)) = (a.point_value(), &b.int) {
        if !b.is_point() {
            return scaled(ic, c, &node.inputs[0]);
        }
    }
    // both constant: point result
    if let (Some(ca), Some(cb)) = (a.point_value(), b.point_value()) {
        return Ok(SiRange::point(&ca.mul(cb)?));
    }
    Ok(float)
}

/// Division by a constant = multiplication by its reciprocal.
fn div(node: &Node, ins: &[&SiRange]) -> Result<SiRange> {
    let (a, b) = (&ins[0], &ins[1]);
    let Some(c) = b.point_value() else {
        // dynamic divisor: only safe if it cannot cross zero
        let (blo, bhi) = b.bounds();
        if blo <= 0.0 && bhi >= 0.0 {
            bail!("Div '{}': divisor range crosses zero", node.name);
        }
        let c1 = a.lo.div(&b.lo)?;
        let c2 = a.lo.div(&b.hi)?;
        let c3 = a.hi.div(&b.lo)?;
        let c4 = a.hi.div(&b.hi)?;
        let lo = c1.minimum(&c2)?.minimum(&c3)?.minimum(&c4)?;
        let hi = c1.maximum(&c2)?.maximum(&c3)?.maximum(&c4)?;
        return SiRange::float(lo, hi);
    };
    if c.data().iter().any(|&v| v == 0.0) {
        bail!("Div '{}': division by zero constant", node.name);
    }
    let recip = c.map(|v| 1.0 / v);
    let fake = SiRange::point(&recip);
    mul(
        &Node::new(&node.name, Op::Mul, &[&node.inputs[0], &node.inputs[1]], &["_"]),
        &[a, &fake],
    )
}

/// Reduce a range tensor to a per-channel view (numel == c or scalar);
/// the channel axis is axis 1 of NCHW/NC reduced shapes.
fn per_channel(t: &Tensor, c: usize, lo_side: bool) -> Result<Tensor> {
    if t.numel() == 1 || t.numel() == c {
        return Ok(t.clone());
    }
    // General: reduce over all axes except the channel axis (1).
    if t.rank() >= 2 && t.shape()[1] == c {
        let init = if lo_side { f64::INFINITY } else { f64::NEG_INFINITY };
        let f = if lo_side { f64::min } else { f64::max };
        let red = t.reduce_except(1, init, f);
        return red.reshape(&[1, c, 1, 1]);
    }
    bail!("cannot reduce range of shape {:?} to {c} channels", t.shape())
}

/// §3.2.4 — matrix multiplication `Y = X · W` (ONNX convention: dynamic
/// activations X of shape (N,K), constant weights W of shape (K,M)).
fn matmul(g: &Graph, node: &Node, ins: &[&SiRange]) -> Result<SiRange> {
    let xs = g
        .shapes
        .get(&node.inputs[0])
        .with_context(|| format!("no shape for {}", node.inputs[0]))?
        .clone();
    let ws = g
        .shapes
        .get(&node.inputs[1])
        .with_context(|| format!("no shape for {}", node.inputs[1]))?
        .clone();
    if xs.len() != 2 || ws.len() != 2 {
        bail!("MatMul '{}': rank-2 operands required", node.name);
    }
    let (k, m) = (ws[0], ws[1]);
    let x = &ins[0];
    let w = &ins[1];
    let w_val = w
        .point_value()
        .with_context(|| format!("MatMul '{}': dynamic weights unsupported", node.name))?;

    // Float range via minimizing/maximizing input vectors (§2.4.2): for
    // output column m, lo = Σ_k min(w*xlo, w*xhi), hi = Σ_k max(...).
    let x_lo = x.lo.broadcast_to(&[1, k]).or_else(|_| x.lo.reshape(&[1, k]))?;
    let x_hi = x.hi.broadcast_to(&[1, k]).or_else(|_| x.hi.reshape(&[1, k]))?;
    let mut flo = vec![0.0; m];
    let mut fhi = vec![0.0; m];
    for kk in 0..k {
        let (xl, xh) = (x_lo.data()[kk], x_hi.data()[kk]);
        for mm in 0..m {
            let wv = w_val.data()[kk * m + mm];
            let (plo, phi) = interval_mul((xl, xh), (wv, wv));
            flo[mm] += plo;
            fhi[mm] += phi;
        }
    }
    let float = SiRange::float(
        Tensor::new(&[1, m], flo)?,
        Tensor::new(&[1, m], fhi)?,
    )?;

    // Scaled-integer propagation conditions.
    let (Some(ix), Some(iw)) = (&x.int, &w.int) else {
        return Ok(float);
    };
    // weights: zero bias, per-output-channel scale (broadcast along K only)
    if !iw.zero_bias() {
        return Ok(float);
    }
    let s_w_per_col = iw.scale.numel() == 1
        || (iw.scale.numel() == m && *iw.scale.shape().last().unwrap_or(&0) == m);
    if !s_w_per_col {
        return Ok(float);
    }
    // activations: per-tensor scale
    if !ix.scalar_scale() {
        return Ok(float);
    }
    let q_w = &iw.lo; // point (lo == hi) for constant weights
    if q_w != &iw.hi {
        return Ok(float);
    }
    // integer output range via miv/mav on integer corners
    let qx_lo = ix.lo.broadcast_to(&[1, k]).or_else(|_| ix.lo.reshape(&[1, k]))?;
    let qx_hi = ix.hi.broadcast_to(&[1, k]).or_else(|_| ix.hi.reshape(&[1, k]))?;
    let mut qlo = vec![0.0; m];
    let mut qhi = vec![0.0; m];
    for kk in 0..k {
        let (xl, xh) = (qx_lo.data()[kk], qx_hi.data()[kk]);
        for mm in 0..m {
            let wv = q_w.data()[kk * m + mm];
            let (plo, phi) = interval_mul((xl, xh), (wv, wv));
            qlo[mm] += plo;
            qhi[mm] += phi;
        }
    }
    // output scale = s_X * s_W, broadcast to (1, M)
    let s_w = if iw.scale.numel() == 1 {
        iw.scale.clone()
    } else {
        iw.scale.reshape(&[1, m])?
    };
    let s_y = ix.scale.mul(&s_w)?;
    // output bias: b_Y = b_X (broadcast to (1,K)) · W_value
    let bias = if ix.bias.all_eq(0.0) {
        Tensor::scalar(0.0)
    } else {
        let b_row = ix.bias.broadcast_to(&[1, k])?;
        b_row.matmul(w_val)?
    };
    let mut sc = ix.scale_contribs.clone();
    sc.extend(iw.scale_contribs.iter().cloned());
    let mut bc = ix.bias_contribs.clone();
    bc.extend(iw.bias_contribs.iter().cloned());
    SiRange::from_int(
        Tensor::new(&[1, m], qlo)?,
        Tensor::new(&[1, m], qhi)?,
        s_y,
        bias,
        sc,
        bc,
    )
}

/// Gemm (pre-lowering): float range = MatMul range + bias.
fn gemm(g: &Graph, node: &Node, ins: &[&SiRange]) -> Result<SiRange> {
    let mm = matmul(g, node, &ins[..2])?;
    let c = ins[2]
        .point_value()
        .with_context(|| format!("Gemm '{}': bias must be constant", node.name))?;
    SiRange::float(mm.lo.add(c)?, mm.hi.add(c)?)
}

/// §3.2.4 — convolution (dense and depthwise). Ranges are tracked
/// per-channel; padding contributes the hull with zero. Output reduced
/// shape is (1, O, 1, 1).
fn conv(
    g: &Graph,
    node: &Node,
    ins: &[&SiRange],
    spec: Conv2dSpec,
    group: usize,
) -> Result<SiRange> {
    let xs = g
        .shapes
        .get(&node.inputs[0])
        .with_context(|| format!("no shape for {}", node.inputs[0]))?
        .clone();
    let ws = g.shapes.get(&node.inputs[1]).unwrap().clone();
    let c_in = xs[1];
    let (o, wi, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
    let depthwise = group == c_in && wi == 1;
    if group != 1 && !depthwise {
        bail!("Conv '{}': only dense (group=1) or depthwise supported", node.name);
    }
    let padded = spec.pad.0 > 0 || spec.pad.1 > 0;
    let x = &ins[0];
    let w = &ins[1];
    let w_val = w
        .point_value()
        .with_context(|| format!("Conv '{}': dynamic weights unsupported", node.name))?;

    let x_lo = per_channel(&x.lo, c_in, true)?;
    let x_hi = per_channel(&x.hi, c_in, false)?;
    let ch_of = |t: &Tensor, c: usize| -> f64 {
        if t.numel() == 1 {
            t.data()[0]
        } else {
            t.data()[c]
        }
    };

    // Float range per output channel.
    let mut flo = vec![0.0; o];
    let mut fhi = vec![0.0; o];
    for oc in 0..o {
        for icc in 0..wi {
            let in_ch = if depthwise { oc } else { icc };
            let (xl, xh) = (ch_of(&x_lo, in_ch), ch_of(&x_hi, in_ch));
            for t in 0..kh * kw {
                let wv = w_val.data()[((oc * wi) + icc) * kh * kw + t];
                let (mut plo, mut phi) = interval_mul((xl, xh), (wv, wv));
                if padded {
                    plo = plo.min(0.0);
                    phi = phi.max(0.0);
                }
                flo[oc] += plo;
                fhi[oc] += phi;
            }
        }
    }
    let float = SiRange::float(
        Tensor::new(&[1, o, 1, 1], flo)?,
        Tensor::new(&[1, o, 1, 1], fhi)?,
    )?;

    // Scaled-integer propagation.
    let (Some(ix), Some(iw)) = (&x.int, &w.int) else {
        return Ok(float);
    };
    if !iw.zero_bias() || &iw.lo != &iw.hi {
        return Ok(float);
    }
    // weight scale: scalar or per-output-channel (O,1,1,1)
    let sw_ok = iw.scale.numel() == 1 || (iw.scale.numel() == o && iw.scale.shape()[0] == o);
    if !sw_ok {
        return Ok(float);
    }
    // input scale: scalar for dense; scalar or per-channel for depthwise
    let sx_ok = ix.scalar_scale() || (depthwise && ix.scale.numel() == c_in);
    if !sx_ok {
        return Ok(float);
    }
    // padded convs require zero input bias (else output bias varies by position)
    if padded && !ix.bias.all_eq(0.0) {
        return Ok(float);
    }
    let qx_lo = per_channel(&ix.lo, c_in, true)?;
    let qx_hi = per_channel(&ix.hi, c_in, false)?;
    let q_w = &iw.lo;
    let mut qlo = vec![0.0; o];
    let mut qhi = vec![0.0; o];
    for oc in 0..o {
        for icc in 0..wi {
            let in_ch = if depthwise { oc } else { icc };
            let (xl, xh) = (ch_of(&qx_lo, in_ch), ch_of(&qx_hi, in_ch));
            for t in 0..kh * kw {
                let wv = q_w.data()[((oc * wi) + icc) * kh * kw + t];
                let (mut plo, mut phi) = interval_mul((xl, xh), (wv, wv));
                if padded {
                    plo = plo.min(0.0);
                    phi = phi.max(0.0);
                }
                qlo[oc] += plo;
                qhi[oc] += phi;
            }
        }
    }
    // output scale: s_X ⊙ s_W reshaped to (1,O,1,1)
    let s_w = if iw.scale.numel() == 1 {
        iw.scale.clone()
    } else {
        iw.scale.reshape(&[1, o, 1, 1])?
    };
    let s_x = if ix.scale.numel() == 1 {
        ix.scale.clone()
    } else {
        // depthwise: per-channel input scale aligns with output channels
        ix.scale.reshape(&[1, o, 1, 1])?
    };
    let s_y = s_x.mul(&s_w)?;
    // output bias: conv of broadcast input bias with the weights (pad == 0
    // guaranteed above when bias != 0)
    let bias = if ix.bias.all_eq(0.0) {
        Tensor::scalar(0.0)
    } else {
        let mut b = vec![0.0; o];
        for oc in 0..o {
            for icc in 0..wi {
                let in_ch = if depthwise { oc } else { icc };
                let bv = ch_of(&ix.bias.broadcast_to(&[1, c_in, 1, 1])?, in_ch);
                for t in 0..kh * kw {
                    b[oc] += bv * w_val.data()[((oc * wi) + icc) * kh * kw + t];
                }
            }
        }
        Tensor::new(&[1, o, 1, 1], b)?
    };
    let mut sc = ix.scale_contribs.clone();
    sc.extend(iw.scale_contribs.iter().cloned());
    let mut bc = ix.bias_contribs.clone();
    bc.extend(iw.bias_contribs.iter().cloned());
    SiRange::from_int(
        Tensor::new(&[1, o, 1, 1], qlo)?,
        Tensor::new(&[1, o, 1, 1], qhi)?,
        s_y,
        bias,
        sc,
        bc,
    )
}

/// BatchNormalization (pre-lowering): float range through the per-channel
/// affine transform. Integer components require lowering to Mul+Add first.
fn batchnorm(ins: &[&SiRange], eps: f64) -> Result<SiRange> {
    let x = &ins[0];
    let gamma = ins[1].point_value().context("BN: gamma must be constant")?;
    let beta = ins[2].point_value().context("BN: beta must be constant")?;
    let mean = ins[3].point_value().context("BN: mean must be constant")?;
    let var = ins[4].point_value().context("BN: var must be constant")?;
    let c = gamma.numel();
    let a = gamma.zip(var, |g, v| g / (v + eps).sqrt())?;
    let b = beta.zip(&mean.mul(&a)?, |bt, ma| bt - ma)?;
    // reshape per-channel params for NCHW broadcast
    let a4 = a.reshape(&[1, c, 1, 1])?;
    let b4 = b.reshape(&[1, c, 1, 1])?;
    let c1 = x.lo.mul(&a4)?.add(&b4)?;
    let c2 = x.hi.mul(&a4)?.add(&b4)?;
    SiRange::float(c1.minimum(&c2)?, c1.maximum(&c2)?)
}

/// MaxPool: per-channel reduced ranges are unchanged (the max of values
/// drawn from [lo,hi] stays in [lo,hi]); scaled-integer preserved when the
/// scale is positive (monotone affine per channel).
fn maxpool(ins: &[&SiRange]) -> Result<SiRange> {
    let x = ins[0];
    let mut out = x.clone();
    if let Some(ic) = &out.int {
        if ic.scale.data().iter().any(|&s| s <= 0.0) {
            out.int = None;
        }
    }
    Ok(out)
}

/// AveragePool / GlobalAveragePool: the average of values in [lo,hi] stays
/// in [lo,hi]. The op is a constant-weighted dot product, so the integer
/// component propagates as `q' = Σ q` with scale `s/K` (requires zero
/// padding to keep the window size constant).
fn avgpool(ins: &[&SiRange], window: usize, pad: (usize, usize)) -> Result<SiRange> {
    let x = ins[0];
    let mut out = x.clone();
    if pad != (0, 0) {
        out.int = None;
        return Ok(out);
    }
    if let Some(ic) = &x.int {
        let kf = window as f64;
        out.int = Some(IntComponent {
            lo: ic.lo.map(|v| v * kf),
            hi: ic.hi.map(|v| v * kf),
            scale: ic.scale.map(|s| s / kf),
            bias: ic.bias.clone(),
            scale_contribs: ic.scale_contribs.clone(),
            bias_contribs: ic.bias_contribs.clone(),
        });
        out.lo = x.lo.clone();
        out.hi = x.hi.clone();
    }
    Ok(out)
}

/// Pure data movement: ranges pass through unchanged when reduced to
/// scalar; per-channel ranges are expanded/reshaped to follow the data.
fn data_movement(g: &Graph, node: &Node, ins: &[&SiRange]) -> Result<SiRange> {
    let x = &ins[0];
    let in_shape = g
        .shapes
        .get(&node.inputs[0])
        .with_context(|| format!("no shape for {}", node.inputs[0]))?
        .clone();
    let out_shape = crate::graph::shapes::infer_node(&node.op, &[in_shape.clone()], &node.name)?
        .remove(0);

    let move_tensor = |t: &Tensor| -> Result<Tensor> {
        if t.numel() == 1 {
            return Ok(t.clone());
        }
        let full = t.broadcast_to(&in_shape)?;
        match &node.op {
            Op::Transpose { perm } => full.permute(perm),
            _ => full.reshape(&out_shape),
        }
    };
    let lo = move_tensor(&x.lo)?;
    let hi = move_tensor(&x.hi)?;
    let int = match &x.int {
        Some(ic) => Some(IntComponent {
            lo: move_tensor(&ic.lo)?,
            hi: move_tensor(&ic.hi)?,
            scale: move_tensor(&ic.scale)?,
            bias: move_tensor(&ic.bias)?,
            scale_contribs: ic.scale_contribs.clone(),
            bias_contribs: ic.bias_contribs.clone(),
        }),
        None => None,
    };
    Ok(SiRange { lo, hi, int })
}

/// Concat: concatenate per-channel ranges along the channel axis when all
/// inputs carry compatible integer components; otherwise fall back to the
/// scalar hull.
fn concat(ins: &[&SiRange], axis: usize) -> Result<SiRange> {
    // Attempt per-channel concat on rank-4 reduced shapes along axis 1.
    let rank4 = ins
        .iter()
        .all(|r| r.lo.rank() == 4 && r.lo.shape()[0] == 1 && r.lo.shape()[2] == 1 && r.lo.shape()[3] == 1);
    if axis == 1 && rank4 {
        let los: Vec<&Tensor> = ins.iter().map(|r| &r.lo).collect();
        let his: Vec<&Tensor> = ins.iter().map(|r| &r.hi).collect();
        let lo = Tensor::concat(&los, 1)?;
        let hi = Tensor::concat(&his, 1)?;
        if ins.iter().all(|r| r.int.is_some()) {
            let ics: Vec<&IntComponent> = ins.iter().map(|r| r.int.as_ref().unwrap()).collect();
            let bcast = |t: &Tensor, c: usize| t.broadcast_to(&[1, c, 1, 1]);
            let parts: Result<Vec<(Tensor, Tensor, Tensor, Tensor)>> = ics
                .iter()
                .zip(ins.iter())
                .map(|(ic, r)| {
                    let c = r.lo.shape()[1];
                    Ok((bcast(&ic.lo, c)?, bcast(&ic.hi, c)?, bcast(&ic.scale, c)?, bcast(&ic.bias, c)?))
                })
                .collect();
            if let Ok(parts) = parts {
                let qlo = Tensor::concat(&parts.iter().map(|p| &p.0).collect::<Vec<_>>(), 1)?;
                let qhi = Tensor::concat(&parts.iter().map(|p| &p.1).collect::<Vec<_>>(), 1)?;
                let s = Tensor::concat(&parts.iter().map(|p| &p.2).collect::<Vec<_>>(), 1)?;
                let b = Tensor::concat(&parts.iter().map(|p| &p.3).collect::<Vec<_>>(), 1)?;
                let mut sc = BTreeSet::new();
                let mut bc = BTreeSet::new();
                for ic in &ics {
                    sc.extend(ic.scale_contribs.iter().cloned());
                    bc.extend(ic.bias_contribs.iter().cloned());
                }
                return SiRange::from_int(qlo, qhi, s, b, sc, bc);
            }
        }
        return SiRange::float(lo, hi);
    }
    // Fallback: scalar hull.
    let lo = ins.iter().map(|r| r.lo.min()).fold(f64::INFINITY, f64::min);
    let hi = ins.iter().map(|r| r.hi.max()).fold(f64::NEG_INFINITY, f64::max);
    Ok(SiRange::scalar(lo, hi))
}

/// MultiThreshold: output = out_bias + out_scale * Σ_i (x >= Θ_i), counted
/// per channel. Counting is monotone, so the integer range is the count at
/// the range endpoints.
fn multithreshold(
    g: &Graph,
    node: &Node,
    ins: &[&SiRange],
    out_scale: f64,
    out_bias: f64,
) -> Result<SiRange> {
    let x = &ins[0];
    let th = ins[1]
        .point_value()
        .with_context(|| format!("MultiThreshold '{}': thresholds must be constant", node.name))?;
    if th.rank() != 2 {
        bail!("MultiThreshold '{}': thresholds must be (C, N)", node.name);
    }
    let (c, n) = (th.shape()[0], th.shape()[1]);
    let count = |v: f64, ch: usize| -> f64 {
        let row = &th.data()[ch * n..(ch + 1) * n];
        row.iter().filter(|&&t| v >= t).count() as f64
    };
    // per-channel input bounds
    let mut qlo = vec![0.0; c];
    let mut qhi = vec![0.0; c];
    for ch in 0..c {
        let (xl, xh) = if x.lo.numel() == 1 || c == 1 {
            // per-tensor thresholds: hull over all elements
            (x.lo.min(), x.hi.max())
        } else {
            let l = per_channel(&x.lo, c, true)?;
            let h = per_channel(&x.hi, c, false)?;
            (
                if l.numel() == 1 { l.data()[0] } else { l.data()[ch] },
                if h.numel() == 1 { h.data()[0] } else { h.data()[ch] },
            )
        };
        qlo[ch] = count(xl, ch);
        qhi[ch] = count(xh, ch);
    }
    // Reduced output shape: scalar for per-tensor thresholds, else a
    // channel vector matching the rank of the data tensor.
    let x_rank = g
        .shapes
        .get(&node.inputs[0])
        .map(|s| s.len())
        .unwrap_or(4);
    let shape: Vec<usize> = if c == 1 {
        vec![]
    } else if x_rank == 2 {
        vec![1, c]
    } else {
        vec![1, c, 1, 1]
    };
    if c == 1 {
        // collapse the per-channel vectors to scalars
        return SiRange::from_int(
            Tensor::scalar(qlo[0]),
            Tensor::scalar(qhi[0]),
            Tensor::scalar(out_scale),
            Tensor::scalar(out_bias),
            {
                let mut sc = BTreeSet::new();
                sc.insert(node.inputs[1].clone());
                sc
            },
            BTreeSet::new(),
        );
    }
    let mut sc = BTreeSet::new();
    sc.insert(node.inputs[1].clone());
    SiRange::from_int(
        Tensor::new(&shape, qlo)?,
        Tensor::new(&shape, qhi)?,
        Tensor::scalar(out_scale),
        Tensor::scalar(out_bias),
        sc,
        BTreeSet::new(),
    )
}
