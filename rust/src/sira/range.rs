//! The scaled-integer range representation (§3): a full-precision value
//! range, optionally carrying an underlying integer component with an
//! affine relationship `[lo, hi] = scale * [int_lo, int_hi] + bias`, plus
//! the contribution history of which graph tensors fed the scale and bias
//! (needed by the aggregation pass of §4.1.2).
//!
//! Range tensors are kept in *broadcast-reduced* shapes (e.g. `(1,C,1,1)`
//! for a per-channel range over an NCHW activation): any shape that
//! broadcasts to the annotated tensor shape is valid. This keeps the
//! analysis memory footprint proportional to channel counts, not to
//! activation volumes.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Integer component of a scaled-integer range.
#[derive(Clone, Debug, PartialEq)]
pub struct IntComponent {
    /// Elementwise minimum of the integer tensor (integral values).
    pub lo: Tensor,
    /// Elementwise maximum of the integer tensor (integral values).
    pub hi: Tensor,
    /// Scale `s_v` (constant, broadcastable to the tensor shape).
    pub scale: Tensor,
    /// Bias `b_v` (constant, broadcastable to the tensor shape).
    pub bias: Tensor,
    /// Names of graph tensors that contributed to the scale.
    pub scale_contribs: BTreeSet<String>,
    /// Names of graph tensors that contributed to the bias.
    pub bias_contribs: BTreeSet<String>,
}

impl IntComponent {
    /// True if the scale is a per-tensor scalar.
    pub fn scalar_scale(&self) -> bool {
        self.scale.numel() == 1
    }

    /// True if the bias is identically zero.
    pub fn zero_bias(&self) -> bool {
        self.bias.all_eq(0.0)
    }

    /// True if the scale is 1 and the bias 0 (a pure integer tensor).
    pub fn is_pure_integer(&self) -> bool {
        self.scale.all_eq(1.0) && self.zero_bias()
    }

    /// Widest integer magnitude (for accumulator sizing).
    pub fn int_bounds(&self) -> (i64, i64) {
        (self.lo.min() as i64, self.hi.max() as i64)
    }
}

/// Scaled-integer range for one tensor (the paper's `ScaledIntRange`).
#[derive(Clone, Debug, PartialEq)]
pub struct SiRange {
    /// Elementwise full-precision minimum (broadcast-reduced shape).
    pub lo: Tensor,
    /// Elementwise full-precision maximum (broadcast-reduced shape).
    pub hi: Tensor,
    /// Optional underlying integer component.
    pub int: Option<IntComponent>,
}

impl SiRange {
    /// A plain float range with no integer component.
    pub fn float(lo: Tensor, hi: Tensor) -> Result<SiRange> {
        for (&l, &h) in lo.data().iter().zip(hi.data()) {
            if l > h {
                bail!("range lo {l} > hi {h}");
            }
        }
        if lo.shape() != hi.shape() {
            bail!("range lo/hi shape mismatch: {:?} vs {:?}", lo.shape(), hi.shape());
        }
        Ok(SiRange { lo, hi, int: None })
    }

    /// Scalar float range.
    pub fn scalar(lo: f64, hi: f64) -> SiRange {
        SiRange::float(Tensor::scalar(lo), Tensor::scalar(hi)).unwrap()
    }

    /// Point range of a constant tensor. Integral constants additionally
    /// get a unit-scale integer component (scale 1, bias 0).
    pub fn point(v: &Tensor) -> SiRange {
        let int = if v.is_integral() {
            Some(IntComponent {
                lo: v.clone(),
                hi: v.clone(),
                scale: Tensor::scalar(1.0),
                bias: Tensor::scalar(0.0),
                scale_contribs: BTreeSet::new(),
                bias_contribs: BTreeSet::new(),
            })
        } else {
            None
        };
        SiRange {
            lo: v.clone(),
            hi: v.clone(),
            int,
        }
    }

    /// Build a scaled-integer range from its integer component, deriving
    /// the full-precision range as the elementwise hull of
    /// `scale*int_lo+bias` and `scale*int_hi+bias` (handles negative
    /// scales produced by multiplication with negative constants).
    pub fn from_int(
        int_lo: Tensor,
        int_hi: Tensor,
        scale: Tensor,
        bias: Tensor,
        scale_contribs: BTreeSet<String>,
        bias_contribs: BTreeSet<String>,
    ) -> Result<SiRange> {
        debug_assert!(int_lo.is_integral(), "int_lo not integral");
        debug_assert!(int_hi.is_integral(), "int_hi not integral");
        let a = int_lo.mul(&scale)?.add(&bias)?;
        // point component (constant tensors): skip the duplicate pass
        let (lo, hi) = if int_lo == int_hi {
            (a.clone(), a)
        } else {
            let b = int_hi.mul(&scale)?.add(&bias)?;
            (a.minimum(&b)?, a.maximum(&b)?)
        };
        Ok(SiRange {
            lo,
            hi,
            int: Some(IntComponent {
                lo: int_lo,
                hi: int_hi,
                scale,
                bias,
                scale_contribs,
                bias_contribs,
            }),
        })
    }

    /// True if lo == hi everywhere (a constant tensor / stuck value).
    pub fn is_point(&self) -> bool {
        self.lo == self.hi
    }

    /// The constant value, if this is a point range.
    pub fn point_value(&self) -> Option<&Tensor> {
        if self.is_point() {
            Some(&self.lo)
        } else {
            None
        }
    }

    /// Drop the integer component, keeping only the float range.
    pub fn float_only(&self) -> SiRange {
        SiRange {
            lo: self.lo.clone(),
            hi: self.hi.clone(),
            int: None,
        }
    }

    /// Scalar overall bounds.
    pub fn bounds(&self) -> (f64, f64) {
        (self.lo.min(), self.hi.max())
    }

    /// Check the affine invariant `hull(s*ql+b, s*qh+b) == [lo, hi]`
    /// (used by tests and the analysis self-check).
    pub fn check_invariant(&self) -> Result<()> {
        if let Some(ic) = &self.int {
            let a = ic.lo.mul(&ic.scale)?.add(&ic.bias)?;
            let b = ic.hi.mul(&ic.scale)?.add(&ic.bias)?;
            let lo = a.minimum(&b)?;
            let hi = a.maximum(&b)?;
            let lo = lo.broadcast_to(self.lo.shape()).unwrap_or(lo);
            let hi = hi.broadcast_to(self.hi.shape()).unwrap_or(hi);
            for (x, y) in lo.data().iter().zip(self.lo.data()) {
                if (x - y).abs() > 1e-9 * (1.0 + x.abs()) {
                    bail!("int/float lo mismatch: {x} vs {y}");
                }
            }
            for (x, y) in hi.data().iter().zip(self.hi.data()) {
                if (x - y).abs() > 1e-9 * (1.0 + x.abs()) {
                    bail!("int/float hi mismatch: {x} vs {y}");
                }
            }
            if !ic.lo.is_integral() || !ic.hi.is_integral() {
                bail!("integer component not integral");
            }
        }
        Ok(())
    }

    /// Does every value of `other` (an observed empirical range) fall
    /// within this analyzed range? (soundness check, Fig 20).
    pub fn contains_range(&self, obs_lo: &Tensor, obs_hi: &Tensor) -> Result<bool> {
        let lo_ok = self
            .lo
            .zip(obs_lo, |a, o| if o + 1e-9 >= a - 1e-9 * a.abs() { 1.0 } else { 0.0 })?;
        let hi_ok = self
            .hi
            .zip(obs_hi, |a, o| if o - 1e-9 <= a + 1e-9 * a.abs() { 1.0 } else { 0.0 })?;
        Ok(lo_ok.all_eq(1.0) && hi_ok.all_eq(1.0))
    }
}

/// Scalar interval multiplication: hull of the four corner products.
pub fn interval_mul(a: (f64, f64), b: (f64, f64)) -> (f64, f64) {
    let c = [a.0 * b.0, a.0 * b.1, a.1 * b.0, a.1 * b.1];
    (
        c.iter().cloned().fold(f64::INFINITY, f64::min),
        c.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_of_integral_constant_is_scaled_int() {
        let r = SiRange::point(&Tensor::from_vec(vec![1.0, -3.0]));
        assert!(r.int.is_some());
        assert!(r.int.as_ref().unwrap().is_pure_integer());
        assert!(r.is_point());
        r.check_invariant().unwrap();
    }

    #[test]
    fn point_of_float_constant_is_not() {
        let r = SiRange::point(&Tensor::from_vec(vec![0.5]));
        assert!(r.int.is_none());
    }

    #[test]
    fn from_int_negative_scale_orders_bounds() {
        // scale -2: int [1, 3] -> values [-6, -2]
        let r = SiRange::from_int(
            Tensor::scalar(1.0),
            Tensor::scalar(3.0),
            Tensor::scalar(-2.0),
            Tensor::scalar(0.0),
            BTreeSet::new(),
            BTreeSet::new(),
        )
        .unwrap();
        assert_eq!(r.bounds(), (-6.0, -2.0));
        r.check_invariant().unwrap();
    }

    #[test]
    fn invalid_range_rejected() {
        assert!(SiRange::float(Tensor::scalar(2.0), Tensor::scalar(1.0)).is_err());
    }

    #[test]
    fn interval_mul_corners() {
        assert_eq!(interval_mul((-2.0, 3.0), (-1.0, 4.0)), (-8.0, 12.0));
        assert_eq!(interval_mul((1.0, 2.0), (3.0, 4.0)), (3.0, 8.0));
        assert_eq!(interval_mul((-2.0, -1.0), (-4.0, -3.0)), (3.0, 8.0));
    }

    #[test]
    fn containment() {
        let r = SiRange::scalar(-5.0, 5.0);
        assert!(r
            .contains_range(&Tensor::scalar(-4.0), &Tensor::scalar(5.0))
            .unwrap());
        assert!(!r
            .contains_range(&Tensor::scalar(-6.0), &Tensor::scalar(0.0))
            .unwrap());
    }

    #[test]
    fn per_channel_range_invariant() {
        let r = SiRange::from_int(
            Tensor::new(&[1, 2, 1, 1], vec![-7.0, -3.0]).unwrap(),
            Tensor::new(&[1, 2, 1, 1], vec![5.0, 6.0]).unwrap(),
            Tensor::new(&[1, 2, 1, 1], vec![0.1, 0.2]).unwrap(),
            Tensor::scalar(0.0),
            BTreeSet::new(),
            BTreeSet::new(),
        )
        .unwrap();
        r.check_invariant().unwrap();
        let (lo, hi) = r.bounds();
        assert!((lo + 0.7).abs() < 1e-12 && (hi - 1.2).abs() < 1e-12);
    }
}
