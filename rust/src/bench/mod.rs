//! In-repo micro-benchmark harness (criterion is unavailable offline).
//! Provides warmup + repeated measurement with mean/stddev reporting and
//! a simple ops/sec view. All `cargo bench` targets use `harness = false`
//! and drive this module, printing the paper-table reproductions alongside
//! the timing numbers.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} ± {:>10}  (n={}, min {:?}, max {:?})",
            self.name,
            format!("{:?}", self.mean),
            format!("{:?}", self.stddev),
            self.iters,
            self.min,
            self.max
        )
    }
}

/// Benchmark runner with configurable warmup and measurement budgets.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            max_iters: 1_000,
        }
    }

    /// Run `f` repeatedly, returning timing statistics. The closure's
    /// return value is passed through `std::hint::black_box` to keep the
    /// optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup
        let start = Instant::now();
        let mut warm_iters = 0usize;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Measure
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = stats::mean(&samples);
        let sd = stats::stddev(&samples);
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(0.0, f64::max);
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(sd),
            min: Duration::from_secs_f64(min),
            max: Duration::from_secs_f64(max),
        }
    }
}

/// Print a section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_iters: 100,
        };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 1);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.mean && r.mean <= r.max + Duration::from_nanos(1));
    }
}
