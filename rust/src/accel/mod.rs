//! FDNA builder (the FINN backend, §5.1): maps a streamlined QNN graph
//! onto hardware kernel instances, solves the folding configuration for a
//! target throughput (§6.2.2), inserts FIFOs and width converters, and
//! aggregates resources with the MAC / non-MAC breakdown of Fig 21.

use anyhow::{bail, Context, Result};

use crate::dataflow::{fifo_depths, simulate, PipelineReport};
use crate::graph::{DataType, Graph, Op};
use crate::hw::{
    Dwc, ElementwiseKernel, EwDtype, EwOp, Fifo, KernelCategory, KernelInstance, Mvu,
    PoolKernel, SlidingWindow, Thresholding, ThresholdStyle, MAX_STREAM_BITS,
};
use crate::passes::accmin::{minimize_accumulators, AccPolicy, AccReport};
use crate::passes::thresholds::{convert_to_thresholds, ThresholdReport};
use crate::passes::{fold, lower, streamline};
use crate::sira::{analyze, Analysis, SiRange};
use crate::synth::{MemStyle, Resources, Synth};
use crate::util::bits_for_range;

/// Layer-tail implementation mode (Fig 14).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TailStyle {
    /// option 1: elementwise meta-kernels with the given arithmetic dtype
    Composite(EwDtype),
    /// option 2: threshold conversion + RTL thresholding kernel
    Thresholding(ThresholdStyle),
}

/// Full compile configuration.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub tail_style: TailStyle,
    pub acc_policy: AccPolicy,
    /// target cycles per frame for the folding solver (lower = more
    /// parallel = more resources)
    pub target_cycles: u64,
    pub freq_hz: f64,
    pub mem_style: MemStyle,
    /// force LUT-only arithmetic in layer tails (microbenchmark mode)
    pub force_lut_tails: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            tail_style: TailStyle::Thresholding(ThresholdStyle::BinarySearch),
            acc_policy: AccPolicy::Sira,
            target_cycles: 1 << 16,
            freq_hz: 200e6,
            mem_style: MemStyle::Auto,
            force_lut_tails: false,
        }
    }
}

/// A compiled FDNA.
pub struct Fdna {
    pub kernels: Vec<KernelInstance>,
    pub perf: PipelineReport,
    pub total: Resources,
    pub mac: Resources,
    pub non_mac: Resources,
}

/// Result of the full frontend+backend compile.
pub struct CompiledAccel {
    pub graph: Graph,
    pub analysis: Analysis,
    pub acc_report: AccReport,
    pub thr_report: Option<ThresholdReport>,
    pub fdna: Fdna,
}

/// Bits carried by a tensor: datatype annotation first, then the SIRA
/// integer range, then a conservative float default.
fn tensor_bits(g: &Graph, analysis: &Analysis, name: &str, default: u32) -> u32 {
    if let Some(dt) = g.dtypes.get(name) {
        return dt.bits();
    }
    if let Ok(r) = analysis.get(name) {
        if let Some(ic) = &r.int {
            let (lo, hi) = ic.int_bounds();
            return bits_for_range(lo, hi);
        }
    }
    default
}

/// Smallest divisor `d` of `n` with `n/d <= limit` (folding helper).
fn divisor_for(n: usize, limit: u64) -> usize {
    if n == 0 {
        return 1;
    }
    for d in 1..=n {
        if n % d == 0 && (n / d) as u64 <= limit {
            return d;
        }
    }
    n
}

/// Largest divisor of `n` that is <= `pe` and keeps the stream width
/// `pe * bits` within the 8192-bit ap_int limit (§6.2.2: "the output of
/// an individual layer cannot be wider than this limit, thus limiting
/// the available parallelism").
fn clamp_pe(n: usize, pe: usize, bits: u32) -> usize {
    let max_pe = (MAX_STREAM_BITS / bits.max(1) as u64).max(1) as usize;
    let mut best = 1;
    for d in 1..=n.max(1) {
        if n.max(1) % d == 0 && d <= pe && d <= max_pe {
            best = d;
        }
    }
    best
}

/// Folding for elementwise-style kernels: channels processed PE at a
/// time over `elems` total elements; pick the smallest PE meeting the
/// cycle target, clamped by the stream-width limit.
fn ew_pe(channels: usize, elems: usize, tc: u64, bits: u32) -> usize {
    let spatial = (elems / channels.max(1)).max(1) as u64;
    let limit = (tc / spatial).max(1);
    let pe = divisor_for(channels.max(1), limit);
    clamp_pe(channels.max(1), pe, bits)
}

/// Frontend: lower → fold → extract scales → streamline (§4.1.2)
/// [→ threshold conversion (§4.1.3)] → SIRA → accumulator minimization.
pub fn frontend(
    g: &mut Graph,
    input_ranges: &std::collections::BTreeMap<String, SiRange>,
    opts: &CompileOptions,
) -> Result<(Analysis, AccReport, Option<ThresholdReport>)> {
    lower::lower_all(g)?;
    fold::fold_constants(g, false)?;
    streamline::extract_quant_scales(g)?;
    fold::duplicate_shared_initializers(g)?;
    streamline::streamline(g)?;
    let thr_report = if matches!(opts.tail_style, TailStyle::Thresholding(_)) {
        Some(convert_to_thresholds(g, input_ranges)?)
    } else {
        None
    };
    let analysis = analyze(g, input_ranges)?;
    let acc_report = minimize_accumulators(g, &analysis, opts.acc_policy)?;
    // annotate remaining pure-integer tensors
    for (name, r) in &analysis.ranges {
        if g.dtypes.contains_key(name) {
            continue;
        }
        if let Some(ic) = &r.int {
            if ic.is_pure_integer() {
                let (lo, hi) = ic.int_bounds();
                g.dtypes.insert(name.clone(), DataType::for_range(lo, hi));
            }
        }
    }
    Ok((analysis, acc_report, thr_report))
}

/// Backend: map graph nodes to kernel instances and fold for throughput.
pub fn backend(g: &Graph, analysis: &Analysis, opts: &CompileOptions) -> Result<Fdna> {
    let mut kernels: Vec<KernelInstance> = Vec::new();
    let tc = opts.target_cycles;
    let tail_dtype = match opts.tail_style {
        TailStyle::Composite(dt) => dt,
        TailStyle::Thresholding(_) => EwDtype::Float32, // residual non-converted ops
    };
    let frame_elems = |shape: &[usize]| -> usize { shape.iter().product() };

    for node in g.topo_nodes()? {
        let out = node.output();
        let out_shape = g.shapes[out].clone();
        match &node.op {
            Op::MatMul => {
                let (k, m) = (g.shapes[&node.inputs[1]][0], g.shapes[&node.inputs[1]][1]);
                let abits = tensor_bits(g, analysis, &node.inputs[0], 8);
                let wbits = tensor_bits(g, analysis, &node.inputs[1], 8);
                let acc_bits = tensor_bits(g, analysis, out, 32);
                let vectors = out_shape[..out_shape.len() - 1].iter().product::<usize>();
                let per_vec = tc / vectors.max(1) as u64;
                // fold: choose pe then simd, clamped by stream widths
                let pe = clamp_pe(m, divisor_for(m, per_vec.max(1)), acc_bits);
                let simd = clamp_pe(
                    k,
                    divisor_for(k, (per_vec.max(1) / (m / pe) as u64).max(1)),
                    abits,
                );
                kernels.push(KernelInstance {
                    kernel: Box::new(Mvu {
                        name: format!("MVU_{}", node.name),
                        mh: m,
                        mw: k,
                        pe,
                        simd,
                        wbits,
                        abits,
                        acc_bits,
                        vectors_per_frame: vectors,
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::Conv { spec, group } => {
                let in_shape = g.shapes[&node.inputs[0]].clone();
                let w_shape = g.shapes[&node.inputs[1]].clone();
                let abits = tensor_bits(g, analysis, &node.inputs[0], 8);
                let wbits = tensor_bits(g, analysis, &node.inputs[1], 8);
                let acc_bits = tensor_bits(g, analysis, out, 32);
                let depthwise = *group > 1;
                let (oh, ow) = (out_shape[2], out_shape[3]);
                let vectors = oh * ow;
                let (mh, mw) = if depthwise {
                    (w_shape[0], spec.kernel.0 * spec.kernel.1)
                } else {
                    (w_shape[0], w_shape[1] * spec.kernel.0 * spec.kernel.1)
                };
                let per_vec = (tc / vectors.max(1) as u64).max(1);
                let pe = clamp_pe(mh, divisor_for(mh, per_vec), acc_bits);
                let simd = clamp_pe(
                    mw,
                    divisor_for(mw, (per_vec / (mh / pe) as u64).max(1)),
                    abits,
                );
                kernels.push(KernelInstance {
                    kernel: Box::new(SlidingWindow {
                        name: format!("SWU_{}", node.name),
                        channels: in_shape[1],
                        kernel: spec.kernel.0,
                        ifm_dim: in_shape[2],
                        ofm_dim: oh,
                        stride: spec.stride.0,
                        in_bits: abits,
                        simd: if depthwise { pe } else { simd },
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
                kernels.push(KernelInstance {
                    kernel: Box::new(Mvu {
                        name: format!("MVU_{}", node.name),
                        mh,
                        mw,
                        pe,
                        simd,
                        wbits,
                        abits,
                        acc_bits,
                        vectors_per_frame: vectors,
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::MultiThreshold { .. } => {
                let th = g
                    .initializer(&node.inputs[1])
                    .context("thresholds must be initializers")?;
                let (c, n) = (th.shape()[0], th.shape()[1]);
                let in_bits = tensor_bits(g, analysis, &node.inputs[0], 24);
                let out_bits = crate::util::ceil_log2(n as u64 + 1).max(1);
                let elems = frame_elems(&out_shape);
                let data_ch = if out_shape.len() >= 2 { out_shape[1] } else { 1 };
                let pe = ew_pe(data_ch, elems, tc, in_bits);
                let style = match opts.tail_style {
                    TailStyle::Thresholding(s) => s,
                    _ => ThresholdStyle::BinarySearch,
                };
                // threshold compression (paper §9): channels with
                // identical threshold vectors share one memory row
                let unique_rows = {
                    let mut rows: std::collections::BTreeSet<Vec<u64>> = Default::default();
                    for ch in 0..c {
                        let row: Vec<u64> = th.data()[ch * n..(ch + 1) * n]
                            .iter()
                            .map(|v| v.to_bits())
                            .collect();
                        rows.insert(row);
                    }
                    rows.len()
                };
                kernels.push(KernelInstance {
                    kernel: Box::new(Thresholding {
                        name: format!("THR_{}", node.name),
                        channels: c,
                        unique_rows,
                        elems_per_frame: elems,
                        in_bits,
                        out_bits,
                        pe,
                        style,
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::Mul | Op::Add | Op::Div | Op::Sub => {
                let elems = frame_elems(&out_shape);
                let in_bits = tensor_bits(g, analysis, &node.inputs[0], 24);
                // parameter side (const) or second stream (residual add)
                let (param_bits, per_channel, channels) = match node
                    .inputs
                    .get(1)
                    .filter(|i| g.is_initializer(i))
                {
                    Some(p) => {
                        let t = &g.initializers[p.as_str()];
                        let bits = if t.is_integral() {
                            let (lo, hi) = (t.min() as i64, t.max() as i64);
                            bits_for_range(lo.min(0), hi.max(1))
                        } else {
                            tail_dtype.bits()
                        };
                        (bits, t.numel() > 1, t.numel())
                    }
                    None => (in_bits, false, 1),
                };
                let dtype = match g.dtypes.get(out) {
                    Some(dt) if dt.is_integer() => EwDtype::Int(dt.bits()),
                    _ => tail_dtype,
                };
                let op = match node.op {
                    Op::Mul | Op::Div => EwOp::Mul,
                    _ => EwOp::Add,
                };
                let data_ch = out_shape.get(1).copied().unwrap_or(1);
                let pe = ew_pe(data_ch, elems, tc, in_bits.max(dtype.bits()));
                kernels.push(KernelInstance {
                    kernel: Box::new(ElementwiseKernel {
                        name: format!("EW_{}", node.name),
                        op,
                        in_bits,
                        param_bits,
                        out_bits: tensor_bits(g, analysis, out, in_bits + param_bits),
                        dtype,
                        channels,
                        per_channel,
                        elems_per_frame: elems,
                        pe,
                        force_lut: opts.force_lut_tails,
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::Relu | Op::Clip { .. } => {
                let elems = frame_elems(&out_shape);
                let in_bits = tensor_bits(g, analysis, &node.inputs[0], 24);
                let data_ch = out_shape.get(1).copied().unwrap_or(1);
                let pe = ew_pe(data_ch, elems, tc, in_bits);
                kernels.push(KernelInstance {
                    kernel: Box::new(ElementwiseKernel {
                        name: format!("EW_{}", node.name),
                        op: EwOp::Max,
                        in_bits,
                        param_bits: 0,
                        out_bits: in_bits,
                        dtype: match g.dtypes.get(&node.inputs[0]) {
                            Some(dt) if dt.is_integer() => EwDtype::Int(in_bits),
                            _ => tail_dtype,
                        },
                        channels: 1,
                        per_channel: false,
                        elems_per_frame: elems,
                        pe,
                        force_lut: opts.force_lut_tails,
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::Quant { .. } => {
                // post-streamlining unit quantizer = ToInt conversion
                let elems = frame_elems(&out_shape);
                let in_bits = tensor_bits(g, analysis, &node.inputs[0], 24);
                let out_bits = tensor_bits(g, analysis, out, 8);
                let data_ch = out_shape.get(1).copied().unwrap_or(1);
                let pe = ew_pe(data_ch, elems, tc, in_bits.max(tail_dtype.bits()));
                kernels.push(KernelInstance {
                    kernel: Box::new(ElementwiseKernel {
                        name: format!("EW_{}", node.name),
                        op: EwOp::ToInt,
                        in_bits: in_bits.max(tail_dtype.bits()),
                        param_bits: 0,
                        out_bits,
                        dtype: tail_dtype,
                        channels: 1,
                        per_channel: false,
                        elems_per_frame: elems,
                        pe,
                        force_lut: opts.force_lut_tails,
                        mem_style: opts.mem_style,
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::MaxPool { spec } | Op::AveragePool { spec } => {
                let in_shape = g.shapes[&node.inputs[0]].clone();
                let in_bits = tensor_bits(g, analysis, &node.inputs[0], 8);
                let windows = (out_shape[2] * out_shape[3] * spec.kernel.0 * spec.kernel.1)
                    .max(1) as u64;
                let pe = ew_pe(in_shape[1], in_shape[1] * windows as usize, tc, in_bits);
                kernels.push(KernelInstance {
                    kernel: Box::new(PoolKernel {
                        name: format!("POOL_{}", node.name),
                        channels: in_shape[1],
                        kernel: spec.kernel.0,
                        ifm_dim: in_shape[2],
                        in_bits,
                        pe,
                        is_max: matches!(node.op, Op::MaxPool { .. }),
                    }),
                    source_node: node.name.clone(),
                });
            }
            Op::GlobalAveragePool => {
                let in_shape = g.shapes[&node.inputs[0]].clone();
                let in_bits = tensor_bits(g, analysis, &node.inputs[0], 8);
                kernels.push(KernelInstance {
                    kernel: Box::new(PoolKernel {
                        name: format!("GAP_{}", node.name),
                        channels: in_shape[1],
                        kernel: in_shape[2],
                        ifm_dim: in_shape[2],
                        in_bits,
                        pe: ew_pe(
                            in_shape[1],
                            in_shape[1] * in_shape[2] * in_shape[3],
                            tc,
                            in_bits,
                        ),
                        is_max: false,
                    }),
                    source_node: node.name.clone(),
                });
            }
            // pure data movement: no hardware
            Op::Reshape { .. } | Op::Flatten { .. } | Op::Transpose { .. } | Op::Identity => {}
            Op::Concat { .. } => {
                // stream merger: modeled as a width-matched mux
                kernels.push(KernelInstance {
                    kernel: Box::new(Dwc {
                        name: format!("CAT_{}", node.name),
                        in_bits: 64,
                        out_bits: 64,
                    }),
                    source_node: node.name.clone(),
                });
            }
            other => bail!("backend: unmapped op {} in node '{}'", other.name(), node.name),
        }
    }
    if kernels.is_empty() {
        bail!("backend produced no kernels");
    }

    // insert DWCs on width mismatches, then FIFOs sized by rate mismatch
    let mut staged: Vec<KernelInstance> = Vec::new();
    for ki in kernels {
        if let Some(prev) = staged.last() {
            let (_, w_out) = prev.kernel.stream_widths();
            let (w_in, _) = ki.kernel.stream_widths();
            if w_out != w_in && w_out > 0 && w_in > 0 {
                staged.push(KernelInstance {
                    kernel: Box::new(Dwc {
                        name: format!("DWC_{}", ki.kernel.name()),
                        in_bits: w_out.min(MAX_STREAM_BITS),
                        out_bits: w_in.min(MAX_STREAM_BITS),
                    }),
                    source_node: ki.source_node.clone(),
                });
            }
        }
        staged.push(ki);
    }
    let depths = fifo_depths(&staged);
    let mut with_fifos: Vec<KernelInstance> = Vec::new();
    for (ki, depth) in staged.into_iter().zip(depths) {
        let (_, w_out) = ki.kernel.stream_widths();
        let fifo_name = format!("FIFO_{}", ki.kernel.name());
        let src = ki.source_node.clone();
        with_fifos.push(ki);
        with_fifos.push(KernelInstance {
            kernel: Box::new(Fifo {
                name: fifo_name,
                width_bits: w_out.min(MAX_STREAM_BITS),
                depth,
            }),
            source_node: src,
        });
    }

    let perf = simulate(&with_fifos, opts.freq_hz)?;
    // resource aggregation (average of three seeded synthesis runs, as in
    // the paper's methodology §6.3)
    let mut total = Resources::default();
    let mut mac = Resources::default();
    let mut non_mac = Resources::default();
    for ki in &with_fifos {
        let mut r = Resources::default();
        for seed in 1..=3u64 {
            r += ki.kernel.resources(&Synth::with_seed(seed));
        }
        let r = r * (1.0 / 3.0);
        total += r;
        match ki.kernel.category() {
            KernelCategory::Mac => mac += r,
            KernelCategory::NonMac => non_mac += r,
        }
    }
    Ok(Fdna {
        kernels: with_fifos,
        perf,
        total: total.round(),
        mac: mac.round(),
        non_mac: non_mac.round(),
    })
}

/// Full compile: frontend + backend.
pub fn compile_qnn(
    mut graph: Graph,
    input_ranges: &std::collections::BTreeMap<String, SiRange>,
    opts: &CompileOptions,
) -> Result<CompiledAccel> {
    let (analysis, acc_report, thr_report) = frontend(&mut graph, input_ranges, opts)?;
    let fdna = backend(&graph, &analysis, opts)?;
    Ok(CompiledAccel {
        graph,
        analysis,
        acc_report,
        thr_report,
        fdna,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn opts(tail: TailStyle, acc: AccPolicy) -> CompileOptions {
        CompileOptions {
            tail_style: tail,
            acc_policy: acc,
            target_cycles: 1 << 14,
            ..Default::default()
        }
    }

    #[test]
    fn compiles_tfc_with_thresholds() {
        let m = models::tfc_w2a2().unwrap();
        let c = compile_qnn(
            m.graph,
            &m.input_ranges,
            &opts(
                TailStyle::Thresholding(ThresholdStyle::BinarySearch),
                AccPolicy::Sira,
            ),
        )
        .unwrap();
        assert!(c.thr_report.as_ref().unwrap().converted >= 4);
        assert!(c.fdna.total.lut > 0.0);
        assert!(c.fdna.perf.fps > 0.0);
        // MAC and non-MAC resources both present
        assert!(c.fdna.mac.lut > 0.0);
        assert!(c.fdna.non_mac.lut > 0.0);
    }

    #[test]
    fn compiles_tfc_composite() {
        let m = models::tfc_w2a2().unwrap();
        let c = compile_qnn(
            m.graph,
            &m.input_ranges,
            &opts(
                TailStyle::Composite(EwDtype::Fixed(16, 8)),
                AccPolicy::Datatype,
            ),
        )
        .unwrap();
        assert!(c.thr_report.is_none());
        assert!(c.fdna.total.lut > 0.0);
    }

    #[test]
    fn sira_accumulators_do_not_exceed_datatype_bound() {
        let m = models::tfc_w2a2().unwrap();
        let c = compile_qnn(
            m.graph,
            &m.input_ranges,
            &opts(
                TailStyle::Thresholding(ThresholdStyle::BinarySearch),
                AccPolicy::Sira,
            ),
        )
        .unwrap();
        for row in &c.acc_report.rows {
            assert!(
                row.bits_sira <= row.bits_datatype,
                "{}: sira {} > datatype {}",
                row.node,
                row.bits_sira,
                row.bits_datatype
            );
        }
    }

    #[test]
    fn sira_opts_reduce_resources_vs_baseline() {
        let baseline = {
            let m = models::tfc_w2a2().unwrap();
            compile_qnn(
                m.graph,
                &m.input_ranges,
                &opts(
                    TailStyle::Composite(EwDtype::Fixed(32, 16)),
                    AccPolicy::Datatype,
                ),
            )
            .unwrap()
        };
        let optimized = {
            let m = models::tfc_w2a2().unwrap();
            compile_qnn(
                m.graph,
                &m.input_ranges,
                &opts(
                    TailStyle::Thresholding(ThresholdStyle::BinarySearch),
                    AccPolicy::Sira,
                ),
            )
            .unwrap()
        };
        assert!(
            optimized.fdna.total.lut < baseline.fdna.total.lut,
            "optimized {} vs baseline {}",
            optimized.fdna.total.lut,
            baseline.fdna.total.lut
        );
        // throughput unchanged by the optimizations (§7.2)
        let r = optimized.fdna.perf.fps / baseline.fdna.perf.fps;
        assert!(r > 0.8, "fps ratio {r}");
    }

    #[test]
    fn folding_divisor_helper() {
        assert_eq!(divisor_for(64, 64), 1);
        assert_eq!(divisor_for(64, 16), 4);
        assert_eq!(divisor_for(64, 1), 64);
        assert_eq!(divisor_for(10, 3), 5); // divisors of 10: need 10/d<=3 -> d=5
    }
}
