//! sira-finn CLI: analyze, compile, and serve quantized neural networks
//! with the SIRA-enhanced FDNA compiler.
//!
//! ```text
//! sira-finn analyze --model tfc|cnv|rn8|mnv1
//! sira-finn compile --model tfc --tail thresholding|composite \
//!                   --acc sira|datatype|32 --target-cycles 16384
//! sira-finn serve   --model tfc --workers 4 --requests 256 \
//!                   [--engine [--streamline] --threads N --pipeline N]
//! sira-finn e2e     [--artifacts artifacts]
//! ```

use anyhow::{bail, Result};

use sira_finn::accel::{compile_qnn, CompileOptions, TailStyle};
use sira_finn::coordinator::{BatchPolicy, Coordinator};
use sira_finn::engine;
use sira_finn::executor::Executor;
use sira_finn::hw::{EwDtype, ThresholdStyle};
use sira_finn::models::{self, ZooModel};
use sira_finn::passes::accmin::AccPolicy;
use sira_finn::sira::analyze;
use sira_finn::tensor::Tensor;
use sira_finn::util::cli::Args;
use sira_finn::util::table::Table;

fn zoo_model(name: &str) -> Result<ZooModel> {
    match name {
        "tfc" => models::tfc_w2a2(),
        "cnv" => models::cnv_w2a2(),
        "rn8" => models::rn8_w3a3(),
        "mnv1" => models::mnv1_w4a4_scaled(4),
        "mnv1-full" => models::mnv1_w4a4(),
        other => bail!("unknown model '{other}' (tfc|cnv|rn8|mnv1|mnv1-full)"),
    }
}

fn parse_opts(args: &Args) -> Result<CompileOptions> {
    let tail = match args.get_or("tail", "thresholding") {
        "thresholding" | "thr" => TailStyle::Thresholding(ThresholdStyle::BinarySearch),
        "thresholding-parallel" => TailStyle::Thresholding(ThresholdStyle::Parallel),
        "composite" | "fix" => TailStyle::Composite(EwDtype::Fixed(16, 8)),
        "composite-fix32" => TailStyle::Composite(EwDtype::Fixed(32, 16)),
        "composite-float" => TailStyle::Composite(EwDtype::Float32),
        other => bail!("unknown tail style '{other}'"),
    };
    let acc = match args.get_or("acc", "sira") {
        "sira" => AccPolicy::Sira,
        "datatype" => AccPolicy::Datatype,
        "32" => AccPolicy::Bound32,
        other => bail!("unknown acc policy '{other}'"),
    };
    Ok(CompileOptions {
        tail_style: tail,
        acc_policy: acc,
        target_cycles: args.get_u64("target-cycles", 1 << 16)?,
        freq_hz: args.get_f64("freq", 200e6)?,
        ..Default::default()
    })
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let m = zoo_model(args.get_or("model", "tfc"))?;
    let a = analyze(&m.graph, &m.input_ranges)?;
    let mut t = Table::new(&["Tensor", "lo", "hi", "int?", "scale", "bits"]);
    for node in m.graph.topo_nodes()? {
        let out = node.output();
        let r = a.get(out)?;
        let (lo, hi) = r.bounds();
        let (is_int, scale, bits) = match &r.int {
            Some(ic) => {
                let (l, h) = ic.int_bounds();
                (
                    if ic.is_pure_integer() { "pure" } else { "scaled" },
                    format!("{:.4}", ic.scale.data()[0]),
                    format!("{}", sira_finn::util::bits_for_range(l, h)),
                )
            }
            None => ("-", "-".into(), "-".into()),
        };
        t.row(vec![
            format!("{} ({})", out, node.op.name()),
            format!("{lo:.3}"),
            format!("{hi:.3}"),
            is_int.to_string(),
            scale,
            bits,
        ]);
    }
    println!("SIRA analysis of {}:\n{}", m.name, t.render());
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let m = zoo_model(args.get_or("model", "tfc"))?;
    let opts = parse_opts(args)?;
    let c = compile_qnn(m.graph, &m.input_ranges, &opts)?;
    println!("compiled {} with {:?} / {:?}", m.name, opts.tail_style, opts.acc_policy);
    if let Some(tr) = &c.thr_report {
        println!(
            "threshold conversion: {} tails converted, {} thresholds, {} skipped",
            tr.converted,
            tr.threshold_count,
            tr.skipped_nonmonotone + tr.skipped_no_int_input
        );
    }
    let mut t = Table::new(&["Layer", "K", "SIRA bits", "Datatype bits"]);
    for row in &c.acc_report.rows {
        t.row(vec![
            row.node.clone(),
            row.k.to_string(),
            row.bits_sira.to_string(),
            row.bits_datatype.to_string(),
        ]);
    }
    println!("{}", t.render());
    let f = &c.fdna;
    println!(
        "resources: LUT {:.0}  BRAM18 {:.1}  DSP {:.0}   (MAC: {:.0} LUT / non-MAC: {:.0} LUT)",
        f.total.lut, f.total.bram18, f.total.dsp, f.mac.lut, f.non_mac.lut
    );
    println!(
        "performance @{:.0} MHz: {:.1} FPS, latency {:.3} ms, bottleneck {}",
        opts.freq_hz / 1e6,
        f.perf.fps,
        f.perf.latency_ms,
        f.perf.bottleneck
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let m = zoo_model(args.get_or("model", "tfc"))?;
    let workers = args.get_usize("workers", 4)?;
    let n = args.get_usize("requests", 256)?;
    let threads = args.get_usize("threads", 1)?;
    let pipeline = args.get_usize("pipeline", 1)?;
    // --streamline only makes sense on the engine path: imply --engine
    let engine_mode = args.flag("engine") || args.flag("streamline") || pipeline > 1;
    let shape = m.input_shape.clone();
    let coord = if engine_mode {
        // direct engine serve path: plan-compiled integer runtime with a
        // persistent worker pool; --pipeline N swaps the batched workers
        // for one stage thread per plan segment
        let mut g = m.graph.clone();
        let analysis = if args.flag("streamline") {
            engine::prepare_streamlined(&mut g, &m.input_ranges)?
        } else {
            analyze(&g, &m.input_ranges)?
        };
        let mut plan = engine::compile(&g, &analysis)?;
        plan.set_threads(threads);
        println!(
            "backend: plan engine ({}{}, threads={threads}) — {}",
            m.name,
            if args.flag("streamline") { ", streamlined" } else { "" },
            plan.stats()
        );
        if pipeline > 1 {
            let sp = engine::SegmentedPlan::new(plan, pipeline);
            println!("pipeline: {}", sp.describe());
            Coordinator::start_pipelined(sp, BatchPolicy::default())
        } else {
            Coordinator::start_batched(workers, BatchPolicy::default(), move || {
                let mut p = plan.clone();
                move |xs: &[Tensor]| p.run_batch(xs)
            })
        }
    } else {
        println!("backend: graph executor ({})", m.name);
        let g = std::sync::Arc::new(m.graph);
        Coordinator::start(workers, BatchPolicy::default(), move || {
            let g = std::sync::Arc::clone(&g);
            move |x: &Tensor| {
                let mut e = Executor::new(&g)?;
                Ok(e.run_single(x)?.remove(0))
            }
        })
    };
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|i| coord.submit(Tensor::full(&shape, (i % 255) as f64)).unwrap())
        .collect();
    for h in handles {
        h.recv().unwrap()?;
    }
    let dt = t0.elapsed();
    let (p50, p95, p99) = coord.metrics.percentiles();
    println!(
        "{} requests in {:.2?} -> {:.1} req/s (workers={workers})",
        n,
        dt,
        n as f64 / dt.as_secs_f64()
    );
    println!("latency p50 {p50} us, p95 {p95} us, p99 {p99} us");
    print!("{}", coord.metrics.segment_summary(dt));
    coord.shutdown();
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    sira_finn::e2e::run_e2e(dir, 8)
}

fn main() -> Result<()> {
    let args = Args::from_env(&["help", "engine", "streamline"])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "analyze" => cmd_analyze(&args),
        "compile" => cmd_compile(&args),
        "serve" => cmd_serve(&args),
        "e2e" => cmd_e2e(&args),
        _ => {
            println!(
                "sira-finn — SIRA-enhanced FDNA compiler\n\
                 usage: sira-finn <analyze|compile|serve|e2e> [--model tfc|cnv|rn8|mnv1] ...\n\
                 serve: --workers N (coordinator workers) --requests N\n\
                 \x20      --engine      serve the plan-compiled integer runtime\n\
                 \x20      --streamline  streamline first (implies --engine)\n\
                 \x20      --threads N   persistent-pool thread budget per engine call\n\
                 \x20                    (sample-sharded batches + row-sharded MVUs)\n\
                 \x20      --pipeline N  pipeline-parallel serving over N plan\n\
                 \x20                    segments (implies --engine)\n\
                 see README.md"
            );
            Ok(())
        }
    }
}
