//! sira-finn CLI: analyze, compile, and serve quantized neural networks
//! with the SIRA-enhanced FDNA compiler.
//!
//! ```text
//! sira-finn analyze --model tfc|cnv|vgg12|rn8|rn12|mnv1|dws
//! sira-finn compile --model tfc --tail thresholding|composite \
//!                   --acc sira|datatype|32 --target-cycles 16384
//! sira-finn import  model.onnx [--streamline] [--snapshot model.plan]
//! sira-finn serve   --model tfc --workers 4 --requests 256 \
//!                   [--engine [--streamline] --threads N --pipeline N]
//! sira-finn serve   --listen 127.0.0.1:8080 --models tfc,cnv --engine \
//!                   [--threads N --pipeline N --replicas N --snapshot FILE \
//!                   --max-pending N --deadline-ms N]
//! sira-finn loadgen --addr 127.0.0.1:8080 --model cnv --conns 4 \
//!                   --requests 256 --batch 8 [--rate R --deadline-ms N --prom]
//! sira-finn snapshot save --model tfc [--streamline] [--out tfc.plan]
//! sira-finn snapshot load --file tfc.plan [--check-model tfc [--streamline]]
//! sira-finn profile --model tfc [--streamline --threads N --batch K \
//!                   --requests N --sample-every N]
//! sira-finn e2e     [--artifacts artifacts]
//! ```

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use sira_finn::accel::{compile_qnn, CompileOptions, TailStyle};
use sira_finn::coordinator::BatchPolicy;
use sira_finn::hw::{EwDtype, ThresholdStyle};
use sira_finn::models;
use sira_finn::passes::accmin::AccPolicy;
use sira_finn::serve::{self, LoadSpec, ModelEntry, ModelSpec, Server, ServerConfig};
use sira_finn::sira::analyze;
use sira_finn::tensor::Tensor;
use sira_finn::util::cli::Args;
use sira_finn::util::json::Json;
use sira_finn::util::table::Table;

fn parse_opts(args: &Args) -> Result<CompileOptions> {
    let tail = match args.get_or("tail", "thresholding") {
        "thresholding" | "thr" => TailStyle::Thresholding(ThresholdStyle::BinarySearch),
        "thresholding-parallel" => TailStyle::Thresholding(ThresholdStyle::Parallel),
        "composite" | "fix" => TailStyle::Composite(EwDtype::Fixed(16, 8)),
        "composite-fix32" => TailStyle::Composite(EwDtype::Fixed(32, 16)),
        "composite-float" => TailStyle::Composite(EwDtype::Float32),
        other => bail!("unknown tail style '{other}'"),
    };
    let acc = match args.get_or("acc", "sira") {
        "sira" => AccPolicy::Sira,
        "datatype" => AccPolicy::Datatype,
        "32" => AccPolicy::Bound32,
        other => bail!("unknown acc policy '{other}'"),
    };
    Ok(CompileOptions {
        tail_style: tail,
        acc_policy: acc,
        target_cycles: args.get_u64("target-cycles", 1 << 16)?,
        freq_hz: args.get_f64("freq", 200e6)?,
        ..Default::default()
    })
}

/// Render the per-tensor SIRA range table (shared by `analyze` and
/// `import`).
fn sira_table(g: &sira_finn::graph::Graph, a: &sira_finn::sira::Analysis) -> Result<String> {
    let mut t = Table::new(&["Tensor", "lo", "hi", "int?", "scale", "bits"]);
    for node in g.topo_nodes()? {
        let out = node.output();
        let r = a.get(out)?;
        let (lo, hi) = r.bounds();
        let (is_int, scale, bits) = match &r.int {
            Some(ic) => {
                let (l, h) = ic.int_bounds();
                (
                    if ic.is_pure_integer() { "pure" } else { "scaled" },
                    format!("{:.4}", ic.scale.data()[0]),
                    format!("{}", sira_finn::util::bits_for_range(l, h)),
                )
            }
            None => ("-", "-".into(), "-".into()),
        };
        t.row(vec![
            format!("{} ({})", out, node.op.name()),
            format!("{lo:.3}"),
            format!("{hi:.3}"),
            is_int.to_string(),
            scale,
            bits,
        ]);
    }
    Ok(t.render())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let m = models::by_name(args.get_or("model", "tfc"))?;
    let a = analyze(&m.graph, &m.input_ranges)?;
    println!("SIRA analysis of {}:\n{}", m.name, sira_table(&m.graph, &a)?);
    Ok(())
}

/// `import`: decode an ONNX/QONNX file into the internal graph, run
/// SIRA over it (uint8 input convention), compile the engine plan, and
/// prove it executes with a probe batch. `--snapshot FILE` additionally
/// writes the compiled plan as a cold-start sidecar, after which the
/// model serves via `serve --snapshot FILE` without re-importing.
fn cmd_import(args: &Args) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("file"))
        .ok_or_else(|| {
            anyhow!("usage: sira-finn import FILE.onnx [--streamline] [--snapshot OUT.plan]")
        })?;
    let bytes = std::fs::read(file)?;
    let t0 = std::time::Instant::now();
    let mut g = models::import_model(&bytes)?;
    let import_dt = t0.elapsed();
    println!(
        "imported {file}: graph '{}' in {import_dt:.2?} — {} nodes, {} initializers, inputs {:?}",
        g.name,
        g.nodes.len(),
        g.initializers.len(),
        g.inputs
    );
    let ranges = models::default_input_ranges(&g)?;
    let analysis = analyze(&g, &ranges)?;
    println!("SIRA analysis of {}:\n{}", g.name, sira_table(&g, &analysis)?);
    let analysis = if args.flag("streamline") {
        sira_finn::engine::prepare_streamlined(&mut g, &ranges)?
    } else {
        analysis
    };
    let t0 = std::time::Instant::now();
    let mut plan = sira_finn::engine::compile(&g, &analysis)?;
    let compile_dt = t0.elapsed();
    let shape = plan.input_shape().to_vec();
    let xs: Vec<Tensor> = (0..2)
        .map(|i| Tensor::full(&shape, (i * 37 % 255) as f64))
        .collect();
    plan.run_batch(&xs)?;
    println!(
        "engine probe ok: compiled in {compile_dt:.2?}{} and ran a {}-sample batch — {}",
        if args.flag("streamline") { " (streamlined)" } else { "" },
        xs.len(),
        plan.stats()
    );
    if let Some(out) = args.get("snapshot") {
        sira_finn::engine::snapshot::save(&plan, out)?;
        println!(
            "wrote {out}: plan '{}' ({} bytes)",
            plan.name(),
            std::fs::metadata(out)?.len()
        );
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let m = models::by_name(args.get_or("model", "tfc"))?;
    let opts = parse_opts(args)?;
    let c = compile_qnn(m.graph, &m.input_ranges, &opts)?;
    println!("compiled {} with {:?} / {:?}", m.name, opts.tail_style, opts.acc_policy);
    if let Some(tr) = &c.thr_report {
        println!(
            "threshold conversion: {} tails converted, {} thresholds, {} skipped",
            tr.converted,
            tr.threshold_count,
            tr.skipped_nonmonotone + tr.skipped_no_int_input
        );
    }
    let mut t = Table::new(&["Layer", "K", "SIRA bits", "Datatype bits"]);
    for row in &c.acc_report.rows {
        t.row(vec![
            row.node.clone(),
            row.k.to_string(),
            row.bits_sira.to_string(),
            row.bits_datatype.to_string(),
        ]);
    }
    println!("{}", t.render());
    let f = &c.fdna;
    println!(
        "resources: LUT {:.0}  BRAM18 {:.1}  DSP {:.0}   (MAC: {:.0} LUT / non-MAC: {:.0} LUT)",
        f.total.lut, f.total.bram18, f.total.dsp, f.mac.lut, f.non_mac.lut
    );
    println!(
        "performance @{:.0} MHz: {:.1} FPS, latency {:.3} ms, bottleneck {}",
        opts.freq_hz / 1e6,
        f.perf.fps,
        f.perf.latency_ms,
        f.perf.bottleneck
    );
    Ok(())
}

/// One [`ModelSpec`] from the shared serve flags (`--engine`,
/// `--streamline`, `--threads`, `--pipeline`, `--workers`,
/// `--replicas`, `--snapshot`) — the same backend-selection rules for
/// the in-process loop and the network server, built through the
/// serving registry in both cases.
fn spec_from_args(name: &str, args: &Args) -> Result<ModelSpec> {
    let pipeline = args.get_usize("pipeline", 1)?;
    let snapshot_path = args.get("snapshot").map(|s| s.to_string());
    Ok(ModelSpec {
        name: name.to_string(),
        // --streamline / --pipeline / --snapshot only make sense on the
        // engine path: imply --engine
        engine: args.flag("engine")
            || args.flag("streamline")
            || pipeline > 1
            || snapshot_path.is_some(),
        streamline: args.flag("streamline"),
        threads: args.get_usize("threads", 1)?,
        pipeline,
        workers: args.get_usize("workers", 4)?,
        profile: args.flag("profile"),
        replicas: args.get_usize("replicas", 1)?,
        snapshot_path,
        onnx_path: args.get("onnx").map(|s| s.to_string()),
    })
}

fn batch_policy(args: &Args) -> Result<BatchPolicy> {
    Ok(BatchPolicy {
        max_batch: args.get_usize("batch", 8)?,
        ..Default::default()
    })
}

fn opt_ms(args: &Args, key: &str) -> Result<Option<u64>> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.parse()?)),
    }
}

/// `serve --listen ADDR`: the network front end ([`sira_finn::serve`]).
/// Runs until a client POSTs `/admin/shutdown`, then drains gracefully
/// and prints the final per-model metrics via the shared JSON emitter.
fn cmd_serve_network(args: &Args, listen: &str) -> Result<()> {
    let names: Vec<String> = args
        .get_or("models", args.get_or("model", "tfc"))
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let specs = names
        .iter()
        .map(|n| spec_from_args(n, args))
        .collect::<Result<Vec<_>>>()?;
    let cfg = ServerConfig {
        listen: listen.to_string(),
        specs,
        policy: batch_policy(args)?,
        max_pending: args.get_usize("max-pending", 256)?,
        default_deadline: opt_ms(args, "deadline-ms")?.map(Duration::from_millis),
        ..Default::default()
    };
    let server = Server::start(cfg)?;
    println!("listening on http://{}", server.addr());
    for e in server.registry().entries() {
        println!("  model {}: {}", e.spec.name, e.describe);
    }
    println!(
        "routes: POST /v1/models/{{name}}/infer | GET /metrics | GET /v1/models | \
         POST /admin/shutdown (graceful drain)"
    );
    server.wait_for_shutdown_request();
    println!("shutdown requested; draining in-flight work");
    let (drained, final_metrics) = server.shutdown_with_report();
    println!("{final_metrics}");
    println!("drained={drained}");
    Ok(())
}

/// `serve` without `--listen`: the original in-process synthetic
/// request loop, now built through the same registry as the network
/// path so the two backends cannot drift.
fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(listen) = args.get("listen") {
        return cmd_serve_network(args, listen);
    }
    let n = args.get_usize("requests", 256)?;
    let spec = spec_from_args(args.get_or("model", "tfc"), args)?;
    let entry = ModelEntry::build(&spec, batch_policy(args)?)?;
    println!("backend: {}", entry.describe);
    let shape = entry.input_shape.clone();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            entry
                .route()
                .submit(Tensor::full(&shape, (i % 255) as f64))
                .unwrap()
        })
        .collect();
    for h in handles {
        h.recv().unwrap()?;
    }
    let dt = t0.elapsed();
    println!(
        "{} requests in {:.2?} -> {:.1} req/s (workers={})",
        n,
        dt,
        n as f64 / dt.as_secs_f64(),
        spec.workers
    );
    // machine-readable summary: the same emitter /metrics serves
    // (aggregated across replicas when --replicas > 1)
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::Str("serve".to_string())),
            ("model", Json::Str(spec.name.clone())),
            ("metrics", entry.metrics_json()),
        ])
    );
    for c in &entry.replicas {
        print!("{}", c.metrics.segment_summary(dt));
    }
    if let Some(p) = &entry.profiler {
        print!("{}", p.report());
    }
    entry.shutdown();
    Ok(())
}

/// `profile`: compile one model's plan, attach the per-step profiler,
/// run a synthetic in-process workload, and print the per-step cost
/// report (table plus one JSON line).
fn cmd_profile(args: &Args) -> Result<()> {
    let m = models::by_name(args.get_or("model", "tfc"))?;
    let mut g = m.graph;
    let analysis = if args.flag("streamline") {
        sira_finn::engine::prepare_streamlined(&mut g, &m.input_ranges)?
    } else {
        analyze(&g, &m.input_ranges)?
    };
    let mut plan = sira_finn::engine::compile(&g, &analysis)?;
    plan.set_threads(args.get_usize("threads", 1)?);
    plan.enable_profiling(args.get_u64("sample-every", 1)?);
    let batch = args.get_usize("batch", 8)?;
    let iters = args.get_usize("requests", 32)?;
    let shape = plan.input_shape().to_vec();
    let xs: Vec<Tensor> = (0..batch)
        .map(|i| Tensor::full(&shape, (i % 255) as f64))
        .collect();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        plan.run_batch(&xs)?;
    }
    let wall = t0.elapsed();
    let report = plan.profiler().expect("profiler attached").report();
    print!("{report}");
    println!(
        "wall: {wall:.2?} for {iters} batches of {batch} ({:.1} samples/s)",
        (iters * batch) as f64 / wall.as_secs_f64()
    );
    println!(
        "{}",
        Json::obj(vec![
            ("bench", Json::Str("profile".to_string())),
            ("model", Json::Str(m.name.to_string())),
            ("wall_ms", Json::Num(wall.as_secs_f64() * 1e3)),
            ("profile", report.json()),
        ])
    );
    Ok(())
}

/// `loadgen`: drive a running serve front end over loopback (or any
/// reachable address) and print the client-side latency/throughput
/// report as one JSON line.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow!("loadgen needs --addr HOST:PORT (start one with serve --listen)"))?;
    let spec = LoadSpec {
        addr: addr.to_string(),
        model: args.get_or("model", "tfc").to_string(),
        conns: args.get_usize("conns", 4)?,
        requests: args.get_usize("requests", 256)?,
        batch: args.get_usize("batch", 1)?,
        rate: match args.get("rate") {
            None => None,
            Some(v) => Some(v.parse()?),
        },
        deadline_ms: opt_ms(args, "deadline-ms")?,
        seed: args.get_u64("seed", 0x10AD)?,
    };
    let report = serve::loadgen::run(&spec)?;
    println!("{}", report.json());
    if args.flag("metrics") {
        let mut c = serve::http::Client::connect(addr)?;
        let (status, body) = c.get("/metrics")?;
        if status == 200 {
            println!("{}", String::from_utf8_lossy(&body));
        } else {
            bail!("GET /metrics returned {status}");
        }
    }
    if args.flag("prom") {
        // scrape + validate the Prometheus exposition; any malformed
        // line fails the run (this is the CI smoke's teeth)
        let n = serve::loadgen::scrape_prom(addr)?;
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::Str("prom-scrape".to_string())),
                ("samples", Json::Num(n as f64)),
            ])
        );
    }
    if args.flag("shutdown") {
        let mut c = serve::http::Client::connect(addr)?;
        c.request("POST", "/admin/shutdown", &[], b"")?;
    }
    Ok(())
}

/// Compile one zoo model to a [`sira_finn::engine::Plan`] — the same
/// streamline-or-raw choice the serve registry makes.
fn compile_plan(name: &str, streamline: bool) -> Result<sira_finn::engine::Plan> {
    let m = models::by_name(name)?;
    let mut g = m.graph;
    let analysis = if streamline {
        sira_finn::engine::prepare_streamlined(&mut g, &m.input_ranges)?
    } else {
        analyze(&g, &m.input_ranges)?
    };
    sira_finn::engine::compile(&g, &analysis)
}

/// `snapshot save|load`: the serialized-plan cold-start path
/// ([`sira_finn::engine::snapshot`]). `save` compiles a zoo model and
/// writes the versioned binary sidecar; `load` reads one back (timing
/// the read) and with `--check-model` proves it bit-exact against a
/// fresh compile before exiting 0.
fn cmd_snapshot(args: &Args) -> Result<()> {
    use sira_finn::engine::snapshot;
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("save") => {
            let name = args.get_or("model", "tfc");
            let default_out = format!("{name}.plan");
            let out = args.get("out").unwrap_or(&default_out);
            let t0 = std::time::Instant::now();
            let plan = compile_plan(name, args.flag("streamline"))?;
            let compile_dt = t0.elapsed();
            snapshot::save(&plan, out)?;
            println!(
                "wrote {out}: plan '{}' ({} bytes, compiled in {compile_dt:.2?}) — {}",
                plan.name(),
                std::fs::metadata(out)?.len(),
                plan.stats()
            );
            Ok(())
        }
        Some("load") => {
            let file = args
                .get("file")
                .ok_or_else(|| anyhow!("snapshot load needs --file FILE"))?;
            let t0 = std::time::Instant::now();
            let mut plan = snapshot::load(file)?;
            let load_dt = t0.elapsed();
            println!(
                "loaded {file}: plan '{}' in {load_dt:.2?} — {}",
                plan.name(),
                plan.stats()
            );
            if let Some(name) = args.get("check-model") {
                let mut fresh = compile_plan(name, args.flag("streamline"))?;
                let shape = fresh.input_shape().to_vec();
                let xs: Vec<Tensor> = (0..4)
                    .map(|i| Tensor::full(&shape, (i * 37 % 255) as f64))
                    .collect();
                let want = fresh.run_batch(&xs)?;
                let got = plan.run_batch(&xs)?;
                for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                    if w.data() != g.data() {
                        bail!("snapshot output diverges from fresh compile on sample {i}");
                    }
                }
                println!("check ok: bit-exact against freshly compiled '{name}'");
            }
            Ok(())
        }
        other => bail!(
            "usage: sira-finn snapshot <save|load> (got {:?}); \
             save --model NAME [--streamline] [--out FILE] | \
             load --file FILE [--check-model NAME [--streamline]]",
            other.unwrap_or("nothing")
        ),
    }
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    sira_finn::e2e::run_e2e(dir, 8)
}

/// `tune`: measure MAC tiling-scheme candidates on this machine and
/// persist the winners ([`sira_finn::engine::tune`]). Every later
/// `engine::compile` and snapshot cold-start on this host picks the
/// table up; deleting the file falls back to the fixed default scheme.
fn cmd_tune(args: &Args) -> Result<()> {
    use sira_finn::engine::tune;
    let shapes = match args.get("shapes") {
        None => tune::default_shapes(),
        Some(list) => {
            let mut v = Vec::new();
            for part in list.split(',').filter(|s| !s.trim().is_empty()) {
                let (k, n) = part
                    .split_once('x')
                    .ok_or_else(|| anyhow!("--shapes wants KxN[,KxN...], got '{part}'"))?;
                v.push((k.trim().parse::<usize>()?, n.trim().parse::<usize>()?));
            }
            v
        }
    };
    let quick = args.flag("quick");
    let t0 = std::time::Instant::now();
    let table = tune::tune(&shapes, quick);
    let dt = t0.elapsed();
    let mut t = Table::new(&["Shape", "mr", "nr_panels", "kc", "ns/iter"]);
    for (key, e) in &table.entries {
        t.row(vec![
            key.clone(),
            e.scheme.mr.to_string(),
            e.scheme.nr_panels.to_string(),
            if e.scheme.kc == 0 { "-".into() } else { e.scheme.kc.to_string() },
            format!("{:.0}", e.ns),
        ]);
    }
    println!("{}", t.render());
    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => tune::default_path(),
    };
    table.save(&out)?;
    println!(
        "tuned {} shapes in {dt:.2?}{} -> {}",
        shapes.len(),
        if quick { " (quick)" } else { "" },
        out.display()
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "help",
        "engine",
        "streamline",
        "metrics",
        "shutdown",
        "profile",
        "prom",
        "quick",
    ])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "analyze" => cmd_analyze(&args),
        "compile" => cmd_compile(&args),
        "import" => cmd_import(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "snapshot" => cmd_snapshot(&args),
        "profile" => cmd_profile(&args),
        "tune" => cmd_tune(&args),
        "e2e" => cmd_e2e(&args),
        _ => {
            println!(
                "sira-finn — SIRA-enhanced FDNA compiler\n\
                 usage: sira-finn <analyze|compile|import|serve|loadgen|snapshot|profile|tune|e2e> [--model tfc|cnv|vgg12|rn8|rn12|mnv1|dws] ...\n\
                 import: sira-finn import FILE.onnx [--streamline] [--snapshot OUT.plan]\n\
                 \x20      decode a QONNX/ONNX model, print its SIRA report, compile\n\
                 \x20      and probe the engine plan (see README, Model interchange)\n\
                 serve: --workers N (coordinator workers) --requests N\n\
                 \x20      --engine      serve the plan-compiled integer runtime\n\
                 \x20      --streamline  streamline first (implies --engine)\n\
                 \x20      --threads N   persistent-pool thread budget per engine call\n\
                 \x20                    (sample-sharded batches + row-sharded MVUs)\n\
                 \x20      --pipeline N  pipeline-parallel serving over N plan\n\
                 \x20                    segments (implies --engine)\n\
                 \x20      --profile     attach the per-step plan profiler (engine\n\
                 \x20                    only); report lands under `profile` in /metrics\n\
                 \x20      --replicas N  N coordinator replicas per model over clones of\n\
                 \x20                    one plan (Arc-shared packed weights); requests\n\
                 \x20                    route to the least-loaded replica\n\
                 \x20      --snapshot F  cold-start the plan from a snapshot sidecar\n\
                 \x20                    instead of compiling (implies --engine)\n\
                 \x20      --onnx F      build the model from an ONNX file instead of\n\
                 \x20                    the zoo (the --model name is just its label)\n\
                 \x20      --listen ADDR serve over HTTP instead of the in-process loop\n\
                 \x20                    (--models tfc,cnv --max-pending N --deadline-ms N;\n\
                 \x20                    stop with POST /admin/shutdown)\n\
                 loadgen: --addr HOST:PORT --model NAME --conns N --requests N\n\
                 \x20      --batch K     samples per request\n\
                 \x20      --rate R      open-loop at R req/s (default: closed loop)\n\
                 \x20      --deadline-ms N  per-request budget (x-deadline-ms)\n\
                 \x20      --metrics     fetch and print GET /metrics after the run\n\
                 \x20      --prom        scrape + validate /metrics?format=prom after the run\n\
                 \x20      --shutdown    POST /admin/shutdown after the run\n\
                 snapshot: save --model NAME [--streamline] [--out FILE]\n\
                 \x20      load --file FILE [--check-model NAME [--streamline]]\n\
                 \x20      (serve picks snapshots up via --snapshot FILE per model)\n\
                 profile: --model NAME [--streamline --threads N]\n\
                 \x20      --batch K --requests N  synthetic workload size\n\
                 \x20      --sample-every N        timing sample period (default 1)\n\
                 tune: measure MAC tiling schemes on this machine and save them\n\
                 \x20      --shapes KxN[,KxN...]   shapes to tune (default: zoo MVU shapes)\n\
                 \x20      --quick                 short measurement windows (CI smoke)\n\
                 \x20      --out FILE              tuning file (default: target/SIRA_tuning.local.json\n\
                 \x20                              or $SIRA_TUNING_FILE); compiles pick it up\n\
                 see README.md (Observability)"
            );
            Ok(())
        }
    }
}
