//! Structural out-of-context "synthesis" estimator for the Zynq
//! UltraScale+ XCZU9EG.
//!
//! The paper evaluates resource usage with Vivado 2024.2 OOC synthesis;
//! no FPGA toolchain exists in this environment, so this module plays the
//! synthesizer's role: it builds each hardware kernel from bit-level
//! primitives (carry-chain adders/comparators, LUT or DSP multipliers,
//! LUTRAM/BRAM memories) using device-accurate cost functions, plus a
//! small seeded noise model that emulates run-to-run synthesis variance
//! (the paper averages three synthesis runs per microbenchmark; we do the
//! same against this model). Absolute counts land in the right ballpark;
//! the *relative* comparisons the paper's claims rest on (rLUT/rDSP,
//! scaling in bitwidth × PE × channels) are what the model preserves.

use std::ops::{Add, AddAssign, Mul};

use crate::util::rng::Rng;

/// FPGA resource vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    pub lut: f64,
    pub ff: f64,
    /// RAMB18 halves, reported like FINN in units of BRAM18 (0.5 = half a
    /// RAMB36).
    pub bram18: f64,
    pub uram: f64,
    pub dsp: f64,
}

impl Resources {
    pub fn lut_only(lut: f64) -> Resources {
        Resources {
            lut,
            ff: lut * 0.8,
            ..Default::default()
        }
    }

    pub fn round(&self) -> Resources {
        Resources {
            lut: self.lut.round(),
            ff: self.ff.round(),
            bram18: (self.bram18 * 2.0).round() / 2.0,
            uram: self.uram.round(),
            dsp: self.dsp.round(),
        }
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, r: Resources) -> Resources {
        Resources {
            lut: self.lut + r.lut,
            ff: self.ff + r.ff,
            bram18: self.bram18 + r.bram18,
            uram: self.uram + r.uram,
            dsp: self.dsp + r.dsp,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, r: Resources) {
        *self = *self + r;
    }
}

impl Mul<f64> for Resources {
    type Output = Resources;
    fn mul(self, k: f64) -> Resources {
        Resources {
            lut: self.lut * k,
            ff: self.ff * k,
            bram18: self.bram18 * k,
            uram: self.uram * k,
            dsp: self.dsp * k,
        }
    }
}

/// Memory implementation style (the FINN `ram_style` attribute).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemStyle {
    /// let the "tool" decide by size thresholds
    Auto,
    /// force distributed LUT memory
    Lut,
    /// force block RAM
    Bram,
}

/// The synthesis context: device model + seeded variance.
#[derive(Clone, Debug)]
pub struct Synth {
    /// relative std-dev of per-component LUT noise (0 = deterministic)
    pub noise: f64,
    seed: u64,
}

impl Default for Synth {
    fn default() -> Self {
        Synth { noise: 0.0, seed: 0 }
    }
}

impl Synth {
    /// Deterministic estimator (noise disabled).
    pub fn exact() -> Synth {
        Synth::default()
    }

    /// Noisy estimator emulating a particular synthesis run.
    pub fn with_seed(seed: u64) -> Synth {
        Synth { noise: 0.03, seed }
    }

    /// Apply multiplicative noise to a LUT count, keyed by a component
    /// fingerprint so the same component in the same run is stable.
    fn jitter(&self, lut: f64, fingerprint: u64) -> f64 {
        if self.noise == 0.0 {
            return lut;
        }
        let mut rng = Rng::new(self.seed ^ fingerprint.wrapping_mul(0x9E3779B97F4A7C15));
        (lut * (1.0 + self.noise * rng.gauss())).max(0.0)
    }

    // ---- primitives --------------------------------------------------------

    /// Ripple/carry-chain adder of width w: ~1 LUT/bit (CARRY8 assisted).
    pub fn adder(&self, w: u32) -> Resources {
        let lut = self.jitter(w as f64 + 1.0, 0xA000 + w as u64);
        Resources {
            lut,
            ff: w as f64 + 1.0,
            ..Default::default()
        }
    }

    /// Magnitude comparator (>=) of width w, registered: ~1 LUT/bit
    /// including the pipeline register and select logic.
    pub fn comparator(&self, w: u32) -> Resources {
        let lut = self.jitter(w as f64, 0xC000 + w as u64);
        Resources {
            lut,
            ff: w as f64 * 0.5,
            ..Default::default()
        }
    }

    /// 2:1 mux of width w: one LUT per two bits.
    pub fn mux2(&self, w: u32) -> Resources {
        Resources::lut_only(self.jitter(w as f64 / 2.0, 0xD000 + w as u64))
    }

    /// Integer multiplier in LUTs: partial-product array ≈ a*b LUTs with a
    /// small constant overhead (matches the scaling the paper's Mul model
    /// regresses to: α·n_i·n_p with α ≈ 1.18).
    pub fn multiplier_lut(&self, a: u32, b: u32) -> Resources {
        let lut = self.jitter(
            1.1 * a as f64 * b as f64 + 0.5 * (a + b) as f64,
            0xE000 + ((a as u64) << 8) + b as u64,
        );
        Resources {
            lut,
            ff: (a + b) as f64,
            ..Default::default()
        }
    }

    /// Integer multiplier on DSP48E2 slices (27x18 signed).
    pub fn multiplier_dsp(&self, a: u32, b: u32) -> Resources {
        let dsp = (a as f64 / 27.0).ceil() * (b as f64 / 18.0).ceil();
        Resources {
            lut: self.jitter(10.0, 0xF000),
            ff: 20.0,
            dsp,
            ..Default::default()
        }
    }

    /// float32 adder (LUT-only Vitis HLS fadd): ~380 LUTs.
    pub fn fadd32(&self) -> Resources {
        Resources {
            lut: self.jitter(380.0, 0x1F1),
            ff: 500.0,
            ..Default::default()
        }
    }

    /// float32 multiplier (LUT-only): ~650 LUTs.
    pub fn fmul32(&self) -> Resources {
        Resources {
            lut: self.jitter(650.0, 0x1F2),
            ff: 700.0,
            ..Default::default()
        }
    }

    /// float32 <-> integer conversion: ~230 LUTs.
    pub fn fcvt32(&self) -> Resources {
        Resources {
            lut: self.jitter(230.0, 0x1F3),
            ff: 250.0,
            ..Default::default()
        }
    }

    /// ROM/RAM of `bits` total with read width `width`. Auto picks
    /// distributed memory below the BRAM threshold, BRAM18 units above.
    pub fn memory(&self, bits: u64, width: u32, style: MemStyle) -> Resources {
        let use_bram = match style {
            MemStyle::Lut => false,
            MemStyle::Bram => true,
            // auto threshold: distributed under ~16 kbit
            MemStyle::Auto => bits > 16 * 1024,
        };
        if use_bram {
            // RAMB18 = 18 kbit, max read width 36; wide reads take
            // parallel BRAMs
            let by_bits = bits as f64 / 18432.0;
            let by_width = (width as f64 / 36.0).ceil();
            let bram18 = by_bits.max(by_width).max(0.5);
            // round to half-BRAM granularity like Vivado reports
            let bram18 = (bram18 * 2.0).ceil() / 2.0;
            Resources {
                lut: self.jitter(10.0, 0x2F0 + bits),
                ff: 10.0,
                bram18,
                ..Default::default()
            }
        } else {
            // 6-LUT = 64x1 ROM -> bits/64 LUTs (the paper's LUT_mem model)
            Resources::lut_only(self.jitter(bits as f64 / 64.0, 0x3F0 + bits))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_scale_with_width() {
        let s = Synth::exact();
        assert!(s.adder(16).lut > s.adder(8).lut);
        assert!(s.comparator(32).lut > s.comparator(8).lut);
        assert!(s.multiplier_lut(8, 8).lut > s.multiplier_lut(4, 4).lut);
        // multiplier is roughly quadratic
        let r44 = s.multiplier_lut(4, 4).lut;
        let r88 = s.multiplier_lut(8, 8).lut;
        assert!(r88 / r44 > 3.0 && r88 / r44 < 5.0);
    }

    #[test]
    fn memory_style_thresholds() {
        let s = Synth::exact();
        let small = s.memory(1024, 8, MemStyle::Auto);
        assert_eq!(small.bram18, 0.0);
        assert_eq!(small.lut, 16.0);
        let big = s.memory(64 * 1024, 16, MemStyle::Auto);
        assert!(big.bram18 >= 3.5, "bram = {}", big.bram18);
        assert!(big.lut < 20.0);
        // forcing LUT keeps big memories in LUTs (the paper's
        // microbenchmark setup)
        let forced = s.memory(64 * 1024, 16, MemStyle::Lut);
        assert_eq!(forced.lut, 1024.0);
    }

    #[test]
    fn dsp_multiplier_packing_shape() {
        let s = Synth::exact();
        assert_eq!(s.multiplier_dsp(8, 8).dsp, 1.0);
        assert_eq!(s.multiplier_dsp(27, 18).dsp, 1.0);
        assert_eq!(s.multiplier_dsp(28, 18).dsp, 2.0);
    }

    #[test]
    fn noise_is_seeded_and_bounded() {
        let a = Synth::with_seed(1);
        let b = Synth::with_seed(1);
        let c = Synth::with_seed(2);
        assert_eq!(a.adder(16).lut, b.adder(16).lut);
        assert_ne!(a.adder(16).lut, c.adder(16).lut);
        let exact = Synth::exact().adder(16).lut;
        assert!((a.adder(16).lut - exact).abs() / exact < 0.2);
    }

    #[test]
    fn resources_arithmetic() {
        let a = Resources::lut_only(10.0);
        let b = Resources {
            lut: 5.0,
            dsp: 2.0,
            ..Default::default()
        };
        let c = a + b * 2.0;
        assert_eq!(c.lut, 20.0);
        assert_eq!(c.dsp, 4.0);
    }
}
