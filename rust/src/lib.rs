//! # sira-finn
//!
//! A production-quality reproduction of *SIRA: Scaled-Integer Range
//! Analysis for Optimizing FPGA Dataflow Neural Network Accelerators*
//! (CS.AR 2025).
//!
//! The crate implements the complete SIRA-enhanced FINN-style FDNA
//! compiler stack:
//!
//! - [`tensor`] — an n-dimensional array substrate (f64/i64) with ONNX
//!   multidirectional broadcasting, matmul, im2col convolution, pooling.
//! - [`graph`] — a QONNX-like graph intermediate representation with
//!   shape/datatype inference and graph-surgery utilities.
//! - [`sira`] — the paper's contribution: scaled-integer range analysis
//!   via interval arithmetic (§3), tracking `range`, `int_range`,
//!   `scale` and `bias` per tensor plus scale/bias contribution history.
//! - [`passes`] — compiler passes built on SIRA: operator lowering,
//!   scale/bias aggregation (§4.1.2), threshold conversion (§4.1.3),
//!   accumulator minimization (§4.2), stuck-channel detection (§7.1).
//! - [`executor`] — a bit-exact graph interpreter (float + integer
//!   paths) with min/max instrumentation, used for verification.
//! - [`engine`] — the serving hot path: an ahead-of-time plan compiler
//!   (constant folding, elementwise-chain and im2col+MVU+threshold
//!   fusion, SIRA-narrowed i32/i64 accumulators, stuck-channel elision)
//!   and a batched multi-threaded integer runtime (sample sharding plus
//!   intra-kernel row/channel sharding, one buffer arena per worker),
//!   bit-exact vs [`executor`] at every thread count.
//! - [`models`] — the QNN workload zoo of the paper's evaluation
//!   (TFC-w2a2, CNV-w2a2, RN8-w3a3, MNv1-w4a4) plus synthetic datasets.
//! - [`hw`] — hardware kernel models: MVU, thresholding (parallel and
//!   binary-search), elementwise meta-kernel, FIFOs, width converters.
//! - [`synth`] — a structural out-of-context synthesis estimator for the
//!   Zynq UltraScale+ XCZU9EG (LUT/FF/BRAM/DSP), replacing Vivado.
//! - [`analytical`] — the analytical resource cost models of §5.4 and
//!   the linear-regression fitting used to calibrate them.
//! - [`dataflow`] — a streaming dataflow performance simulator
//!   (initiation intervals, FIFO sizing, FPS/latency at 200 MHz).
//! - [`accel`] — the FDNA builder mapping graphs onto kernel instances
//!   with a folding-config solver.
//! - [`runtime`] — the PJRT runtime loading AOT-compiled JAX artifacts
//!   (`artifacts/*.hlo.txt`) via the `xla` crate.
//! - [`coordinator`] — a multi-threaded inference-serving coordinator
//!   (request router, dynamic batcher, worker pool, metrics).
//! - [`obs`] — the observability layer: bounded-memory metric
//!   instruments with Prometheus text exposition, structured request
//!   tracing (JSON-line spans, `SIRA_TRACE` env filter, slow-request
//!   threshold) and the per-step plan profiler.
//! - [`serve`] — the std-only network serving subsystem: hand-rolled
//!   HTTP/1.1 front end, multi-model registry over compiled engine
//!   plans, admission control with load-shed and deadlines, graceful
//!   drain, and the loopback load generator.
//! - [`util`] — substrates unavailable offline: JSON, seeded RNG, CLI
//!   parsing, table formatting, timing/bench harness.
//!
//! See `DESIGN.md` for the per-experiment index mapping every table and
//! figure of the paper onto modules and bench targets.

pub mod accel;
pub mod analytical;
pub mod bench;
pub mod coordinator;
pub mod dataflow;
pub mod e2e;
pub mod engine;
pub mod executor;
pub mod graph;
pub mod hw;
pub mod models;
pub mod obs;
pub mod passes;
pub mod runtime;
pub mod serve;
pub mod sira;
pub mod synth;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
