//! Multi-model registry: compile an engine [`Plan`] (or stand up the
//! interpretive executor) for each requested zoo model **once** at
//! server start, wrap each in its own [`Coordinator`], and route
//! requests by model name. Per-model serving knobs (streamlining, thread
//! budget, pipeline segments, worker count) live in [`ModelSpec`], so a
//! server can host e.g. a pipelined CNV next to a single-threaded TFC.
//!
//! Both binaries' serve paths build through this module ([`crate::serve`]
//! for the network front end, `sira-finn serve` / `examples/serve.rs`
//! for the in-process loops), so backend construction cannot drift
//! between them.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{BatchPolicy, Coordinator};
use crate::engine::{self, SegmentedPlan};
use crate::executor::Executor;
use crate::models;
use crate::obs::PlanProfiler;
use crate::sira::analyze;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// How one model should be served.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// zoo name ([`crate::models::by_name`])
    pub name: String,
    /// plan-compiled engine (the hot path) vs the interpretive executor
    pub engine: bool,
    /// streamline before compiling (pure-integer plan); engine only
    pub streamline: bool,
    /// persistent-pool thread budget per plan ([`engine::Plan::set_threads`])
    pub threads: usize,
    /// pipeline-parallel segments; >1 serves via
    /// [`Coordinator::start_pipelined`]
    pub pipeline: usize,
    /// coordinator workers (ignored on the pipelined path, which runs
    /// one stage thread per segment instead)
    pub workers: usize,
    /// attach a per-step [`PlanProfiler`] to the compiled plan (engine
    /// only): always-on step counters plus 1-in-[`PROFILE_SAMPLE_EVERY`]
    /// sampled kernel timing, reported under `profile` in the model's
    /// metrics
    pub profile: bool,
    /// serving replicas: N coordinators over clones of **one** compiled
    /// plan. Clones share the packed weights behind an `Arc`, so N
    /// replicas cost one weight allocation; requests route to the
    /// least-pending replica ([`ModelEntry::route`]). 0 is treated as 1.
    pub replicas: usize,
    /// load the compiled plan from this [`engine::snapshot`] sidecar
    /// instead of compiling (engine only) — the fleet cold-start path:
    /// file read + weight re-pack instead of streamline → SIRA → compile
    pub snapshot_path: Option<String>,
    /// build the model from this ONNX/QONNX file
    /// ([`models::import_model`]) instead of the zoo; `name` is then
    /// just the serving label. Works on both backends, uses the uint8
    /// input convention ([`models::default_input_ranges`]), and is
    /// mutually exclusive with `snapshot_path` (import once, snapshot,
    /// then cold-start from the sidecar).
    pub onnx_path: Option<String>,
}

/// Sampling period the serving paths use when `--profile` is on: cheap
/// enough to leave running (one `Instant` pair per step per 16 calls),
/// dense enough to converge on steady traffic within seconds.
pub const PROFILE_SAMPLE_EVERY: u64 = 16;

impl ModelSpec {
    /// The default serving shape: plan engine, raw graph, serial plan,
    /// two batched workers.
    pub fn engine_default(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            engine: true,
            streamline: false,
            threads: 1,
            pipeline: 1,
            workers: 2,
            profile: false,
            replicas: 1,
            snapshot_path: None,
            onnx_path: None,
        }
    }
}

/// Resolve a spec's model source: the zoo by name, or — when
/// `onnx_path` is set — an imported ONNX graph with the default uint8
/// input ranges. Returns the graph, its SIRA input ranges and a
/// describe-string fragment naming the source.
fn graph_for(
    spec: &ModelSpec,
) -> Result<(
    crate::graph::Graph,
    BTreeMap<String, crate::sira::SiRange>,
    String,
)> {
    match &spec.onnx_path {
        Some(path) => {
            let bytes =
                std::fs::read(path).with_context(|| format!("reading onnx file {path}"))?;
            let g = models::import_model(&bytes)?;
            let ranges = models::default_input_ranges(&g)?;
            Ok((g, ranges, format!(", onnx {path}")))
        }
        None => {
            let m = models::by_name(&spec.name)?;
            Ok((m.graph, m.input_ranges, String::new()))
        }
    }
}

/// Index of the replica with the fewest unresolved requests (first one
/// wins ties, so a quiet server routes to replica 0). Standalone so the
/// routing policy is testable without standing up coordinators.
pub fn least_loaded(pending: &[u64]) -> usize {
    let mut best = 0;
    for (i, &p) in pending.iter().enumerate().skip(1) {
        if p < pending[best] {
            best = i;
        }
    }
    best
}

/// One served model: its coordinator plus the metadata the HTTP layer
/// needs to validate and describe requests.
pub struct ModelEntry {
    pub spec: ModelSpec,
    /// per-sample input shape (leading batch dim 1), e.g. `[1, 784]`
    pub input_shape: Vec<usize>,
    pub input_numel: usize,
    /// per-sample output shape; empty when the backend cannot state it
    /// ahead of time
    pub output_shape: Vec<usize>,
    /// one-line backend description (plan composition stats or backend
    /// name), for logs and `GET /v1/models`
    pub describe: String,
    /// the serving replicas, never empty; route new work through
    /// [`ModelEntry::route`], use [`ModelEntry::coordinator`] when any
    /// replica will do (admin surfaces, single-replica callers)
    pub replicas: Vec<Coordinator>,
    /// compiled-plan composition stats (engine backends only). With the
    /// serve-time flat-oracle drop, `flat_weight_elems` is 0 here and
    /// `packed_weight_elems` is the **whole** weight footprint — shared
    /// across every replica, not multiplied by them.
    pub plan_stats: Option<engine::PlanStats>,
    /// per-step profiler shared with every plan clone (engine backends
    /// built with `spec.profile`, absent otherwise)
    pub profiler: Option<Arc<PlanProfiler>>,
    started: Instant,
}

impl ModelEntry {
    /// Compile (or snapshot-load) and start serving one model across
    /// `spec.replicas` coordinators.
    pub fn build(spec: &ModelSpec, policy: BatchPolicy) -> Result<ModelEntry> {
        let n_replicas = spec.replicas.max(1);
        if spec.snapshot_path.is_some() && spec.onnx_path.is_some() {
            bail!(
                "model '{}': --snapshot and --onnx are mutually exclusive \
                 (import + snapshot once, then cold-start from the sidecar)",
                spec.name
            );
        }
        if spec.engine {
            // one plan per model, however many replicas serve it
            let (mut plan, origin) = match &spec.snapshot_path {
                Some(path) => (engine::snapshot::load(path)?, format!(", snapshot {path}")),
                None => {
                    let (mut g, input_ranges, source) = graph_for(spec)?;
                    let analysis = if spec.streamline {
                        engine::prepare_streamlined(&mut g, &input_ranges)?
                    } else {
                        analyze(&g, &input_ranges)?
                    };
                    let tag = if spec.streamline { ", streamlined" } else { "" };
                    (engine::compile(&g, &analysis)?, format!("{source}{tag}"))
                }
            };
            plan.set_threads(spec.threads);
            if spec.profile {
                // attach before any clone so workers/stages all share it
                plan.enable_profiling(PROFILE_SAMPLE_EVERY);
            }
            // serve-time memory trim: serving always dispatches the
            // tiled kernels (bit-identical to the scalar oracle, locked
            // by the kernel property suite), so the flat weight copies
            // are dead here — drop them and the whole fleet runs on one
            // packed, Arc-shared allocation per model
            plan.drop_flat_oracles();
            let profiler = plan.profiler().cloned();
            let input_shape = plan.input_shape().to_vec();
            let input_numel = input_shape.iter().product();
            let output_shape = plan.output_shape().to_vec();
            let replica_tag = if n_replicas > 1 {
                format!(", replicas={n_replicas}")
            } else {
                String::new()
            };
            let mut describe = format!(
                "engine({}{origin}, threads={}{replica_tag}) — {}",
                spec.name,
                spec.threads,
                plan.stats()
            );
            let plan_stats = Some(plan.stats().clone());
            let mut replicas = Vec::with_capacity(n_replicas);
            if spec.pipeline > 1 {
                let mut pipe_desc = String::new();
                for r in 0..n_replicas {
                    let sp = SegmentedPlan::new(plan.clone(), spec.pipeline);
                    if r == 0 {
                        pipe_desc = sp.describe();
                    }
                    replicas.push(Coordinator::start_pipelined(sp, policy));
                }
                describe = format!("{describe}; pipeline: {pipe_desc}");
            } else {
                for _ in 0..n_replicas {
                    let plan = plan.clone();
                    replicas.push(Coordinator::start_batched(
                        spec.workers.max(1),
                        policy,
                        move || {
                            let mut p = plan.clone();
                            move |xs: &[Tensor]| p.run_batch(xs)
                        },
                    ));
                }
            }
            Ok(ModelEntry {
                spec: spec.clone(),
                input_shape,
                input_numel,
                output_shape,
                describe,
                replicas,
                plan_stats,
                profiler,
                started: Instant::now(),
            })
        } else {
            if spec.snapshot_path.is_some() {
                bail!(
                    "model '{}': snapshot serving needs the engine backend (--engine)",
                    spec.name
                );
            }
            let (graph, _, source) = graph_for(spec)?;
            let input_shape = graph
                .inputs
                .first()
                .and_then(|i| graph.shapes.get(i))
                .cloned()
                .unwrap_or_default();
            let input_numel = input_shape.iter().product();
            let output_shape = graph
                .outputs
                .first()
                .and_then(|o| graph.shapes.get(o))
                .cloned()
                .unwrap_or_default();
            let replica_tag = if n_replicas > 1 {
                format!(", replicas={n_replicas}")
            } else {
                String::new()
            };
            let describe = format!("executor({}{source}{replica_tag})", spec.name);
            let g = Arc::new(graph);
            let replicas = (0..n_replicas)
                .map(|_| {
                    let g = Arc::clone(&g);
                    Coordinator::start(spec.workers.max(1), policy, move || {
                        let g = Arc::clone(&g);
                        move |x: &Tensor| {
                            let mut e = Executor::new(&g)?;
                            Ok(e.run_single(x)?.remove(0))
                        }
                    })
                })
                .collect();
            Ok(ModelEntry {
                spec: spec.clone(),
                input_shape,
                input_numel,
                output_shape,
                describe,
                replicas,
                plan_stats: None,
                profiler: None,
                started: Instant::now(),
            })
        }
    }

    /// The replica a new request should go to: the one with the fewest
    /// unresolved submissions right now ([`Metrics::pending`] — relaxed
    /// counters, so the reading is approximate under churn; any answer
    /// is a correct replica, the depth signal only shapes the spread).
    ///
    /// [`Metrics::pending`]: crate::coordinator::Metrics::pending
    pub fn route(&self) -> &Coordinator {
        let pending: Vec<u64> = self.replicas.iter().map(|c| c.metrics.pending()).collect();
        &self.replicas[least_loaded(&pending)]
    }

    /// The first replica — for admin surfaces and callers that existed
    /// before replication (every entry has at least one).
    pub fn coordinator(&self) -> &Coordinator {
        &self.replicas[0]
    }

    /// Drain and join every replica.
    pub fn shutdown(&self) {
        for c in &self.replicas {
            c.shutdown();
        }
    }

    /// Serving metrics for this model via the shared JSON emitter —
    /// plus the per-step `profile` report when a profiler is attached
    /// (a pure addition, so the base schema cannot drift). A single
    /// replica reports exactly as before replication existed; with
    /// N > 1 the top level carries the summed counters plus aggregate
    /// throughput, and each replica's full shared-schema report lands
    /// under `replicas` (histograms are per-replica state, so they are
    /// reported there rather than approximately merged).
    pub fn metrics_json(&self) -> Json {
        use std::sync::atomic::Ordering;
        let wall = self.started.elapsed();
        let mut j = if self.replicas.len() == 1 {
            self.replicas[0].metrics.json_report(wall)
        } else {
            let sum = |f: &dyn Fn(&crate::coordinator::Metrics) -> u64| -> f64 {
                self.replicas.iter().map(|c| f(&c.metrics)).sum::<u64>() as f64
            };
            let completed = sum(&|m| m.completed.load(Ordering::Relaxed));
            Json::obj(vec![
                ("submitted", Json::Num(sum(&|m| m.submitted.load(Ordering::Relaxed)))),
                ("pending", Json::Num(sum(&|m| m.pending()))),
                ("completed", Json::Num(completed)),
                ("failed", Json::Num(sum(&|m| m.failed.load(Ordering::Relaxed)))),
                ("expired", Json::Num(sum(&|m| m.expired.load(Ordering::Relaxed)))),
                ("batches", Json::Num(sum(&|m| m.batches.load(Ordering::Relaxed)))),
                ("wall_ms", Json::Num(wall.as_secs_f64() * 1e3)),
                (
                    "throughput_rps",
                    Json::Num(completed / wall.as_secs_f64().max(1e-9)),
                ),
                (
                    "replicas",
                    Json::Arr(
                        self.replicas
                            .iter()
                            .map(|c| c.metrics.json_report(wall))
                            .collect(),
                    ),
                ),
            ])
        };
        if let Some(p) = &self.profiler {
            if let Json::Obj(map) = &mut j {
                map.insert("profile".to_string(), p.report().json());
            }
        }
        j
    }

    /// Model card for `GET /v1/models`.
    pub fn model_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.spec.name.clone())),
            (
                "backend",
                Json::Str(if self.spec.engine { "engine" } else { "executor" }.to_string()),
            ),
            ("streamline", Json::Bool(self.spec.streamline)),
            ("threads", Json::Num(self.spec.threads as f64)),
            ("pipeline", Json::Num(self.spec.pipeline as f64)),
            ("replicas", Json::Num(self.replicas.len() as f64)),
            ("snapshot", Json::Bool(self.spec.snapshot_path.is_some())),
            ("onnx", Json::Bool(self.spec.onnx_path.is_some())),
            (
                "input_shape",
                Json::nums(&self.input_shape.iter().map(|&d| d as f64).collect::<Vec<_>>()),
            ),
            (
                "output_shape",
                Json::nums(&self.output_shape.iter().map(|&d| d as f64).collect::<Vec<_>>()),
            ),
            ("describe", Json::Str(self.describe.clone())),
        ])
    }
}

/// The registry: name → served model.
pub struct Registry {
    entries: BTreeMap<String, ModelEntry>,
}

impl Registry {
    /// Compile and start every requested model. Duplicate names are an
    /// error (they would silently shadow each other's metrics).
    pub fn build(specs: &[ModelSpec], policy: BatchPolicy) -> Result<Registry> {
        if specs.is_empty() {
            bail!("registry needs at least one model");
        }
        let mut entries = BTreeMap::new();
        for spec in specs {
            if entries.contains_key(&spec.name) {
                bail!("model '{}' listed twice", spec.name);
            }
            entries.insert(spec.name.clone(), ModelEntry::build(spec, policy)?);
        }
        Ok(Registry { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.values()
    }

    /// `GET /v1/models` payload.
    pub fn models_json(&self) -> Json {
        Json::obj(vec![(
            "models",
            Json::Arr(self.entries.values().map(|e| e.model_json()).collect()),
        )])
    }

    /// Per-model serving metrics, one shared-schema report each.
    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(k, e)| (k.clone(), e.metrics_json()))
                .collect(),
        )
    }

    /// Graceful: drain and join every replica of every model. Requests
    /// submitted afterwards fail with the coordinator's clean shutdown
    /// error.
    pub fn shutdown(&self) {
        for e in self.entries.values() {
            e.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_routes_a_model() {
        let reg = Registry::build(
            &[ModelSpec::engine_default("tfc")],
            BatchPolicy::default(),
        )
        .unwrap();
        let e = reg.get("tfc").unwrap();
        assert_eq!(e.input_shape, vec![1, 784]);
        assert_eq!(e.input_numel, 784);
        assert_eq!(e.output_shape, vec![1, 10]);
        let y = e
            .coordinator()
            .infer(Tensor::full(&[1, 784], 100.0))
            .unwrap();
        assert_eq!(y.shape(), &[1, 10]);
        assert!(reg.get("cnv").is_none());
        let cards = reg.models_json();
        let arr = cards.get("models").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "tfc");
        reg.shutdown();
        // post-shutdown submits fail cleanly (the drain contract)
        let err = e
            .coordinator()
            .infer(Tensor::full(&[1, 784], 1.0))
            .unwrap_err();
        assert!(err.to_string().contains("shut down"));
    }

    #[test]
    fn profiled_entry_reports_step_costs() {
        let spec = ModelSpec {
            profile: true,
            ..ModelSpec::engine_default("tfc")
        };
        let reg = Registry::build(&[spec], BatchPolicy::default()).unwrap();
        let e = reg.get("tfc").unwrap();
        for _ in 0..4 {
            e.coordinator()
                .infer(Tensor::full(&[1, 784], 100.0))
                .unwrap();
        }
        let r = e.profiler.as_ref().expect("profiler attached").report();
        assert!(!r.steps.is_empty());
        assert!(r.steps.iter().all(|s| s.calls >= 1), "{r:?}");
        assert!(r.mac_tiled + r.mac_scalar > 0, "{r:?}");
        let j = e.metrics_json();
        let prof = j.get("profile").unwrap();
        assert_eq!(prof.get("sample_every").unwrap().as_usize().unwrap(), 16);
        // the base metrics schema is untouched by the addition
        assert!(j.get("latency_us").unwrap().get("count").unwrap().as_usize().unwrap() >= 4);
        reg.shutdown();
    }

    /// The tie-break is "first minimum" so a quiet server deterministically
    /// routes to replica 0; any strictly smaller depth wins.
    #[test]
    fn least_loaded_picks_first_minimum() {
        assert_eq!(least_loaded(&[5]), 0);
        assert_eq!(least_loaded(&[3, 1, 2]), 1);
        assert_eq!(least_loaded(&[2, 1, 1]), 1);
        assert_eq!(least_loaded(&[7, 7, 7]), 0);
        assert_eq!(least_loaded(&[9, 8, 0]), 2);
    }

    /// N replicas serve clones of one plan: same answers, flat oracle
    /// dropped, one shared packed-weight footprint in the stats.
    #[test]
    fn replicas_share_one_plan_and_stay_bit_exact() {
        let spec = ModelSpec {
            replicas: 3,
            ..ModelSpec::engine_default("tfc")
        };
        let reg = Registry::build(&[spec], BatchPolicy::default()).unwrap();
        let e = reg.get("tfc").unwrap();
        assert_eq!(e.replicas.len(), 3);
        let stats = e.plan_stats.as_ref().unwrap();
        assert!(stats.packed_weight_elems > 0);
        assert_eq!(
            stats.flat_weight_elems, 0,
            "serve-time plans must drop the flat oracle"
        );
        let x = Tensor::full(&[1, 784], 100.0);
        let want = e.replicas[0].infer(x.clone()).unwrap();
        for c in &e.replicas[1..] {
            assert_eq!(c.infer(x.clone()).unwrap().data(), want.data());
        }
        // route() always answers one of the replicas and stays exact
        for _ in 0..6 {
            assert_eq!(e.route().infer(x.clone()).unwrap().data(), want.data());
        }
        // aggregated metrics: every submission above is accounted for
        let j = e.metrics_json();
        assert_eq!(j.get("completed").unwrap().as_usize().unwrap(), 9);
        assert_eq!(j.get("pending").unwrap().as_usize().unwrap(), 0);
        assert_eq!(
            j.get("replicas").unwrap().as_arr().unwrap().len(),
            3,
            "per-replica reports present when N > 1"
        );
        reg.shutdown();
    }

    /// The fleet cold-start path end to end: serve a model from a
    /// snapshot sidecar (`ModelSpec::snapshot_path`) and get the
    /// freshly compiled plan's bits.
    #[test]
    fn snapshot_cold_start_serves_identical_bits() {
        let m = models::by_name("tfc").unwrap();
        let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
        let mut compiled = engine::compile(&m.graph, &analysis).unwrap();
        let path = std::env::temp_dir()
            .join(format!("sira-registry-snap-{}.plan", std::process::id()));
        engine::snapshot::save(&compiled, &path).unwrap();
        let spec = ModelSpec {
            snapshot_path: Some(path.to_string_lossy().into_owned()),
            ..ModelSpec::engine_default("tfc")
        };
        let reg = Registry::build(&[spec], BatchPolicy::default()).unwrap();
        let e = reg.get("tfc").unwrap();
        assert!(e.describe.contains("snapshot"), "{}", e.describe);
        let x = Tensor::full(&[1, 784], 100.0);
        let want = compiled.run_batch(std::slice::from_ref(&x)).unwrap().remove(0);
        let got = e.coordinator().infer(x).unwrap();
        assert_eq!(got.data(), want.data(), "snapshot-served bits diverged");
        reg.shutdown();
        std::fs::remove_file(&path).ok();
    }

    /// `ModelSpec::onnx_path` end to end: export tfc to a file, serve
    /// the file on both backends, and get the bits of the zoo-built
    /// original back.
    #[test]
    fn onnx_file_serves_identical_bits_on_both_backends() {
        let m = models::by_name("tfc").unwrap();
        let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
        let mut compiled = engine::compile(&m.graph, &analysis).unwrap();
        let path = std::env::temp_dir()
            .join(format!("sira-registry-onnx-{}.onnx", std::process::id()));
        std::fs::write(&path, models::export_model(&m.graph)).unwrap();
        let x = Tensor::full(&[1, 784], 100.0);
        let want = compiled.run_batch(std::slice::from_ref(&x)).unwrap().remove(0);
        for engine_backend in [true, false] {
            let spec = ModelSpec {
                engine: engine_backend,
                onnx_path: Some(path.to_string_lossy().into_owned()),
                ..ModelSpec::engine_default("tfc-onnx")
            };
            let reg = Registry::build(&[spec], BatchPolicy::default()).unwrap();
            let e = reg.get("tfc-onnx").unwrap();
            assert!(e.describe.contains("onnx"), "{}", e.describe);
            assert_eq!(e.input_shape, vec![1, 784]);
            let got = e.coordinator().infer(x.clone()).unwrap();
            assert_eq!(got.data(), want.data(), "onnx-served bits diverged (engine={engine_backend})");
            let card = e.model_json();
            assert!(card.get("onnx").unwrap().as_bool().unwrap());
            reg.shutdown();
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn onnx_path_and_snapshot_path_are_mutually_exclusive() {
        let spec = ModelSpec {
            onnx_path: Some("a.onnx".to_string()),
            snapshot_path: Some("a.plan".to_string()),
            ..ModelSpec::engine_default("tfc")
        };
        let err = Registry::build(&[spec], BatchPolicy::default()).unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err:#}");
    }

    #[test]
    fn snapshot_path_on_executor_backend_is_an_error() {
        let spec = ModelSpec {
            engine: false,
            snapshot_path: Some("nowhere.plan".to_string()),
            ..ModelSpec::engine_default("tfc")
        };
        let err = Registry::build(&[spec], BatchPolicy::default()).unwrap_err();
        assert!(err.to_string().contains("engine backend"), "{err:#}");
    }

    #[test]
    fn duplicate_and_unknown_names_are_errors() {
        let two = [
            ModelSpec::engine_default("tfc"),
            ModelSpec::engine_default("tfc"),
        ];
        assert!(Registry::build(&two, BatchPolicy::default()).is_err());
        let bogus = [ModelSpec::engine_default("nope")];
        let err = Registry::build(&bogus, BatchPolicy::default()).unwrap_err();
        assert!(err.to_string().contains("unknown model"));
        assert!(Registry::build(&[], BatchPolicy::default()).is_err());
    }
}
