//! Multi-model registry: compile an engine [`Plan`] (or stand up the
//! interpretive executor) for each requested zoo model **once** at
//! server start, wrap each in its own [`Coordinator`], and route
//! requests by model name. Per-model serving knobs (streamlining, thread
//! budget, pipeline segments, worker count) live in [`ModelSpec`], so a
//! server can host e.g. a pipelined CNV next to a single-threaded TFC.
//!
//! Both binaries' serve paths build through this module ([`crate::serve`]
//! for the network front end, `sira-finn serve` / `examples/serve.rs`
//! for the in-process loops), so backend construction cannot drift
//! between them.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::{BatchPolicy, Coordinator};
use crate::engine::{self, SegmentedPlan};
use crate::executor::Executor;
use crate::models;
use crate::obs::PlanProfiler;
use crate::sira::analyze;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// How one model should be served.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// zoo name ([`crate::models::by_name`])
    pub name: String,
    /// plan-compiled engine (the hot path) vs the interpretive executor
    pub engine: bool,
    /// streamline before compiling (pure-integer plan); engine only
    pub streamline: bool,
    /// persistent-pool thread budget per plan ([`engine::Plan::set_threads`])
    pub threads: usize,
    /// pipeline-parallel segments; >1 serves via
    /// [`Coordinator::start_pipelined`]
    pub pipeline: usize,
    /// coordinator workers (ignored on the pipelined path, which runs
    /// one stage thread per segment instead)
    pub workers: usize,
    /// attach a per-step [`PlanProfiler`] to the compiled plan (engine
    /// only): always-on step counters plus 1-in-[`PROFILE_SAMPLE_EVERY`]
    /// sampled kernel timing, reported under `profile` in the model's
    /// metrics
    pub profile: bool,
}

/// Sampling period the serving paths use when `--profile` is on: cheap
/// enough to leave running (one `Instant` pair per step per 16 calls),
/// dense enough to converge on steady traffic within seconds.
pub const PROFILE_SAMPLE_EVERY: u64 = 16;

impl ModelSpec {
    /// The default serving shape: plan engine, raw graph, serial plan,
    /// two batched workers.
    pub fn engine_default(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            engine: true,
            streamline: false,
            threads: 1,
            pipeline: 1,
            workers: 2,
            profile: false,
        }
    }
}

/// One served model: its coordinator plus the metadata the HTTP layer
/// needs to validate and describe requests.
pub struct ModelEntry {
    pub spec: ModelSpec,
    /// per-sample input shape (leading batch dim 1), e.g. `[1, 784]`
    pub input_shape: Vec<usize>,
    pub input_numel: usize,
    /// per-sample output shape; empty when the backend cannot state it
    /// ahead of time
    pub output_shape: Vec<usize>,
    /// one-line backend description (plan composition stats or backend
    /// name), for logs and `GET /v1/models`
    pub describe: String,
    pub coordinator: Coordinator,
    /// per-step profiler shared with every plan clone (engine backends
    /// built with `spec.profile`, absent otherwise)
    pub profiler: Option<Arc<PlanProfiler>>,
    started: Instant,
}

impl ModelEntry {
    /// Compile and start serving one model.
    pub fn build(spec: &ModelSpec, policy: BatchPolicy) -> Result<ModelEntry> {
        let m = models::by_name(&spec.name)?;
        if spec.engine {
            let mut g = m.graph;
            let analysis = if spec.streamline {
                engine::prepare_streamlined(&mut g, &m.input_ranges)?
            } else {
                analyze(&g, &m.input_ranges)?
            };
            let mut plan = engine::compile(&g, &analysis)?;
            plan.set_threads(spec.threads);
            if spec.profile {
                // attach before any clone so workers/stages all share it
                plan.enable_profiling(PROFILE_SAMPLE_EVERY);
            }
            let profiler = plan.profiler().cloned();
            let input_shape = plan.input_shape().to_vec();
            let input_numel = input_shape.iter().product();
            let output_shape = plan.output_shape().to_vec();
            let mut describe = format!(
                "engine({}{}, threads={}) — {}",
                m.name,
                if spec.streamline { ", streamlined" } else { "" },
                spec.threads,
                plan.stats()
            );
            let coordinator = if spec.pipeline > 1 {
                let sp = SegmentedPlan::new(plan, spec.pipeline);
                describe = format!("{describe}; pipeline: {}", sp.describe());
                Coordinator::start_pipelined(sp, policy)
            } else {
                Coordinator::start_batched(spec.workers.max(1), policy, move || {
                    let mut p = plan.clone();
                    move |xs: &[Tensor]| p.run_batch(xs)
                })
            };
            Ok(ModelEntry {
                spec: spec.clone(),
                input_shape,
                input_numel,
                output_shape,
                describe,
                coordinator,
                profiler,
                started: Instant::now(),
            })
        } else {
            let input_shape = m.input_shape.clone();
            let input_numel = input_shape.iter().product();
            let output_shape = m
                .graph
                .outputs
                .first()
                .and_then(|o| m.graph.shapes.get(o))
                .cloned()
                .unwrap_or_default();
            let describe = format!("executor({})", m.name);
            let g = Arc::new(m.graph);
            let coordinator = Coordinator::start(spec.workers.max(1), policy, move || {
                let g = Arc::clone(&g);
                move |x: &Tensor| {
                    let mut e = Executor::new(&g)?;
                    Ok(e.run_single(x)?.remove(0))
                }
            });
            Ok(ModelEntry {
                spec: spec.clone(),
                input_shape,
                input_numel,
                output_shape,
                describe,
                coordinator,
                profiler: None,
                started: Instant::now(),
            })
        }
    }

    /// Serving metrics for this model via the shared JSON emitter —
    /// plus the per-step `profile` report when a profiler is attached
    /// (a pure addition, so the base schema cannot drift).
    pub fn metrics_json(&self) -> Json {
        let mut j = self.coordinator.metrics.json_report(self.started.elapsed());
        if let Some(p) = &self.profiler {
            if let Json::Obj(map) = &mut j {
                map.insert("profile".to_string(), p.report().json());
            }
        }
        j
    }

    /// Model card for `GET /v1/models`.
    pub fn model_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.spec.name.clone())),
            (
                "backend",
                Json::Str(if self.spec.engine { "engine" } else { "executor" }.to_string()),
            ),
            ("streamline", Json::Bool(self.spec.streamline)),
            ("threads", Json::Num(self.spec.threads as f64)),
            ("pipeline", Json::Num(self.spec.pipeline as f64)),
            (
                "input_shape",
                Json::nums(&self.input_shape.iter().map(|&d| d as f64).collect::<Vec<_>>()),
            ),
            (
                "output_shape",
                Json::nums(&self.output_shape.iter().map(|&d| d as f64).collect::<Vec<_>>()),
            ),
            ("describe", Json::Str(self.describe.clone())),
        ])
    }
}

/// The registry: name → served model.
pub struct Registry {
    entries: BTreeMap<String, ModelEntry>,
}

impl Registry {
    /// Compile and start every requested model. Duplicate names are an
    /// error (they would silently shadow each other's metrics).
    pub fn build(specs: &[ModelSpec], policy: BatchPolicy) -> Result<Registry> {
        if specs.is_empty() {
            bail!("registry needs at least one model");
        }
        let mut entries = BTreeMap::new();
        for spec in specs {
            if entries.contains_key(&spec.name) {
                bail!("model '{}' listed twice", spec.name);
            }
            entries.insert(spec.name.clone(), ModelEntry::build(spec, policy)?);
        }
        Ok(Registry { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.values()
    }

    /// `GET /v1/models` payload.
    pub fn models_json(&self) -> Json {
        Json::obj(vec![(
            "models",
            Json::Arr(self.entries.values().map(|e| e.model_json()).collect()),
        )])
    }

    /// Per-model serving metrics, one shared-schema report each.
    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(k, e)| (k.clone(), e.metrics_json()))
                .collect(),
        )
    }

    /// Graceful: drain and join every coordinator. Requests submitted
    /// afterwards fail with the coordinator's clean shutdown error.
    pub fn shutdown(&self) {
        for e in self.entries.values() {
            e.coordinator.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_routes_a_model() {
        let reg = Registry::build(
            &[ModelSpec::engine_default("tfc")],
            BatchPolicy::default(),
        )
        .unwrap();
        let e = reg.get("tfc").unwrap();
        assert_eq!(e.input_shape, vec![1, 784]);
        assert_eq!(e.input_numel, 784);
        assert_eq!(e.output_shape, vec![1, 10]);
        let y = e
            .coordinator
            .infer(Tensor::full(&[1, 784], 100.0))
            .unwrap();
        assert_eq!(y.shape(), &[1, 10]);
        assert!(reg.get("cnv").is_none());
        let cards = reg.models_json();
        let arr = cards.get("models").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "tfc");
        reg.shutdown();
        // post-shutdown submits fail cleanly (the drain contract)
        let err = e
            .coordinator
            .infer(Tensor::full(&[1, 784], 1.0))
            .unwrap_err();
        assert!(err.to_string().contains("shut down"));
    }

    #[test]
    fn profiled_entry_reports_step_costs() {
        let spec = ModelSpec {
            profile: true,
            ..ModelSpec::engine_default("tfc")
        };
        let reg = Registry::build(&[spec], BatchPolicy::default()).unwrap();
        let e = reg.get("tfc").unwrap();
        for _ in 0..4 {
            e.coordinator
                .infer(Tensor::full(&[1, 784], 100.0))
                .unwrap();
        }
        let r = e.profiler.as_ref().expect("profiler attached").report();
        assert!(!r.steps.is_empty());
        assert!(r.steps.iter().all(|s| s.calls >= 1), "{r:?}");
        assert!(r.mac_tiled + r.mac_scalar > 0, "{r:?}");
        let j = e.metrics_json();
        let prof = j.get("profile").unwrap();
        assert_eq!(prof.get("sample_every").unwrap().as_usize().unwrap(), 16);
        // the base metrics schema is untouched by the addition
        assert!(j.get("latency_us").unwrap().get("count").unwrap().as_usize().unwrap() >= 4);
        reg.shutdown();
    }

    #[test]
    fn duplicate_and_unknown_names_are_errors() {
        let two = [
            ModelSpec::engine_default("tfc"),
            ModelSpec::engine_default("tfc"),
        ];
        assert!(Registry::build(&two, BatchPolicy::default()).is_err());
        let bogus = [ModelSpec::engine_default("nope")];
        let err = Registry::build(&bogus, BatchPolicy::default()).unwrap_err();
        assert!(err.to_string().contains("unknown model"));
        assert!(Registry::build(&[], BatchPolicy::default()).is_err());
    }
}
