//! Minimal hand-rolled HTTP/1.1 (std only — no hyper offline): exactly
//! the subset the serving front end needs. Request-line/header/body
//! parsing with `content-length` framing, keep-alive connection reuse,
//! and a matching client used by the loopback load generator and the
//! integration tests. No TLS, no HTTP/2 — explicit non-goals in
//! ROADMAP.md. Chunked transfer is not implemented either, but it is
//! *detected*: a request declaring any `transfer-encoding` gets a
//! framed `501 Not Implemented` (via [`UnsupportedTransferEncoding`])
//! rather than having its body misread under content-length framing.
//!
//! Framing rules implemented (the load-bearing parts of RFC 9112):
//! * request line `METHOD target HTTP/1.x`, headers until an empty line,
//!   then exactly `content-length` body bytes (0 when absent);
//! * header names are case-insensitive (lowercased on parse);
//! * HTTP/1.1 connections persist unless `connection: close`; HTTP/1.0
//!   connections close unless `connection: keep-alive`;
//! * hard limits on header-line, header-block and body sizes so a
//!   misbehaving client cannot make the server allocate unboundedly.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Longest accepted single header/request line, in bytes.
pub const MAX_LINE_BYTES: usize = 8 * 1024;
/// Largest accepted header block (request line + all headers).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Largest accepted request body. Generous for batch inference payloads
/// (an 8-sample CNV batch is ~0.5 MB of JSON) while still bounding a
/// hostile `content-length`.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// path component of the request target (query string stripped)
    pub path: String,
    /// raw query string after `?`, empty when absent
    pub query: String,
    /// `HTTP/1.1` or `HTTP/1.0`
    pub version: String,
    /// header (name, value) pairs, names lowercased
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header value for `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this exchange.
    ///
    /// The `connection` header is a comma-separated token list
    /// (RFC 9112 §9.6): `keep-alive, upgrade` must parse, and a token
    /// like `closed` must NOT match `close` (substring matching would).
    pub fn keep_alive(&self) -> bool {
        let conn = self
            .header("connection")
            .unwrap_or("")
            .to_ascii_lowercase();
        let has_token = |want: &str| conn.split(',').any(|t| t.trim() == want);
        if self.version == "HTTP/1.0" {
            has_token("keep-alive")
        } else {
            !has_token("close")
        }
    }

    /// Parse the body as JSON.
    pub fn body_json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|e| anyhow!("request body is not UTF-8: {e}"))?;
        Json::parse(text)
    }
}

/// Read one line (including the terminator) with a hard length cap.
/// io errors keep their source (`anyhow::Context`), so the server can
/// tell an idle-timeout/torn connection from a protocol violation.
fn read_line_limited(r: &mut impl BufRead, out: &mut String, limit: usize) -> Result<usize> {
    let mut lim = r.take(limit as u64 + 1);
    let n = lim.read_line(out).context("reading header line")?;
    if n > limit {
        bail!("header line exceeds {limit} bytes");
    }
    Ok(n)
}

/// Typed error for a request declaring `Transfer-Encoding` (chunked or
/// otherwise): this server frames bodies by `content-length` only, so
/// the body cannot be read safely. [`crate::serve`]'s connection loop
/// downcasts to this to answer with a framed `501 Not Implemented`
/// before closing, instead of the generic best-effort 400.
#[derive(Debug)]
pub struct UnsupportedTransferEncoding(pub String);

impl std::fmt::Display for UnsupportedTransferEncoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transfer-encoding {:?} not implemented (bodies must be content-length framed)",
            self.0
        )
    }
}

impl std::error::Error for UnsupportedTransferEncoding {}

/// Read one request off a buffered connection. `Ok(None)` means the peer
/// closed a kept-alive connection cleanly between requests (EOF before
/// the first request byte); any mid-request EOF or malformed framing is
/// an error.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<Request>> {
    let mut line = String::new();
    if read_line_limited(r, &mut line, MAX_LINE_BYTES)? == 0 {
        return Ok(None);
    }
    let start = line.trim_end_matches(['\r', '\n']);
    let mut parts = start.split(' ').filter(|s| !s.is_empty());
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| anyhow!("request line missing target: {start:?}"))?;
    let version = parts
        .next()
        .ok_or_else(|| anyhow!("request line missing HTTP version: {start:?}"))?
        .to_string();
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version {version:?}");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers = Vec::new();
    let mut header_bytes = start.len();
    loop {
        let mut h = String::new();
        if read_line_limited(r, &mut h, MAX_LINE_BYTES)? == 0 {
            bail!("connection closed inside the header block");
        }
        let h = h.trim_end_matches(['\r', '\n']);
        if h.is_empty() {
            break;
        }
        header_bytes += h.len();
        if header_bytes > MAX_HEADER_BYTES {
            bail!("header block exceeds {MAX_HEADER_BYTES} bytes");
        }
        let (k, v) = h
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line {h:?}"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let mut req = Request {
        method,
        path,
        query,
        version,
        headers,
        body: Vec::new(),
    };
    // Declared transfer-encoding means the body is not content-length
    // framed; reading it as such would desynchronize the connection
    // (the request-smuggling shape of the bug). Surface a typed error
    // so the connection loop can answer with a framed 501 and close
    // instead of misreading the body.
    if let Some((_, v)) = req.headers.iter().find(|(k, _)| k == "transfer-encoding") {
        return Err(UnsupportedTransferEncoding(v.clone()).into());
    }
    // Framing is decided by content-length; a request carrying more than
    // one (even with equal values) is ambiguous across intermediaries —
    // the classic request-smuggling vector — so reject it outright
    // instead of silently trusting the first match.
    let cl: Vec<&str> = req
        .headers
        .iter()
        .filter(|(k, _)| k == "content-length")
        .map(|(_, v)| v.as_str())
        .collect();
    let len = match cl.as_slice() {
        [] => 0usize,
        [v] => v
            .trim()
            .parse::<usize>()
            .map_err(|_| anyhow!("bad content-length {v:?}"))?,
        _ => bail!("{} content-length headers in one request ({cl:?})", cl.len()),
    };
    if len > MAX_BODY_BYTES {
        bail!("body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte limit");
    }
    if len > 0 {
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)
            .with_context(|| format!("reading {len}-byte body"))?;
        req.body = body;
    }
    Ok(Some(req))
}

/// One response, written with explicit `content-length` framing.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers beyond the framing set (names should be
    /// lowercase; used for `x-request-id` echo and similar).
    pub headers: Vec<(String, String)>,
}

impl Response {
    /// JSON response with the given status.
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
            headers: Vec::new(),
        }
    }

    /// Plain-text response (Prometheus exposition and friends).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response {
            status,
            content_type,
            body: body.into_bytes(),
            headers: Vec::new(),
        }
    }

    /// Builder-style extra header.
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    /// JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    /// Canonical reason phrase for the status codes this server emits.
    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            501 => "Not Implemented",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Response",
        }
    }

    /// Serialize status line + headers + body onto the wire.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
            self.status,
            Response::reason(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Read one response off a buffered connection: `(status, body)`.
/// Client-side mirror of [`read_request`], same framing rules.
pub fn read_response(r: &mut impl BufRead) -> Result<(u16, Vec<u8>)> {
    let (status, _headers, body) = read_response_headers(r)?;
    Ok((status, body))
}

/// [`read_response`], but keeping the response headers (names
/// lowercased) — what the request-id round-trip assertions read.
pub fn read_response_headers(r: &mut impl BufRead) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut line = String::new();
    if read_line_limited(r, &mut line, MAX_LINE_BYTES)? == 0 {
        bail!("connection closed before the status line");
    }
    let start = line.trim_end_matches(['\r', '\n']);
    let mut parts = start.split(' ').filter(|s| !s.is_empty());
    let version = parts
        .next()
        .ok_or_else(|| anyhow!("empty status line"))?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version {version:?}");
    }
    let status: u16 = parts
        .next()
        .ok_or_else(|| anyhow!("status line missing code: {start:?}"))?
        .parse()
        .map_err(|_| anyhow!("bad status code in {start:?}"))?;

    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if read_line_limited(r, &mut h, MAX_LINE_BYTES)? == 0 {
            bail!("connection closed inside the response headers");
        }
        let h = h.trim_end_matches(['\r', '\n']);
        if h.is_empty() {
            break;
        }
        // Same strictness as the server side: a header line without a
        // colon is a framing error, not noise to skip — skipping could
        // silently drop the content-length that frames the body.
        let (k, v) = h
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed response header line {h:?}"))?;
        if k.trim().eq_ignore_ascii_case("content-length") {
            content_length = v
                .trim()
                .parse()
                .map_err(|_| anyhow!("bad content-length {v:?}"))?;
        }
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    if content_length > MAX_BODY_BYTES {
        bail!("response body of {content_length} bytes exceeds the limit");
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)
        .map_err(|e| anyhow!("reading {content_length}-byte response body: {e}"))?;
    Ok((status, headers, body))
}

/// A keep-alive HTTP client over one TCP connection — what the loopback
/// load generator and the integration tests drive the server with.
/// Reads are buffered; writes go straight to the socket.
pub struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow!("connecting to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream),
        })
    }

    /// One request/response exchange; the connection stays usable
    /// afterwards (keep-alive).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<(u16, Vec<u8>)> {
        let (status, _headers, body) = self.request_full(method, path, headers, body)?;
        Ok((status, body))
    }

    /// [`Client::request`], but returning the response headers too
    /// (names lowercased).
    pub fn request_full(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
        let s = self.reader.get_mut();
        write!(
            s,
            "{method} {path} HTTP/1.1\r\nhost: loopback\r\ncontent-length: {}\r\n",
            body.len()
        )?;
        for (k, v) in headers {
            write!(s, "{k}: {v}\r\n")?;
        }
        write!(s, "\r\n")?;
        s.write_all(body)?;
        s.flush()?;
        read_response_headers(&mut self.reader)
    }

    pub fn get(&mut self, path: &str) -> Result<(u16, Vec<u8>)> {
        self.request("GET", path, &[], b"")
    }

    /// POST a JSON body; returns the status and the parsed JSON reply.
    pub fn post_json(
        &mut self,
        path: &str,
        headers: &[(&str, &str)],
        body: &Json,
    ) -> Result<(u16, Json)> {
        let (status, bytes) = self.request("POST", path, headers, body.to_string().as_bytes())?;
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| anyhow!("response body is not UTF-8: {e}"))?;
        Ok((status, Json::parse(text)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.to_vec()))
    }

    #[test]
    fn parses_a_post_with_body() {
        let req = parse(
            b"POST /v1/models/tfc/infer?trace=1 HTTP/1.1\r\n\
              Host: x\r\nContent-Length: 4\r\nX-Deadline-Ms: 250\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/models/tfc/infer");
        assert_eq!(req.query, "trace=1");
        assert_eq!(req.header("x-deadline-ms"), Some("250"));
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn keep_alive_semantics() {
        let r = parse(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive());
        let r = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive(), "HTTP/1.0 defaults to close");
        let r = parse(b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive());
    }

    #[test]
    fn keep_alive_matches_whole_tokens_not_substrings() {
        // "closed" is not the "close" token — HTTP/1.1 stays open
        let r = parse(b"GET / HTTP/1.1\r\nConnection: closed\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive(), "token 'closed' must not match 'close'");
        // comma-separated lists parse per token on both versions
        let r = parse(b"GET / HTTP/1.1\r\nConnection: upgrade, close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive(), "'close' anywhere in the list closes");
        let r = parse(b"GET / HTTP/1.0\r\nConnection: Keep-Alive, Upgrade\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive(), "1.0 list containing keep-alive persists");
        // a 1.0 token that merely contains "keep-alive" is not the token
        let r = parse(b"GET / HTTP/1.0\r\nConnection: not-keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive());
    }

    #[test]
    fn duplicate_content_length_is_rejected() {
        // equal duplicates: still ambiguous across intermediaries
        assert!(parse(
            b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 4\r\n\r\nabcd"
        )
        .is_err());
        // conflicting duplicates: the smuggling shape proper
        assert!(parse(
            b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 11\r\n\r\nabcdGET /x H"
        )
        .is_err());
        // one header still frames normally
        let r = parse(b"POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn transfer_encoding_is_rejected_with_a_typed_error() {
        // chunked framing would desynchronize the content-length reader;
        // the typed error lets the connection loop answer 501
        let err = parse(
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nabcd\r\n0\r\n\r\n",
        )
        .unwrap_err();
        assert!(
            err.downcast_ref::<UnsupportedTransferEncoding>().is_some(),
            "{err:#}"
        );
        assert!(err.to_string().contains("chunked"), "{err:#}");
        // any declared transfer-encoding is refused, not just chunked
        assert!(parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n").is_err());
        // ...including when a content-length is also present (the
        // TE+CL smuggling shape)
        assert!(parse(
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 4\r\n\r\nabcd"
        )
        .is_err());
    }

    #[test]
    fn malformed_response_header_lines_error() {
        // a colonless line inside the response headers is a framing
        // error for the client reader, never silently skipped
        let wire = b"HTTP/1.1 200 OK\r\nno-colon-here\r\ncontent-length: 0\r\n\r\n";
        assert!(read_response_headers(&mut Cursor::new(wire.to_vec())).is_err());
        // server side already errors; pin it too (torn-framing family)
        assert!(parse(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
    }

    #[test]
    fn clean_eof_is_none_and_torn_requests_error() {
        assert!(parse(b"").unwrap().is_none());
        assert!(parse(b"GET / HTTP/1.1\r\nHost: x\r\n").is_err()); // EOF mid-headers
        assert!(parse(b"GET / HTTP/1.1\r\nContent-Length: 9\r\n\r\nabc").is_err()); // short body
        assert!(parse(b"GARBAGE\r\n\r\n").is_err()); // no target/version
        assert!(parse(b"GET / SPDY/3\r\n\r\n").is_err()); // wrong protocol
        assert!(parse(b"GET / HTTP/1.1\r\nContent-Length: nine\r\n\r\n").is_err());
    }

    #[test]
    fn oversized_lines_are_rejected() {
        let mut raw = b"GET /".to_vec();
        raw.extend(vec![b'a'; MAX_LINE_BYTES + 10]);
        raw.extend_from_slice(b" HTTP/1.1\r\n\r\n");
        assert!(parse(&raw).is_err());
    }

    #[test]
    fn two_keep_alive_requests_on_one_connection() {
        let raw =
            b"GET /healthz HTTP/1.1\r\n\r\nPOST /x HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".to_vec();
        let mut cur = Cursor::new(raw);
        let a = read_request(&mut cur).unwrap().unwrap();
        assert_eq!(a.path, "/healthz");
        let b = read_request(&mut cur).unwrap().unwrap();
        assert_eq!(b.body, b"hi");
        assert!(read_request(&mut cur).unwrap().is_none());
    }

    #[test]
    fn response_roundtrips_through_the_client_reader() {
        let resp = Response::json(503, &Json::obj(vec![("error", Json::Str("full".into()))]));
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let (status, body) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 503);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "full");
    }

    #[test]
    fn extra_headers_roundtrip_lowercased() {
        let resp = Response::json(200, &Json::Null).with_header("X-Request-Id", "r-1-2f");
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let (status, headers, _) = read_response_headers(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 200);
        let rid = headers
            .iter()
            .find(|(k, _)| k == "x-request-id")
            .map(|(_, v)| v.as_str());
        assert_eq!(rid, Some("r-1-2f"));
    }

    #[test]
    fn reason_phrases_cover_the_emitted_codes() {
        for code in [200u16, 400, 404, 405, 413, 500, 501, 503, 504] {
            assert!(!Response::reason(code).is_empty());
        }
    }
}
