//! `serve`: the std-only network serving subsystem — bytes on a socket
//! to the plan-compiled integer engine and back.
//!
//! Until this module existed, every "serving" surface was an in-process
//! synthetic request loop: no socket ever opened, so the coordinator's
//! dynamic batcher, the pipelined [`crate::engine::SegmentedPlan`] and
//! the tiled engine had never seen real concurrent clients, overload or
//! deadlines. Following FINN-R's argument that a quantized-accelerator
//! stack is only as good as its end-to-end deployment, this is a real
//! request path, built entirely on `std::net` plus the crate's own JSON
//! (`tokio`/`hyper`/`serde` are unavailable offline):
//!
//! * [`http`] — a hand-rolled HTTP/1.1 subset: request/response framing
//!   with `content-length`, keep-alive, hard input limits, and the
//!   matching client the load generator and tests use.
//! * [`registry`] — the multi-model registry: engine `Plan`s compiled
//!   once per model at startup (raw or streamlined, per-model
//!   thread/pipeline budgets) or loaded from an
//!   [`engine::snapshot`](crate::engine::snapshot) sidecar, served by N
//!   replica [`Coordinator`](crate::coordinator::Coordinator)s over
//!   clones of the one plan (packed weights Arc-shared, flat oracles
//!   dropped); requests route by name via
//!   `POST /v1/models/{name}/infer`, then to the least-loaded replica.
//! * [`admit`] — admission control: a bounded pending-sample gate that
//!   sheds overload with HTTP 503 instead of queueing unboundedly,
//!   per-request deadline budgets (`x-deadline-ms`) that drop expired
//!   work *before* it reaches a batch (HTTP 504), and the drain
//!   handshake graceful shutdown waits on.
//! * [`loadgen`] — the loopback load generator (`sira-finn loadgen`):
//!   open- and closed-loop client fleets reporting p50/p95/p99 and
//!   throughput as JSON lines.
//!
//! Routes: `GET /healthz`, `GET /metrics` (machine-readable
//! [`Metrics::json_report`](crate::coordinator::Metrics::json_report)
//! per model + admission counters; `?format=prom` selects Prometheus
//! text exposition 0.0.4 instead), `GET /v1/models`,
//! `POST /v1/models/{name}/infer`, `POST /admin/shutdown` (begin
//! graceful drain). Every request carries an id — the client's
//! `x-request-id` header, or a minted one — echoed back as a response
//! header, attached to coordinator jobs, and stamped on the JSON-line
//! spans the [`crate::obs::trace`] layer emits (`SIRA_TRACE=info`
//! for per-request summaries, `debug` for batch/segment spans). Request bodies carry `{"inputs": [[...], ...]}`
//! (one flat f64 array per sample) or `{"input": [...]}`; replies carry
//! `{"outputs": [[...], ...]}` bit-exact against
//! [`Plan::run_batch`](crate::engine::Plan::run_batch) — f64 values
//! survive the JSON round trip exactly (shortest-roundtrip formatting on
//! write, exact parse on read), which the loopback integration test
//! (`rust/tests/serve_loopback.rs`) locks.
//!
//! Concurrency model: one accept loop, one thread per connection
//! (plenty for a CPU inference server whose real concurrency bound is
//! the engine pool), coordinator worker threads per model. Connection
//! threads are detached; graceful shutdown is gated on *admitted work*
//! (the permit gate), not on connection count, so an idle kept-alive
//! connection can never stall a drain.

pub mod admit;
pub mod http;
pub mod loadgen;
pub mod registry;

pub use admit::{Admission, AdmitError};
pub use loadgen::{LoadReport, LoadSpec};
pub use registry::{ModelEntry, ModelSpec, Registry};

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{BatchPolicy, DEADLINE_EXCEEDED, SHUT_DOWN, WORKERS_GONE};
use crate::obs::trace::{next_request_id, tracer, Level};
use crate::obs::PromWriter;
use crate::tensor::Tensor;
use crate::util::json::Json;

use http::{Request, Response};

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// bind address; port 0 picks a free port (see [`Server::addr`])
    pub listen: String,
    /// models to compile and serve
    pub specs: Vec<ModelSpec>,
    /// dynamic-batching policy shared by every model's coordinator
    pub policy: BatchPolicy,
    /// admission bound, in *samples* across all models
    pub max_pending: usize,
    /// default per-request deadline when no `x-deadline-ms` is sent
    pub default_deadline: Option<Duration>,
    /// per-connection idle read timeout
    pub idle_timeout: Duration,
    /// how long graceful shutdown waits for admitted work to finish
    pub drain_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".to_string(),
            specs: vec![ModelSpec::engine_default("tfc")],
            policy: BatchPolicy::default(),
            max_pending: 256,
            default_deadline: None,
            idle_timeout: Duration::from_secs(60),
            drain_timeout: Duration::from_secs(30),
        }
    }
}

/// Shared server state: what every connection thread sees.
struct ServerCtx {
    registry: Registry,
    admit: Admission,
    default_deadline: Option<Duration>,
    /// set by `POST /admin/shutdown`;
    /// [`Server::wait_for_shutdown_request`] polls it
    shutdown_requested: AtomicBool,
    started: Instant,
}

/// A running serving front end.
pub struct Server {
    addr: SocketAddr,
    ctx: Arc<ServerCtx>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    drain_timeout: Duration,
}

impl Server {
    /// Compile the registry, bind the listener and start accepting.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let registry = Registry::build(&cfg.specs, cfg.policy)?;
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| anyhow!("binding {}: {e}", cfg.listen))?;
        let addr = listener.local_addr()?;
        let ctx = Arc::new(ServerCtx {
            registry,
            admit: Admission::new(cfg.max_pending),
            default_deadline: cfg.default_deadline,
            shutdown_requested: AtomicBool::new(false),
            started: Instant::now(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept_handle = {
            let ctx = Arc::clone(&ctx);
            let stop = Arc::clone(&stop);
            let idle = cfg.idle_timeout;
            std::thread::spawn(move || accept_loop(listener, &stop, &ctx, idle))
        };
        Ok(Server {
            addr,
            ctx,
            stop,
            accept_handle: Some(accept_handle),
            drain_timeout: cfg.drain_timeout,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Registry {
        &self.ctx.registry
    }

    pub fn admission(&self) -> &Admission {
        &self.ctx.admit
    }

    /// Whether a client has requested `POST /admin/shutdown`.
    pub fn shutdown_requested(&self) -> bool {
        self.ctx.shutdown_requested.load(Ordering::Acquire)
    }

    /// Block until a client requests shutdown over HTTP (the CLI's
    /// foreground loop; no signal handling exists in offline std).
    pub fn wait_for_shutdown_request(&self) {
        while !self.shutdown_requested() {
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Graceful shutdown: stop accepting, shed new work, wait for every
    /// admitted sample to finish (bounded by `drain_timeout`), then
    /// drain and join the model coordinators. Returns whether the
    /// admission gate fully drained in time.
    pub fn shutdown(mut self) -> bool {
        self.shutdown_impl()
    }

    /// [`Server::shutdown`], then a final `/metrics`-schema snapshot
    /// taken *after* the drain — so work that completed during the
    /// drain window is included (what the CLI prints on exit).
    pub fn shutdown_with_report(mut self) -> (bool, Json) {
        let drained = self.shutdown_impl();
        (drained, metrics_json(&self.ctx))
    }

    fn shutdown_impl(&mut self) -> bool {
        let Some(handle) = self.accept_handle.take() else {
            return true; // already shut down
        };
        self.stop.store(true, Ordering::Release);
        // poke the blocking accept() so the loop observes `stop`
        let _ = TcpStream::connect(self.addr);
        let _ = handle.join();
        self.ctx.admit.begin_drain();
        let drained = self.ctx.admit.await_drain(self.drain_timeout);
        self.ctx.registry.shutdown();
        drained
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn accept_loop(listener: TcpListener, stop: &AtomicBool, ctx: &Arc<ServerCtx>, idle: Duration) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue, // transient accept error
        };
        let ctx = Arc::clone(ctx);
        // detached on purpose: drain is gated on admitted work, not on
        // connection threads (an idle keep-alive must not stall it)
        std::thread::spawn(move || handle_connection(stream, &ctx, idle));
    }
}

/// Per-request phase timings the infer handler fills in for the
/// request summary span (all zero on non-inference routes).
#[derive(Default)]
struct Phases {
    /// body JSON parse + sample validation
    parse_us: u64,
    /// admission gate acquire
    admit_us: u64,
    /// submit-to-last-reply through the coordinator
    exec_us: u64,
}

fn handle_connection(stream: TcpStream, ctx: &ServerCtx, idle: Duration) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(idle)).ok();
    stream.set_write_timeout(Some(idle)).ok();
    let mut reader = BufReader::new(stream);
    loop {
        let t_accept = Instant::now();
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return, // peer closed between requests
            Err(e) => {
                // A declared transfer-encoding gets a framed 501: the
                // request head parsed fine, only the body framing is
                // unimplemented — say so, then close (the unread body
                // bytes make the connection unusable for keep-alive).
                if e.downcast_ref::<http::UnsupportedTransferEncoding>().is_some() {
                    let resp = Response::error(501, &format!("{e:#}"));
                    let _ = resp.write_to(reader.get_mut(), false);
                    return;
                }
                // io-rooted failures (idle timeout, torn connection)
                // close silently — writing a framed 400 would
                // desynchronize a keep-alive client's next exchange.
                // Genuine protocol violations get the best-effort 400.
                if e.root_cause().downcast_ref::<std::io::Error>().is_none() {
                    let resp = Response::error(400, &format!("{e:#}"));
                    let _ = resp.write_to(reader.get_mut(), false);
                }
                return;
            }
        };
        let t_read = t_accept.elapsed();
        // request id: honour the client's x-request-id, mint one
        // otherwise; flows through admission, batching and spans, and
        // echoes back on the response
        let rid: Arc<str> = match req.header("x-request-id") {
            Some(v) if !v.is_empty() => Arc::from(v),
            _ => Arc::from(next_request_id().as_str()),
        };
        let keep = req.keep_alive();
        let mut phases = Phases::default();
        let resp = route(ctx, &req, &rid, &mut phases).with_header("x-request-id", &rid);
        let t_respond = Instant::now();
        let write_ok = resp.write_to(reader.get_mut(), keep).is_ok();
        trace_request(&req, &rid, resp.status, &phases, t_accept, t_read, t_respond.elapsed());
        if !write_ok || !keep {
            return;
        }
    }
}

/// Emit the per-request summary span: Info normally, escalated to
/// Error with `slow: true` past the `SIRA_TRACE_SLOW_MS` threshold.
fn trace_request(
    req: &Request,
    rid: &str,
    status: u16,
    ph: &Phases,
    t_accept: Instant,
    read: Duration,
    respond: Duration,
) {
    let total_us = t_accept.elapsed().as_micros() as u64;
    let slow = total_us >= tracer().slow_us();
    let level = if slow { Level::Error } else { Level::Info };
    if !tracer().enabled(level) {
        return;
    }
    tracer().emit(
        level,
        "request",
        vec![
            ("id", Json::Str(rid.to_string())),
            ("method", Json::Str(req.method.clone())),
            ("path", Json::Str(req.path.clone())),
            ("status", Json::Num(status as f64)),
            ("read_us", Json::Num(read.as_micros() as f64)),
            ("parse_us", Json::Num(ph.parse_us as f64)),
            ("admit_us", Json::Num(ph.admit_us as f64)),
            ("exec_us", Json::Num(ph.exec_us as f64)),
            ("respond_us", Json::Num(respond.as_micros() as f64)),
            ("total_us", Json::Num(total_us as f64)),
            ("slow", Json::Bool(slow)),
        ],
    );
}

/// Dispatch one request to its handler.
fn route(ctx: &ServerCtx, req: &Request, rid: &Arc<str>, phases: &mut Phases) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(
            200,
            &Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "uptime_ms",
                    Json::Num(ctx.started.elapsed().as_secs_f64() * 1e3),
                ),
                ("draining", Json::Bool(ctx.admit.is_draining())),
            ]),
        ),
        ("GET", "/metrics") => {
            if req.query.split('&').any(|kv| kv == "format=prom") {
                Response::text(200, "text/plain; version=0.0.4", metrics_prom(ctx))
            } else {
                Response::json(200, &metrics_json(ctx))
            }
        }
        ("GET", "/v1/models") => Response::json(200, &ctx.registry.models_json()),
        ("POST", "/admin/shutdown") => {
            ctx.admit.begin_drain();
            ctx.shutdown_requested.store(true, Ordering::Release);
            Response::json(200, &Json::obj(vec![("draining", Json::Bool(true))]))
        }
        (method, path) => {
            let infer_target = path
                .strip_prefix("/v1/models/")
                .and_then(|rest| rest.strip_suffix("/infer"));
            match infer_target {
                Some(model) if method == "POST" => handle_infer(ctx, model, req, rid, phases),
                Some(_) => Response::error(405, "inference requires POST"),
                None => Response::error(404, &format!("no route for {method} {path}")),
            }
        }
    }
}

/// `GET /metrics`: admission gate counters plus one shared-schema
/// metrics report per model — all machine-readable, no prose.
fn metrics_json(ctx: &ServerCtx) -> Json {
    Json::obj(vec![
        (
            "uptime_ms",
            Json::Num(ctx.started.elapsed().as_secs_f64() * 1e3),
        ),
        ("admission", ctx.admit.json()),
        ("models", ctx.registry.metrics_json()),
    ])
}

/// Sum one counter across a model's replicas: prom counter series stay
/// per-model (`model="..."`) no matter how many replicas serve it.
fn sum_replicas(e: &ModelEntry, f: impl Fn(&crate::coordinator::Metrics) -> u64) -> f64 {
    e.replicas.iter().map(|c| f(&c.metrics)).sum::<u64>() as f64
}

/// `GET /metrics?format=prom`: the same state as [`metrics_json`] in
/// Prometheus text exposition format 0.0.4 (one family per instrument,
/// per-model series labelled `model="..."`; with replicated models,
/// counters are summed per model and histogram series gain a
/// `replica` label, since bucket state is per-replica and cannot be
/// merged exactly).
fn metrics_prom(ctx: &ServerCtx) -> String {
    let mut w = PromWriter::new();
    w.family("sira_uptime_seconds", "Seconds since server start.", "gauge");
    w.sample("sira_uptime_seconds", &[], ctx.started.elapsed().as_secs_f64());

    w.family(
        "sira_admission_pending_samples",
        "Samples currently admitted and in flight.",
        "gauge",
    );
    w.sample("sira_admission_pending_samples", &[], ctx.admit.pending() as f64);
    w.family(
        "sira_admission_max_pending_samples",
        "Admission gate capacity in samples.",
        "gauge",
    );
    w.sample(
        "sira_admission_max_pending_samples",
        &[],
        ctx.admit.max_pending() as f64,
    );
    w.family(
        "sira_admission_admitted_requests_total",
        "Requests admitted since start.",
        "counter",
    );
    w.sample(
        "sira_admission_admitted_requests_total",
        &[],
        ctx.admit.admitted_total() as f64,
    );
    w.family(
        "sira_admission_shed_requests_total",
        "Requests shed (gate full or draining) since start.",
        "counter",
    );
    w.sample(
        "sira_admission_shed_requests_total",
        &[],
        ctx.admit.shed_total() as f64,
    );
    w.family(
        "sira_admission_draining",
        "1 while the server is draining for shutdown.",
        "gauge",
    );
    w.sample(
        "sira_admission_draining",
        &[],
        if ctx.admit.is_draining() { 1.0 } else { 0.0 },
    );

    w.family(
        "sira_samples_completed_total",
        "Samples served successfully, per model.",
        "counter",
    );
    for e in ctx.registry.entries() {
        w.sample(
            "sira_samples_completed_total",
            &[("model", &e.spec.name)],
            sum_replicas(e, |m| m.completed.load(std::sync::atomic::Ordering::Relaxed)),
        );
    }
    w.family(
        "sira_samples_failed_total",
        "Samples that failed in the engine, per model.",
        "counter",
    );
    for e in ctx.registry.entries() {
        w.sample(
            "sira_samples_failed_total",
            &[("model", &e.spec.name)],
            sum_replicas(e, |m| m.failed.load(std::sync::atomic::Ordering::Relaxed)),
        );
    }
    w.family(
        "sira_samples_expired_total",
        "Samples dropped on deadline before batching, per model.",
        "counter",
    );
    for e in ctx.registry.entries() {
        w.sample(
            "sira_samples_expired_total",
            &[("model", &e.spec.name)],
            sum_replicas(e, |m| m.expired.load(std::sync::atomic::Ordering::Relaxed)),
        );
    }
    w.family(
        "sira_batches_total",
        "Engine batches executed, per model.",
        "counter",
    );
    for e in ctx.registry.entries() {
        w.sample(
            "sira_batches_total",
            &[("model", &e.spec.name)],
            sum_replicas(e, |m| m.batches.load(std::sync::atomic::Ordering::Relaxed)),
        );
    }
    w.family(
        "sira_pending_requests",
        "Requests submitted but not yet resolved, per model (the least-loaded routing signal).",
        "gauge",
    );
    for e in ctx.registry.entries() {
        w.sample(
            "sira_pending_requests",
            &[("model", &e.spec.name)],
            sum_replicas(e, |m| m.pending()),
        );
    }
    w.family(
        "sira_request_latency_microseconds",
        "End-to-end per-sample latency (submit to reply), per model.",
        "histogram",
    );
    for e in ctx.registry.entries() {
        if e.replicas.len() == 1 {
            w.histogram(
                "sira_request_latency_microseconds",
                &[("model", &e.spec.name)],
                e.replicas[0].metrics.latency_histogram(),
            );
        } else {
            for (i, c) in e.replicas.iter().enumerate() {
                let r = i.to_string();
                w.histogram(
                    "sira_request_latency_microseconds",
                    &[("model", &e.spec.name), ("replica", &r)],
                    c.metrics.latency_histogram(),
                );
            }
        }
    }
    w.family(
        "sira_batch_occupancy_samples",
        "Samples per executed engine batch, per model.",
        "histogram",
    );
    for e in ctx.registry.entries() {
        if e.replicas.len() == 1 {
            w.histogram(
                "sira_batch_occupancy_samples",
                &[("model", &e.spec.name)],
                e.replicas[0].metrics.occupancy_histogram(),
            );
        } else {
            for (i, c) in e.replicas.iter().enumerate() {
                let r = i.to_string();
                w.histogram(
                    "sira_batch_occupancy_samples",
                    &[("model", &e.spec.name), ("replica", &r)],
                    c.metrics.occupancy_histogram(),
                );
            }
        }
    }
    w.finish()
}

/// Extract the request's sample list: `{"inputs": [[...], ...]}` or the
/// single-sample shorthand `{"input": [...]}`.
fn parse_samples(body: &Json) -> Result<Vec<Vec<f64>>> {
    if let Some(inputs) = body.opt("inputs") {
        inputs.as_arr()?.iter().map(|s| s.as_f64_vec()).collect()
    } else if let Some(single) = body.opt("input") {
        Ok(vec![single.as_f64_vec()?])
    } else {
        bail!("body must carry 'inputs' (array of samples) or 'input' (one sample)")
    }
}

/// Map coordinator/engine error text onto HTTP semantics: deadline
/// drops are 504, shutdown/drain races are 503 (retryable), everything
/// else is a 500.
fn error_response(msg: &str) -> Response {
    if msg.contains(DEADLINE_EXCEEDED) {
        Response::error(504, msg)
    } else if msg.contains(SHUT_DOWN) || msg.contains(WORKERS_GONE) {
        Response::error(503, msg)
    } else {
        Response::error(500, msg)
    }
}

/// `POST /v1/models/{name}/infer`.
fn handle_infer(
    ctx: &ServerCtx,
    model: &str,
    req: &Request,
    rid: &Arc<str>,
    phases: &mut Phases,
) -> Response {
    let Some(entry) = ctx.registry.get(model) else {
        return Response::error(
            404,
            &format!(
                "unknown model '{model}' (served: {})",
                ctx.registry.names().join(", ")
            ),
        );
    };
    let t_parse = Instant::now();
    let body = match req.body_json() {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
    };
    let samples = match parse_samples(&body) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if samples.is_empty() {
        return Response::error(400, "empty batch");
    }
    for (i, s) in samples.iter().enumerate() {
        if s.len() != entry.input_numel {
            return Response::error(
                400,
                &format!(
                    "sample {i} has {} elements, model '{model}' wants {} (shape {:?})",
                    s.len(),
                    entry.input_numel,
                    entry.input_shape
                ),
            );
        }
    }
    phases.parse_us = t_parse.elapsed().as_micros() as u64;
    let budget_ms = match req.header("x-deadline-ms") {
        None => None,
        Some(v) => match v.trim().parse::<u64>() {
            Ok(ms) => Some(ms),
            Err(_) => return Response::error(400, &format!("bad x-deadline-ms {v:?}")),
        },
    };
    let deadline = admit::deadline_in(budget_ms, ctx.default_deadline);

    // admission: one unit per sample, held until every reply landed
    let n = samples.len();
    let t_admit = Instant::now();
    let _permit = match ctx.admit.try_acquire(n) {
        Ok(p) => p,
        Err(e) => return Response::error(503, &e.to_string()),
    };
    phases.admit_us = t_admit.elapsed().as_micros() as u64;

    // submit each sample individually — the coordinator's dynamic
    // batcher coalesces them (and concurrent clients' samples) into
    // engine batches; every job carries the request id so batch spans
    // can be joined back to this request. Routing is per *request*, not
    // per sample: one least-loaded decision sends all of a request's
    // samples to the same replica, preserving batching locality.
    let t_exec = Instant::now();
    let coordinator = entry.route();
    let mut handles = Vec::with_capacity(n);
    for data in samples {
        let t = match Tensor::new(&entry.input_shape, data) {
            Ok(t) => t,
            Err(e) => return Response::error(400, &format!("{e:#}")),
        };
        match coordinator.submit_traced(t, deadline, Some(Arc::clone(rid))) {
            Ok(h) => handles.push(h),
            Err(e) => return error_response(&format!("{e:#}")),
        }
    }

    // await every reply before releasing the permit, even on partial
    // failure — admitted work must stay visible to the drain gate
    let mut outs = Vec::with_capacity(handles.len());
    let mut first_err: Option<String> = None;
    for h in handles {
        match h.recv() {
            Ok(Ok(t)) => outs.push(t),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(format!("{e:#}"));
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some("worker dropped the reply channel".to_string());
                }
            }
        }
    }
    phases.exec_us = t_exec.elapsed().as_micros() as u64;
    if let Some(msg) = first_err {
        return error_response(&msg);
    }
    Response::json(
        200,
        &Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("batch", Json::Num(outs.len() as f64)),
            (
                "output_shape",
                Json::nums(
                    &entry
                        .output_shape
                        .iter()
                        .map(|&d| d as f64)
                        .collect::<Vec<_>>(),
                ),
            ),
            (
                "outputs",
                Json::Arr(outs.iter().map(|t| Json::nums(t.data())).collect()),
            ),
        ]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::http::Client;

    fn tiny_server(max_pending: usize) -> Server {
        let cfg = ServerConfig {
            specs: vec![ModelSpec::engine_default("tfc")],
            max_pending,
            ..Default::default()
        };
        Server::start(cfg).unwrap()
    }

    #[test]
    fn healthz_models_and_infer_roundtrip() {
        let server = tiny_server(64);
        let addr = server.addr().to_string();
        let mut c = Client::connect(&addr).unwrap();
        let (status, body) = c.get("/healthz").unwrap();
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());

        let (status, body) = c.get("/v1/models").unwrap();
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let models = v.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(
            models[0].get("input_shape").unwrap().as_usize_vec().unwrap(),
            vec![1, 784]
        );

        // same keep-alive connection: two inference requests
        let sample = Json::nums(&[100.0; 784]);
        let body = Json::obj(vec![("inputs", Json::Arr(vec![sample.clone(), sample]))]);
        for _ in 0..2 {
            let (status, reply) = c
                .post_json("/v1/models/tfc/infer", &[], &body)
                .unwrap();
            assert_eq!(status, 200, "{reply}");
            assert_eq!(reply.get("batch").unwrap().as_usize().unwrap(), 2);
            let outs = reply.get("outputs").unwrap().as_arr().unwrap();
            assert_eq!(outs.len(), 2);
            assert_eq!(outs[0].as_f64_vec().unwrap().len(), 10);
        }
        assert!(server.shutdown(), "gate should drain");
    }

    /// A chunked request gets a framed 501 (not a body misread or a
    /// silent close), and the connection is then closed.
    #[test]
    fn chunked_transfer_encoding_gets_a_framed_501() {
        use std::io::{BufReader, Write};
        let server = tiny_server(64);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(
            b"POST /v1/models/tfc/infer HTTP/1.1\r\nhost: x\r\n\
              transfer-encoding: chunked\r\n\r\n4\r\nabcd\r\n0\r\n\r\n",
        )
        .unwrap();
        let mut r = BufReader::new(s);
        let (status, body) = http::read_response(&mut r).unwrap();
        assert_eq!(status, 501, "{}", String::from_utf8_lossy(&body));
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(
            v.get("error").unwrap().as_str().unwrap().contains("transfer-encoding"),
            "{v}"
        );
        // server closed the connection after answering
        let mut rest = Vec::new();
        std::io::Read::read_to_end(&mut r, &mut rest).unwrap();
        assert!(rest.is_empty());
        assert!(server.shutdown());
    }

    #[test]
    fn prom_metrics_and_request_id_echo() {
        let server = tiny_server(64);
        let addr = server.addr().to_string();
        let mut c = Client::connect(&addr).unwrap();
        // client-supplied id echoes back
        let body = Json::obj(vec![("input", Json::nums(&[1.0; 784]))]);
        let (status, headers, _) = c
            .request_full(
                "POST",
                "/v1/models/tfc/infer",
                &[("x-request-id", "my-rid-1")],
                body.to_string().as_bytes(),
            )
            .unwrap();
        assert_eq!(status, 200);
        let rid = headers.iter().find(|(k, _)| k == "x-request-id").map(|(_, v)| v.as_str());
        assert_eq!(rid, Some("my-rid-1"));
        // a minted id is present when the client sends none
        let (_, headers, _) = c.request_full("GET", "/healthz", &[], b"").unwrap();
        let rid = headers
            .iter()
            .find(|(k, _)| k == "x-request-id")
            .map(|(_, v)| v.clone())
            .expect("minted request id");
        assert!(rid.starts_with("r-"), "{rid}");
        // the prom exposition parses and carries the per-model histogram
        let (status, text) = c.get("/metrics?format=prom").unwrap();
        assert_eq!(status, 200);
        let text = String::from_utf8(text).unwrap();
        let n = crate::obs::validate_exposition(&text).unwrap();
        assert!(n > 10, "{n} samples:\n{text}");
        assert!(
            text.contains("sira_request_latency_microseconds_bucket{model=\"tfc\",le=\"+Inf\"}"),
            "{text}"
        );
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_400_class_errors() {
        let server = tiny_server(64);
        let addr = server.addr().to_string();
        let mut c = Client::connect(&addr).unwrap();
        // unknown model
        let one = Json::obj(vec![("input", Json::nums(&[1.0]))]);
        let (status, _) = c.post_json("/v1/models/nope/infer", &[], &one).unwrap();
        assert_eq!(status, 404);
        // wrong method on an infer route
        let (status, _) = c.get("/v1/models/tfc/infer").unwrap();
        assert_eq!(status, 405);
        // malformed JSON
        let (status, _) = c
            .request("POST", "/v1/models/tfc/infer", &[], b"{nope")
            .unwrap();
        assert_eq!(status, 400);
        // wrong sample size
        let (status, reply) = c.post_json("/v1/models/tfc/infer", &[], &one).unwrap();
        assert_eq!(status, 400, "{reply}");
        // bad deadline header
        let good = Json::obj(vec![("input", Json::nums(&[0.0; 784]))]);
        let (status, _) = c
            .post_json("/v1/models/tfc/infer", &[("x-deadline-ms", "soon")], &good)
            .unwrap();
        assert_eq!(status, 400);
        // unknown route
        let (status, _) = c.get("/nope").unwrap();
        assert_eq!(status, 404);
        server.shutdown();
    }
}
