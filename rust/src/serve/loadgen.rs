//! Loopback load generator: drive a running serve front end over real
//! sockets and report client-observed latency percentiles + throughput,
//! so serving performance joins the benchmark trajectory next to the
//! kernel-level numbers.
//!
//! Two modes:
//! * **closed loop** (default) — each connection fires its next request
//!   the moment the previous response lands: measures the server's
//!   capacity at a fixed concurrency.
//! * **open loop** (`rate`) — requests are fired on a fixed global
//!   schedule regardless of response progress, and latency is measured
//!   from the *scheduled* send time, so queueing delay under overload is
//!   charged to the server instead of silently omitted (the coordinated-
//!   omission correction).
//!
//! Responses are classified by status: 200 ok, 503 shed (admission
//! load-shed or drain), 504 expired (deadline), anything else failed.
//! The JSON report renders latency through the shared percentile emitter
//! ([`crate::util::stats::percentile_json`]) — the same schema the
//! server's own `/metrics` uses.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::trace::next_request_id;
use crate::obs::validate_exposition;
use crate::serve::http::Client;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;

/// One load-generation run.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// server address, e.g. `127.0.0.1:8080`
    pub addr: String,
    /// model to target (`POST /v1/models/{model}/infer`)
    pub model: String,
    /// concurrent keep-alive connections
    pub conns: usize,
    /// total requests across all connections
    pub requests: usize,
    /// samples per request body
    pub batch: usize,
    /// open-loop target rate in requests/s across all connections;
    /// `None` selects closed-loop mode
    pub rate: Option<f64>,
    /// per-request deadline budget sent as `x-deadline-ms`
    pub deadline_ms: Option<u64>,
    /// seed for the synthetic request payloads
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            addr: String::new(),
            model: "tfc".to_string(),
            conns: 2,
            requests: 64,
            batch: 1,
            rate: None,
            deadline_ms: None,
            seed: 0x10AD,
        }
    }
}

/// Aggregated client-side results of one run.
#[derive(Debug)]
pub struct LoadReport {
    pub mode: &'static str,
    pub model: String,
    pub conns: usize,
    pub requests: usize,
    pub batch: usize,
    pub ok: usize,
    pub shed: usize,
    pub expired: usize,
    pub failed: usize,
    pub wall: Duration,
    /// per-request latency of successful requests, microseconds
    pub latencies_us: Vec<u64>,
}

impl LoadReport {
    /// Successful requests per second over the run's wall time.
    pub fn throughput_rps(&self) -> f64 {
        self.ok as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Successful *samples* per second (requests × batch).
    pub fn throughput_sps(&self) -> f64 {
        self.throughput_rps() * self.batch as f64
    }

    /// One JSON line (`{"bench":"loadgen",...}`) with counters,
    /// throughput and the shared-schema latency percentiles.
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("loadgen".to_string())),
            ("mode", Json::Str(self.mode.to_string())),
            ("model", Json::Str(self.model.clone())),
            ("conns", Json::Num(self.conns as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("wall_ms", Json::Num(self.wall.as_secs_f64() * 1e3)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("throughput_sps", Json::Num(self.throughput_sps())),
            ("latency_us", stats::percentile_json(&self.latencies_us)),
        ])
    }
}

/// Scrape `GET /metrics?format=prom` from a live server and validate
/// every line of the exposition; returns the sample count. Errors on
/// any malformed line — the CI smoke (`sira-finn loadgen --prom`) and
/// `scripts/verify.sh` gate on this.
pub fn scrape_prom(addr: &str) -> Result<usize> {
    let mut c = Client::connect(addr)?;
    let (status, body) = c.get("/metrics?format=prom")?;
    if status != 200 {
        anyhow::bail!("GET /metrics?format=prom returned {status}");
    }
    let text = std::str::from_utf8(&body)?;
    validate_exposition(text)
}

/// Ask the server for the model's per-sample input shape
/// (`GET /v1/models`), so payloads match without hardcoding the zoo.
pub fn fetch_input_shape(addr: &str, model: &str) -> Result<Vec<usize>> {
    let mut c = Client::connect(addr)?;
    let (status, body) = c.get("/v1/models")?;
    if status != 200 {
        anyhow::bail!("GET /v1/models returned {status}");
    }
    let v = Json::parse(std::str::from_utf8(&body)?)?;
    for m in v.get("models")?.as_arr()? {
        if m.get("name")?.as_str()? == model {
            return m.get("input_shape")?.as_usize_vec();
        }
    }
    anyhow::bail!("server does not serve model '{model}'")
}

/// Pre-render a small pool of request bodies (seeded, uint8-valued
/// samples) so JSON generation stays out of the timed loop.
fn payload_pool(spec: &LoadSpec, numel: usize) -> Vec<String> {
    let mut rng = Rng::new(spec.seed);
    (0..8)
        .map(|_| {
            let samples: Vec<Json> = (0..spec.batch)
                .map(|_| {
                    Json::nums(&(0..numel).map(|_| rng.int_in(0, 255) as f64).collect::<Vec<_>>())
                })
                .collect();
            Json::obj(vec![("inputs", Json::Arr(samples))]).to_string()
        })
        .collect()
}

/// Per-thread tallies, merged after join.
#[derive(Default)]
struct Tally {
    ok: usize,
    shed: usize,
    expired: usize,
    failed: usize,
    latencies_us: Vec<u64>,
}

impl Tally {
    fn classify(&mut self, status: u16, latency: Duration) {
        match status {
            200 => {
                self.ok += 1;
                self.latencies_us.push(latency.as_micros() as u64);
            }
            503 => self.shed += 1,
            504 => self.expired += 1,
            _ => self.failed += 1,
        }
    }
}

/// Run one load-generation pass against a live server.
pub fn run(spec: &LoadSpec) -> Result<LoadReport> {
    let shape = fetch_input_shape(&spec.addr, &spec.model)?;
    let numel: usize = shape.iter().product();
    let bodies = Arc::new(payload_pool(spec, numel));
    let path = format!("/v1/models/{}/infer", spec.model);
    let deadline_hdr = spec.deadline_ms.map(|ms| ms.to_string());
    let conns = spec.conns.max(1);
    let interval = spec.rate.map(|r| Duration::from_secs_f64(1.0 / r.max(1e-9)));

    let t0 = Instant::now();
    let tallies: Vec<Tally> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            let bodies = Arc::clone(&bodies);
            let path = &path;
            let addr = &spec.addr;
            let deadline_hdr = deadline_hdr.as_deref();
            handles.push(s.spawn(move || -> Result<Tally> {
                let mut client = Client::connect(addr)?;
                let mut tally = Tally::default();
                let mut j = c;
                while j < spec.requests {
                    let sched = match interval {
                        // open loop: request j fires at t0 + j*interval
                        Some(iv) => {
                            let at = t0 + iv.mul_f64(j as f64);
                            let now = Instant::now();
                            if at > now {
                                std::thread::sleep(at - now);
                            }
                            at
                        }
                        None => Instant::now(),
                    };
                    // one id per request, so server-side spans can be
                    // joined back to this client's timeline
                    let rid = next_request_id();
                    let mut headers: Vec<(&str, &str)> = vec![("x-request-id", &rid)];
                    if let Some(v) = deadline_hdr {
                        headers.push(("x-deadline-ms", v));
                    }
                    let body = &bodies[j % bodies.len()];
                    // the connection is persistent across requests; on a
                    // transport error (server dropped the keep-alive,
                    // mid-run restart) reconnect once and retry the same
                    // request rather than killing the whole connection's
                    // worth of remaining requests
                    let (status, _reply) =
                        match client.request("POST", path, &headers, body.as_bytes()) {
                            Ok(r) => r,
                            Err(_) => {
                                client = Client::connect(addr)?;
                                client.request("POST", path, &headers, body.as_bytes())?
                            }
                        };
                    tally.classify(status, sched.elapsed());
                    j += conns;
                }
                Ok(tally)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("loadgen thread panicked"))?)
            .collect::<Result<Vec<_>>>()
    })?;
    let wall = t0.elapsed();

    let mut report = LoadReport {
        mode: if interval.is_some() { "open" } else { "closed" },
        model: spec.model.clone(),
        conns,
        requests: spec.requests,
        batch: spec.batch,
        ok: 0,
        shed: 0,
        expired: 0,
        failed: 0,
        wall,
        latencies_us: Vec::new(),
    };
    for t in tallies {
        report.ok += t.ok;
        report.shed += t.shed;
        report.expired += t.expired;
        report.failed += t.failed;
        report.latencies_us.extend(t.latencies_us);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_schema() {
        let r = LoadReport {
            mode: "closed",
            model: "tfc".into(),
            conns: 2,
            requests: 10,
            batch: 4,
            ok: 9,
            shed: 1,
            expired: 0,
            failed: 0,
            wall: Duration::from_millis(90),
            latencies_us: vec![100, 200, 300],
        };
        let j = r.json();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "loadgen");
        assert_eq!(j.get("ok").unwrap().as_usize().unwrap(), 9);
        assert_eq!(j.get("shed").unwrap().as_usize().unwrap(), 1);
        assert!(j.get("throughput_rps").unwrap().as_f64().unwrap() > 99.0);
        assert!((r.throughput_sps() - 4.0 * r.throughput_rps()).abs() < 1e-9);
        assert_eq!(
            j.get("latency_us").unwrap().get("count").unwrap().as_usize().unwrap(),
            3
        );
    }

    #[test]
    fn payload_pool_matches_batch_and_numel() {
        let spec = LoadSpec {
            batch: 3,
            ..Default::default()
        };
        let pool = payload_pool(&spec, 5);
        assert_eq!(pool.len(), 8);
        for body in &pool {
            let v = Json::parse(body).unwrap();
            let samples = v.get("inputs").unwrap().as_arr().unwrap();
            assert_eq!(samples.len(), 3);
            for s in samples {
                assert_eq!(s.as_f64_vec().unwrap().len(), 5);
            }
        }
        // seeded: two pools from the same spec are identical
        assert_eq!(pool, payload_pool(&spec, 5));
    }
}
