//! Admission control for the serving front end: a bounded pending-work
//! gate with load-shed, per-request deadline budgets, and the drain
//! handshake graceful shutdown uses.
//!
//! The coordinator's submit channel is unbounded by design (the batcher
//! wants to see everything that has arrived), so overload protection
//! lives one layer up: every HTTP inference request must acquire an
//! admission permit for its sample count before any job is submitted.
//! When the gate is full the request is shed immediately with HTTP 503 —
//! bounded queueing delay for admitted work beats unbounded latency for
//! everyone, which is also how the FDNA hardware this models behaves
//! (backpressure at the input FIFO, not silent buffering).
//!
//! Units are **samples**, not requests: a 8-sample batch request holds 8
//! units, so `max_pending` bounds the actual compute backlog regardless
//! of how clients shape their batches. A request larger than the whole
//! bound is admitted only when the gate is idle (it could never run
//! otherwise).

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Why a request was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// the pending-work gate is at capacity — classic load-shed
    Full { pending: usize, max_pending: usize },
    /// the server is draining for shutdown; no new work is accepted
    Draining,
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmitError::Full {
                pending,
                max_pending,
            } => write!(
                f,
                "server overloaded: {pending} samples pending (limit {max_pending}), try again"
            ),
            AdmitError::Draining => write!(f, "server is draining for shutdown"),
        }
    }
}

/// RAII permit for `n` admitted samples; releases on drop.
pub struct Permit<'a> {
    gate: &'a Admission,
    n: usize,
}

impl Permit<'_> {
    pub fn samples(&self) -> usize {
        self.n
    }
}

impl fmt::Debug for Permit<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Permit({} samples)", self.n)
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.gate.pending.fetch_sub(self.n, Ordering::AcqRel);
    }
}

/// The bounded admission gate.
pub struct Admission {
    max_pending: usize,
    pending: AtomicUsize,
    draining: AtomicBool,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl Admission {
    pub fn new(max_pending: usize) -> Admission {
        Admission {
            max_pending: max_pending.max(1),
            pending: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Try to admit `n` samples. On success the returned [`Permit`] must
    /// be held for as long as the work is in flight — admission is what
    /// graceful drain waits on.
    pub fn try_acquire(&self, n: usize) -> Result<Permit<'_>, AdmitError> {
        if self.draining.load(Ordering::Acquire) {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(AdmitError::Draining);
        }
        let res = self.pending.fetch_update(
            Ordering::AcqRel,
            Ordering::Acquire,
            |p| {
                // an oversized request (n > max_pending) is admitted
                // only from idle, so it can run at all without letting
                // two of them stack up
                if p > 0 && p + n > self.max_pending {
                    None
                } else {
                    Some(p + n)
                }
            },
        );
        match res {
            Ok(_) => {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(Permit { gate: self, n })
            }
            Err(p) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(AdmitError::Full {
                    pending: p,
                    max_pending: self.max_pending,
                })
            }
        }
    }

    /// Stop admitting new work (requests now shed with `Draining`).
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Block until every admitted sample has released its permit, or the
    /// timeout passes. Returns whether the gate fully drained.
    pub fn await_drain(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.pending.load(Ordering::Acquire) > 0 {
            if t0.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }

    /// Samples currently admitted and in flight.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    /// Gate capacity in samples.
    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    /// Requests admitted since start.
    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Requests shed (full or draining) since start.
    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Gate state for the `/metrics` report.
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("max_pending", Json::Num(self.max_pending as f64)),
            ("pending", Json::Num(self.pending() as f64)),
            ("admitted", Json::Num(self.admitted_total() as f64)),
            ("shed", Json::Num(self.shed_total() as f64)),
            ("draining", Json::Bool(self.is_draining())),
        ])
    }
}

/// Resolve a request's absolute deadline: an explicit per-request budget
/// (the `x-deadline-ms` header) overrides the server default; `None`
/// everywhere means no deadline. A zero budget is already expired — the
/// canonical "drop this unless it can run immediately" probe.
pub fn deadline_in(budget_ms: Option<u64>, default: Option<Duration>) -> Option<Instant> {
    match budget_ms {
        Some(ms) => Some(Instant::now() + Duration::from_millis(ms)),
        None => default.map(|d| Instant::now() + d),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_the_bound_and_sheds_past_it() {
        let g = Admission::new(8);
        let a = g.try_acquire(5).unwrap();
        let b = g.try_acquire(3).unwrap();
        assert_eq!(g.pending(), 8);
        let err = g.try_acquire(1).unwrap_err();
        assert!(matches!(err, AdmitError::Full { pending: 8, .. }), "{err}");
        drop(a);
        assert_eq!(g.pending(), 3);
        let c = g.try_acquire(4).unwrap();
        drop(b);
        drop(c);
        assert_eq!(g.pending(), 0);
        assert_eq!(g.admitted_total(), 3);
        assert_eq!(g.shed_total(), 1);
    }

    #[test]
    fn oversized_requests_only_run_from_idle() {
        let g = Admission::new(4);
        // idle: a request bigger than the whole gate is still served
        let big = g.try_acquire(9).unwrap();
        assert_eq!(g.pending(), 9);
        // but nothing else gets in next to it
        assert!(g.try_acquire(1).is_err());
        drop(big);
        assert!(g.try_acquire(1).is_ok());
    }

    #[test]
    fn drain_sheds_new_work_and_waits_for_permits() {
        let g = Admission::new(8);
        let held = g.try_acquire(2).unwrap();
        g.begin_drain();
        assert_eq!(g.try_acquire(1).unwrap_err(), AdmitError::Draining);
        assert!(!g.await_drain(Duration::from_millis(5)), "held permit");
        drop(held);
        assert!(g.await_drain(Duration::from_millis(100)));
        assert!(g.is_draining());
    }

    #[test]
    fn deadline_budget_resolution() {
        assert!(deadline_in(None, None).is_none());
        let d = deadline_in(Some(0), None).unwrap();
        assert!(d <= Instant::now());
        let d = deadline_in(None, Some(Duration::from_secs(5))).unwrap();
        assert!(d > Instant::now());
        // explicit budget wins over the default
        let d = deadline_in(Some(0), Some(Duration::from_secs(500))).unwrap();
        assert!(d <= Instant::now() + Duration::from_secs(1));
    }

    #[test]
    fn json_snapshot_schema() {
        let g = Admission::new(16);
        let _p = g.try_acquire(3).unwrap();
        let j = g.json();
        assert_eq!(j.get("pending").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("max_pending").unwrap().as_usize().unwrap(), 16);
        assert!(!j.get("draining").unwrap().as_bool().unwrap());
    }
}
