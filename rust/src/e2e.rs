//! End-to-end validation driver (DESIGN.md §4): proves all three layers
//! compose by checking four implementations of the same QNN against each
//! other on random inputs:
//!
//! 1. the JAX fake-quant reference, AOT-lowered and executed via PJRT;
//! 2. the JAX streamlined-integer model (through the L1 Pallas kernels),
//!    also via PJRT;
//! 3. the rust graph executor on the graph rebuilt from the sidecar;
//! 4. the rust executor on the SIRA-streamlined + threshold-converted
//!    graph (thresholds re-derived *independently* by the rust compiler).
//!
//! Used by `examples/e2e_cnv.rs` and `sira-finn e2e`.

use anyhow::{bail, Context, Result};

use crate::accel::{compile_qnn, CompileOptions, TailStyle};
use crate::executor::Executor;
use crate::hw::ThresholdStyle;
use crate::models::sidecar::load_sidecar_file;
use crate::passes::accmin::AccPolicy;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Outcome of the end-to-end run.
pub struct E2eReport {
    pub samples: usize,
    pub max_dev_ref_vs_rust: f64,
    pub max_dev_ref_vs_streamlined_rust: f64,
    pub max_dev_ref_vs_streamlined_pjrt: f64,
    pub lut: f64,
    pub dsp: f64,
    pub fps: f64,
}

/// Run the full four-way equivalence check + FDNA build.
pub fn run_e2e(artifact_dir: &str, samples: usize) -> Result<()> {
    let r = e2e_report(artifact_dir, samples)?;
    println!(
        "e2e OK over {} samples:\n\
         max |ref_pjrt - rust_executor|            = {:.2e}\n\
         max |ref_pjrt - rust_streamlined|         = {:.2e}\n\
         max |ref_pjrt - pallas_streamlined_pjrt|  = {:.2e}",
        r.samples, r.max_dev_ref_vs_rust, r.max_dev_ref_vs_streamlined_rust,
        r.max_dev_ref_vs_streamlined_pjrt
    );
    println!(
        "FDNA (thresholding + SIRA accumulators): LUT {:.0}, DSP {:.0}, {:.0} FPS @200MHz",
        r.lut, r.dsp, r.fps
    );
    Ok(())
}

/// Produce the report (library form, used by tests).
pub fn e2e_report(artifact_dir: &str, samples: usize) -> Result<E2eReport> {
    let sidecar_path = format!("{artifact_dir}/model_params.json");
    let m = load_sidecar_file(&sidecar_path)?;
    let rt = Runtime::cpu()?;
    let reference = rt
        .load_hlo_text(&format!("{artifact_dir}/model.hlo.txt"))
        .context("loading reference artifact")?;
    let streamlined_pjrt = rt
        .load_hlo_text(&format!("{artifact_dir}/model_streamlined.hlo.txt"))
        .context("loading streamlined artifact")?;

    // rust compile: streamline + thresholds + SIRA accumulators
    let opts = CompileOptions {
        tail_style: TailStyle::Thresholding(ThresholdStyle::BinarySearch),
        acc_policy: AccPolicy::Sira,
        target_cycles: 4096,
        ..Default::default()
    };
    let compiled = compile_qnn(m.graph.clone(), &m.input_ranges, &opts)?;
    if compiled
        .thr_report
        .as_ref()
        .map(|t| t.converted)
        .unwrap_or(0)
        == 0
    {
        bail!("rust threshold conversion converted nothing");
    }

    let mut exec_orig = Executor::new(&m.graph)?;
    let mut exec_streamlined = Executor::new(&compiled.graph)?;
    let mut rng = Rng::new(0xE2E);
    let numel: usize = m.input_shape.iter().product();
    let mut dev_rust = 0f64;
    let mut dev_st_rust = 0f64;
    let mut dev_st_pjrt = 0f64;
    for _ in 0..samples {
        let x = Tensor::new(
            &m.input_shape,
            (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
        )?;
        let y_ref = reference.run(std::slice::from_ref(&x))?.remove(0);
        let y_rust = exec_orig.run_single(&x)?.remove(0);
        let y_st_rust = exec_streamlined.run_single(&x)?.remove(0);
        let y_st_pjrt = streamlined_pjrt.run(std::slice::from_ref(&x))?.remove(0);
        for i in 0..y_ref.numel() {
            dev_rust = dev_rust.max((y_ref.data()[i] - y_rust.data()[i]).abs());
            dev_st_rust = dev_st_rust.max((y_ref.data()[i] - y_st_rust.data()[i]).abs());
            dev_st_pjrt = dev_st_pjrt.max((y_ref.data()[i] - y_st_pjrt.data()[i]).abs());
        }
    }
    // f32 PJRT vs f64 rust: small tolerance; implementations agree when
    // every pair deviates by less than the smallest quantization step
    let tol = 1e-3;
    if dev_rust > tol || dev_st_rust > tol || dev_st_pjrt > tol {
        bail!(
            "e2e deviation too large: rust {dev_rust:.2e}, streamlined-rust {dev_st_rust:.2e}, \
             streamlined-pjrt {dev_st_pjrt:.2e}"
        );
    }
    Ok(E2eReport {
        samples,
        max_dev_ref_vs_rust: dev_rust,
        max_dev_ref_vs_streamlined_rust: dev_st_rust,
        max_dev_ref_vs_streamlined_pjrt: dev_st_pjrt,
        lut: compiled.fdna.total.lut,
        dsp: compiled.fdna.total.dsp,
        fps: compiled.fdna.perf.fps,
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn e2e_four_way_equivalence() {
        if !std::path::Path::new("artifacts/model_params.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let r = super::e2e_report("artifacts", 4).unwrap();
        assert!(r.max_dev_ref_vs_rust < 1e-3);
        assert!(r.max_dev_ref_vs_streamlined_rust < 1e-3);
        assert!(r.max_dev_ref_vs_streamlined_pjrt < 1e-3);
        assert!(r.lut > 0.0 && r.fps > 0.0);
    }
}
