//! Shape inference over the graph (ONNX-style static shapes).

use anyhow::{bail, Result};

use crate::tensor::broadcast_shape;

use super::{Graph, Op};

/// Infer and record shapes for every tensor in the graph. Requires shapes
/// for all graph inputs and initializers (initializers carry their own).
pub fn infer_shapes(g: &mut Graph) -> Result<()> {
    let order = g.topo_order()?;
    for idx in order {
        let node = g.nodes[idx].clone();
        let in_shapes: Vec<Vec<usize>> = node
            .inputs
            .iter()
            .map(|i| {
                g.shapes
                    .get(i)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("no shape for tensor '{i}' (node '{}')", node.name))
            })
            .collect::<Result<_>>()?;
        let out_shapes = infer_node(&node.op, &in_shapes, &node.name)?;
        if out_shapes.len() != node.outputs.len() {
            bail!("node '{}': inferred {} outputs, node declares {}", node.name, out_shapes.len(), node.outputs.len());
        }
        for (o, s) in node.outputs.iter().zip(out_shapes) {
            g.shapes.insert(o.clone(), s);
        }
    }
    Ok(())
}

/// Shape inference for a single node.
pub fn infer_node(op: &Op, ins: &[Vec<usize>], name: &str) -> Result<Vec<Vec<usize>>> {
    let shape = match op {
        Op::Quant { .. } => {
            // output shape = broadcast(x, scale, zero_point)
            let mut s = ins[0].clone();
            for extra in ins.iter().take(3).skip(1) {
                s = broadcast_shape(&s, extra)?;
            }
            s
        }
        Op::MatMul => {
            let (a, b) = (&ins[0], &ins[1]);
            if a.len() != 2 || b.len() != 2 {
                bail!("node '{name}': MatMul expects rank-2, got {a:?} x {b:?}");
            }
            if a[1] != b[0] {
                bail!("node '{name}': MatMul inner-dim mismatch {a:?} x {b:?}");
            }
            vec![a[0], b[1]]
        }
        Op::Gemm => {
            let (a, b) = (&ins[0], &ins[1]);
            if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
                bail!("node '{name}': Gemm shape mismatch {a:?} x {b:?}");
            }
            vec![a[0], b[1]]
        }
        Op::Conv { spec, group } => {
            let (x, w) = (&ins[0], &ins[1]);
            if x.len() != 4 || w.len() != 4 {
                bail!("node '{name}': Conv expects NCHW x OIHW");
            }
            if w[1] * group != x[1] {
                bail!(
                    "node '{name}': Conv channels mismatch: x C={}, w I={} group={}",
                    x[1],
                    w[1],
                    group
                );
            }
            let (oh, ow) = spec.out_hw(x[2], x[3]);
            vec![x[0], w[0], oh, ow]
        }
        Op::Add | Op::Sub | Op::Mul | Op::Div => broadcast_shape(&ins[0], &ins[1])?,
        Op::Relu | Op::Sigmoid | Op::Identity | Op::Floor | Op::Clip { .. } => ins[0].clone(),
        Op::BatchNorm { .. } => ins[0].clone(),
        Op::MaxPool { spec } | Op::AveragePool { spec } => {
            let x = &ins[0];
            if x.len() != 4 {
                bail!("node '{name}': pooling expects NCHW");
            }
            let (oh, ow) = spec.out_hw(x[2], x[3]);
            vec![x[0], x[1], oh, ow]
        }
        Op::GlobalAveragePool => {
            let x = &ins[0];
            if x.len() != 4 {
                bail!("node '{name}': GlobalAveragePool expects NCHW");
            }
            vec![x[0], x[1], 1, 1]
        }
        Op::Reshape { shape } => {
            let numel: usize = ins[0].iter().product();
            let mut out: Vec<usize> = Vec::with_capacity(shape.len());
            let mut infer_at: Option<usize> = None;
            let mut known: usize = 1;
            for (i, &d) in shape.iter().enumerate() {
                if d == -1 {
                    if infer_at.is_some() {
                        bail!("node '{name}': multiple -1 in reshape");
                    }
                    infer_at = Some(i);
                    out.push(0);
                } else if d == 0 {
                    let v = ins[0][i];
                    out.push(v);
                    known *= v;
                } else {
                    out.push(d as usize);
                    known *= d as usize;
                }
            }
            if let Some(i) = infer_at {
                if numel % known != 0 {
                    bail!("node '{name}': reshape cannot infer -1");
                }
                out[i] = numel / known;
            } else if known != numel {
                bail!("node '{name}': reshape element count mismatch");
            }
            out
        }
        Op::Flatten { axis } => {
            let x = &ins[0];
            let outer: usize = x[..*axis].iter().product();
            let inner: usize = x[*axis..].iter().product();
            vec![outer, inner]
        }
        Op::Transpose { perm } => {
            if perm.len() != ins[0].len() {
                bail!("node '{name}': transpose arity mismatch");
            }
            perm.iter().map(|&p| ins[0][p]).collect()
        }
        Op::Concat { axis } => {
            let mut out = ins[0].clone();
            if *axis >= out.len() {
                bail!("node '{name}': concat axis out of range");
            }
            for s in &ins[1..] {
                if s.len() != out.len() {
                    bail!("node '{name}': concat rank mismatch");
                }
                for d in 0..out.len() {
                    if d != *axis && s[d] != out[d] {
                        bail!("node '{name}': concat dim {d} mismatch");
                    }
                }
                out[*axis] += s[*axis];
            }
            out
        }
        Op::MultiThreshold { .. } => ins[0].clone(),
    };
    Ok(vec![shape])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Node};
    use crate::tensor::{Conv2dSpec, Tensor};

    #[test]
    fn infers_mlp_shapes() {
        let mut g = Graph::new("mlp");
        g.add_input("x", &[1, 784]);
        g.add_initializer("w", Tensor::zeros(&[784, 64]));
        g.add_node(Node::new("mm", Op::MatMul, &["x", "w"], &["h"]));
        g.add_node(Node::new("r", Op::Relu, &["h"], &["y"]));
        g.outputs.push("y".into());
        infer_shapes(&mut g).unwrap();
        assert_eq!(g.shapes["h"], vec![1, 64]);
        assert_eq!(g.shapes["y"], vec![1, 64]);
    }

    #[test]
    fn infers_conv_chain() {
        let mut g = Graph::new("conv");
        g.add_input("x", &[1, 3, 32, 32]);
        g.add_initializer("w", Tensor::zeros(&[16, 3, 3, 3]));
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (1, 1),
            pad: (1, 1),
        };
        g.add_node(Node::new("c", Op::Conv { spec, group: 1 }, &["x", "w"], &["h"]));
        g.add_node(Node::new(
            "p",
            Op::MaxPool {
                spec: Conv2dSpec {
                    kernel: (2, 2),
                    stride: (2, 2),
                    pad: (0, 0),
                },
            },
            &["h"],
            &["y"],
        ));
        g.outputs.push("y".into());
        infer_shapes(&mut g).unwrap();
        assert_eq!(g.shapes["h"], vec![1, 16, 32, 32]);
        assert_eq!(g.shapes["y"], vec![1, 16, 16, 16]);
    }

    #[test]
    fn reshape_with_minus_one_and_zero() {
        let out = infer_node(
            &Op::Reshape {
                shape: vec![0, -1],
            },
            &[vec![2, 3, 4]],
            "r",
        )
        .unwrap();
        assert_eq!(out[0], vec![2, 12]);
        assert!(infer_node(
            &Op::Reshape {
                shape: vec![-1, -1]
            },
            &[vec![4]],
            "r"
        )
        .is_err());
    }

    #[test]
    fn flatten_axis() {
        let out = infer_node(&Op::Flatten { axis: 1 }, &[vec![2, 3, 4, 5]], "f").unwrap();
        assert_eq!(out[0], vec![2, 60]);
    }

    #[test]
    fn conv_group_mismatch_rejected() {
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (1, 1),
            pad: (1, 1),
        };
        // depthwise weights (C,1,3,3) with group=C ok
        let ok = infer_node(
            &Op::Conv { spec, group: 8 },
            &[vec![1, 8, 16, 16], vec![8, 1, 3, 3]],
            "dw",
        );
        assert!(ok.is_ok());
        let bad = infer_node(
            &Op::Conv { spec, group: 1 },
            &[vec![1, 8, 16, 16], vec![8, 4, 3, 3]],
            "dw",
        );
        assert!(bad.is_err());
    }

    #[test]
    fn quant_broadcasts_scale() {
        // per-channel scale (1,C,1,1) over NCHW input
        let out = infer_node(
            &Op::Quant {
                signed: true,
                narrow: false,
                rounding: crate::graph::RoundMode::RoundEven,
            },
            &[
                vec![1, 4, 8, 8],
                vec![1, 4, 1, 1],
                vec![],
                vec![],
            ],
            "q",
        )
        .unwrap();
        assert_eq!(out[0], vec![1, 4, 8, 8]);
    }

    #[test]
    fn missing_shape_is_error() {
        let mut g = Graph::new("bad");
        g.add_input("x", &[1, 4]);
        g.add_node(Node::new("mm", Op::MatMul, &["x", "w_undef"], &["y"]));
        g.outputs.push("y".into());
        assert!(infer_shapes(&mut g).is_err());
    }
}
