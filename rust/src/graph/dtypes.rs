//! Arbitrary-precision container datatypes, mirroring QONNX/FINN datatype
//! annotations: INT<b>, UINT<b>, FLOAT32 and fixed-point FIXED<W,I>.
//! These drive datapath widths in the hardware kernels and the datatype
//! accumulator bound of §4.2.

use std::fmt;

use anyhow::{bail, Result};

/// A container datatype for a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataType {
    /// Single-precision float (scales/biases before fixed-point quantization).
    Float32,
    /// Signed two's-complement integer of the given bitwidth.
    Int(u32),
    /// Unsigned integer of the given bitwidth.
    UInt(u32),
    /// Binary {0, 1}.
    Binary,
    /// Bipolar {-1, +1} (BNN legacy; 1 bit of storage).
    Bipolar,
    /// Fixed-point with total width W and integer bits I (value = m / 2^(W-I)).
    Fixed { w: u32, i: u32 },
}

impl DataType {
    /// Storage bits for one element.
    pub fn bits(&self) -> u32 {
        match self {
            DataType::Float32 => 32,
            DataType::Int(b) | DataType::UInt(b) => *b,
            DataType::Binary | DataType::Bipolar => 1,
            DataType::Fixed { w, .. } => *w,
        }
    }

    /// Minimum representable value.
    pub fn min_value(&self) -> f64 {
        match self {
            DataType::Float32 => f64::NEG_INFINITY,
            DataType::Int(b) => -((1i64 << (b - 1)) as f64),
            DataType::UInt(_) | DataType::Binary => 0.0,
            DataType::Bipolar => -1.0,
            DataType::Fixed { w, i } => {
                -((1i64 << (w - 1)) as f64) / (1i64 << (w - i)) as f64
            }
        }
    }

    /// Maximum representable value.
    pub fn max_value(&self) -> f64 {
        match self {
            DataType::Float32 => f64::INFINITY,
            DataType::Int(b) => ((1i64 << (b - 1)) - 1) as f64,
            DataType::UInt(b) => ((1u64 << b) - 1) as f64,
            DataType::Binary => 1.0,
            DataType::Bipolar => 1.0,
            DataType::Fixed { w, i } => {
                ((1i64 << (w - 1)) - 1) as f64 / (1i64 << (w - i)) as f64
            }
        }
    }

    pub fn signed(&self) -> bool {
        matches!(
            self,
            DataType::Int(_) | DataType::Bipolar | DataType::Fixed { .. } | DataType::Float32
        )
    }

    pub fn is_integer(&self) -> bool {
        matches!(
            self,
            DataType::Int(_) | DataType::UInt(_) | DataType::Binary | DataType::Bipolar
        )
    }

    /// Does `v` fit this datatype exactly?
    pub fn allows(&self, v: f64) -> bool {
        match self {
            DataType::Float32 => true,
            DataType::Bipolar => v == -1.0 || v == 1.0,
            DataType::Fixed { w, i } => {
                let scale = (1i64 << (w - i)) as f64;
                let m = v * scale;
                m.fract() == 0.0 && v >= self.min_value() && v <= self.max_value()
            }
            _ => v.fract() == 0.0 && v >= self.min_value() && v <= self.max_value(),
        }
    }

    /// Smallest integer datatype covering the closed interval [lo, hi].
    pub fn for_range(lo: i64, hi: i64) -> DataType {
        let bits = crate::util::bits_for_range(lo, hi);
        if lo < 0 {
            DataType::Int(bits)
        } else {
            DataType::UInt(bits)
        }
    }

    pub fn parse(s: &str) -> Result<DataType> {
        if s == "FLOAT32" {
            return Ok(DataType::Float32);
        }
        if s == "BINARY" {
            return Ok(DataType::Binary);
        }
        if s == "BIPOLAR" {
            return Ok(DataType::Bipolar);
        }
        if let Some(b) = s.strip_prefix("UINT") {
            return Ok(DataType::UInt(b.parse()?));
        }
        if let Some(b) = s.strip_prefix("INT") {
            return Ok(DataType::Int(b.parse()?));
        }
        if let Some(rest) = s.strip_prefix("FIXED<") {
            let rest = rest.trim_end_matches('>');
            let (w, i) = rest
                .split_once(',')
                .ok_or_else(|| anyhow::anyhow!("bad FIXED spec {s}"))?;
            return Ok(DataType::Fixed {
                w: w.trim().parse()?,
                i: i.trim().parse()?,
            });
        }
        bail!("unknown datatype '{s}'")
    }
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataType::Float32 => write!(f, "FLOAT32"),
            DataType::Int(b) => write!(f, "INT{b}"),
            DataType::UInt(b) => write!(f, "UINT{b}"),
            DataType::Binary => write!(f, "BINARY"),
            DataType::Bipolar => write!(f, "BIPOLAR"),
            DataType::Fixed { w, i } => write!(f, "FIXED<{w},{i}>"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_ranges() {
        assert_eq!(DataType::Int(4).min_value(), -8.0);
        assert_eq!(DataType::Int(4).max_value(), 7.0);
        assert_eq!(DataType::UInt(4).min_value(), 0.0);
        assert_eq!(DataType::UInt(4).max_value(), 15.0);
        assert_eq!(DataType::Int(8).bits(), 8);
    }

    #[test]
    fn fixed_point_ranges() {
        // fixed16.8: 8 fractional bits
        let t = DataType::Fixed { w: 16, i: 8 };
        assert_eq!(t.max_value(), (32767.0) / 256.0);
        assert_eq!(t.min_value(), -128.0);
        assert!(t.allows(1.5));
        assert!(t.allows(-0.00390625));
        assert!(!t.allows(0.001));
    }

    #[test]
    fn allows_integers() {
        assert!(DataType::Int(4).allows(-8.0));
        assert!(!DataType::Int(4).allows(8.0));
        assert!(!DataType::Int(4).allows(0.5));
        assert!(DataType::UInt(2).allows(3.0));
        assert!(!DataType::UInt(2).allows(-1.0));
        assert!(DataType::Bipolar.allows(-1.0));
        assert!(!DataType::Bipolar.allows(0.0));
    }

    #[test]
    fn for_range_picks_minimal() {
        assert_eq!(DataType::for_range(0, 15), DataType::UInt(4));
        assert_eq!(DataType::for_range(-8, 7), DataType::Int(4));
        assert_eq!(DataType::for_range(-96, 96), DataType::Int(8));
        assert_eq!(DataType::for_range(0, 0), DataType::UInt(1));
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["FLOAT32", "INT5", "UINT13", "BINARY", "BIPOLAR", "FIXED<16,8>"] {
            let t = DataType::parse(s).unwrap();
            assert_eq!(t.to_string(), s);
        }
        assert!(DataType::parse("floaty").is_err());
    }
}
