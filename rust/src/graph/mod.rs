//! QONNX-like graph intermediate representation.
//!
//! A [`Graph`] is a list of [`Node`]s over named tensors, with constant
//! tensors ("initializers", e.g. trained weights and quantization
//! parameters) stored inline. The representation deliberately mirrors
//! (Q)ONNX: SIRA (§3) and the streamlining passes (§4) are expressed as
//! analyses and rewrites over this graph, exactly as the paper implements
//! them over QONNX.

pub mod dtypes;
pub mod node;
pub mod shapes;

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use anyhow::{anyhow, bail, Result};

pub use dtypes::DataType;
pub use node::{Node, Op, RoundMode};

use crate::tensor::Tensor;

/// A neural network compute graph.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    /// Nodes in insertion order (not necessarily topological; use
    /// [`Graph::topo_order`]).
    pub nodes: Vec<Node>,
    /// Names of dynamic graph inputs.
    pub inputs: Vec<String>,
    /// Names of graph outputs.
    pub outputs: Vec<String>,
    /// Constant tensors (weights, scales, zero-points, bitwidths, ...).
    pub initializers: BTreeMap<String, Tensor>,
    /// Shape annotations for dynamic tensors (graph inputs at minimum;
    /// the rest are filled in by [`shapes::infer_shapes`]).
    pub shapes: BTreeMap<String, Vec<usize>>,
    /// Optional container-datatype annotations (filled by passes).
    pub dtypes: BTreeMap<String, DataType>,
    counter: usize,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph {
            name: name.to_string(),
            ..Default::default()
        }
    }

    // ---- naming ----------------------------------------------------------

    /// Fresh tensor/node name with the given prefix.
    pub fn fresh(&mut self, prefix: &str) -> String {
        loop {
            let name = format!("{prefix}_{}", self.counter);
            self.counter += 1;
            if !self.tensor_exists(&name) && !self.nodes.iter().any(|n| n.name == name) {
                return name;
            }
        }
    }

    fn tensor_exists(&self, name: &str) -> bool {
        self.initializers.contains_key(name)
            || self.shapes.contains_key(name)
            || self.inputs.iter().any(|i| i == name)
            || self
                .nodes
                .iter()
                .any(|n| n.outputs.iter().any(|o| o == name))
    }

    // ---- construction ----------------------------------------------------

    pub fn add_input(&mut self, name: &str, shape: &[usize]) {
        self.inputs.push(name.to_string());
        self.shapes.insert(name.to_string(), shape.to_vec());
    }

    pub fn add_initializer(&mut self, name: &str, t: Tensor) {
        self.shapes.insert(name.to_string(), t.shape().to_vec());
        self.initializers.insert(name.to_string(), t);
    }

    pub fn add_node(&mut self, node: Node) {
        self.nodes.push(node);
    }

    /// Convenience: add a node with a fresh name and fresh single output;
    /// returns the output tensor name.
    pub fn emit(&mut self, op: Op, inputs: &[&str]) -> String {
        let name = self.fresh(op.name());
        let out = self.fresh(&format!("{}_out", op.name()));
        self.nodes.push(Node {
            name,
            op,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: vec![out.clone()],
        });
        out
    }

    // ---- queries -----------------------------------------------------------

    pub fn is_initializer(&self, tensor: &str) -> bool {
        self.initializers.contains_key(tensor)
    }

    pub fn initializer(&self, tensor: &str) -> Option<&Tensor> {
        self.initializers.get(tensor)
    }

    /// Index of the node producing `tensor`, if any.
    pub fn producer(&self, tensor: &str) -> Option<usize> {
        self.nodes
            .iter()
            .position(|n| n.outputs.iter().any(|o| o == tensor))
    }

    /// Indices of nodes consuming `tensor`.
    pub fn consumers(&self, tensor: &str) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.iter().any(|i| i == tensor))
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of consumers of a tensor (graph outputs count as one each).
    pub fn fanout(&self, tensor: &str) -> usize {
        self.consumers(tensor).len() + self.outputs.iter().filter(|o| *o == tensor).count()
    }

    /// All tensor names referenced by the graph.
    pub fn all_tensors(&self) -> BTreeSet<String> {
        let mut out: BTreeSet<String> = self.inputs.iter().cloned().collect();
        out.extend(self.initializers.keys().cloned());
        for n in &self.nodes {
            out.extend(n.inputs.iter().cloned());
            out.extend(n.outputs.iter().cloned());
        }
        out
    }

    /// Topological order of node indices (Kahn's algorithm). Errors on
    /// cycles or on references to undefined tensors.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let mut produced: BTreeSet<&str> = self.inputs.iter().map(|s| s.as_str()).collect();
        produced.extend(self.initializers.keys().map(|s| s.as_str()));
        let mut remaining: VecDeque<usize> = (0..self.nodes.len()).collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        let mut stuck = 0usize;
        while let Some(i) = remaining.pop_front() {
            let ready = self.nodes[i]
                .inputs
                .iter()
                .all(|inp| produced.contains(inp.as_str()));
            if ready {
                for o in &self.nodes[i].outputs {
                    produced.insert(o);
                }
                order.push(i);
                stuck = 0;
            } else {
                remaining.push_back(i);
                stuck += 1;
                if stuck > remaining.len() {
                    let n = &self.nodes[i];
                    let missing: Vec<_> = n
                        .inputs
                        .iter()
                        .filter(|inp| !produced.contains(inp.as_str()))
                        .collect();
                    bail!(
                        "graph has a cycle or undefined tensors: node '{}' waits on {:?}",
                        n.name,
                        missing
                    );
                }
            }
        }
        Ok(order)
    }

    /// Nodes sorted topologically (cloned indices view).
    pub fn topo_nodes(&self) -> Result<Vec<&Node>> {
        Ok(self.topo_order()?.into_iter().map(|i| &self.nodes[i]).collect())
    }

    // ---- surgery -----------------------------------------------------------

    /// Remove node by index, reconnecting its single input to its single
    /// output's consumers (only valid for 1-in/1-out pass-through removal).
    pub fn remove_node_bypass(&mut self, idx: usize) -> Result<()> {
        let node = self.nodes[idx].clone();
        let dynamic_inputs: Vec<&String> = node
            .inputs
            .iter()
            .filter(|i| !self.is_initializer(i))
            .collect();
        if dynamic_inputs.len() != 1 || node.outputs.len() != 1 {
            bail!(
                "remove_node_bypass requires 1 dynamic input / 1 output, node '{}' has {}/{}",
                node.name,
                dynamic_inputs.len(),
                node.outputs.len()
            );
        }
        let src = dynamic_inputs[0].clone();
        let dst = node.outputs[0].clone();
        self.nodes.remove(idx);
        for n in &mut self.nodes {
            for i in &mut n.inputs {
                if *i == dst {
                    *i = src.clone();
                }
            }
        }
        for o in &mut self.outputs {
            if *o == dst {
                *o = src.clone();
            }
        }
        self.shapes.remove(&dst);
        self.dtypes.remove(&dst);
        Ok(())
    }

    /// Insert a node so that it consumes `tensor` and all previous
    /// consumers of `tensor` (and graph outputs) read the node's output
    /// instead. Returns the new output tensor name.
    pub fn insert_after(&mut self, tensor: &str, op: Op, extra_inputs: &[&str]) -> Result<String> {
        if !self.tensor_exists(tensor) {
            bail!("insert_after: tensor '{tensor}' not found");
        }
        let name = self.fresh(op.name());
        let out = self.fresh(&format!("{tensor}_post"));
        // Rewire existing consumers first.
        for n in &mut self.nodes {
            for i in &mut n.inputs {
                if i == tensor {
                    *i = out.clone();
                }
            }
        }
        for o in &mut self.outputs {
            if o == tensor {
                *o = out.clone();
            }
        }
        let mut inputs = vec![tensor.to_string()];
        inputs.extend(extra_inputs.iter().map(|s| s.to_string()));
        self.nodes.push(Node {
            name,
            op,
            inputs,
            outputs: vec![out.clone()],
        });
        Ok(out)
    }

    /// Drop initializers that no node references (cleanup after rewrites).
    pub fn prune_unused_initializers(&mut self) {
        let used: BTreeSet<&String> = self
            .nodes
            .iter()
            .flat_map(|n| n.inputs.iter())
            .chain(self.outputs.iter())
            .collect();
        let dead: Vec<String> = self
            .initializers
            .keys()
            .filter(|k| !used.contains(k))
            .cloned()
            .collect();
        for k in dead {
            self.initializers.remove(&k);
            self.shapes.remove(&k);
            self.dtypes.remove(&k);
        }
    }

    /// Remove nodes whose outputs reach no graph output (dead code).
    pub fn eliminate_dead_nodes(&mut self) -> Result<()> {
        let mut live: BTreeSet<String> = self.outputs.iter().cloned().collect();
        let order = self.topo_order()?;
        let mut keep = vec![false; self.nodes.len()];
        for &i in order.iter().rev() {
            let n = &self.nodes[i];
            if n.outputs.iter().any(|o| live.contains(o)) {
                keep[i] = true;
                live.extend(n.inputs.iter().cloned());
            }
        }
        let mut idx = 0;
        self.nodes.retain(|_| {
            let k = keep[idx];
            idx += 1;
            k
        });
        self.prune_unused_initializers();
        Ok(())
    }

    /// Validate structural invariants: unique node outputs, defined inputs,
    /// acyclicity, output existence.
    pub fn check(&self) -> Result<()> {
        let mut produced: BTreeSet<&str> = BTreeSet::new();
        for n in &self.nodes {
            for o in &n.outputs {
                if self.inputs.iter().any(|i| i == o) || self.initializers.contains_key(o) {
                    bail!("node '{}' writes graph input/initializer '{}'", n.name, o);
                }
                if !produced.insert(o) {
                    bail!("tensor '{}' produced twice", o);
                }
            }
        }
        self.topo_order()?;
        for o in &self.outputs {
            if !self.tensor_exists(o) {
                bail!("graph output '{o}' is not produced");
            }
        }
        Ok(())
    }

    /// Find a node by name.
    pub fn node_by_name(&self, name: &str) -> Result<&Node> {
        self.nodes
            .iter()
            .find(|n| n.name == name)
            .ok_or_else(|| anyhow!("no node named '{name}'"))
    }

    /// Count nodes with a given operator name.
    pub fn count_op(&self, op_name: &str) -> usize {
        self.nodes.iter().filter(|n| n.op.name() == op_name).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // x -> relu -> a ; x -> sigmoid -> b ; a+b -> y
        let mut g = Graph::new("diamond");
        g.add_input("x", &[1, 4]);
        g.add_node(Node::new("r", Op::Relu, &["x"], &["a"]));
        g.add_node(Node::new("s", Op::Sigmoid, &["x"], &["b"]));
        g.add_node(Node::new("add", Op::Add, &["a", "b"], &["y"]));
        g.outputs.push("y".into());
        g
    }

    #[test]
    fn topo_order_respects_deps() {
        let mut g = diamond();
        // scramble: put add first
        g.nodes.swap(0, 2);
        let order = g.topo_order().unwrap();
        let pos = |name: &str| order.iter().position(|&i| g.nodes[i].name == name).unwrap();
        assert!(pos("r") < pos("add"));
        assert!(pos("s") < pos("add"));
        g.check().unwrap();
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new("cyc");
        g.add_input("x", &[1]);
        g.add_node(Node::new("a", Op::Add, &["x", "w"], &["v"]));
        g.add_node(Node::new("b", Op::Relu, &["v"], &["w"]));
        g.outputs.push("w".into());
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn producer_consumer_maps() {
        let g = diamond();
        assert_eq!(g.producer("a"), Some(0));
        assert_eq!(g.producer("x"), None);
        assert_eq!(g.consumers("x").len(), 2);
        assert_eq!(g.fanout("y"), 1); // graph output
    }

    #[test]
    fn bypass_removal() {
        let mut g = Graph::new("line");
        g.add_input("x", &[2]);
        g.add_node(Node::new("i", Op::Identity, &["x"], &["m"]));
        g.add_node(Node::new("r", Op::Relu, &["m"], &["y"]));
        g.outputs.push("y".into());
        g.remove_node_bypass(0).unwrap();
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.nodes[0].inputs[0], "x");
        g.check().unwrap();
    }

    #[test]
    fn bypass_requires_single_dynamic_input() {
        let mut g = diamond();
        assert!(g.remove_node_bypass(2).is_err()); // Add has 2 dynamic inputs
    }

    #[test]
    fn insert_after_rewires_consumers() {
        let mut g = diamond();
        let new_out = g.insert_after("x", Op::Identity, &[]).unwrap();
        g.check().unwrap();
        // relu and sigmoid now read the identity output
        assert_eq!(g.node_by_name("r").unwrap().inputs[0], new_out);
        assert_eq!(g.node_by_name("s").unwrap().inputs[0], new_out);
        // identity reads x
        let id = g.nodes.iter().find(|n| n.op == Op::Identity).unwrap();
        assert_eq!(id.inputs[0], "x");
    }

    #[test]
    fn insert_after_graph_output() {
        let mut g = diamond();
        let new_out = g.insert_after("y", Op::Relu, &[]).unwrap();
        assert_eq!(g.outputs[0], new_out);
        g.check().unwrap();
    }

    #[test]
    fn dead_node_elimination() {
        let mut g = diamond();
        g.add_node(Node::new("dead", Op::Relu, &["a"], &["unused"]));
        g.eliminate_dead_nodes().unwrap();
        assert!(g.node_by_name("dead").is_err());
        assert_eq!(g.nodes.len(), 3);
    }

    #[test]
    fn prune_initializers() {
        let mut g = diamond();
        g.add_initializer("w_dead", Tensor::scalar(1.0));
        g.prune_unused_initializers();
        assert!(!g.is_initializer("w_dead"));
    }

    #[test]
    fn fresh_names_unique() {
        let mut g = diamond();
        let a = g.fresh("t");
        let b = g.fresh("t");
        assert_ne!(a, b);
    }

    #[test]
    fn check_rejects_double_produce() {
        let mut g = Graph::new("bad");
        g.add_input("x", &[1]);
        g.add_node(Node::new("a", Op::Relu, &["x"], &["y"]));
        g.add_node(Node::new("b", Op::Sigmoid, &["x"], &["y"]));
        g.outputs.push("y".into());
        assert!(g.check().is_err());
    }
}
