//! Structured request tracing: JSON-line span records on a pluggable
//! sink, with a level filter and a slow-request threshold.
//!
//! Every record is one JSON object per line with at least `ts_ms`
//! (milliseconds since the Unix epoch), `level` and `record` keys; the
//! emitting site adds its own fields (`id` for the request id, `span`,
//! `dur_us`, ...). The request path emits:
//!
//! - `record:"request"` — one summary per HTTP inference request with a
//!   phase breakdown (`parse`/`admit`/`exec`/`respond`/`total`
//!   microseconds), at **info**; escalated to **error** with
//!   `slow:true` when total latency exceeds the slow-request
//!   threshold.
//! - `record:"span"` — fine-grained spans (`batch_wait` per job,
//!   `batch_exec`/`segment_exec` per drained batch with the request
//!   ids it carried), at **debug**.
//!
//! The global tracer is configured from the environment on first use:
//!
//! - `SIRA_TRACE` = `off` (default) | `error` | `info` | `debug`
//! - `SIRA_TRACE_SLOW_MS` = slow-request threshold in milliseconds
//!   (default 1000)
//!
//! With the default `off` level every instrumentation site reduces to
//! one relaxed atomic load, so tracing costs nothing unless asked for.
//! Sinks are pluggable ([`TraceSink`]): stderr by default, an in-memory
//! buffer for tests.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Trace verbosity, ordered: a record is emitted when its level is at
/// or below the tracer's configured level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "info" => Level::Info,
            "debug" | "1" | "on" => Level::Debug,
            _ => Level::Off,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Destination for trace lines. Implementations must tolerate
/// concurrent `emit` calls.
pub trait TraceSink: Send + Sync {
    fn emit(&self, line: &str);
}

/// Default sink: one line to stderr per record.
#[derive(Debug, Default)]
pub struct StderrSink;

impl TraceSink for StderrSink {
    fn emit(&self, line: &str) {
        eprintln!("{line}");
    }
}

/// Test sink: buffers lines in memory.
#[derive(Debug, Default)]
pub struct MemorySink {
    lines: Mutex<Vec<String>>,
}

impl MemorySink {
    pub fn new() -> Arc<MemorySink> {
        Arc::new(MemorySink::default())
    }

    /// Drain and return everything captured so far.
    pub fn take(&self) -> Vec<String> {
        std::mem::take(&mut *self.lines.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl TraceSink for MemorySink {
    fn emit(&self, line: &str) {
        self.lines.lock().unwrap_or_else(|e| e.into_inner()).push(line.to_string());
    }
}

/// A level-filtered JSON-line emitter over a swappable sink.
pub struct Tracer {
    level: AtomicU8,
    slow_us: AtomicU64,
    sink: Mutex<Arc<dyn TraceSink>>,
}

impl Tracer {
    pub fn new(level: Level, slow_us: u64, sink: Arc<dyn TraceSink>) -> Tracer {
        Tracer {
            level: AtomicU8::new(level as u8),
            slow_us: AtomicU64::new(slow_us),
            sink: Mutex::new(sink),
        }
    }

    /// Tracer configured from `SIRA_TRACE` / `SIRA_TRACE_SLOW_MS`,
    /// writing to stderr.
    pub fn from_env() -> Tracer {
        let level = std::env::var("SIRA_TRACE").map(|v| Level::parse(&v)).unwrap_or(Level::Off);
        let slow_ms = std::env::var("SIRA_TRACE_SLOW_MS")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(1000);
        Tracer::new(level, slow_ms * 1000, Arc::new(StderrSink))
    }

    /// One relaxed load — the fast path every instrumentation site
    /// guards on.
    pub fn enabled(&self, level: Level) -> bool {
        level as u8 <= self.level.load(Ordering::Relaxed)
    }

    /// Slow-request threshold in microseconds.
    pub fn slow_us(&self) -> u64 {
        self.slow_us.load(Ordering::Relaxed)
    }

    pub fn set_level(&self, level: Level) {
        self.level.store(level as u8, Ordering::Relaxed);
    }

    pub fn set_slow_ms(&self, ms: u64) {
        self.slow_us.store(ms * 1000, Ordering::Relaxed);
    }

    pub fn set_sink(&self, sink: Arc<dyn TraceSink>) {
        *self.sink.lock().unwrap_or_else(|e| e.into_inner()) = sink;
    }

    /// Emit one record (if the level passes) with `ts_ms`, `level` and
    /// `record` added to the caller's fields.
    pub fn emit(&self, level: Level, record: &str, fields: Vec<(&str, Json)>) {
        if !self.enabled(level) {
            return;
        }
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let mut all = vec![
            ("ts_ms", Json::Num(ts_ms)),
            ("level", Json::Str(level.name().into())),
            ("record", Json::Str(record.into())),
        ];
        all.extend(fields);
        let line = Json::obj(all).to_string();
        let sink = Arc::clone(&*self.sink.lock().unwrap_or_else(|e| e.into_inner()));
        sink.emit(&line);
    }
}

/// The process-wide tracer, configured from the environment on first
/// use. Serving and coordinator instrumentation goes through here;
/// tests that need isolation construct their own [`Tracer`].
pub fn tracer() -> &'static Tracer {
    static GLOBAL: OnceLock<Tracer> = OnceLock::new();
    GLOBAL.get_or_init(Tracer::from_env)
}

/// Generate a request id: process-unique, monotonic, cheap. Requests
/// arriving with an `x-request-id` header keep their caller-assigned id
/// instead.
pub fn next_request_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("r-{:x}-{n:x}", std::process::id())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering_and_sink_capture() {
        let sink = MemorySink::new();
        let t = Tracer::new(Level::Info, 1_000_000, sink.clone() as Arc<dyn TraceSink>);
        assert!(t.enabled(Level::Error) && t.enabled(Level::Info));
        assert!(!t.enabled(Level::Debug));
        t.emit(Level::Debug, "span", vec![("id", Json::Str("x".into()))]);
        t.emit(Level::Info, "request", vec![("id", Json::Str("r-1".into()))]);
        let lines = sink.take();
        assert_eq!(lines.len(), 1);
        let j = Json::parse(&lines[0]).unwrap();
        assert_eq!(j.get("record").unwrap().as_str().unwrap(), "request");
        assert_eq!(j.get("level").unwrap().as_str().unwrap(), "info");
        assert_eq!(j.get("id").unwrap().as_str().unwrap(), "r-1");
        assert!(j.get("ts_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn level_parse_and_off_is_free() {
        assert_eq!(Level::parse("DEBUG"), Level::Debug);
        assert_eq!(Level::parse("info"), Level::Info);
        assert_eq!(Level::parse("error"), Level::Error);
        assert_eq!(Level::parse(""), Level::Off);
        assert_eq!(Level::parse("nonsense"), Level::Off);
        let sink = MemorySink::new();
        let t = Tracer::new(Level::Off, 0, sink.clone() as Arc<dyn TraceSink>);
        t.emit(Level::Error, "request", vec![]);
        assert!(sink.take().is_empty());
    }

    #[test]
    fn request_ids_are_unique_and_structured() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
        assert!(a.starts_with("r-"));
    }

    #[test]
    fn slow_threshold_units() {
        let t = Tracer::new(Level::Error, 0, Arc::new(StderrSink));
        t.set_slow_ms(250);
        assert_eq!(t.slow_us(), 250_000);
    }
}
