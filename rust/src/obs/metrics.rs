//! Bounded-memory metric primitives and Prometheus text exposition.
//!
//! Three atomic instrument types — [`Counter`], [`Gauge`] and
//! fixed-bucket [`Histogram`] — replace the unbounded `Vec<u64>` sample
//! logs the serving metrics used to accumulate: a histogram's memory is
//! fixed at construction (one `AtomicU64` per bucket plus streaming
//! count/sum/min/max), so a serve that stays up for a week costs the
//! same bytes as one that served a single request. Count and sum are
//! exact; percentiles are estimated at bucket resolution (linear
//! interpolation inside the bucket holding the rank, clamped to the
//! observed min/max so degenerate distributions report exact values).
//!
//! [`PromWriter`] renders instruments as Prometheus text exposition
//! format 0.0.4 (`# HELP`/`# TYPE` headers, escaped label values,
//! cumulative `_bucket{le=...}` series), and [`validate_exposition`]
//! parses an exposition body back, line by line — the checker behind the
//! golden test and the `scripts/verify.sh` loadgen smoke run.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Monotonically increasing atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed atomic gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram over `u64` samples (latency microseconds,
/// batch occupancies). Bucket upper bounds are inclusive (`v <= bound`
/// lands in that bucket, mirroring Prometheus `le`); one extra overflow
/// bucket catches everything above the last bound. All state is atomic,
/// so concurrent `record` calls from pool shards and stage threads need
/// no lock.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    /// Histogram with the given strictly increasing upper bounds.
    pub fn new(bounds: &[u64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Default latency histogram: 1µs → 60s in a 1-2-5 decade ladder —
    /// 24 buckets, fixed forever, regardless of how many samples land.
    pub fn latency_us() -> Histogram {
        Histogram::new(&[
            1,
            2,
            5,
            10,
            20,
            50,
            100,
            200,
            500,
            1_000,
            2_000,
            5_000,
            10_000,
            20_000,
            50_000,
            100_000,
            200_000,
            500_000,
            1_000_000,
            2_000_000,
            5_000_000,
            10_000_000,
            30_000_000,
            60_000_000,
        ])
    }

    /// Batch-occupancy histogram: exact buckets through 16 (the
    /// interesting range for `max_batch` defaults), then doubling.
    pub fn occupancy() -> Histogram {
        Histogram::new(&[
            1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 512, 1024,
        ])
    }

    pub fn record(&self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact mean (streaming sum / count); 0 when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Bucket upper bounds (without the overflow bucket).
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Non-cumulative per-bucket counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Percentile estimate at bucket resolution: the rank formula
    /// matches `util::stats::percentiles_u64` (index `(n-1)*p` of the
    /// sorted samples), the value is linearly interpolated inside the
    /// bucket containing that rank and clamped to the observed min/max.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = crate::util::stats::percentile_rank(n, p); // 1-based
        let counts = self.bucket_counts();
        let (min, max) = (self.min.load(Ordering::Relaxed), self.max.load(Ordering::Relaxed));
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                let lo = if i == 0 { 0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() { self.bounds[i] } else { max.max(lo) };
                let frac = (rank - cum) as f64 / c as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est.round() as u64).clamp(min, max);
            }
            cum += c;
        }
        max
    }

    /// The shared `{count, mean, p50, p95, p99}` serving-metrics schema
    /// (`util::stats::percentile_json`), computed from bucket state:
    /// count and mean are exact, percentiles are bucket-resolution
    /// estimates.
    pub fn percentile_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.percentile(0.50) as f64)),
            ("p95", Json::Num(self.percentile(0.95) as f64)),
            ("p99", Json::Num(self.percentile(0.99) as f64)),
        ])
    }
}

/// Escape a label value per the exposition format: backslash, double
/// quote and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape a HELP text: backslash and newline (quotes are legal there).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn format_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Incremental renderer for Prometheus text exposition format 0.0.4.
/// Serve it with content type `text/plain; version=0.0.4`.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    /// Emit the `# HELP` / `# TYPE` header for a metric family.
    /// `kind` is one of `counter`, `gauge`, `histogram`.
    pub fn family(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {}\n", escape_help(help)));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    /// Emit one sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&format_value(value));
        self.out.push('\n');
    }

    /// Emit a full histogram family body: cumulative `_bucket` series
    /// (ending in `le="+Inf"`), `_sum` and `_count`. The family header
    /// must have been written by the caller (so several labelled
    /// histograms can share one family).
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let counts = h.bucket_counts();
        let mut cum = 0u64;
        let bucket_name = format!("{name}_bucket");
        for (i, bound) in h.bounds().iter().enumerate() {
            cum += counts[i];
            let le = format!("{bound}");
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", &le));
            self.sample(&bucket_name, &ls, cum as f64);
        }
        cum += counts[h.bounds().len()];
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.sample(&bucket_name, &ls, cum as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum() as f64);
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse one `{k="v",...}` label block; returns the byte just past the
/// closing brace.
fn parse_labels(line: &str, start: usize) -> Result<usize> {
    let bytes = line.as_bytes();
    let mut i = start + 1; // past '{'
    loop {
        // label name
        let name_start = i;
        while i < bytes.len() && bytes[i] != b'=' {
            i += 1;
        }
        if i >= bytes.len() {
            bail!("label without '=': {line}");
        }
        if !valid_label_name(&line[name_start..i]) {
            bail!("bad label name in: {line}");
        }
        i += 1; // past '='
        if i >= bytes.len() || bytes[i] != b'"' {
            bail!("label value must be quoted: {line}");
        }
        i += 1; // past opening quote
        while i < bytes.len() {
            match bytes[i] {
                b'\\' => i += 2, // escaped char
                b'"' => break,
                _ => i += 1,
            }
        }
        if i >= bytes.len() {
            bail!("unterminated label value: {line}");
        }
        i += 1; // past closing quote
        match bytes.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => return Ok(i + 1),
            _ => bail!("expected ',' or '}}' after label value: {line}"),
        }
    }
}

/// Validate a Prometheus text exposition body line by line. Returns the
/// number of sample lines on success; fails on any malformed line (bad
/// metric name, unbalanced label quotes, non-numeric value, unknown
/// comment form). An exposition with zero samples is also an error —
/// a scrape that returns only comments means the registry is wired
/// wrong.
pub fn validate_exposition(text: &str) -> Result<usize> {
    let mut samples = 0usize;
    for line in text.lines() {
        let line = line.trim_end_matches('\r');
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if let Some(r) = rest.strip_prefix("HELP ").or_else(|| rest.strip_prefix("TYPE ")) {
                let mut parts = r.splitn(2, ' ');
                let name = parts.next().unwrap_or("");
                if !valid_metric_name(name) {
                    bail!("bad metric name in comment: {line}");
                }
                if rest.starts_with("TYPE ") {
                    let kind = parts.next().unwrap_or("").trim();
                    if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                        bail!("unknown metric type '{kind}': {line}");
                    }
                }
            }
            // other comments are legal and ignored
            continue;
        }
        // sample line: name[{labels}] value [timestamp]
        let name_end = line
            .find(|c: char| c == '{' || c == ' ')
            .ok_or_else(|| anyhow::anyhow!("sample line without value: {line}"))?;
        if !valid_metric_name(&line[..name_end]) {
            bail!("bad metric name: {line}");
        }
        let value_start = if line.as_bytes()[name_end] == b'{' {
            let after = parse_labels(line, name_end)?;
            if line.as_bytes().get(after) != Some(&b' ') {
                bail!("expected space after labels: {line}");
            }
            after + 1
        } else {
            name_end + 1
        };
        let mut fields = line[value_start..].split_whitespace();
        let value = fields.next().ok_or_else(|| anyhow::anyhow!("missing value: {line}"))?;
        if !matches!(value, "+Inf" | "-Inf" | "NaN") && value.parse::<f64>().is_err() {
            bail!("bad sample value '{value}': {line}");
        }
        if let Some(ts) = fields.next() {
            if ts.parse::<i64>().is_err() {
                bail!("bad timestamp '{ts}': {line}");
            }
        }
        if fields.next().is_some() {
            bail!("trailing garbage: {line}");
        }
        samples += 1;
    }
    if samples == 0 {
        bail!("exposition contains no samples");
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentiles_u64;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.add(3);
        g.sub(4);
        assert_eq!(g.get(), 6);
    }

    #[test]
    fn histogram_exact_count_sum_mean() {
        let h = Histogram::latency_us();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 100);
        assert_eq!(h.mean(), 25.0);
    }

    #[test]
    fn histogram_degenerate_distribution_is_exact() {
        // all samples equal: every percentile must clamp to that value
        let h = Histogram::occupancy();
        for _ in 0..100 {
            h.record(16);
        }
        assert_eq!(h.percentile(0.50), 16);
        assert_eq!(h.percentile(0.99), 16);
        assert_eq!(h.mean(), 16.0);
    }

    #[test]
    fn histogram_percentiles_are_monotone_and_empty_is_zero() {
        let h = Histogram::latency_us();
        assert_eq!(h.percentile(0.99), 0);
        let mut rng = Rng::new(0x0B5);
        for _ in 0..500 {
            h.record(rng.int_in(1, 1_000_000) as u64);
        }
        let (p50, p95, p99) = (h.percentile(0.5), h.percentile(0.95), h.percentile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    }

    /// Property test: bucket counts and the streaming sum must match a
    /// scalar oracle over seeded random samples, and every percentile
    /// estimate must land inside the bucket that holds the true
    /// (sorted-sample) percentile.
    #[test]
    fn histogram_matches_scalar_oracle() {
        let bounds = Histogram::latency_us();
        let bounds = bounds.bounds().to_vec();
        for seed in [1u64, 0xBEEF, 0x5EED, 42] {
            let mut rng = Rng::new(seed);
            let h = Histogram::new(&bounds);
            let mut samples: Vec<u64> = Vec::new();
            for _ in 0..2000 {
                // mix of magnitudes so every decade of buckets is hit
                let mag = rng.int_in(0, 6) as u32;
                let v = rng.int_in(1, 10i64.pow(mag).max(2)) as u64;
                h.record(v);
                samples.push(v);
            }
            // oracle bucket counts
            let mut oracle = vec![0u64; bounds.len() + 1];
            for &v in &samples {
                let idx = bounds.partition_point(|&b| b < v);
                oracle[idx] += 1;
            }
            assert_eq!(h.bucket_counts(), oracle, "seed {seed}");
            assert_eq!(h.sum(), samples.iter().sum::<u64>(), "seed {seed}");
            assert_eq!(h.count(), samples.len() as u64, "seed {seed}");
            // percentile estimates stay within the true value's bucket
            let (t50, t95, t99) = percentiles_u64(&samples);
            for (p, truth) in [(0.50, t50), (0.95, t95), (0.99, t99)] {
                let est = h.percentile(p);
                let truth_bucket = bounds.partition_point(|&b| b < truth);
                let lo = if truth_bucket == 0 { 0 } else { bounds[truth_bucket - 1] };
                let hi = bounds.get(truth_bucket).copied().unwrap_or(u64::MAX);
                assert!(
                    est >= lo && est <= hi,
                    "seed {seed} p{p}: est {est} outside bucket ({lo}, {hi}] of true {truth}"
                );
            }
        }
    }

    /// Property: a one-sample histogram reports that exact sample at
    /// every percentile (frac = 1.0 lands on the bucket's upper bound,
    /// then the min/max clamp collapses it to the sample) — same answer
    /// as the sorted-vector oracle on `[v]`.
    #[test]
    fn histogram_single_sample_is_exact_at_every_percentile() {
        // one value per region: first bucket, mid-ladder, last bucket,
        // and the overflow bucket
        for v in [1u64, 3, 7_777, 60_000_000, 123_456_789] {
            let h = Histogram::latency_us();
            h.record(v);
            let (t50, t95, t99) = percentiles_u64(&[v]);
            assert_eq!((t50, t95, t99), (v, v, v));
            for p in [0.0, 0.5, 0.95, 0.99, 1.0] {
                assert_eq!(h.percentile(p), v, "v={v} p={p}");
            }
        }
    }

    /// Property: when every sample lands in the overflow bucket (above
    /// the last bound), estimates interpolate between the last bound and
    /// the observed max, clamped to [min, max] — always inside the
    /// oracle value's (overflow) bucket.
    #[test]
    fn histogram_all_in_overflow_bucket_stays_within_min_max() {
        let h = Histogram::latency_us();
        let top = *h.bounds().last().unwrap();
        let mut rng = Rng::new(0x0F10);
        let mut samples = Vec::new();
        for _ in 0..200 {
            let v = top + 1 + rng.int_in(0, 1_000_000) as u64;
            h.record(v);
            samples.push(v);
        }
        let (lo, hi) = (
            *samples.iter().min().unwrap(),
            *samples.iter().max().unwrap(),
        );
        let (t50, t95, t99) = percentiles_u64(&samples);
        for (p, truth) in [(0.50, t50), (0.95, t95), (0.99, t99)] {
            let est = h.percentile(p);
            // the overflow bucket is (top, max]; clamp keeps the
            // estimate inside the observed range, which contains truth
            assert!(est > top, "p{p}: est {est} fell below the last bound");
            assert!(
                est >= lo && est <= hi,
                "p{p}: est {est} outside observed [{lo}, {hi}], truth {truth}"
            );
        }
    }

    /// Property: a rank that lands exactly on a bucket's cumulative
    /// count edge resolves to that bucket's upper bound (frac = 1.0) and
    /// stays inside the oracle value's bucket.
    #[test]
    fn histogram_rank_exactly_at_bucket_boundary() {
        let bounds = [10u64, 20, 30];
        let h = Histogram::new(&bounds);
        let mut samples = Vec::new();
        // 10 samples in (0,10], 10 in (10,20]: p50's rank (10) is
        // exactly the cumulative count of the first bucket
        for i in 0..10u64 {
            let v = i + 1;
            h.record(v);
            samples.push(v);
        }
        for i in 0..10u64 {
            let v = 11 + i;
            h.record(v);
            samples.push(v);
        }
        let rank = crate::util::stats::percentile_rank(20, 0.50);
        assert_eq!(rank, 10, "rank must sit exactly on the bucket edge");
        let (t50, _, _) = percentiles_u64(&samples);
        let est = h.percentile(0.50);
        // truth is sample #10 (value 10) — bucket 0, whose bound is 10
        let tb = bounds.partition_point(|&b| b < t50);
        let blo = if tb == 0 { 0 } else { bounds[tb - 1] };
        let bhi = bounds.get(tb).copied().unwrap_or(u64::MAX);
        assert!(
            est >= blo && est <= bhi,
            "est {est} outside truth bucket ({blo}, {bhi}] of {t50}"
        );
        assert_eq!(est, 10, "boundary rank resolves to the bucket bound");
    }

    #[test]
    fn percentile_json_matches_vec_schema() {
        let h = Histogram::latency_us();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        let j = h.percentile_json();
        assert_eq!(j.get("count").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("mean").unwrap().as_f64().unwrap(), 25.0);
        assert!(j.get("p50").unwrap().as_f64().unwrap() <= j.get("p99").unwrap().as_f64().unwrap());
    }

    /// Golden test: exact exposition text for a small fixed registry.
    #[test]
    fn prom_exposition_golden() {
        let h = Histogram::new(&[1, 5, 10]);
        for v in [1u64, 3, 7, 20] {
            h.record(v);
        }
        let mut w = PromWriter::new();
        w.family("sira_requests_total", "Completed requests.", "counter");
        w.sample("sira_requests_total", &[("model", "cnv")], 42.0);
        w.family("sira_pending", "Admitted samples in flight.", "gauge");
        w.sample("sira_pending", &[], 3.0);
        w.family("sira_latency_us", "Request latency (microseconds).", "histogram");
        w.histogram("sira_latency_us", &[("model", "c\"v\n")], &h);
        let text = w.finish();
        let expected = "\
# HELP sira_requests_total Completed requests.
# TYPE sira_requests_total counter
sira_requests_total{model=\"cnv\"} 42
# HELP sira_pending Admitted samples in flight.
# TYPE sira_pending gauge
sira_pending 3
# HELP sira_latency_us Request latency (microseconds).
# TYPE sira_latency_us histogram
sira_latency_us_bucket{model=\"c\\\"v\\n\",le=\"1\"} 1
sira_latency_us_bucket{model=\"c\\\"v\\n\",le=\"5\"} 2
sira_latency_us_bucket{model=\"c\\\"v\\n\",le=\"10\"} 3
sira_latency_us_bucket{model=\"c\\\"v\\n\",le=\"+Inf\"} 4
sira_latency_us_sum{model=\"c\\\"v\\n\"} 31
sira_latency_us_count{model=\"c\\\"v\\n\"} 4
";
        assert_eq!(text, expected);
        assert_eq!(validate_exposition(&text).unwrap(), 8);
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(validate_exposition("").is_err()); // no samples
        assert!(validate_exposition("# HELP only comments\n").is_err());
        assert!(validate_exposition("9bad_name 1\n").is_err());
        assert!(validate_exposition("name{l=unquoted} 1\n").is_err());
        assert!(validate_exposition("name{l=\"open} 1\n").is_err());
        assert!(validate_exposition("name notanumber\n").is_err());
        assert!(validate_exposition("name 1 2 3\n").is_err());
        assert!(validate_exposition("# TYPE x rainbow\nx 1\n").is_err());
        assert_eq!(validate_exposition("x 1\nx{a=\"b\"} 2.5\ny +Inf\n").unwrap(), 3);
        assert_eq!(validate_exposition("x 1 1700000000000\n").unwrap(), 1);
    }
}
