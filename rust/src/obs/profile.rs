//! Per-step plan profiler: attributes engine time to individual plan
//! steps (the paper's per-operator cost breakdown, live instead of
//! offline).
//!
//! A [`PlanProfiler`] is attached to a `Plan` with
//! `Plan::enable_profiling(sample_every)` and shared by every clone of
//! that plan (pool shards, coordinator workers, pipeline stages), so
//! one report aggregates the whole serving fleet for a model. Two
//! cost tiers:
//!
//! - **step counters** — always on while a profiler is attached: one
//!   relaxed atomic add per step per call.
//! - **sampled timing** — `Instant` pairs around 1-in-`sample_every`
//!   calls per step (`sample_every = 1` times everything, `0` disables
//!   timing and keeps only the counters). Reported totals are scaled
//!   back up by `calls / sampled`, so a 1-in-16 sample still estimates
//!   full step cost.
//!
//! A detached plan (the default) carries no profiler and pays nothing —
//! the hot loop's only change is an `Option` check that predicts
//! perfectly.
//!
//! The profiler also counts MAC-core dispatch (tiled register-blocked
//! vs scalar) — the observable behind the `min_tile_work` gate tuning
//! in ROADMAP item 2.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Per-step accumulator. `work` is the compile-time per-sample op
/// estimate (`Step::work()`), kept so reports can show ns-per-op.
#[derive(Debug)]
struct StepSlot {
    label: String,
    work: u64,
    calls: AtomicU64,
    sampled: AtomicU64,
    ns: AtomicU64,
    items: AtomicU64,
}

/// Aggregated profiling state for one compiled plan (shared across
/// plan clones via `Arc`).
#[derive(Debug)]
pub struct PlanProfiler {
    plan: String,
    sample_every: u64,
    steps: Vec<StepSlot>,
    mac_tiled: AtomicU64,
    mac_scalar: AtomicU64,
}

impl PlanProfiler {
    /// `labels` carries one `(kind label, per-sample work)` pair per
    /// plan step, in step order. `sample_every = 0` keeps counters
    /// only; `n >= 1` times one call in `n` per step.
    pub(crate) fn new(plan: &str, labels: Vec<(String, u64)>, sample_every: u64) -> PlanProfiler {
        PlanProfiler {
            plan: plan.to_string(),
            sample_every,
            steps: labels
                .into_iter()
                .map(|(label, work)| StepSlot {
                    label,
                    work,
                    calls: AtomicU64::new(0),
                    sampled: AtomicU64::new(0),
                    ns: AtomicU64::new(0),
                    items: AtomicU64::new(0),
                })
                .collect(),
            mac_tiled: AtomicU64::new(0),
            mac_scalar: AtomicU64::new(0),
        }
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }

    /// Count a step call; returns a start timestamp when this call is
    /// selected for timing.
    pub(crate) fn begin(&self, step: usize) -> Option<Instant> {
        let n = self.steps[step].calls.fetch_add(1, Ordering::Relaxed);
        if self.sample_every > 0 && n % self.sample_every == 0 {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a timed call opened by [`begin`](Self::begin); `b` is the
    /// batch (sample) count the call processed.
    pub(crate) fn end(&self, step: usize, t0: Option<Instant>, b: usize) {
        if let Some(t0) = t0 {
            let slot = &self.steps[step];
            slot.sampled.fetch_add(1, Ordering::Relaxed);
            slot.ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            slot.items.fetch_add(b as u64, Ordering::Relaxed);
        }
    }

    /// Count one MAC kernel dispatch (tiled register-blocked core vs
    /// the scalar oracle).
    pub(crate) fn note_mac(&self, tiled: bool) {
        if tiled {
            self.mac_tiled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.mac_scalar.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot the accumulated state into a report.
    pub fn report(&self) -> ProfileReport {
        let steps: Vec<StepReport> = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let calls = s.calls.load(Ordering::Relaxed);
                let sampled = s.sampled.load(Ordering::Relaxed);
                let ns = s.ns.load(Ordering::Relaxed);
                let items = s.items.load(Ordering::Relaxed);
                // scale the sampled time back up to an estimate of the
                // full cost of this step across all calls
                let est_ns = if sampled > 0 { (ns as f64 * calls as f64 / sampled as f64) as u64 } else { 0 };
                StepReport { index: i, kind: s.label.clone(), work: s.work, calls, sampled, ns, items, est_ns }
            })
            .collect();
        ProfileReport {
            plan: self.plan.clone(),
            sample_every: self.sample_every,
            mac_tiled: self.mac_tiled.load(Ordering::Relaxed),
            mac_scalar: self.mac_scalar.load(Ordering::Relaxed),
            steps,
        }
    }
}

/// One step's aggregated numbers.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Step index in plan order.
    pub index: usize,
    /// Kind label, e.g. `matmul(i32)` or `ew[4]`.
    pub kind: String,
    /// Compile-time per-sample op estimate.
    pub work: u64,
    /// Total calls (always-on counter).
    pub calls: u64,
    /// Calls that were actually timed.
    pub sampled: u64,
    /// Nanoseconds across the sampled calls only.
    pub ns: u64,
    /// Samples (batch elements) across the sampled calls.
    pub items: u64,
    /// Sampled time scaled up by `calls / sampled`.
    pub est_ns: u64,
}

/// Snapshot report for one plan, renderable as a table or JSON.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub plan: String,
    pub sample_every: u64,
    pub mac_tiled: u64,
    pub mac_scalar: u64,
    pub steps: Vec<StepReport>,
}

impl ProfileReport {
    /// Estimated total ns across all steps (sum of scaled step times).
    pub fn est_total_ns(&self) -> u64 {
        self.steps.iter().map(|s| s.est_ns).sum()
    }

    /// Aggregate estimated ns by kind label, heaviest first.
    pub fn by_kind(&self) -> Vec<(String, u64, u64)> {
        let mut map: std::collections::BTreeMap<&str, (u64, u64)> = std::collections::BTreeMap::new();
        for s in &self.steps {
            let e = map.entry(&s.kind).or_insert((0, 0));
            e.0 += s.est_ns;
            e.1 += s.calls;
        }
        let mut v: Vec<(String, u64, u64)> =
            map.into_iter().map(|(k, (ns, calls))| (k.to_string(), ns, calls)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    pub fn json(&self) -> Json {
        let total = self.est_total_ns();
        Json::obj(vec![
            ("plan", Json::Str(self.plan.clone())),
            ("sample_every", Json::Num(self.sample_every as f64)),
            (
                "mac",
                Json::obj(vec![
                    ("tiled", Json::Num(self.mac_tiled as f64)),
                    ("scalar", Json::Num(self.mac_scalar as f64)),
                ]),
            ),
            ("est_total_ns", Json::Num(total as f64)),
            (
                "steps",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("index", Json::Num(s.index as f64)),
                                ("kind", Json::Str(s.kind.clone())),
                                ("work", Json::Num(s.work as f64)),
                                ("calls", Json::Num(s.calls as f64)),
                                ("sampled", Json::Num(s.sampled as f64)),
                                ("ns", Json::Num(s.ns as f64)),
                                ("items", Json::Num(s.items as f64)),
                                ("est_ns", Json::Num(s.est_ns as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "kinds",
                Json::Arr(
                    self.by_kind()
                        .into_iter()
                        .map(|(kind, ns, calls)| {
                            Json::obj(vec![
                                ("kind", Json::Str(kind)),
                                ("est_ns", Json::Num(ns as f64)),
                                ("calls", Json::Num(calls as f64)),
                                (
                                    "share",
                                    Json::Num(if total > 0 { ns as f64 / total as f64 } else { 0.0 }),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl std::fmt::Display for ProfileReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let total = self.est_total_ns().max(1);
        writeln!(
            f,
            "plan '{}' step profile (sample 1/{}, mac dispatch: {} tiled / {} scalar)",
            self.plan,
            self.sample_every.max(1),
            self.mac_tiled,
            self.mac_scalar
        )?;
        writeln!(f, "{:>4} {:<18} {:>10} {:>8} {:>12} {:>6}", "step", "kind", "work", "calls", "est_ns", "share")?;
        for s in &self.steps {
            writeln!(
                f,
                "{:>4} {:<18} {:>10} {:>8} {:>12} {:>5.1}%",
                s.index,
                s.kind,
                s.work,
                s.calls,
                s.est_ns,
                100.0 * s.est_ns as f64 / total as f64
            )?;
        }
        for (kind, ns, calls) in self.by_kind() {
            writeln!(
                f,
                "  by kind: {:<18} {:>12} ns ({:>5.1}%) over {} calls",
                kind,
                ns,
                100.0 * ns as f64 / total as f64,
                calls
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_without_sampling() {
        let p = PlanProfiler::new("t", vec![("matmul(i32)".into(), 100), ("ew[2]".into(), 10)], 0);
        for _ in 0..5 {
            let t = p.begin(0);
            assert!(t.is_none(), "sample_every=0 must not time");
            p.end(0, t, 8);
        }
        let r = p.report();
        assert_eq!(r.steps[0].calls, 5);
        assert_eq!(r.steps[0].sampled, 0);
        assert_eq!(r.steps[0].est_ns, 0);
        assert_eq!(r.steps[1].calls, 0);
    }

    #[test]
    fn sampling_scales_estimates() {
        let p = PlanProfiler::new("t", vec![("pool".into(), 50)], 4);
        let mut timed = 0;
        for _ in 0..16 {
            let t = p.begin(0);
            if t.is_some() {
                timed += 1;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            p.end(0, t, 1);
        }
        assert_eq!(timed, 4); // calls 0, 4, 8, 12
        let r = p.report();
        assert_eq!(r.steps[0].calls, 16);
        assert_eq!(r.steps[0].sampled, 4);
        // est scales the 4 timed calls up 4x
        assert!(r.steps[0].est_ns >= 4 * r.steps[0].ns / 5, "{r:?}");
        assert!(r.est_total_ns() >= r.steps[0].ns);
    }

    #[test]
    fn mac_dispatch_counters_and_json_shape() {
        let p = PlanProfiler::new("t", vec![("matmul(i32)".into(), 100)], 1);
        p.note_mac(true);
        p.note_mac(true);
        p.note_mac(false);
        let t = p.begin(0);
        p.end(0, t, 8);
        let j = p.report().json();
        assert_eq!(j.get("mac").unwrap().get("tiled").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("mac").unwrap().get("scalar").unwrap().as_usize().unwrap(), 1);
        let steps = j.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("kind").unwrap().as_str().unwrap(), "matmul(i32)");
        assert_eq!(steps[0].get("calls").unwrap().as_usize().unwrap(), 1);
        let kinds = j.get("kinds").unwrap().as_arr().unwrap();
        assert_eq!(kinds.len(), 1);
        // round-trips through the parser like every other report
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // display renders without panicking
        let _ = p.report().to_string();
    }
}
