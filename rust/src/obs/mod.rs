//! Observability: bounded-memory metrics, structured request tracing
//! and per-step plan profiling — the sensor layer for the serving
//! stack (and the live counterpart of the paper's per-operator cost
//! attribution).
//!
//! - [`metrics`] — atomic [`Counter`]/[`Gauge`]/fixed-bucket
//!   [`Histogram`] instruments plus Prometheus text exposition
//!   ([`PromWriter`], [`validate_exposition`]). Replaces the unbounded
//!   `Vec<u64>` sample logs the coordinator metrics used to keep.
//! - [`trace`] — per-request ids and JSON-line span records on a
//!   pluggable sink, filtered by `SIRA_TRACE` with a
//!   `SIRA_TRACE_SLOW_MS` slow-request threshold.
//! - [`profile`] — per-step plan profiler ([`PlanProfiler`]): always-on
//!   step counters plus opt-in sampled kernel timing, surfaced by
//!   `sira-finn profile` and `--profile` on the serving paths.

pub mod metrics;
pub mod profile;
pub mod trace;

pub use metrics::{validate_exposition, Counter, Gauge, Histogram, PromWriter};
pub use profile::{PlanProfiler, ProfileReport, StepReport};
pub use trace::{next_request_id, tracer, Level, MemorySink, StderrSink, TraceSink, Tracer};
