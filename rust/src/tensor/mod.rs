//! N-dimensional array substrate: f64 storage, ONNX multidirectional
//! broadcasting, matrix multiplication, im2col convolution and pooling.
//!
//! f64 exactly represents every integer with magnitude below 2^53, far
//! beyond the widest accumulator the paper encounters (24 bits), so the
//! same storage serves both the real-valued and the integer-valued
//! (post-streamlining) execution paths; the integer executor additionally
//! checks integrality and width bounds (see [`crate::executor`]).

use anyhow::{bail, Result};

mod conv;
mod ops;

pub use conv::{conv2d, conv2d_depthwise, im2col, pool2d, Conv2dSpec, PoolKind};
pub use ops::round_half_even;

/// Dense n-dimensional array of f64 in row-major (C) order.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl Tensor {
    // ---- constructors ----------------------------------------------------

    pub fn new(shape: &[usize], data: Vec<f64>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!(
                "shape {:?} implies {} elements, got {}",
                shape,
                numel,
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(v: f64) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f64) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_vec(data: Vec<f64>) -> Tensor {
        Tensor {
            shape: vec![data.len()],
            data,
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// True if every element equals `v`.
    pub fn all_eq(&self, v: f64) -> bool {
        self.data.iter().all(|&x| x == v)
    }

    /// True if the tensor holds a single value (any shape with numel 1).
    pub fn is_scalar(&self) -> bool {
        self.numel() == 1
    }

    pub fn first(&self) -> f64 {
        self.data[0]
    }

    /// True if all elements are integers.
    pub fn is_integral(&self) -> bool {
        self.data.iter().all(|&x| x.fract() == 0.0 && x.is_finite())
    }

    /// Row-major strides for this shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    pub fn at(&self, idx: &[usize]) -> f64 {
        debug_assert_eq!(idx.len(), self.rank());
        let mut off = 0;
        let strides = self.strides();
        for (i, &x) in idx.iter().enumerate() {
            debug_assert!(x < self.shape[i]);
            off += x * strides[i];
        }
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f64) {
        let mut off = 0;
        let strides = self.strides();
        for (i, &x) in idx.iter().enumerate() {
            off += x * strides[i];
        }
        self.data[off] = v;
    }

    // ---- shape manipulation ----------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.numel() {
            bail!("cannot reshape {:?} to {:?}", self.shape, shape);
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Transpose a rank-2 tensor.
    pub fn t(&self) -> Result<Tensor> {
        if self.rank() != 2 {
            bail!("t() requires rank 2, got {:?}", self.shape);
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// General axis permutation.
    pub fn permute(&self, perm: &[usize]) -> Result<Tensor> {
        if perm.len() != self.rank() {
            bail!("permute arity mismatch");
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                bail!("invalid permutation {:?}", perm);
            }
            seen[p] = true;
        }
        let out_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let in_strides = self.strides();
        let mut out = Tensor::zeros(&out_shape);
        let out_strides = out.strides();
        let mut idx = vec![0usize; out_shape.len()];
        for flat in 0..out.numel() {
            // decompose flat into out index
            let mut rem = flat;
            for (d, &s) in out_strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let mut src = 0;
            for (d, &p) in perm.iter().enumerate() {
                src += idx[d] * in_strides[p];
            }
            out.data[flat] = self.data[src];
        }
        Ok(out)
    }

    /// Concatenate along `axis`.
    pub fn concat(tensors: &[&Tensor], axis: usize) -> Result<Tensor> {
        if tensors.is_empty() {
            bail!("concat of zero tensors");
        }
        let rank = tensors[0].rank();
        if axis >= rank {
            bail!("concat axis {axis} out of range for rank {rank}");
        }
        let mut out_shape = tensors[0].shape.clone();
        out_shape[axis] = 0;
        for t in tensors {
            if t.rank() != rank {
                bail!("concat rank mismatch");
            }
            for d in 0..rank {
                if d != axis && t.shape[d] != tensors[0].shape[d] {
                    bail!("concat shape mismatch on axis {d}");
                }
            }
            out_shape[axis] += t.shape[axis];
        }
        let outer: usize = out_shape[..axis].iter().product();
        let inner: usize = out_shape[axis + 1..].iter().product();
        let mut data = Vec::with_capacity(out_shape.iter().product());
        for o in 0..outer {
            for t in tensors {
                let ax = t.shape[axis];
                let start = o * ax * inner;
                data.extend_from_slice(&t.data[start..start + ax * inner]);
            }
        }
        Tensor::new(&out_shape, data)
    }

    // ---- reductions --------------------------------------------------------

    pub fn min(&self) -> f64 {
        self.data.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Reduce all axes except `axis`, producing a rank-1 tensor of the
    /// per-slice minimum (used for per-channel range instrumentation).
    pub fn reduce_except(&self, axis: usize, init: f64, f: impl Fn(f64, f64) -> f64) -> Tensor {
        let n = self.shape[axis];
        let mut out = vec![init; n];
        let strides = self.strides();
        for (flat, &v) in self.data.iter().enumerate() {
            let c = (flat / strides[axis]) % n;
            out[c] = f(out[c], v);
        }
        Tensor::from_vec(out)
    }

    /// argmax over the last axis for a rank-2 (batch, classes) tensor.
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        if self.rank() != 2 {
            bail!("argmax_rows requires rank 2");
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Vec::with_capacity(m);
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            let mut best = 0;
            for j in 1..n {
                if row[j] > row[best] {
                    best = j;
                }
            }
            out.push(best);
        }
        Ok(out)
    }

    // ---- matmul ------------------------------------------------------------

    /// Matrix multiplication of rank-2 tensors: (M,K) x (K,N) -> (M,N).
    pub fn matmul(&self, rhs: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || rhs.rank() != 2 {
            bail!(
                "matmul requires rank-2 operands, got {:?} x {:?}",
                self.shape,
                rhs.shape
            );
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        if k != k2 {
            bail!("matmul inner dim mismatch: {:?} x {:?}", self.shape, rhs.shape);
        }
        let mut out = vec![0.0; m * n];
        // ikj loop order for cache-friendly access of rhs rows.
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &rhs.data[kk * n..(kk + 1) * n];
                for (j, &b) in b_row.iter().enumerate() {
                    o_row[j] += a * b;
                }
            }
        }
        Tensor::new(&[m, n], out)
    }
}

/// Row-major strides for a shape.
pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

/// ONNX multidirectional broadcast of two shapes.
pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            bail!("shapes {:?} and {:?} are not broadcastable", a, b)
        };
    }
    Ok(out)
}

/// True if `src` can broadcast to exactly `dst`.
pub fn broadcastable_to(src: &[usize], dst: &[usize]) -> bool {
    match broadcast_shape(src, dst) {
        Ok(s) => s == dst,
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.at(&[0, 2]), 3.0);
        assert_eq!(t.at(&[1, 0]), 4.0);
        assert!(Tensor::new(&[2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn strides_and_reshape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        let r = t.reshape(&[6, 4]).unwrap();
        assert_eq!(r.shape(), &[6, 4]);
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn transpose() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.t().unwrap();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn permute_nchw_to_nhwc() {
        let t = Tensor::new(&[1, 2, 2, 2], (0..8).map(|i| i as f64).collect()).unwrap();
        let p = t.permute(&[0, 2, 3, 1]).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2, 2]);
        assert_eq!(p.at(&[0, 0, 0, 1]), t.at(&[0, 1, 0, 0]));
        assert!(t.permute(&[0, 0, 1, 2]).is_err());
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
        assert!(a.matmul(&Tensor::zeros(&[3, 2])).is_err());
    }

    #[test]
    fn broadcast_shapes() {
        assert_eq!(broadcast_shape(&[2, 3], &[3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shape(&[4, 1, 3], &[2, 1]).unwrap(), vec![4, 2, 3]);
        assert_eq!(broadcast_shape(&[], &[5]).unwrap(), vec![5]);
        assert!(broadcast_shape(&[2, 3], &[4]).is_err());
        assert!(broadcastable_to(&[1, 3], &[2, 3]));
        assert!(!broadcastable_to(&[2, 3], &[1, 3]));
    }

    #[test]
    fn concat_axis1() {
        let a = Tensor::new(&[2, 1], vec![1., 2.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![3., 4., 5., 6.]).unwrap();
        let c = Tensor::concat(&[&a, &b], 1).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[1., 3., 4., 2., 5., 6.]);
        assert!(Tensor::concat(&[&a, &Tensor::zeros(&[3, 1])], 1).is_err());
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(&[2, 2], vec![-1., 5., 2., 0.]).unwrap();
        assert_eq!(t.min(), -1.0);
        assert_eq!(t.max(), 5.0);
        assert_eq!(t.sum(), 6.0);
    }

    #[test]
    fn reduce_except_channel() {
        // NCHW tensor, channel axis 1
        let t = Tensor::new(
            &[1, 2, 1, 2],
            vec![1., -3., /* ch0 */ 10., 20. /* ch1 */],
        )
        .unwrap();
        let mins = t.reduce_except(1, f64::INFINITY, f64::min);
        assert_eq!(mins.data(), &[-3., 10.]);
        let maxs = t.reduce_except(1, f64::NEG_INFINITY, f64::max);
        assert_eq!(maxs.data(), &[1., 20.]);
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, 0.3, 0.8, 0.2, 0.1]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
    }

    #[test]
    fn integrality() {
        assert!(Tensor::from_vec(vec![1.0, -3.0, 0.0]).is_integral());
        assert!(!Tensor::from_vec(vec![1.5]).is_integral());
        assert!(!Tensor::from_vec(vec![f64::INFINITY]).is_integral());
    }
}
