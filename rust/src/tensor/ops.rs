//! Elementwise tensor operations with ONNX multidirectional broadcasting.

use anyhow::Result;

use super::{broadcast_shape, strides_of, Tensor};

impl Tensor {
    /// Apply a unary function elementwise.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Apply a binary function elementwise with broadcasting.
    pub fn zip(&self, rhs: &Tensor, f: impl Fn(f64, f64) -> f64) -> Result<Tensor> {
        let out_shape = broadcast_shape(&self.shape, &rhs.shape)?;
        // Fast path: identical shapes.
        if self.shape == rhs.shape {
            return Ok(Tensor {
                shape: out_shape,
                data: self
                    .data
                    .iter()
                    .zip(&rhs.data)
                    .map(|(&a, &b)| f(a, b))
                    .collect(),
            });
        }
        // Fast path: rhs scalar.
        if rhs.numel() == 1 {
            let b = rhs.data[0];
            let mut out = self.clone();
            // output shape may have higher rank than self if rhs is e.g. [1,1]
            out.shape = out_shape;
            for v in &mut out.data {
                *v = f(*v, b);
            }
            return Ok(out);
        }
        if self.numel() == 1 {
            let a = self.data[0];
            let mut out = rhs.clone();
            out.shape = out_shape;
            for v in &mut out.data {
                *v = f(a, *v);
            }
            return Ok(out);
        }
        // Fast path: rhs broadcasts as a suffix (e.g. (K,M) ⨯ (1,M)) or a
        // prefix-with-trailing-ones (e.g. (O,I,KH,KW) ⨯ (O,1,1,1)) of an
        // output that matches self. These cover the per-channel parameter
        // patterns that dominate analysis time (see EXPERIMENTS.md §Perf).
        if out_shape == self.shape {
            let rn = rhs.numel();
            let rshape = &rhs.shape;
            let pad = out_shape.len() - rshape.len();
            let suffix = rshape
                .iter()
                .enumerate()
                .all(|(i, &d)| d == 1 || d == out_shape[pad + i])
                && {
                    // all non-1 dims must be a contiguous tail
                    let first_non1 = rshape.iter().position(|&d| d != 1).unwrap_or(0);
                    rshape[first_non1..]
                        .iter()
                        .zip(&out_shape[pad + first_non1..])
                        .all(|(&a, &b)| a == b)
                };
            if suffix && self.numel() % rn == 0 && rn > 0 {
                let data = self
                    .data
                    .iter()
                    .enumerate()
                    .map(|(i, &a)| f(a, rhs.data[i % rn]))
                    .collect();
                return Ok(Tensor {
                    shape: out_shape,
                    data,
                });
            }
            // prefix: rhs = (d0, 1, 1, ...) with d0 == out_shape[pad]
            if pad == 0
                && rshape[0] == out_shape[0]
                && rshape[1..].iter().all(|&d| d == 1)
                && rshape[0] > 0
            {
                let inner = self.numel() / rshape[0];
                let data = self
                    .data
                    .iter()
                    .enumerate()
                    .map(|(i, &a)| f(a, rhs.data[i / inner]))
                    .collect();
                return Ok(Tensor {
                    shape: out_shape,
                    data,
                });
            }
        }
        // General broadcast: compute effective strides (0 on broadcast dims).
        let rank = out_shape.len();
        let eff = |shape: &[usize]| -> Vec<usize> {
            let pad = rank - shape.len();
            let native = strides_of(shape);
            (0..rank)
                .map(|d| {
                    if d < pad || shape[d - pad] == 1 {
                        0
                    } else {
                        native[d - pad]
                    }
                })
                .collect()
        };
        let sa = eff(&self.shape);
        let sb = eff(&rhs.shape);
        let out_strides = strides_of(&out_shape);
        let numel: usize = out_shape.iter().product();
        let mut data = Vec::with_capacity(numel);
        let mut idx = vec![0usize; rank];
        for flat in 0..numel {
            let mut rem = flat;
            let mut oa = 0;
            let mut ob = 0;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
                oa += idx[d] * sa[d];
                ob += idx[d] * sb[d];
            }
            data.push(f(self.data[oa], rhs.data[ob]));
        }
        Ok(Tensor {
            shape: out_shape,
            data,
        })
    }

    pub fn add(&self, rhs: &Tensor) -> Result<Tensor> {
        self.zip(rhs, |a, b| a + b)
    }

    pub fn sub(&self, rhs: &Tensor) -> Result<Tensor> {
        self.zip(rhs, |a, b| a - b)
    }

    pub fn mul(&self, rhs: &Tensor) -> Result<Tensor> {
        self.zip(rhs, |a, b| a * b)
    }

    pub fn div(&self, rhs: &Tensor) -> Result<Tensor> {
        self.zip(rhs, |a, b| a / b)
    }

    pub fn maximum(&self, rhs: &Tensor) -> Result<Tensor> {
        self.zip(rhs, f64::max)
    }

    pub fn minimum(&self, rhs: &Tensor) -> Result<Tensor> {
        self.zip(rhs, f64::min)
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    pub fn neg(&self) -> Tensor {
        self.map(|x| -x)
    }

    /// Round half to even (banker's rounding), matching numpy/ONNX `Round`
    /// and the `round` used inside the Quant operator.
    pub fn round_even(&self) -> Tensor {
        self.map(round_half_even)
    }

    pub fn floor(&self) -> Tensor {
        self.map(f64::floor)
    }

    pub fn ceil(&self) -> Tensor {
        self.map(f64::ceil)
    }

    pub fn clip(&self, lo: f64, hi: f64) -> Tensor {
        self.map(|x| x.clamp(lo, hi))
    }

    /// Broadcast this tensor to a target shape (must be compatible).
    pub fn broadcast_to(&self, shape: &[usize]) -> Result<Tensor> {
        self.zip(&Tensor::zeros(shape), |a, _| a)
    }

    /// Maximum absolute element.
    pub fn abs_max(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Sigmoid activation.
    pub fn sigmoid(&self) -> Tensor {
        self.map(|x| 1.0 / (1.0 + (-x).exp()))
    }
}

/// Round half to even at f64 precision.
pub fn round_half_even(x: f64) -> f64 {
    let r = x.round(); // rounds half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: choose even
        if r % 2.0 == 0.0 {
            r
        } else {
            r - (r - x).signum()
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_same_shape() {
        let a = Tensor::from_vec(vec![1., 2., 3.]);
        let b = Tensor::from_vec(vec![10., 20., 30.]);
        assert_eq!(a.add(&b).unwrap().data(), &[11., 22., 33.]);
    }

    #[test]
    fn scalar_broadcast() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let s = Tensor::scalar(10.0);
        assert_eq!(a.mul(&s).unwrap().data(), &[10., 20., 30., 40.]);
        assert_eq!(s.sub(&a).unwrap().data(), &[9., 8., 7., 6.]);
    }

    #[test]
    fn row_and_col_broadcast() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let row = Tensor::new(&[3], vec![10., 20., 30.]).unwrap();
        let col = Tensor::new(&[2, 1], vec![100., 200.]).unwrap();
        assert_eq!(
            a.add(&row).unwrap().data(),
            &[11., 22., 33., 14., 25., 36.]
        );
        assert_eq!(
            a.add(&col).unwrap().data(),
            &[101., 102., 103., 204., 205., 206.]
        );
    }

    #[test]
    fn both_sides_broadcast() {
        // (2,1) x (1,3) -> (2,3)
        let a = Tensor::new(&[2, 1], vec![1., 2.]).unwrap();
        let b = Tensor::new(&[1, 3], vec![10., 20., 30.]).unwrap();
        let c = a.mul(&b).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[10., 20., 30., 20., 40., 60.]);
    }

    #[test]
    fn nchw_channel_param_broadcast() {
        // per-channel scale of shape (1, C, 1, 1) against NCHW activations
        let x = Tensor::new(&[1, 2, 1, 2], vec![1., 2., 3., 4.]).unwrap();
        let s = Tensor::new(&[1, 2, 1, 1], vec![10., 100.]).unwrap();
        let y = x.mul(&s).unwrap();
        assert_eq!(y.data(), &[10., 20., 300., 400.]);
    }

    #[test]
    fn incompatible_shapes_error() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4]);
        assert!(a.add(&b).is_err());
    }

    #[test]
    fn round_half_even_ties() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4), 1.0);
        assert_eq!(round_half_even(-1.6), -2.0);
    }

    #[test]
    fn relu_and_clip() {
        let a = Tensor::from_vec(vec![-2., 0., 3.]);
        assert_eq!(a.relu().data(), &[0., 0., 3.]);
        assert_eq!(a.clip(-1.0, 1.0).data(), &[-1., 0., 1.]);
    }

    #[test]
    fn broadcast_to_target() {
        let s = Tensor::new(&[1, 2, 1, 1], vec![5., 7.]).unwrap();
        let b = s.broadcast_to(&[1, 2, 2, 2]).unwrap();
        assert_eq!(b.shape(), &[1, 2, 2, 2]);
        assert_eq!(b.data(), &[5., 5., 5., 5., 7., 7., 7., 7.]);
    }
}
