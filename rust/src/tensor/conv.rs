//! Convolution (via im2col lowering, as the paper notes convolutions can
//! be treated as matrix-matrix multiplications [Chellapilla et al.]) and
//! pooling over NCHW tensors.

use anyhow::{bail, Result};

use super::Tensor;

/// 2-D convolution hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dSpec {
    pub kernel: (usize, usize),
    pub stride: (usize, usize),
    /// Symmetric padding (top/bottom, left/right).
    pub pad: (usize, usize),
}

impl Conv2dSpec {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        let oh = (h + 2 * self.pad.0 - self.kernel.0) / self.stride.0 + 1;
        let ow = (w + 2 * self.pad.1 - self.kernel.1) / self.stride.1 + 1;
        (oh, ow)
    }
}

/// im2col: lower an NCHW input into a (N*OH*OW, C*KH*KW) matrix whose rows
/// are flattened receptive fields. Padding contributes `pad_value`.
pub fn im2col(
    x: &Tensor,
    spec: Conv2dSpec,
    pad_value: f64,
) -> Result<(Tensor, usize, usize)> {
    if x.rank() != 4 {
        bail!("im2col expects NCHW, got {:?}", x.shape());
    }
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, w);
    let cols = c * kh * kw;
    let mut out = Vec::with_capacity(n * oh * ow * cols);
    let xd = x.data();
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                            let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                            let v = if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                pad_value
                            } else {
                                xd[((b * c + ch) * h + iy as usize) * w + ix as usize]
                            };
                            out.push(v);
                        }
                    }
                }
            }
        }
    }
    Ok((Tensor::new(&[n * oh * ow, cols], out)?, oh, ow))
}

/// Dense 2-D convolution: input NCHW, weights OIHW -> output NOHW.
pub fn conv2d(x: &Tensor, w: &Tensor, spec: Conv2dSpec) -> Result<Tensor> {
    if w.rank() != 4 {
        bail!("conv2d weights must be OIHW, got {:?}", w.shape());
    }
    let (n, c) = (x.shape()[0], x.shape()[1]);
    let (oc, ic, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    if ic != c {
        bail!("conv2d channel mismatch: input C={c}, weight I={ic}");
    }
    if (kh, kw) != spec.kernel {
        bail!("conv2d kernel mismatch: weights {kh}x{kw}, spec {:?}", spec.kernel);
    }
    let (cols, oh, ow) = {
        let (m, oh, ow) = im2col(x, spec, 0.0)?;
        (m, oh, ow)
    };
    // weights as (C*KH*KW, OC)
    let wmat = w.reshape(&[oc, ic * kh * kw])?.t()?;
    let y = cols.matmul(&wmat)?; // (N*OH*OW, OC)
    // reshape to NCHW
    let y = y.reshape(&[n, oh, ow, oc])?.permute(&[0, 3, 1, 2])?;
    Ok(y)
}

/// Depthwise 2-D convolution: input NCHW, weights (C,1,KH,KW) -> NCHW.
/// Each channel is convolved independently — the sparse structure the
/// paper exploits in §3.2.4 (per-channel scales suffice).
pub fn conv2d_depthwise(x: &Tensor, w: &Tensor, spec: Conv2dSpec) -> Result<Tensor> {
    if w.rank() != 4 || w.shape()[1] != 1 {
        bail!("depthwise weights must be (C,1,KH,KW), got {:?}", w.shape());
    }
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    if w.shape()[0] != c {
        bail!("depthwise channel mismatch");
    }
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, wd);
    let mut out = vec![0.0; n * c * oh * ow];
    let xd = x.data();
    let wdta = w.data();
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                            let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                continue;
                            }
                            acc += xd[((b * c + ch) * h + iy as usize) * wd + ix as usize]
                                * wdta[(ch * kh + ky) * kw + kx];
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    Tensor::new(&[n, c, oh, ow], out)
}

/// Pooling kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Average,
}

/// 2-D pooling over NCHW. Max-pool padding uses -inf; average uses
/// count_include_pad=false semantics (divisor = window elements inside).
pub fn pool2d(x: &Tensor, kind: PoolKind, spec: Conv2dSpec) -> Result<Tensor> {
    if x.rank() != 4 {
        bail!("pool2d expects NCHW, got {:?}", x.shape());
    }
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, w);
    let mut out = vec![0.0; n * c * oh * ow];
    let xd = x.data();
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = match kind {
                        PoolKind::Max => f64::NEG_INFINITY,
                        PoolKind::Average => 0.0,
                    };
                    let mut count = 0usize;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                            let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                continue;
                            }
                            let v = xd[((b * c + ch) * h + iy as usize) * w + ix as usize];
                            match kind {
                                PoolKind::Max => acc = acc.max(v),
                                PoolKind::Average => acc += v,
                            }
                            count += 1;
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = match kind {
                        PoolKind::Max => acc,
                        PoolKind::Average => acc / count.max(1) as f64,
                    };
                }
            }
        }
    }
    Tensor::new(&[n, c, oh, ow], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|i| i as f64).collect()).unwrap()
    }

    #[test]
    fn im2col_identity_kernel() {
        let x = seq(&[1, 1, 2, 2]);
        let spec = Conv2dSpec {
            kernel: (1, 1),
            stride: (1, 1),
            pad: (0, 0),
        };
        let (m, oh, ow) = im2col(&x, spec, 0.0).unwrap();
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(m.shape(), &[4, 1]);
        assert_eq!(m.data(), &[0., 1., 2., 3.]);
    }

    #[test]
    fn conv2d_sum_kernel() {
        // 3x3 ones kernel over a 3x3 input of ones, no pad -> single output 9
        let x = Tensor::full(&[1, 1, 3, 3], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (1, 1),
            pad: (0, 0),
        };
        let y = conv2d(&x, &w, spec).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data(), &[9.0]);
    }

    #[test]
    fn conv2d_padding() {
        let x = Tensor::full(&[1, 1, 2, 2], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (1, 1),
            pad: (1, 1),
        };
        let y = conv2d(&x, &w, spec).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        // each output sees the full 2x2 ones block
        assert_eq!(y.data(), &[4., 4., 4., 4.]);
    }

    #[test]
    fn conv2d_multichannel() {
        // 2 in-channels, 2 out-channels, 1x1 kernels: a channel mix
        let x = Tensor::new(&[1, 2, 1, 1], vec![3., 5.]).unwrap();
        let w = Tensor::new(&[2, 2, 1, 1], vec![1., 1., 1., -1.]).unwrap();
        let spec = Conv2dSpec {
            kernel: (1, 1),
            stride: (1, 1),
            pad: (0, 0),
        };
        let y = conv2d(&x, &w, spec).unwrap();
        assert_eq!(y.data(), &[8., -2.]);
    }

    #[test]
    fn depthwise_keeps_channels_separate() {
        let x = Tensor::new(&[1, 2, 2, 2], vec![1., 1., 1., 1., 2., 2., 2., 2.]).unwrap();
        let w = Tensor::new(&[2, 1, 2, 2], vec![1., 1., 1., 1., 1., 1., 1., 1.]).unwrap();
        let spec = Conv2dSpec {
            kernel: (2, 2),
            stride: (1, 1),
            pad: (0, 0),
        };
        let y = conv2d_depthwise(&x, &w, spec).unwrap();
        assert_eq!(y.shape(), &[1, 2, 1, 1]);
        assert_eq!(y.data(), &[4., 8.]);
    }

    #[test]
    fn depthwise_matches_dense_with_diagonal_weights() {
        // depthwise == dense conv with block-diagonal weights
        let x = seq(&[1, 2, 3, 3]);
        let wd = seq(&[2, 1, 2, 2]);
        let spec = Conv2dSpec {
            kernel: (2, 2),
            stride: (1, 1),
            pad: (0, 0),
        };
        let y_dw = conv2d_depthwise(&x, &wd, spec).unwrap();
        // build dense OIHW with zeros off-diagonal
        let mut dense = Tensor::zeros(&[2, 2, 2, 2]);
        for o in 0..2 {
            for ky in 0..2 {
                for kx in 0..2 {
                    dense.set(&[o, o, ky, kx], wd.at(&[o, 0, ky, kx]));
                }
            }
        }
        let y_dense = conv2d(&x, &dense, spec).unwrap();
        assert_eq!(y_dw, y_dense);
    }

    #[test]
    fn maxpool_and_avgpool() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 2., 3., 4.]).unwrap();
        let spec = Conv2dSpec {
            kernel: (2, 2),
            stride: (2, 2),
            pad: (0, 0),
        };
        assert_eq!(pool2d(&x, PoolKind::Max, spec).unwrap().data(), &[4.0]);
        assert_eq!(pool2d(&x, PoolKind::Average, spec).unwrap().data(), &[2.5]);
    }

    #[test]
    fn strided_conv_output_shape() {
        let x = Tensor::zeros(&[1, 3, 8, 8]);
        let w = Tensor::zeros(&[4, 3, 3, 3]);
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (2, 2),
            pad: (1, 1),
        };
        let y = conv2d(&x, &w, spec).unwrap();
        assert_eq!(y.shape(), &[1, 4, 4, 4]);
    }
}
