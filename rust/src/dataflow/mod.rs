//! Streaming dataflow performance simulator: given the chain of kernel
//! instances produced by the FDNA builder, computes steady-state
//! throughput (FPS at the target clock), end-to-end single-frame latency,
//! FIFO depths and stream-width legality (the 8192-bit ap_int limit of
//! §6.2.2). This stands in for the paper's on-board ZCU102 measurements
//! (DESIGN.md §Hardware-Adaptation).

use anyhow::{bail, Result};

use crate::hw::{KernelInstance, MAX_STREAM_BITS};

/// Performance summary of a dataflow pipeline.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// steady-state initiation interval in cycles (slowest stage)
    pub ii_cycles: u64,
    /// index + name of the bottleneck kernel
    pub bottleneck: String,
    /// end-to-end first-frame latency in cycles
    pub latency_cycles: u64,
    /// frames per second at `freq_hz`
    pub fps: f64,
    /// latency in milliseconds
    pub latency_ms: f64,
    /// per-kernel (name, cycles_per_frame)
    pub stage_cycles: Vec<(String, u64)>,
}

/// Simulate a pipeline at the given clock frequency.
pub fn simulate(kernels: &[KernelInstance], freq_hz: f64) -> Result<PipelineReport> {
    if kernels.is_empty() {
        bail!("empty pipeline");
    }
    let mut ii = 0u64;
    let mut bottleneck = String::new();
    let mut latency = 0u64;
    let mut stage_cycles = Vec::new();
    for ki in kernels {
        let k = &ki.kernel;
        let (w_in, w_out) = k.stream_widths();
        if w_in > MAX_STREAM_BITS || w_out > MAX_STREAM_BITS {
            bail!(
                "kernel '{}' exceeds the {}-bit stream limit ({} in / {} out)",
                k.name(),
                MAX_STREAM_BITS,
                w_in,
                w_out
            );
        }
        let c = k.cycles_per_frame();
        stage_cycles.push((k.name(), c));
        if c > ii {
            ii = c;
            bottleneck = k.name();
        }
        latency += k.latency();
    }
    // first frame flows through every stage sequentially; subsequent
    // frames pipeline at the bottleneck II
    let first_frame = latency + ii;
    let ii = ii.max(1);
    Ok(PipelineReport {
        ii_cycles: ii,
        bottleneck,
        latency_cycles: first_frame,
        fps: freq_hz / ii as f64,
        latency_ms: first_frame as f64 / freq_hz * 1e3,
        stage_cycles,
    })
}

/// Size inter-stage FIFOs: a stage that produces in bursts feeding a
/// slower consumer needs buffering proportional to the rate mismatch.
/// Returns the suggested depth for the FIFO after each kernel.
pub fn fifo_depths(kernels: &[KernelInstance]) -> Vec<u64> {
    let mut depths = Vec::with_capacity(kernels.len());
    for w in kernels.windows(2) {
        let a = w[0].kernel.cycles_per_frame().max(1);
        let b = w[1].kernel.cycles_per_frame().max(1);
        // rate ratio rounded up; capped like FINN's simulated FIFO sizing
        // (rate mismatches beyond ~32x are absorbed by backpressure, not
        // buffering)
        let ratio = (b as f64 / a as f64).max(a as f64 / b as f64);
        let depth = (2.0 * ratio).ceil() as u64;
        depths.push(depth.clamp(2, 64));
    }
    depths.push(2);
    depths
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{Fifo, KernelInstance};
    use crate::synth::MemStyle;

    fn inst(cycles: u64, width: u64) -> KernelInstance {
        // use a Thresholding kernel shim with configurable cycles by
        // abusing elems_per_frame
        KernelInstance {
            kernel: Box::new(crate::hw::Thresholding {
                name: format!("k{cycles}"),
                channels: 1,
                unique_rows: 0,
                elems_per_frame: cycles as usize,
                in_bits: width as u32,
                out_bits: 4,
                pe: 1,
                style: crate::hw::ThresholdStyle::BinarySearch,
                mem_style: MemStyle::Lut,
            }),
            source_node: "n".into(),
        }
    }

    #[test]
    fn bottleneck_sets_fps() {
        let ks = vec![inst(100, 8), inst(400, 8), inst(50, 8)];
        let r = simulate(&ks, 200e6).unwrap();
        assert_eq!(r.ii_cycles, 400);
        assert_eq!(r.bottleneck, "k400");
        assert!((r.fps - 200e6 / 400.0).abs() < 1e-6);
        assert!(r.latency_cycles > 400);
    }

    #[test]
    fn stream_width_limit_enforced() {
        let wide = KernelInstance {
            kernel: Box::new(Fifo {
                name: "wide".into(),
                width_bits: 10_000,
                depth: 2,
            }),
            source_node: "n".into(),
        };
        assert!(simulate(&[wide], 200e6).is_err());
    }

    #[test]
    fn fifo_depths_track_rate_mismatch() {
        let ks = vec![inst(10, 8), inst(1000, 8)];
        let d = fifo_depths(&ks);
        assert!(d[0] >= 64, "depth {:?}", d); // capped at 64
    }

    #[test]
    fn empty_pipeline_rejected() {
        assert!(simulate(&[], 200e6).is_err());
    }
}
