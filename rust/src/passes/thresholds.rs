//! Threshold conversion (§4.1.3): collapse a quantized layer tail — a
//! chain of elementwise Mul/Add/Div/ReLU/Clip/Floor ops terminating in a
//! unit-scale quantizer — into a single MultiThreshold operator.
//!
//! Following the paper, the conversion characterises the tail by its
//! end-to-end behaviour: the tail function is evaluated over the integer
//! input range reported by SIRA and the thresholds are the step locations
//! of the resulting piecewise-constant function (found here by binary
//! search per output level — the tail function is monotone whenever the
//! paper's "positive unit steps" kernel restriction holds; non-monotone
//! tails are detected and skipped). Thresholds are rounded up to integers
//! and clipped to the input range (Eq. 3), right-padded with +inf proxies
//! (`hi+1`, any value outside the input range) and the sign bias of Eq. 2
//! is applied through the MultiThreshold output bias.

use anyhow::{bail, Result};

use crate::graph::{Graph, Node, Op, RoundMode};
use crate::sira::{analyze, quant_bounds, Analysis, SiRange};
use crate::tensor::{round_half_even, Tensor};

/// One elementwise step of a layer tail, parameterised per channel.
#[derive(Clone, Debug)]
enum TailOp {
    MulC(Tensor),
    AddC(Tensor),
    DivC(Tensor),
    Relu,
    Clip(f64, f64),
    Floor,
}

impl TailOp {
    fn param(&self, ch: usize) -> f64 {
        match self {
            TailOp::MulC(t) | TailOp::AddC(t) | TailOp::DivC(t) => {
                if t.numel() == 1 {
                    t.data()[0]
                } else {
                    t.data()[ch]
                }
            }
            _ => 0.0,
        }
    }

    fn apply(&self, x: f64, ch: usize) -> f64 {
        match self {
            TailOp::MulC(_) => x * self.param(ch),
            TailOp::AddC(_) => x + self.param(ch),
            TailOp::DivC(_) => x / self.param(ch),
            TailOp::Relu => x.max(0.0),
            TailOp::Clip(lo, hi) => x.clamp(*lo, *hi),
            TailOp::Floor => x.floor(),
        }
    }
}

/// An extracted layer tail: the chain from an integer tensor to (and
/// including) a unit-scale quantizer.
struct Tail {
    /// tensor feeding the tail
    start: String,
    /// true when the start tensor is a pure integer per SIRA (enables
    /// integer threshold rounding, Eq. 3)
    integer_input: bool,
    /// indices of the chain nodes (excluding the quantizer)
    chain_nodes: Vec<usize>,
    /// quantizer node index
    quant_node: usize,
    ops: Vec<TailOp>,
    /// channels of the tail (1 = per-tensor)
    channels: usize,
    signed: bool,
    narrow: bool,
    rounding: RoundMode,
    bits: u32,
}

impl Tail {
    /// Evaluate the tail function for channel `ch` at integer input `x`,
    /// returning the quantizer's integer output level.
    fn eval(&self, x: f64, ch: usize) -> i64 {
        let mut v = x;
        for op in &self.ops {
            v = op.apply(v, ch);
        }
        let (qmin, qmax) = quant_bounds(self.bits, self.signed, self.narrow);
        let r = match self.rounding {
            RoundMode::RoundEven => round_half_even(v),
            RoundMode::Floor => v.floor(),
            RoundMode::Ceil => v.ceil(),
        };
        r.clamp(qmin, qmax) as i64
    }
}

/// Report of a conversion run.
#[derive(Debug, Default, Clone)]
pub struct ThresholdReport {
    pub converted: usize,
    pub skipped_nonmonotone: usize,
    pub skipped_no_int_input: usize,
    /// total threshold parameters materialised
    pub threshold_count: usize,
}

/// Convert every eligible layer tail in `g` to a MultiThreshold operator.
/// `input_ranges` are the graph input ranges for the SIRA run.
pub fn convert_to_thresholds(
    g: &mut Graph,
    input_ranges: &std::collections::BTreeMap<String, SiRange>,
) -> Result<ThresholdReport> {
    let mut report = ThresholdReport::default();
    // Anchor at final quantizers, working upwards (reverse topological
    // order) to fuse maximally-extending subgraphs. Conversions preserve
    // tensor values and names, so one SIRA run stays valid for every tail
    // converted in the same sweep (perf: see EXPERIMENTS.md §Perf).
    loop {
        let analysis = analyze(g, input_ranges)?;
        let mut progressed = false;
        // collect anchor names up front; indices shift as tails collapse
        let order = g.topo_order()?;
        let anchors: Vec<String> = order
            .iter()
            .rev()
            .filter(|&&i| matches!(g.nodes[i].op, Op::Quant { .. }))
            .map(|&i| g.nodes[i].name.clone())
            .collect();
        for name in anchors {
            let Some(qi) = g.nodes.iter().position(|n| n.name == name) else {
                continue;
            };
            let Op::Quant {
                signed,
                narrow,
                rounding,
            } = g.nodes[qi].op
            else {
                continue;
            };
            // unit-scale quantizer with zero zero-point only
            let s_ok = g
                .initializer(&g.nodes[qi].inputs[1])
                .map(|t| t.all_eq(1.0))
                .unwrap_or(false);
            let z_ok = g
                .initializer(&g.nodes[qi].inputs[2])
                .map(|t| t.all_eq(0.0))
                .unwrap_or(false);
            if !s_ok || !z_ok {
                continue;
            }
            let bits = g.initializers[&g.nodes[qi].inputs[3]].first() as u32;
            match extract_tail(g, &analysis, qi, signed, narrow, rounding, bits) {
                Ok(Some(tail)) => {
                    if materialise(g, &analysis, tail, &mut report)? {
                        progressed = true;
                    }
                }
                Ok(None) => {
                    report.skipped_no_int_input += 1;
                }
                Err(_) => {}
            }
        }
        if !progressed {
            break;
        }
    }
    g.prune_unused_initializers();
    crate::graph::shapes::infer_shapes(g)?;
    Ok(report)
}

/// Walk upstream from the quantizer through elementwise ops to an integer
/// tensor. Returns None when the walk dead-ends on a non-integer tensor.
fn extract_tail(
    g: &Graph,
    analysis: &Analysis,
    quant_node: usize,
    signed: bool,
    narrow: bool,
    rounding: RoundMode,
    bits: u32,
) -> Result<Option<Tail>> {
    let mut ops_rev: Vec<TailOp> = Vec::new();
    let mut chain_nodes: Vec<usize> = Vec::new();
    let mut cur = g.nodes[quant_node].inputs[0].clone();
    let mut channels = 1usize;

    // Walk upstream while the producer is an absorbable elementwise op
    // and the chain tensors are single-use.
    loop {
        // stop if `cur` is already a pure integer tensor per SIRA: keep
        // the tail minimal over the integer domain (Eq. 3 applies)
        let is_pure_int = analysis
            .get(&cur)
            .ok()
            .and_then(|r| r.int.as_ref().map(|ic| ic.is_pure_integer()))
            .unwrap_or(false);
        if is_pure_int {
            break;
        }
        let Some(pi) = g.producer(&cur) else {
            break; // graph input (float range): continuous thresholds
        };
        if g.consumers(&cur).len() != 1 || g.outputs.iter().any(|o| *o == cur) {
            break; // tail tensors must be single-use
        }
        let node = &g.nodes[pi];
        match &node.op {
            Op::Relu => {
                cur = node.inputs[0].clone();
                chain_nodes.push(pi);
                ops_rev.push(TailOp::Relu);
            }
            Op::Floor => {
                cur = node.inputs[0].clone();
                chain_nodes.push(pi);
                ops_rev.push(TailOp::Floor);
            }
            Op::Clip { lo, hi } => {
                cur = node.inputs[0].clone();
                chain_nodes.push(pi);
                ops_rev.push(TailOp::Clip(*lo, *hi));
            }
            Op::Mul | Op::Add | Op::Div => {
                let (ci, di) = match (
                    g.is_initializer(&node.inputs[0]),
                    g.is_initializer(&node.inputs[1]),
                ) {
                    (false, true) => (1, 0),
                    (true, false) => {
                        if matches!(node.op, Op::Div) {
                            break; // const / dynamic unsupported
                        }
                        (0, 1)
                    }
                    _ => break,
                };
                let param = g.initializers[&node.inputs[ci]].clone();
                let pn = param.numel();
                if pn > 1 {
                    if channels > 1 && channels != pn {
                        break; // mixed granularities
                    }
                    channels = pn;
                }
                let op = match node.op {
                    Op::Mul => TailOp::MulC(param),
                    Op::Add => TailOp::AddC(param),
                    Op::Div => TailOp::DivC(param),
                    _ => unreachable!(),
                };
                cur = node.inputs[di].clone();
                chain_nodes.push(pi);
                ops_rev.push(op);
            }
            _ => break,
        }
    }
    // `cur` is now the tail start: need a usable range.
    let Ok(r) = analysis.get(&cur) else {
        return Ok(None);
    };
    let integer_input = r
        .int
        .as_ref()
        .map(|ic| ic.is_pure_integer())
        .unwrap_or(false);
    if !integer_input && !r.lo.data().iter().all(|v| v.is_finite()) {
        return Ok(None);
    }
    let data_channels = g
        .shapes
        .get(&cur)
        .map(|s| if s.len() >= 2 { s[1] } else { 1 })
        .unwrap_or(1);
    if channels > 1 && channels != data_channels {
        bail!("tail params have {channels} channels, data has {data_channels}");
    }
    let chs = if channels > 1 { data_channels } else { 1 };
    let mut ops = ops_rev;
    ops.reverse();
    Ok(Some(Tail {
        start: cur,
        integer_input,
        chain_nodes,
        quant_node,
        ops,
        channels: chs,
        signed,
        narrow,
        rounding,
        bits,
    }))
}

/// Compute thresholds for a tail and rewrite the graph. Returns false if
/// the tail is non-monotone (left untouched).
fn materialise(
    g: &mut Graph,
    analysis: &Analysis,
    tail: Tail,
    report: &mut ThresholdReport,
) -> Result<bool> {
    let r = analysis.get(&tail.start)?;
    let c = tail.channels;
    // per-channel bounds of the tail input (integer domain when available)
    let (blo, bhi) = match (&r.int, tail.integer_input) {
        (Some(ic), true) => (ic.lo.clone(), ic.hi.clone()),
        _ => (r.lo.clone(), r.hi.clone()),
    };
    let (qmin, qmax) = quant_bounds(tail.bits, tail.signed, tail.narrow);
    let n_levels = (qmax - qmin) as usize;

    // Monotonicity check: sample the tail function per channel.
    for ch in 0..c {
        let (lo, hi) = (chan_bound_lo(&blo, ch, c), chan_bound_hi(&bhi, ch, c));
        let span = (hi - lo).max(1.0);
        let mut prev = tail.eval(lo, ch);
        for k in 1..=16 {
            let x = lo + span * k as f64 / 16.0;
            let x = if tail.integer_input { x.round() } else { x };
            let v = tail.eval(x, ch);
            if v < prev {
                report.skipped_nonmonotone += 1;
                return Ok(false);
            }
            prev = v;
        }
    }

    // Binary search per channel and output level: θ = smallest input with
    // f(x) >= level. Integer bisection when the input is integer (Eq. 3
    // rounding/clipping falls out for free); continuous bisection for
    // float inputs (e.g. the network input quantizer).
    let mut th = Vec::with_capacity(c * n_levels);
    for ch in 0..c {
        let (lo, hi) = (chan_bound_lo(&blo, ch, c), chan_bound_hi(&bhi, ch, c));
        for k in 1..=n_levels {
            let level = qmin as i64 + k as i64;
            if tail.eval(lo, ch) >= level {
                th.push(lo); // clipped to the input lower bound
                continue;
            }
            if tail.eval(hi, ch) < level {
                // +inf proxy (right padding): any value outside the range
                th.push(if tail.integer_input { hi + 1.0 } else { hi * (1.0 + 1e-9) + 1.0 });
                continue;
            }
            if tail.integer_input {
                let (mut a, mut b) = (lo as i64, hi as i64);
                while b - a > 1 {
                    let mid = a + (b - a) / 2;
                    if tail.eval(mid as f64, ch) >= level {
                        b = mid;
                    } else {
                        a = mid;
                    }
                }
                th.push(b as f64);
            } else {
                let (mut a, mut b) = (lo, hi);
                for _ in 0..100 {
                    let mid = 0.5 * (a + b);
                    if tail.eval(mid, ch) >= level {
                        b = mid;
                    } else {
                        a = mid;
                    }
                }
                th.push(b);
            }
        }
    }
    let th_t = Tensor::new(&[c, n_levels], th)?;

    // Validation: reconstruct f from thresholds on sampled inputs.
    for ch in 0..c {
        let (lo, hi) = (chan_bound_lo(&blo, ch, c), chan_bound_hi(&bhi, ch, c));
        let span = (hi - lo).max(1.0);
        for k in 0..=24 {
            let x = (lo + span * k as f64 / 24.0).clamp(lo, hi);
            let x = if tail.integer_input { x.round().clamp(lo, hi) } else { x };
            let want = tail.eval(x, ch);
            let row = &th_t.data()[ch * n_levels..(ch + 1) * n_levels];
            let got = qmin as i64 + row.iter().filter(|&&t| x >= t).count() as i64;
            if want != got {
                report.skipped_nonmonotone += 1;
                return Ok(false); // behaviour not representable; leave as-is
            }
        }
    }

    // Rewrite: MultiThreshold(start, thresholds) replaces chain + quant.
    let y = g.nodes[tail.quant_node].outputs[0].clone();
    let th_name = g.fresh(&format!("{}_thresholds", y));
    g.add_initializer(&th_name, th_t);
    let mt = Node {
        name: g.fresh("MultiThreshold"),
        op: Op::MultiThreshold {
            out_scale: 1.0,
            out_bias: qmin,
        },
        inputs: vec![tail.start.clone(), th_name],
        outputs: vec![y],
    };
    // remove quant + chain nodes (by name, indices shift)
    let mut doomed: Vec<String> = vec![g.nodes[tail.quant_node].name.clone()];
    doomed.extend(tail.chain_nodes.iter().map(|&i| g.nodes[i].name.clone()));
    g.nodes.retain(|n| !doomed.contains(&n.name));
    g.nodes.push(mt);
    g.prune_unused_initializers();
    report.converted += 1;
    report.threshold_count += c * n_levels;
    Ok(true)
}

fn chan_bound_lo(t: &Tensor, ch: usize, c: usize) -> f64 {
    if t.numel() == 1 {
        t.data()[0]
    } else if t.numel() == c {
        t.data()[ch]
    } else {
        t.min()
    }
}

fn chan_bound_hi(t: &Tensor, ch: usize, c: usize) -> f64 {
    if t.numel() == 1 {
        t.data()[0]
    } else if t.numel() == c {
        t.data()[ch]
    } else {
        t.max()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::executor::Executor;
    use crate::passes::{fold, streamline};
    use crate::tensor::Tensor;

    fn q_op(signed: bool) -> Op {
        Op::Quant {
            signed,
            narrow: false,
            rounding: RoundMode::RoundEven,
        }
    }

    /// Integer input -> Mul -> Add -> Relu -> Quant(1) tail.
    fn tail_graph(per_channel: bool) -> (Graph, BTreeMap<String, SiRange>) {
        let mut g = Graph::new("tail");
        g.add_input("x", &[1, 3]);
        let (m, a) = if per_channel {
            (
                Tensor::new(&[1, 3], vec![0.05, 0.1, 0.2]).unwrap(),
                Tensor::new(&[1, 3], vec![-1.0, 0.5, 0.0]).unwrap(),
            )
        } else {
            (Tensor::scalar(0.1), Tensor::scalar(-0.7))
        };
        g.add_initializer("m", m);
        g.add_initializer("a", a);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("bits", Tensor::scalar(4.0));
        g.add_node(Node::new("mul", Op::Mul, &["x", "m"], &["h1"]));
        g.add_node(Node::new("add", Op::Add, &["h1", "a"], &["h2"]));
        g.add_node(Node::new("relu", Op::Relu, &["h2"], &["h3"]));
        g.add_node(Node::new("q", q_op(false), &["h3", "one", "z", "bits"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let mut inputs = BTreeMap::new();
        // pure-integer input range [-100, 100]
        inputs.insert(
            "x".to_string(),
            SiRange::from_int(
                Tensor::scalar(-100.0),
                Tensor::scalar(100.0),
                Tensor::scalar(1.0),
                Tensor::scalar(0.0),
                Default::default(),
                Default::default(),
            )
            .unwrap(),
        );
        (g, inputs)
    }

    fn exhaustive_equivalence(g0: &Graph, g1: &Graph) {
        let mut e0 = Executor::new(g0).unwrap();
        let mut e1 = Executor::new(g1).unwrap();
        for x in -100..=100 {
            let t = Tensor::new(&[1, 3], vec![x as f64, x as f64, x as f64]).unwrap();
            let y0 = e0.run_single(&t).unwrap();
            let y1 = e1.run_single(&t).unwrap();
            assert_eq!(y0[0].data(), y1[0].data(), "mismatch at x={x}");
        }
    }

    #[test]
    fn converts_per_tensor_tail() {
        let (g0, inputs) = tail_graph(false);
        let mut g1 = g0.clone();
        let rep = convert_to_thresholds(&mut g1, &inputs).unwrap();
        assert_eq!(rep.converted, 1);
        assert_eq!(g1.count_op("MultiThreshold"), 1);
        assert_eq!(g1.count_op("Mul"), 0);
        assert_eq!(g1.count_op("Quant"), 0);
        // per-tensor: 1 channel x 15 thresholds
        let mt = g1.nodes.iter().find(|n| n.op.name() == "MultiThreshold").unwrap();
        assert_eq!(g1.initializers[&mt.inputs[1]].shape(), &[1, 15]);
        exhaustive_equivalence(&g0, &g1);
    }

    #[test]
    fn converts_per_channel_tail() {
        let (g0, inputs) = tail_graph(true);
        let mut g1 = g0.clone();
        let rep = convert_to_thresholds(&mut g1, &inputs).unwrap();
        assert_eq!(rep.converted, 1);
        let mt = g1.nodes.iter().find(|n| n.op.name() == "MultiThreshold").unwrap();
        assert_eq!(g1.initializers[&mt.inputs[1]].shape(), &[3, 15]);
        exhaustive_equivalence(&g0, &g1);
    }

    #[test]
    fn thresholds_are_integers_within_clip_bounds() {
        let (_, inputs) = tail_graph(true);
        let (mut g, _) = tail_graph(true);
        convert_to_thresholds(&mut g, &inputs).unwrap();
        let mt = g.nodes.iter().find(|n| n.op.name() == "MultiThreshold").unwrap();
        let th = &g.initializers[&mt.inputs[1]];
        assert!(th.is_integral());
        // Eq. 3: thresholds clipped to [lo, hi+1]
        assert!(th.data().iter().all(|&t| (-100.0..=101.0).contains(&t)));
    }

    #[test]
    fn nonmonotone_tail_is_skipped() {
        let (mut g, inputs) = tail_graph(false);
        // negate the scale -> decreasing tail
        g.initializers.insert("m".to_string(), Tensor::scalar(-0.1));
        let rep = convert_to_thresholds(&mut g, &inputs).unwrap();
        assert_eq!(rep.converted, 0);
        assert!(rep.skipped_nonmonotone >= 1);
        assert_eq!(g.count_op("Quant"), 1); // untouched
    }

    #[test]
    fn float_input_tail_gets_continuous_thresholds() {
        let (g0, mut inputs) = tail_graph(false);
        // plain float input range -> continuous-bisection thresholds
        inputs.insert("x".to_string(), SiRange::scalar(-100.0, 100.0));
        let mut g1 = g0.clone();
        let rep = convert_to_thresholds(&mut g1, &inputs).unwrap();
        assert_eq!(rep.converted, 1);
        // equivalence on non-integer inputs away from threshold boundaries
        let mut e0 = Executor::new(&g0).unwrap();
        let mut e1 = Executor::new(&g1).unwrap();
        for i in 0..100 {
            let v = -99.5 + 2.0 * i as f64 + 0.137;
            let t = Tensor::new(&[1, 3], vec![v, v, v]).unwrap();
            let y0 = e0.run_single(&t).unwrap();
            let y1 = e1.run_single(&t).unwrap();
            assert_eq!(y0[0].data(), y1[0].data(), "mismatch at {v}");
        }
    }

    #[test]
    fn full_streamline_then_threshold_pipeline() {
        // End-to-end: the Fig 7 layer through extraction + streamlining +
        // threshold conversion, equivalence checked on float inputs.
        use crate::graph::Node;
        let mut g = Graph::new("layer");
        g.add_input("x", &[1, 2]);
        for (n, t) in [
            ("qs_x", Tensor::scalar(0.7)),
            ("z", Tensor::scalar(0.0)),
            ("b4", Tensor::scalar(4.0)),
            ("qs_w", Tensor::new(&[1, 3], vec![0.2, 0.3, 0.1]).unwrap()),
            (
                "W",
                Tensor::new(&[2, 3], vec![-1.4, 0.9, -1.3, 1.2, 0.0, -0.7]).unwrap(),
            ),
            ("B", Tensor::new(&[1, 3], vec![-3.3, 1.1, 0.0]).unwrap()),
            ("M", Tensor::new(&[1, 3], vec![0.6, 0.2, 0.4]).unwrap()),
            ("N", Tensor::new(&[1, 3], vec![-0.2, -0.4, 1.1]).unwrap()),
            ("qs_y", Tensor::scalar(0.1)),
        ] {
            g.add_initializer(n, t);
        }
        g.add_node(Node::new("qx", q_op(true), &["x", "qs_x", "z", "b4"], &["xq"]));
        g.add_node(Node::new("qw", q_op(true), &["W", "qs_w", "z", "b4"], &["wq"]));
        g.add_node(Node::new("mm", Op::MatMul, &["xq", "wq"], &["h"]));
        g.add_node(Node::new("addb", Op::Add, &["h", "B"], &["hb"]));
        g.add_node(Node::new("mulm", Op::Mul, &["hb", "M"], &["hm"]));
        g.add_node(Node::new("addn", Op::Add, &["hm", "N"], &["hn"]));
        g.add_node(Node::new("relu", Op::Relu, &["hn"], &["hr"]));
        g.add_node(Node::new("qy", q_op(false), &["hr", "qs_y", "z", "b4"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();

        let g0 = g.clone();
        streamline::extract_quant_scales(&mut g).unwrap();
        fold::duplicate_shared_initializers(&mut g).unwrap();
        streamline::streamline(&mut g).unwrap();
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), SiRange::scalar(-6.0, 6.0));
        let rep = convert_to_thresholds(&mut g, &inputs).unwrap();
        assert_eq!(rep.converted, 2, "input quant + layer tail"); // qx & qy
        assert_eq!(g.count_op("Quant"), 0);
        g.check().unwrap();

        // equivalence on a float grid
        let mut e0 = Executor::new(&g0).unwrap();
        let mut e1 = Executor::new(&g).unwrap();
        for i in 0..60 {
            let a = -6.0 + 0.2 * i as f64;
            let t = Tensor::new(&[1, 2], vec![a, -a * 0.5]).unwrap();
            let y0 = e0.run_single(&t).unwrap();
            let y1 = e1.run_single(&t).unwrap();
            for (u, v) in y0[0].data().iter().zip(y1[0].data()) {
                assert!((u - v).abs() < 1e-9, "{u} vs {v} at {a}");
            }
        }
    }
}
