//! Compiler passes built on SIRA (§4): operator lowering, constant
//! folding, streamlining (scale/bias aggregation), threshold conversion,
//! accumulator minimization and stuck-channel detection.

pub mod accmin;
pub mod fixedpoint;
pub mod fold;
pub mod lower;
pub mod streamline;
pub mod stuck;
pub mod thresholds;
