//! Stuck-channel detection (§7.1): channels whose analyzed range is a
//! point interval produce a constant regardless of input — a
//! generalisation of the dying-ReLU problem. Such channels offer no
//! predictive power and can be removed. The paper leaves removal to
//! future work; here the plan engine ([`crate::engine`]) consumes
//! [`stuck_channels`] / [`stuck_elements`] to elide proven-constant
//! channels from fused integer MAC kernels, folding their contribution
//! into the accumulator bias.

use anyhow::Result;

use crate::graph::Graph;
use crate::sira::Analysis;

/// A stuck channel: (channel index, constant output value).
#[derive(Clone, Debug, PartialEq)]
pub struct StuckChannel {
    pub channel: usize,
    pub value: f64,
}

/// Find stuck channels of a tensor from its analyzed per-channel range.
pub fn stuck_channels(analysis: &Analysis, tensor: &str) -> Result<Vec<StuckChannel>> {
    let r = analysis.get(tensor)?;
    let lo = r.lo.data();
    let hi = r.hi.data();
    let mut out = Vec::new();
    for (ch, (&l, &h)) in lo.iter().zip(hi).enumerate() {
        if l == h {
            out.push(StuckChannel {
                channel: ch,
                value: l,
            });
        }
    }
    Ok(out)
}

/// Per-element stuck view of a tensor over its full per-sample `shape`:
/// `out[i] = Some(v)` when flat element `i` is analytically proven
/// constant `v`. When the analyzed range tensor already has one entry
/// per element this is [`stuck_channels`] verbatim; coarser (per-channel
/// or per-tensor) ranges are broadcast, so a point interval marks every
/// element it governs.
pub fn stuck_elements(
    analysis: &Analysis,
    tensor: &str,
    shape: &[usize],
) -> Result<Vec<Option<f64>>> {
    let r = analysis.get(tensor)?;
    let numel: usize = shape.iter().product();
    let mut out = vec![None; numel];
    if r.lo.numel() == numel {
        for sc in stuck_channels(analysis, tensor)? {
            out[sc.channel] = Some(sc.value);
        }
        return Ok(out);
    }
    let lo = r.lo.broadcast_to(shape)?;
    let hi = r.hi.broadcast_to(shape)?;
    for (e, (&l, &h)) in out.iter_mut().zip(lo.data().iter().zip(hi.data())) {
        if l == h {
            *e = Some(l);
        }
    }
    Ok(out)
}

/// Summary of stuck channels over all activation tensors of the graph
/// (tensors produced by Quant or MultiThreshold nodes).
pub fn stuck_report(g: &Graph, analysis: &Analysis) -> Vec<(String, Vec<StuckChannel>)> {
    let mut rows = Vec::new();
    for node in &g.nodes {
        if !matches!(node.op.name(), "Quant" | "MultiThreshold") {
            continue;
        }
        // activations only: weight quantizers are constants by definition
        if g.is_initializer(&node.inputs[0]) {
            continue;
        }
        if let Ok(sc) = stuck_channels(analysis, node.output()) {
            if !sc.is_empty() {
                rows.push((node.output().to_string(), sc));
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sira::SiRange;
    use crate::tensor::Tensor;

    #[test]
    fn detects_point_channels() {
        let mut a = Analysis::default();
        a.ranges.insert(
            "t".to_string(),
            SiRange::float(
                Tensor::new(&[1, 3, 1, 1], vec![0.0, -1.0, 0.48]).unwrap(),
                Tensor::new(&[1, 3, 1, 1], vec![0.0, 2.0, 0.48]).unwrap(),
            )
            .unwrap(),
        );
        let sc = stuck_channels(&a, "t").unwrap();
        assert_eq!(
            sc,
            vec![
                StuckChannel { channel: 0, value: 0.0 },
                StuckChannel { channel: 2, value: 0.48 }
            ]
        );
    }

    #[test]
    fn missing_tensor_errors() {
        let a = Analysis::default();
        assert!(stuck_channels(&a, "nope").is_err());
    }

    #[test]
    fn stuck_elements_broadcasts_per_channel_ranges() {
        let mut a = Analysis::default();
        a.ranges.insert(
            "t".to_string(),
            SiRange::float(
                Tensor::new(&[1, 2, 1, 1], vec![3.0, -1.0]).unwrap(),
                Tensor::new(&[1, 2, 1, 1], vec![3.0, 2.0]).unwrap(),
            )
            .unwrap(),
        );
        let e = stuck_elements(&a, "t", &[1, 2, 2, 2]).unwrap();
        assert_eq!(&e[..4], &[Some(3.0); 4]);
        assert_eq!(&e[4..], &[None; 4]);
        // exact-shape ranges round-trip through stuck_channels
        let e = stuck_elements(&a, "t", &[1, 2, 1, 1]).unwrap();
        assert_eq!(e, vec![Some(3.0), None]);
    }
}
