//! Constant folding and shared-parameter duplication.
//!
//! Constant folding evaluates nodes whose inputs are all initializers and
//! replaces them with constants. Quant nodes are excluded by default:
//! folding a weight quantizer would replace the scaled-integer structure
//! with an opaque float constant and block SIRA's integer propagation —
//! weight quantizers are instead handled by
//! [`crate::passes::streamline::extract_quant_scales`].
//!
//! Shared-parameter duplication (§4.1.2 step 1) gives every consumer of a
//! scale/bias initializer its own private copy so the aggregation pass can
//! erase contributions independently.

use anyhow::Result;

use crate::executor::execute_op;
use crate::graph::{Graph, Op};
use crate::tensor::Tensor;

/// Fold constant subexpressions. `fold_quant` controls whether Quant
/// nodes with constant inputs are folded (default: keep them).
pub fn fold_constants(g: &mut Graph, fold_quant: bool) -> Result<usize> {
    let mut total = 0;
    loop {
        let mut changed = false;
        let order = g.topo_order()?;
        for idx in order {
            let node = g.nodes[idx].clone();
            if matches!(node.op, Op::Quant { .. }) && !fold_quant {
                continue;
            }
            if node.inputs.is_empty() || !node.inputs.iter().all(|i| g.is_initializer(i)) {
                continue;
            }
            let ins: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|i| g.initializers[i].clone())
                .collect();
            let outs = execute_op(&node.op, &ins)?;
            for (oname, t) in node.outputs.iter().zip(outs) {
                g.add_initializer(oname, t);
            }
            g.nodes.remove(idx);
            g.prune_unused_initializers();
            total += 1;
            changed = true;
            break; // indices shifted; restart scan
        }
        if !changed {
            return Ok(total);
        }
    }
}

/// Give each consumer of a multiply-referenced initializer its own copy.
/// Returns the number of duplicates created.
pub fn duplicate_shared_initializers(g: &mut Graph) -> Result<usize> {
    let mut created = 0;
    let names: Vec<String> = g.initializers.keys().cloned().collect();
    for name in names {
        let consumers = g.consumers(&name);
        if consumers.len() <= 1 {
            continue;
        }
        // keep the first consumer on the original; clone for the rest
        for &ci in &consumers[1..] {
            let copy_name = g.fresh(&format!("{name}_dup"));
            let t = g.initializers[&name].clone();
            g.add_initializer(&copy_name, t);
            for inp in &mut g.nodes[ci].inputs {
                if *inp == name {
                    *inp = copy_name.clone();
                }
            }
            created += 1;
        }
    }
    Ok(created)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Node, RoundMode};

    #[test]
    fn folds_constant_chain() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 2]);
        g.add_initializer("a", Tensor::from_vec(vec![1.0, 2.0]));
        g.add_initializer("b", Tensor::from_vec(vec![3.0, 4.0]));
        g.add_node(Node::new("cadd", Op::Add, &["a", "b"], &["c"]));
        g.add_node(Node::new("use", Op::Mul, &["x", "c"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let n = fold_constants(&mut g, false).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.initializers["c"].data(), &[4.0, 6.0]);
        g.check().unwrap();
    }

    #[test]
    fn quant_not_folded_by_default() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 2]);
        g.add_initializer("w", Tensor::from_vec(vec![0.5, 1.5]));
        g.add_initializer("s", Tensor::scalar(0.5));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("b", Tensor::scalar(4.0));
        g.add_node(Node::new(
            "q",
            Op::Quant {
                signed: true,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["w", "s", "z", "b"],
            &["wq"],
        ));
        g.add_node(Node::new("m", Op::Mul, &["x", "wq"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        assert_eq!(fold_constants(&mut g, false).unwrap(), 0);
        assert_eq!(fold_constants(&mut g, true).unwrap(), 1);
        assert_eq!(g.initializers["wq"].data(), &[0.5, 1.5]);
    }

    #[test]
    fn duplicates_shared_scale() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 2]);
        g.add_initializer("s", Tensor::scalar(2.0));
        g.add_node(Node::new("m1", Op::Mul, &["x", "s"], &["a"]));
        g.add_node(Node::new("m2", Op::Mul, &["a", "s"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let n = duplicate_shared_initializers(&mut g).unwrap();
        assert_eq!(n, 1);
        let (i1, i2) = (
            g.nodes[0].inputs[1].clone(),
            g.nodes[1].inputs[1].clone(),
        );
        assert_ne!(i1, i2);
        assert_eq!(g.initializers[&i1].data(), g.initializers[&i2].data());
        g.check().unwrap();
    }
}
