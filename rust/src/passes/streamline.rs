//! SIRA-based streamlining (§4.1): make quantizer scales explicit, move
//! scales and biases downstream through linear regions, and aggregate
//! them into a single Mul + Add in front of each activation (the *target
//! tensor*), revealing pure-integer MatMul/Conv kernels.
//!
//! The rewrite rules are local algebraic identities, each of which is
//! exact over the reals; as the paper notes (§4.1.2), aggregation of
//! floating-point scales is not bit-identical to the original composition
//! — the end-to-end tests therefore compare *quantized* outputs.

use anyhow::{bail, Result};

use crate::executor::ops::quant_int;
use crate::graph::{DataType, Graph, Node, Op};
use crate::tensor::Tensor;

/// Step 1 of streamlining: make every quantizer's scale explicit.
///
/// * Weight quantizers (constant input) are folded to integer weight
///   initializers followed by an explicit `Mul(W_q, s)` dequantization.
/// * Activation quantizers become `Div(x, s) → Quant(scale=1) → Mul(q, s)`
///   so the integer tensor `q` is visible between them.
///
/// Returns the number of quantizers rewritten. Quantizers with non-zero
/// zero-points are left untouched (asymmetric activation quantization is
/// outside the paper's streamlining scope, see §9).
pub fn extract_quant_scales(g: &mut Graph) -> Result<usize> {
    let mut count = 0;
    let mut idx = 0;
    while idx < g.nodes.len() {
        let node = g.nodes[idx].clone();
        let Op::Quant {
            signed,
            narrow,
            rounding,
        } = node.op
        else {
            idx += 1;
            continue;
        };
        let s_name = node.inputs[1].clone();
        let z_name = node.inputs[2].clone();
        let b_name = node.inputs[3].clone();
        let (Some(s), Some(z), Some(b)) = (
            g.initializer(&s_name).cloned(),
            g.initializer(&z_name).cloned(),
            g.initializer(&b_name).cloned(),
        ) else {
            idx += 1;
            continue;
        };
        if !z.all_eq(0.0) {
            idx += 1;
            continue; // asymmetric quantization: not streamlined
        }
        // Skip already-extracted unit-scale quantizers.
        if s.all_eq(1.0) {
            idx += 1;
            continue;
        }
        let bits = b.first() as u32;
        let out_dt = if signed {
            DataType::Int(bits)
        } else {
            DataType::UInt(bits)
        };
        let x_name = node.inputs[0].clone();
        let y_name = node.outputs[0].clone();

        if let Some(w) = g.initializer(&x_name).cloned() {
            // ---- weight quantizer: fold to integer weights + Mul(s) ----
            let wq = quant_int(
                &[w, s.clone(), z.clone(), b.clone()],
                signed,
                narrow,
                rounding,
            )?;
            let wq_name = g.fresh(&format!("{x_name}_int"));
            g.add_initializer(&wq_name, wq);
            g.dtypes.insert(wq_name.clone(), out_dt);
            let mul = Node {
                name: g.fresh(&format!("{}_deq", node.name)),
                op: Op::Mul,
                inputs: vec![wq_name, s_name.clone()],
                outputs: vec![y_name],
            };
            g.nodes.remove(idx);
            g.nodes.insert(idx, mul);
            g.prune_unused_initializers();
        } else {
            // ---- activation quantizer: Div → Quant(1) → Mul ----
            let div_out = g.fresh(&format!("{}_scaled", node.name));
            let int_out = g.fresh(&format!("{}_int", node.name));
            let one_name = g.fresh(&format!("{}_one", node.name));
            g.add_initializer(&one_name, Tensor::scalar(1.0));
            let div = Node {
                name: g.fresh(&format!("{}_Div", node.name)),
                op: Op::Div,
                inputs: vec![x_name, s_name.clone()],
                outputs: vec![div_out.clone()],
            };
            let quant = Node {
                name: node.name.clone(),
                op: Op::Quant {
                    signed,
                    narrow,
                    rounding,
                },
                inputs: vec![div_out, one_name, z_name, b_name],
                outputs: vec![int_out.clone()],
            };
            g.dtypes.insert(int_out.clone(), out_dt);
            let mul = Node {
                name: g.fresh(&format!("{}_deq", node.name)),
                op: Op::Mul,
                inputs: vec![int_out, s_name.clone()],
                outputs: vec![node.outputs[0].clone()],
            };
            g.nodes.remove(idx);
            g.nodes.insert(idx, div);
            g.nodes.insert(idx + 1, quant);
            g.nodes.insert(idx + 2, mul);
        }
        count += 1;
        idx += 1;
    }
    crate::graph::shapes::infer_shapes(g)?;
    Ok(count)
}

/// Which input of a 2-ary elementwise node is a constant? Returns
/// (const_idx, dynamic_idx).
fn const_side(g: &Graph, node: &Node) -> Option<(usize, usize)> {
    if node.inputs.len() != 2 {
        return None;
    }
    match (
        g.is_initializer(&node.inputs[0]),
        g.is_initializer(&node.inputs[1]),
    ) {
        (false, true) => Some((1, 0)),
        (true, false) => Some((0, 1)),
        _ => None,
    }
}

/// True if `tensor` is consumed exactly once and is not a graph output.
///
/// Counts input-position *occurrences*, not consuming nodes: a node that
/// reads the tensor twice (e.g. `Add(t, t)` after a shared scale Mul) is
/// two uses. `Graph::consumers` would report one consumer for that shape,
/// which let rules like residual factoring rewrite a branch while the
/// other occurrence still referenced it.
fn single_use(g: &Graph, tensor: &str) -> bool {
    let uses: usize = g
        .nodes
        .iter()
        .map(|n| n.inputs.iter().filter(|i| i.as_str() == tensor).count())
        .sum();
    uses == 1 && !g.outputs.iter().any(|o| o == tensor)
}

/// The streamlining rule engine: applies local rewrites until fixpoint.
/// Returns the number of rewrites applied.
pub fn streamline(g: &mut Graph) -> Result<usize> {
    let mut total = 0;
    let budget = 200 + 50 * g.nodes.len();
    loop {
        let applied = apply_one_rule(g)?;
        if !applied {
            break;
        }
        total += 1;
        if total > budget {
            bail!("streamlining did not reach a fixpoint (applied {total} rewrites)");
        }
    }
    remove_identities(g)?;
    g.prune_unused_initializers();
    crate::graph::shapes::infer_shapes(g)?;
    Ok(total)
}

/// Try each rule in priority order; apply the first match.
fn apply_one_rule(g: &mut Graph) -> Result<bool> {
    let order = g.topo_order()?;
    for &i in &order {
        if try_fuse_elementwise(g, i)? {
            return Ok(true);
        }
    }
    for &i in &order {
        if try_swap_mul_over_add(g, i)? {
            return Ok(true);
        }
    }
    for &i in &order {
        if try_move_mul_past_mac(g, i)? {
            return Ok(true);
        }
    }
    for &i in &order {
        if try_move_past_movement_and_pool(g, i)? {
            return Ok(true);
        }
    }
    for &i in &order {
        if try_factor_residual(g, i)? {
            return Ok(true);
        }
    }
    Ok(false)
}

/// R1/R2: `Mul(Mul(x,a),b) → Mul(x,a⊙b)`; `Add(Add(x,a),b) → Add(x,a+b)`.
fn try_fuse_elementwise(g: &mut Graph, i: usize) -> Result<bool> {
    let node = g.nodes[i].clone();
    let want_mul = matches!(node.op, Op::Mul);
    if !want_mul && !matches!(node.op, Op::Add) {
        return Ok(false);
    }
    let Some((ci, di)) = const_side(g, &node) else {
        return Ok(false);
    };
    let dyn_in = node.inputs[di].clone();
    let Some(pi) = g.producer(&dyn_in) else {
        return Ok(false);
    };
    let prev = g.nodes[pi].clone();
    if prev.op != node.op || !single_use(g, &dyn_in) {
        return Ok(false);
    }
    let Some((pci, pdi)) = const_side(g, &prev) else {
        return Ok(false);
    };
    let a = g.initializers[&prev.inputs[pci]].clone();
    let b = g.initializers[&node.inputs[ci]].clone();
    let fused = if want_mul { a.mul(&b)? } else { a.add(&b)? };
    let fused_name = g.fresh("fused_c");
    g.add_initializer(&fused_name, fused);
    // node becomes op(x_prev_dyn, fused)
    let x = prev.inputs[pdi].clone();
    g.nodes[i].inputs = vec![x, fused_name];
    g.nodes.remove(pi);
    g.prune_unused_initializers();
    Ok(true)
}

/// R4: `Mul(Add(x,b),c) → Add(Mul(x,c), b⊙c)` — canonical Mul-then-Add.
fn try_swap_mul_over_add(g: &mut Graph, i: usize) -> Result<bool> {
    let node = g.nodes[i].clone();
    if !matches!(node.op, Op::Mul) {
        return Ok(false);
    }
    let Some((ci, di)) = const_side(g, &node) else {
        return Ok(false);
    };
    let dyn_in = node.inputs[di].clone();
    let Some(pi) = g.producer(&dyn_in) else {
        return Ok(false);
    };
    let prev = g.nodes[pi].clone();
    if !matches!(prev.op, Op::Add) || !single_use(g, &dyn_in) {
        return Ok(false);
    }
    let Some((pci, pdi)) = const_side(g, &prev) else {
        return Ok(false);
    };
    let b = g.initializers[&prev.inputs[pci]].clone();
    let c = g.initializers[&node.inputs[ci]].clone();
    let bc = b.mul(&c)?;
    let bc_name = g.fresh("swapped_b");
    g.add_initializer(&bc_name, bc);
    let x = prev.inputs[pdi].clone();
    // prev becomes Mul(x, c); node becomes Add(prev_out, b*c)
    g.nodes[pi].op = Op::Mul;
    g.nodes[pi].inputs = vec![x, node.inputs[ci].clone()];
    g.nodes[i].op = Op::Add;
    g.nodes[i].inputs = vec![dyn_in, bc_name];
    g.prune_unused_initializers();
    Ok(true)
}

/// R5/R6: move a constant Mul past MatMul/Conv.
/// * activation side: `MatMul(Mul(x,c), W) → Mul(MatMul(x,W), c)` for
///   scalar c (per-channel c allowed for depthwise Conv);
/// * weight side: `MatMul(x, Mul(W,s)) → Mul(MatMul(x,W), s')` for
///   per-output-channel s.
fn try_move_mul_past_mac(g: &mut Graph, i: usize) -> Result<bool> {
    let node = g.nodes[i].clone();
    let (is_matmul, conv_info) = match &node.op {
        Op::MatMul => (true, None),
        Op::Conv { group, .. } => (false, Some(*group)),
        _ => return Ok(false),
    };
    // -- weight-side Mul --
    if let Some(wi) = g.producer(&node.inputs[1]) {
        let wnode = g.nodes[wi].clone();
        if matches!(wnode.op, Op::Mul) && single_use(g, &node.inputs[1]) {
            // the weight dequant Mul has BOTH inputs constant (integer
            // weights x scale); pick the larger-numel side as the weights
            let both_const = wnode.inputs.len() == 2
                && g.is_initializer(&wnode.inputs[0])
                && g.is_initializer(&wnode.inputs[1]);
            let side = if both_const {
                let n0 = g.initializers[&wnode.inputs[0]].numel();
                let n1 = g.initializers[&wnode.inputs[1]].numel();
                if n0 >= n1 { Some((1, 0)) } else { Some((0, 1)) }
            } else {
                const_side(g, &wnode)
            };
            if let Some((ci, di)) = side {
                let s = g.initializers[&wnode.inputs[ci]].clone();
                let w_shape = g.shapes[&wnode.inputs[di]].clone();
                let (ok, out_scale_shape) = if is_matmul {
                    let m = w_shape[1];
                    (
                        s.numel() == 1 || crate::tensor::broadcastable_to(s.shape(), &[1, m]),
                        vec![1, m],
                    )
                } else {
                    let o = w_shape[0];
                    // conv weight scale (O,1,1,1) or scalar
                    (
                        s.numel() == 1 || (s.numel() == o && s.shape()[0] == o),
                        vec![1, o, 1, 1],
                    )
                };
                if ok {
                    let s_out = if s.numel() == 1 {
                        s.clone()
                    } else {
                        s.reshape(&out_scale_shape)?
                    };
                    let s_out_name = g.fresh("wscale_moved");
                    g.add_initializer(&s_out_name, s_out);
                    // rewire: mac reads raw weights; Mul applied after
                    g.nodes[i].inputs[1] = wnode.inputs[di].clone();
                    let y = node.outputs[0].clone();
                    let mid = g.fresh(&format!("{y}_raw"));
                    g.nodes[i].outputs[0] = mid.clone();
                    let new_mul = Node {
                        name: g.fresh("MulW"),
                        op: Op::Mul,
                        inputs: vec![mid, s_out_name],
                        outputs: vec![y],
                    };
                    g.nodes.push(new_mul);
                    // drop the old weight-side Mul
                    let wi = g.producer(&wnode.outputs[0]).unwrap();
                    g.nodes.remove(wi);
                    g.prune_unused_initializers();
                    crate::graph::shapes::infer_shapes(g)?;
                    return Ok(true);
                }
            }
        }
    }
    // -- activation-side Mul --
    if let Some(xi) = g.producer(&node.inputs[0]) {
        let xnode = g.nodes[xi].clone();
        if matches!(xnode.op, Op::Mul) && single_use(g, &node.inputs[0]) {
            if let Some((ci, di)) = const_side(g, &xnode) {
                let c = g.initializers[&xnode.inputs[ci]].clone();
                let depthwise = matches!(conv_info, Some(gr) if gr > 1);
                let movable = c.numel() == 1 || (depthwise && c.rank() == 4 && c.shape()[0] == 1);
                if movable {
                    let c_name = xnode.inputs[ci].clone();
                    g.nodes[i].inputs[0] = xnode.inputs[di].clone();
                    let y = node.outputs[0].clone();
                    let mid = g.fresh(&format!("{y}_raw"));
                    g.nodes[i].outputs[0] = mid.clone();
                    let new_mul = Node {
                        name: g.fresh("MulX"),
                        op: Op::Mul,
                        inputs: vec![mid, c_name],
                        outputs: vec![y],
                    };
                    g.nodes.push(new_mul);
                    let xi = g.producer(&xnode.outputs[0]).unwrap();
                    g.nodes.remove(xi);
                    crate::graph::shapes::infer_shapes(g)?;
                    return Ok(true);
                }
            }
        }
    }
    Ok(false)
}

/// R7-R10: move constant Mul/Add past pooling, ReLU and data movement.
/// MaxPool and ReLU require positive scale for Mul; Add commutes with
/// MaxPool and data movement but not with ReLU.
fn try_move_past_movement_and_pool(g: &mut Graph, i: usize) -> Result<bool> {
    let node = g.nodes[i].clone();
    let kind = match &node.op {
        Op::MaxPool { .. } => "max",
        Op::AveragePool { .. } | Op::GlobalAveragePool => "avg",
        Op::Relu => "relu",
        Op::Reshape { .. } | Op::Flatten { .. } | Op::Transpose { .. } | Op::Identity => "move",
        _ => return Ok(false),
    };
    let Some(pi) = g.producer(&node.inputs[0]) else {
        return Ok(false);
    };
    let prev = g.nodes[pi].clone();
    let prev_is_mul = matches!(prev.op, Op::Mul);
    let prev_is_add = matches!(prev.op, Op::Add);
    if (!prev_is_mul && !prev_is_add) || !single_use(g, &node.inputs[0]) {
        return Ok(false);
    }
    let Some((ci, di)) = const_side(g, &prev) else {
        return Ok(false);
    };
    let c = g.initializers[&prev.inputs[ci]].clone();
    // Pooling mixes values *within* a channel's spatial window, so a
    // constant may only cross it if it is uniform over that window:
    // scalar, or a rank>=3 tensor whose trailing (spatial) dims are 1
    // ([1,C,1,1] bias/scale). A rank-1/2 non-scalar right-aligns onto
    // H/W under broadcasting — spatially varying — and must stay put:
    // max(x+c) != max(x)+c when c differs across the window. The zoo
    // pipeline never emits such constants, but imported ONNX graphs can.
    let spatial_free =
        c.numel() == 1 || (c.rank() >= 3 && c.shape()[c.rank() - 1] == 1 && c.shape()[c.rank() - 2] == 1);
    let allowed = match (kind, prev_is_mul) {
        ("avg", _) => spatial_free,                              // linear per channel
        ("move", _) => c.numel() == 1,                           // scalar only
        ("max", true) => spatial_free && c.data().iter().all(|&v| v > 0.0), // monotone
        ("max", false) => spatial_free,                          // max(x+c) = max(x)+c
        ("relu", true) => c.data().iter().all(|&v| v > 0.0),     // relu(cx)=c relu(x)
        ("relu", false) => false,
        _ => false,
    };
    if !allowed {
        return Ok(false);
    }
    // rewire: node consumes prev's dynamic input; prev applied after node
    let c_name = prev.inputs[ci].clone();
    let op = prev.op.clone();
    g.nodes[i].inputs[0] = prev.inputs[di].clone();
    let y = node.outputs[0].clone();
    let mid = g.fresh(&format!("{y}_raw"));
    g.nodes[i].outputs[0] = mid.clone();
    let nm = g.fresh("moved_ew");
    g.nodes.push(Node {
        name: nm,
        op,
        inputs: vec![mid, c_name],
        outputs: vec![y],
    });
    let pi = g.producer(&prev.outputs[0]).unwrap();
    g.nodes.remove(pi);
    crate::graph::shapes::infer_shapes(g)?;
    Ok(true)
}

/// R11: residual factoring — `Add(Mul(a,c), Mul(b,c)) → Mul(Add(a,b), c)`
/// when both scales are equal (the integer-ratio generalisation of
/// §3.2.2 falls out of re-running this after an integer Mul insertion).
fn try_factor_residual(g: &mut Graph, i: usize) -> Result<bool> {
    let node = g.nodes[i].clone();
    if !matches!(node.op, Op::Add) || node.inputs.len() != 2 {
        return Ok(false);
    }
    if g.is_initializer(&node.inputs[0]) || g.is_initializer(&node.inputs[1]) {
        return Ok(false);
    }
    let (Some(p0), Some(p1)) = (g.producer(&node.inputs[0]), g.producer(&node.inputs[1])) else {
        return Ok(false);
    };
    let (n0, n1) = (g.nodes[p0].clone(), g.nodes[p1].clone());
    if !matches!(n0.op, Op::Mul) || !matches!(n1.op, Op::Mul) {
        return Ok(false);
    }
    if !single_use(g, &node.inputs[0]) || !single_use(g, &node.inputs[1]) {
        return Ok(false);
    }
    let (Some((c0, d0)), Some((c1, d1))) = (const_side(g, &n0), const_side(g, &n1)) else {
        return Ok(false);
    };
    let s0 = g.initializers[&n0.inputs[c0]].clone();
    let s1 = g.initializers[&n1.inputs[c1]].clone();
    if s0.shape() != s1.shape() || s0.data() != s1.data() {
        return Ok(false);
    }
    // Add reads both raw branches; shared Mul applied after.
    let a = n0.inputs[d0].clone();
    let b = n1.inputs[d1].clone();
    let c_name = n0.inputs[c0].clone();
    let y = node.outputs[0].clone();
    let mid = g.fresh(&format!("{y}_raw"));
    g.nodes[i].inputs = vec![a, b];
    g.nodes[i].outputs[0] = mid.clone();
    let nm = g.fresh("residual_scale");
    g.nodes.push(Node {
        name: nm,
        op: Op::Mul,
        inputs: vec![mid, c_name],
        outputs: vec![y],
    });
    // remove both old Muls (recompute indices after mutation)
    let r0 = g.producer(&n0.outputs[0]).unwrap();
    g.nodes.remove(r0);
    let r1 = g.producer(&n1.outputs[0]).unwrap();
    g.nodes.remove(r1);
    g.prune_unused_initializers();
    crate::graph::shapes::infer_shapes(g)?;
    Ok(true)
}

/// R12: remove `Mul(x,1)`, `Add(x,0)`, `Div(x,1)` and Identity nodes.
pub fn remove_identities(g: &mut Graph) -> Result<usize> {
    let mut removed = 0;
    loop {
        let mut found = None;
        for (i, node) in g.nodes.iter().enumerate() {
            let is_id = match &node.op {
                Op::Identity => true,
                Op::Mul | Op::Div => const_side(g, node)
                    .map(|(ci, _)| g.initializers[&node.inputs[ci]].all_eq(1.0))
                    .unwrap_or(false),
                Op::Add | Op::Sub => const_side(g, node)
                    .map(|(ci, _)| g.initializers[&node.inputs[ci]].all_eq(0.0))
                    .unwrap_or(false),
                _ => false,
            };
            if is_id {
                found = Some(i);
                break;
            }
        }
        match found {
            Some(i) => {
                g.remove_node_bypass(i)?;
                g.prune_unused_initializers();
                removed += 1;
            }
            None => return Ok(removed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executor::Executor;
    use crate::graph::RoundMode;
    use crate::tensor::Conv2dSpec;

    fn q_op() -> Op {
        Op::Quant {
            signed: true,
            narrow: false,
            rounding: RoundMode::RoundEven,
        }
    }

    /// x -> Quant -> MatMul(W quantized) -> Add(B) -> BN-lowered Mul/Add
    /// -> Relu -> Quant -> y  (the Fig 7 layer)
    fn layer_graph() -> Graph {
        let mut g = Graph::new("layer");
        g.add_input("x", &[1, 2]);
        g.add_initializer("qs_x", Tensor::scalar(0.7));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("b4", Tensor::scalar(4.0));
        g.add_node(Node::new("qx", q_op(), &["x", "qs_x", "z", "b4"], &["xq"]));
        g.add_initializer(
            "W",
            Tensor::new(&[2, 3], vec![-1.4, 0.9, -1.3, 1.2, 0.0, -0.7]).unwrap(),
        );
        g.add_initializer("qs_w", Tensor::new(&[1, 3], vec![0.2, 0.3, 0.1]).unwrap());
        g.add_node(Node::new("qw", q_op(), &["W", "qs_w", "z", "b4"], &["wq"]));
        g.add_node(Node::new("mm", Op::MatMul, &["xq", "wq"], &["h"]));
        g.add_initializer("B", Tensor::new(&[1, 3], vec![-3.3, 1.1, 0.0]).unwrap());
        g.add_node(Node::new("addb", Op::Add, &["h", "B"], &["hb"]));
        g.add_initializer("M", Tensor::new(&[1, 3], vec![0.6, 0.2, 0.4]).unwrap());
        g.add_node(Node::new("mulm", Op::Mul, &["hb", "M"], &["hm"]));
        g.add_initializer("N", Tensor::new(&[1, 3], vec![-0.2, -0.4, 1.1]).unwrap());
        g.add_node(Node::new("addn", Op::Add, &["hm", "N"], &["hn"]));
        g.add_node(Node::new("relu", Op::Relu, &["hn"], &["hr"]));
        g.add_initializer("qs_y", Tensor::scalar(0.1));
        g.add_node(Node::new(
            "qy",
            Op::Quant {
                signed: false,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["hr", "qs_y", "z", "b4"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        g
    }

    fn run(g: &Graph, x: &Tensor) -> Vec<f64> {
        Executor::new(g)
            .unwrap()
            .run_single(x)
            .unwrap()[0]
            .data()
            .to_vec()
    }

    #[test]
    fn extraction_preserves_semantics() {
        let g0 = layer_graph();
        let x = Tensor::new(&[1, 2], vec![1.37, -2.2]).unwrap();
        let y0 = run(&g0, &x);
        let mut g1 = g0.clone();
        let n = extract_quant_scales(&mut g1).unwrap();
        assert_eq!(n, 3);
        g1.check().unwrap();
        let y1 = run(&g1, &x);
        assert_eq!(y0, y1);
        // integer weights are annotated
        let wq_names: Vec<_> = g1
            .dtypes
            .iter()
            .filter(|(_, dt)| dt.is_integer())
            .collect();
        assert!(!wq_names.is_empty());
    }

    #[test]
    fn streamline_reveals_integer_matmul() {
        let mut g = layer_graph();
        extract_quant_scales(&mut g).unwrap();
        crate::passes::fold::duplicate_shared_initializers(&mut g).unwrap();
        let x = Tensor::new(&[1, 2], vec![1.37, -2.2]).unwrap();
        let y0 = run(&layer_graph(), &x);
        streamline(&mut g).unwrap();
        g.check().unwrap();
        let y1 = run(&g, &x);
        // quantized outputs must agree exactly (values are multiples of qs_y)
        assert_eq!(y0, y1);

        // the MatMul must now read integer-valued tensors on both sides
        let mm = g.nodes.iter().find(|n| n.op == Op::MatMul).unwrap();
        let w = &g.initializers[&mm.inputs[1]];
        assert!(w.is_integral(), "weights not integer after streamlining");
        // and the layer tail collapses to one Mul and one Add before Relu
        let muls = g.count_op("Mul");
        let adds = g.count_op("Add");
        assert!(muls <= 3, "got {muls} Muls: {:?}", g.nodes.iter().map(|n| n.op.name()).collect::<Vec<_>>());
        assert_eq!(adds, 1, "tail adds not aggregated");
    }

    #[test]
    fn mul_moves_past_maxpool_and_flatten() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 1, 2, 2]);
        g.add_initializer("c", Tensor::scalar(2.0));
        g.add_node(Node::new("m", Op::Mul, &["x", "c"], &["a"]));
        g.add_node(Node::new(
            "p",
            Op::MaxPool {
                spec: Conv2dSpec {
                    kernel: (2, 2),
                    stride: (2, 2),
                    pad: (0, 0),
                },
            },
            &["a"],
            &["b"],
        ));
        g.add_node(Node::new("f", Op::Flatten { axis: 1 }, &["b"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 5., 3., 2.]).unwrap();
        let y0 = run(&g, &x);
        streamline(&mut g).unwrap();
        let y1 = run(&g, &x);
        assert_eq!(y0, y1);
        // Mul must now be the last node before output
        let last = g.producer("y").or_else(|| g.producer(&g.outputs[0])).unwrap();
        let out_producer = g
            .nodes
            .iter()
            .position(|n| n.outputs[0] == g.outputs[0])
            .unwrap();
        assert_eq!(last, out_producer);
        assert!(matches!(g.nodes[out_producer].op, Op::Mul));
    }

    #[test]
    fn negative_scale_does_not_cross_maxpool() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 1, 2, 2]);
        g.add_initializer("c", Tensor::scalar(-1.0));
        g.add_node(Node::new("m", Op::Mul, &["x", "c"], &["a"]));
        g.add_node(Node::new(
            "p",
            Op::MaxPool {
                spec: Conv2dSpec {
                    kernel: (2, 2),
                    stride: (2, 2),
                    pad: (0, 0),
                },
            },
            &["a"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 5., 3., 2.]).unwrap();
        let y0 = run(&g, &x);
        streamline(&mut g).unwrap();
        let y1 = run(&g, &x);
        assert_eq!(y0, y1);
        // Mul stays before the pool
        assert!(matches!(g.nodes[g.producer("y").unwrap()].op, Op::MaxPool { .. }));
    }

    #[test]
    fn spatial_add_does_not_cross_maxpool() {
        // A [1,2] constant right-aligns onto the H/W dims of the NCHW
        // input: each pooling window sees two different offsets, so
        // max(x+c) != max(x)+c and the Add must stay upstream of the
        // pool. (Scalar constants still cross, per
        // mul_moves_past_maxpool_and_flatten.)
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 1, 2, 2]);
        g.add_initializer("c", Tensor::new(&[1, 2], vec![10.0, 0.0]).unwrap());
        g.add_node(Node::new("a", Op::Add, &["x", "c"], &["s"]));
        g.add_node(Node::new(
            "p",
            Op::MaxPool {
                spec: Conv2dSpec {
                    kernel: (2, 2),
                    stride: (2, 2),
                    pad: (0, 0),
                },
            },
            &["s"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 5., 3., 2.]).unwrap();
        let y0 = run(&g, &x);
        streamline(&mut g).unwrap();
        g.check().unwrap();
        let y1 = run(&g, &x);
        assert_eq!(y0, y1);
        assert!(matches!(g.nodes[g.producer("y").unwrap()].op, Op::MaxPool { .. }));
    }

    #[test]
    fn residual_factoring() {
        let mut g = Graph::new("res");
        g.add_input("x", &[1, 4]);
        g.add_initializer("s1", Tensor::scalar(0.5));
        g.add_initializer("s2", Tensor::scalar(0.5));
        g.add_node(Node::new("m1", Op::Mul, &["x", "s1"], &["a"]));
        g.add_node(Node::new("r", Op::Relu, &["x"], &["xr"]));
        g.add_node(Node::new("m2", Op::Mul, &["xr", "s2"], &["b"]));
        g.add_node(Node::new("add", Op::Add, &["a", "b"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let x = Tensor::new(&[1, 4], vec![1., -2., 3., -4.]).unwrap();
        let y0 = run(&g, &x);
        streamline(&mut g).unwrap();
        let y1 = run(&g, &x);
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(g.count_op("Mul"), 1, "branch scales not factored");
    }

    #[test]
    fn self_add_of_shared_mul_is_not_factored() {
        // `Add(t, t)` where `t` is the output of one Mul:
        // `Graph::consumers` reports a single consuming node for `t`,
        // but the Add reads it twice. Node-counting single_use let
        // residual factoring fire on this shape — it removed the shared
        // Mul once, then panicked looking up the "second" branch's
        // producer. The occurrence-counting gate must refuse the
        // rewrite, and streamlining must stay bit-exact.
        let mut g = Graph::new("selfadd");
        g.add_input("x", &[1, 4]);
        g.add_initializer("s", Tensor::scalar(0.5));
        g.add_node(Node::new("m", Op::Mul, &["x", "s"], &["t"]));
        g.add_node(Node::new("add", Op::Add, &["t", "t"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let x = Tensor::new(&[1, 4], vec![1., -2., 3., -4.]).unwrap();
        let y0 = run(&g, &x);
        streamline(&mut g).unwrap();
        g.check().unwrap();
        let y1 = run(&g, &x);
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_removal() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 2]);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("zero", Tensor::scalar(0.0));
        g.add_node(Node::new("m", Op::Mul, &["x", "one"], &["a"]));
        g.add_node(Node::new("a", Op::Add, &["a", "zero"], &["b"]));
        g.add_node(Node::new("i", Op::Identity, &["b"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        assert_eq!(remove_identities(&mut g).unwrap(), 3);
        assert_eq!(g.nodes.len(), 0);
        assert_eq!(g.outputs[0], "x");
    }
}
