//! Accumulator minimization (§4.2): choose the minimum accumulator
//! bitwidth for each MatMul/Conv layer.
//!
//! Three policies are modeled:
//! * **Bound32** — the fixed-architecture default (32-bit accumulators);
//! * **Datatype** — the datatype bound of Colbert et al.:
//!   `P = ceil(α + φ(α) + 1)`, `α = log2(K) + N + M - 1`,
//!   `φ(α) = log2(1 + 2^-α)` for a K-element dot product of N-bit
//!   unsigned inputs and M-bit signed weights;
//! * **Sira** — the lossless SIRA bound from the analyzed integer output
//!   interval `[lo, hi]`: `P = ceil(log2(max(|lo|, |hi|+1))) + 1`.

use anyhow::Result;

use crate::executor::ops::dot_length;
use crate::graph::{DataType, Graph, Op};
use crate::sira::Analysis;
use crate::util::bits_for_range;

/// Accumulator sizing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccPolicy {
    Bound32,
    Datatype,
    Sira,
}

/// Per-layer accumulator report row (drives Fig 22).
#[derive(Clone, Debug)]
pub struct AccRow {
    pub node: String,
    /// dot-product length
    pub k: u64,
    /// input/weight bits feeding the datatype bound
    pub n_bits: u32,
    pub m_bits: u32,
    pub bits_32: u32,
    pub bits_datatype: u32,
    pub bits_sira: u32,
}

/// Report for a full accumulator-minimization run.
#[derive(Clone, Debug, Default)]
pub struct AccReport {
    pub rows: Vec<AccRow>,
}

impl AccReport {
    pub fn mean_sira(&self) -> f64 {
        crate::util::stats::mean(&self.rows.iter().map(|r| r.bits_sira as f64).collect::<Vec<_>>())
    }

    pub fn mean_datatype(&self) -> f64 {
        crate::util::stats::mean(
            &self
                .rows
                .iter()
                .map(|r| r.bits_datatype as f64)
                .collect::<Vec<_>>(),
        )
    }
}

/// The paper's datatype-bound accumulator width (§4.2, after Colbert et
/// al.): K-element dot product, N-bit unsigned inputs, M-bit signed
/// weights.
pub fn datatype_bound_bits(k: u64, n_bits: u32, m_bits: u32) -> u32 {
    let alpha = (k as f64).log2() + n_bits as f64 + m_bits as f64 - 1.0;
    let phi = (1.0 + 2f64.powf(-alpha)).log2();
    (alpha + phi + 1.0).ceil() as u32
}

/// The SIRA bound: two's complement bits to losslessly hold [lo, hi] in
/// a signed accumulator — the paper's
/// `P = ceil(log2(max(|lo|, |hi|+1))) + 1`.
pub fn sira_bound_bits(lo: i64, hi: i64) -> u32 {
    let mag = lo.unsigned_abs().max(hi.unsigned_abs() + 1);
    (crate::util::ceil_log2(mag.max(1)) + 1).max(2)
}

/// Lossless integer bounds of a tensor's SIRA integer component, if any.
/// Shared fusion metadata: this pass uses it to size hardware
/// accumulators, and the plan engine ([`crate::engine`]) uses it to pick
/// i32 vs i64 software accumulation for the same MAC outputs.
pub fn sira_int_bounds(analysis: &Analysis, tensor: &str) -> Option<(i64, i64)> {
    analysis
        .get(tensor)
        .ok()
        .and_then(|r| r.int.as_ref())
        .map(|ic| ic.int_bounds())
}

/// Compute accumulator widths for every MAC node and annotate the graph's
/// datatype map according to `policy`. Must run after streamlining (MAC
/// inputs pure-integer) with a completed SIRA [`Analysis`].
pub fn minimize_accumulators(
    g: &mut Graph,
    analysis: &Analysis,
    policy: AccPolicy,
) -> Result<AccReport> {
    let mut report = AccReport::default();
    let order = g.topo_order()?;
    for idx in order {
        let node = g.nodes[idx].clone();
        if !node.op.is_mac() {
            continue;
        }
        let in_shapes: Vec<Vec<usize>> = node
            .inputs
            .iter()
            .map(|i| g.shapes[i].clone())
            .collect();
        let k = dot_length(&node.op, &in_shapes)?;
        // operand bits from SIRA input ranges (falls back to datatype
        // annotations, then conservative 8/8)
        let operand_bits = |name: &str, signed_default: bool| -> u32 {
            if let Ok(r) = analysis.get(name) {
                if let Some(ic) = &r.int {
                    let (lo, hi) = ic.int_bounds();
                    return bits_for_range(lo, hi);
                }
            }
            match g.dtypes.get(name) {
                Some(dt) => dt.bits(),
                None => {
                    let _ = signed_default;
                    8
                }
            }
        };
        let n_bits = operand_bits(&node.inputs[0], false);
        let m_bits = operand_bits(&node.inputs[1], true);
        let bits_datatype = datatype_bound_bits(k, n_bits, m_bits).min(32);
        let out = node.outputs[0].clone();
        // The accumulator holds the *integer component* of the MAC output
        // (scales are applied downstream), so any scaled-integer range —
        // pure or not — provides the lossless SIRA bound.
        let bits_sira = match sira_int_bounds(analysis, &out) {
            Some((lo, hi)) => sira_bound_bits(lo, hi),
            None => bits_datatype, // no lossless info: fall back
        };
        let chosen = match policy {
            AccPolicy::Bound32 => 32,
            AccPolicy::Datatype => bits_datatype,
            AccPolicy::Sira => bits_sira,
        };
        // accumulators are signed whenever weights are signed
        g.dtypes.insert(out.clone(), DataType::Int(chosen));
        report.rows.push(AccRow {
            node: node.name.clone(),
            k,
            n_bits,
            m_bits,
            bits_32: 32,
            bits_datatype,
            bits_sira,
        });
    }
    Ok(report)
}

/// MAC nodes in the graph (helper for reports).
pub fn mac_nodes(g: &Graph) -> Vec<usize> {
    g.nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(n.op, Op::MatMul | Op::Conv { .. } | Op::Gemm))
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datatype_bound_matches_paper_formula() {
        // K=2, N=4 (unsigned), M=4 (signed): α = 1+4+4-1 = 8,
        // φ ≈ 0.0056 → P = ceil(9.0056) = 10
        assert_eq!(datatype_bound_bits(2, 4, 4), 10);
        // large K dominates: K=1024, N=8, M=8 → α = 10+8+8-1 = 25 → 27
        assert_eq!(datatype_bound_bits(1024, 8, 8), 27);
    }

    #[test]
    fn sira_bound_matches_fig12() {
        // Fig 12: output interval ±96 -> ceil(log2(97)) + 1 = 8 bits
        assert_eq!(sira_bound_bits(-96, 96), 8);
        assert_eq!(sira_bound_bits(-1, 1), 2);
        // all-positive interval still gets a sign bit via min(0)
        assert_eq!(sira_bound_bits(5, 96), 8);
    }

    #[test]
    fn sira_never_exceeds_exact_need() {
        for (lo, hi) in [(-100i64, 50i64), (0, 1), (-8, 7), (-129, 130)] {
            let b = sira_bound_bits(lo, hi);
            // interval must fit in b signed bits
            assert!(lo >= -(1 << (b - 1)));
            assert!(hi <= (1 << (b - 1)) - 1);
        }
    }

    #[test]
    fn minimize_on_worked_example() {
        use crate::sira::analyze;
        let (mut g, inputs) = crate::models::worked_example();
        let a = analyze(&g, &inputs).unwrap();
        let rep = minimize_accumulators(&mut g, &a, AccPolicy::Sira).unwrap();
        assert_eq!(rep.rows.len(), 1);
        let row = &rep.rows[0];
        // SIRA: output range ±96 -> 8 bits; inputs 4-bit ranges
        assert_eq!(row.bits_sira, 8);
        assert_eq!(row.k, 2);
        // datatype bound must be >= sira bound
        assert!(row.bits_datatype >= row.bits_sira);
        // the MAC output dtype was annotated
        let mm = g.nodes.iter().find(|n| n.op.name() == "MatMul").unwrap();
        assert_eq!(g.dtypes[&mm.outputs[0]], DataType::Int(8));
    }
}
