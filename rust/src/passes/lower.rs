//! Operator lowering (§3.3): rewrite composite operators into the
//! primitive forms SIRA defines propagation handlers for — `Gemm` with
//! bias becomes `MatMul + Add`, and `BatchNormalization` becomes
//! `Mul + Add` with folded per-channel affine parameters.

use anyhow::Result;

use crate::graph::{Graph, Node, Op};

/// Lower all Gemm nodes to MatMul + Add. Returns the number lowered.
pub fn lower_gemm(g: &mut Graph) -> Result<usize> {
    let mut count = 0;
    let mut i = 0;
    while i < g.nodes.len() {
        if matches!(g.nodes[i].op, Op::Gemm) {
            let node = g.nodes[i].clone();
            let mm_out = g.fresh(&format!("{}_mm", node.name));
            let mm = Node {
                name: g.fresh(&format!("{}_MatMul", node.name)),
                op: Op::MatMul,
                inputs: vec![node.inputs[0].clone(), node.inputs[1].clone()],
                outputs: vec![mm_out.clone()],
            };
            let add = Node {
                name: g.fresh(&format!("{}_Add", node.name)),
                op: Op::Add,
                inputs: vec![mm_out, node.inputs[2].clone()],
                outputs: node.outputs.clone(),
            };
            g.nodes.remove(i);
            g.nodes.insert(i, mm);
            g.nodes.insert(i + 1, add);
            count += 1;
            i += 2;
        } else {
            i += 1;
        }
    }
    if count > 0 {
        crate::graph::shapes::infer_shapes(g)?;
    }
    Ok(count)
}

/// Lower all BatchNormalization nodes to Mul + Add with per-channel
/// constants `A = gamma / sqrt(var + eps)` and `B = beta - mean * A`,
/// reshaped to broadcast over the data layout (NCHW or NC).
pub fn lower_batchnorm(g: &mut Graph) -> Result<usize> {
    let mut count = 0;
    let mut i = 0;
    while i < g.nodes.len() {
        let Op::BatchNorm { eps } = g.nodes[i].op else {
            i += 1;
            continue;
        };
        let node = g.nodes[i].clone();
        let gamma = g.initializers[&node.inputs[1]].clone();
        let beta = g.initializers[&node.inputs[2]].clone();
        let mean = g.initializers[&node.inputs[3]].clone();
        let var = g.initializers[&node.inputs[4]].clone();
        let c = gamma.numel();
        let a = gamma.zip(&var, |gm, v| gm / (v + eps).sqrt())?;
        let b = beta.zip(&mean.mul(&a)?, |bt, ma| bt - ma)?;
        let rank = g.shapes[&node.inputs[0]].len();
        let param_shape: Vec<usize> = if rank == 4 {
            vec![1, c, 1, 1]
        } else {
            vec![1, c]
        };
        let a = a.reshape(&param_shape)?;
        let b = b.reshape(&param_shape)?;
        let a_name = g.fresh(&format!("{}_scale", node.name));
        let b_name = g.fresh(&format!("{}_bias", node.name));
        g.add_initializer(&a_name, a);
        g.add_initializer(&b_name, b);
        let mul_out = g.fresh(&format!("{}_mul", node.name));
        let mul = Node {
            name: g.fresh(&format!("{}_Mul", node.name)),
            op: Op::Mul,
            inputs: vec![node.inputs[0].clone(), a_name],
            outputs: vec![mul_out.clone()],
        };
        let add = Node {
            name: g.fresh(&format!("{}_Add", node.name)),
            op: Op::Add,
            inputs: vec![mul_out, b_name],
            outputs: node.outputs.clone(),
        };
        g.nodes.remove(i);
        g.nodes.insert(i, mul);
        g.nodes.insert(i + 1, add);
        g.prune_unused_initializers();
        count += 1;
        i += 2;
    }
    if count > 0 {
        crate::graph::shapes::infer_shapes(g)?;
    }
    Ok(count)
}

/// Run all lowering passes.
pub fn lower_all(g: &mut Graph) -> Result<usize> {
    Ok(lower_gemm(g)? + lower_batchnorm(g)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executor::Executor;
    use crate::graph::Node;
    use crate::tensor::Tensor;

    fn gemm_bn_graph() -> Graph {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 2]);
        g.add_initializer("w", Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap());
        g.add_initializer("c", Tensor::new(&[1, 2], vec![0.5, -0.5]).unwrap());
        g.add_node(Node::new("gemm", Op::Gemm, &["x", "w", "c"], &["h"]));
        g.add_initializer("gamma", Tensor::from_vec(vec![2.0, 1.0]));
        g.add_initializer("beta", Tensor::from_vec(vec![0.1, 0.2]));
        g.add_initializer("mean", Tensor::from_vec(vec![1.0, -1.0]));
        g.add_initializer("var", Tensor::from_vec(vec![3.0, 0.0]));
        g.add_node(Node::new(
            "bn",
            Op::BatchNorm { eps: 1.0 },
            &["h", "gamma", "beta", "mean", "var"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        g
    }

    #[test]
    fn lowering_preserves_semantics() {
        let g0 = gemm_bn_graph();
        let x = Tensor::new(&[1, 2], vec![1.5, -2.0]).unwrap();
        let y0 = Executor::new(&g0).unwrap().run_single(&x).unwrap();

        let mut g1 = g0.clone();
        let n = lower_all(&mut g1).unwrap();
        assert_eq!(n, 2);
        assert_eq!(g1.count_op("Gemm"), 0);
        assert_eq!(g1.count_op("BatchNormalization"), 0);
        assert_eq!(g1.count_op("MatMul"), 1);
        assert_eq!(g1.count_op("Mul"), 1);
        assert_eq!(g1.count_op("Add"), 2);
        g1.check().unwrap();

        let y1 = Executor::new(&g1).unwrap().run_single(&x).unwrap();
        for (a, b) in y0[0].data().iter().zip(y1[0].data()) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn bn_lowering_rank4() {
        let mut g = Graph::new("t4");
        g.add_input("x", &[1, 2, 2, 2]);
        g.add_initializer("gamma", Tensor::from_vec(vec![1.0, 2.0]));
        g.add_initializer("beta", Tensor::from_vec(vec![0.0, 0.0]));
        g.add_initializer("mean", Tensor::from_vec(vec![0.0, 0.0]));
        g.add_initializer("var", Tensor::from_vec(vec![0.0, 3.0]));
        g.add_node(Node::new(
            "bn",
            Op::BatchNorm { eps: 1.0 },
            &["x", "gamma", "beta", "mean", "var"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let x = Tensor::new(&[1, 2, 2, 2], (0..8).map(|v| v as f64).collect()).unwrap();
        let y0 = Executor::new(&g).unwrap().run_single(&x).unwrap();
        lower_batchnorm(&mut g).unwrap();
        let y1 = Executor::new(&g).unwrap().run_single(&x).unwrap();
        for (a, b) in y0[0].data().iter().zip(y1[0].data()) {
            assert!((a - b).abs() < 1e-12);
        }
        // params must be (1,C,1,1) for NCHW broadcast
        let mul = g.nodes.iter().find(|n| n.op == Op::Mul).unwrap();
        assert_eq!(g.initializers[&mul.inputs[1]].shape(), &[1, 2, 1, 1]);
    }
}
