//! Fixed-point quantization of aggregated scales and biases (§6.2.1):
//! for composite layer tails, the float parameters of elementwise Mul/Add
//! nodes are snapped to a fixed<W,I> grid (the paper grid-searches the
//! fractional bits per tensor; we expose W and F directly). Not part of
//! the SIRA optimizations proper — it is the paper's *baseline* treatment
//! for non-thresholded tails — but needed to reproduce the Table 8
//! accuracy comparison.

use anyhow::Result;

use crate::graph::{Graph, Op};

/// Snap a value to the fixed<W,I> grid (F = W - I fractional bits),
/// saturating at the representable range.
pub fn to_fixed(v: f64, w: u32, i: u32) -> f64 {
    let f = w - i;
    let scale = (1u64 << f) as f64;
    let lo = -((1i64 << (w - 1)) as f64) / scale;
    let hi = ((1i64 << (w - 1)) - 1) as f64 / scale;
    ((v * scale).round() / scale).clamp(lo, hi)
}

/// Quantize every non-integral elementwise constant (Mul/Add/Div/Sub
/// parameters) to a fixed<W,I> format with the integer bits `I` chosen
/// per tensor for lossless representation of the integer part (the
/// paper's §6.2.1 procedure; the remaining W−I bits are fractional).
/// Returns the number of tensors touched.
pub fn quantize_tail_params(g: &mut Graph, w: u32) -> Result<usize> {
    let mut touched = 0;
    let mut targets: Vec<String> = Vec::new();
    for node in &g.nodes {
        if !matches!(node.op, Op::Mul | Op::Add | Op::Div | Op::Sub) {
            continue;
        }
        for inp in &node.inputs {
            if g.is_initializer(inp) && !g.initializers[inp].is_integral() {
                targets.push(inp.clone());
            }
        }
    }
    targets.sort();
    targets.dedup();
    for name in targets {
        let t = &g.initializers[&name];
        // I: signed integer bits covering the integer part losslessly
        let mag = t.abs_max().floor().max(0.0) as u64;
        let i = (crate::util::ceil_log2(mag + 2) + 1).min(w - 1);
        let q = t.map(|v| to_fixed(v, w, i));
        g.add_initializer(&name, q);
        touched += 1;
    }
    Ok(touched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;
    use crate::tensor::Tensor;

    #[test]
    fn fixed_grid_snapping() {
        // fixed16.8: step 1/256
        assert_eq!(to_fixed(0.5, 16, 8), 0.5);
        assert_eq!(to_fixed(0.001, 16, 8), 0.0);
        assert!((to_fixed(0.335, 16, 8) - 0.3359375).abs() < 1e-12);
        // saturation
        assert_eq!(to_fixed(1e9, 16, 8), (32767.0) / 256.0);
        assert_eq!(to_fixed(-1e9, 16, 8), -128.0);
    }

    #[test]
    fn quantizes_only_float_tail_params() {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 2]);
        g.add_initializer("s", Tensor::from_vec(vec![0.333, 1.5]));
        g.add_initializer("k", Tensor::from_vec(vec![3.0, -2.0])); // integral
        g.add_node(Node::new("m", Op::Mul, &["x", "s"], &["a"]));
        g.add_node(Node::new("a", Op::Add, &["a", "k"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let n = quantize_tail_params(&mut g, 16).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.initializers["k"].data(), &[3.0, -2.0]);
        let s = &g.initializers["s"];
        // I is chosen per tensor; values land on some power-of-two grid
        assert!(s
            .data()
            .iter()
            .all(|v| (v * 8192.0).fract() == 0.0 || (v * 256.0).fract() == 0.0));
    }
}
