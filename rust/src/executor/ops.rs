//! Reference execution semantics for every operator (the float path;
//! bit-exact integer behaviour is obtained because all integer values are
//! exactly representable in f64 — see the crate docs of [`crate::tensor`]).

use anyhow::{bail, Result};

use crate::graph::{Op, RoundMode};
use crate::sira::quant_bounds;
use crate::tensor::{conv2d, conv2d_depthwise, pool2d, PoolKind, Tensor};

/// Execute one operator on concrete input tensors.
pub fn execute_op(op: &Op, ins: &[Tensor]) -> Result<Vec<Tensor>> {
    let out = match op {
        Op::Quant {
            signed,
            narrow,
            rounding,
        } => quant(ins, *signed, *narrow, *rounding)?,
        Op::MatMul => ins[0].matmul(&ins[1])?,
        Op::Gemm => ins[0].matmul(&ins[1])?.add(&ins[2])?,
        Op::Conv { spec, group } => {
            let c = ins[0].shape()[1];
            if *group == 1 {
                conv2d(&ins[0], &ins[1], *spec)?
            } else if *group == c && ins[1].shape()[1] == 1 {
                conv2d_depthwise(&ins[0], &ins[1], *spec)?
            } else {
                bail!("unsupported conv group {group}");
            }
        }
        Op::Add => ins[0].add(&ins[1])?,
        Op::Sub => ins[0].sub(&ins[1])?,
        Op::Mul => ins[0].mul(&ins[1])?,
        Op::Div => ins[0].div(&ins[1])?,
        Op::Relu => ins[0].relu(),
        Op::Sigmoid => ins[0].sigmoid(),
        Op::Floor => ins[0].floor(),
        Op::Clip { lo, hi } => ins[0].clip(*lo, *hi),
        Op::BatchNorm { eps } => {
            let (x, gamma, beta, mean, var) = (&ins[0], &ins[1], &ins[2], &ins[3], &ins[4]);
            let c = gamma.numel();
            let a = gamma.zip(var, |g, v| g / (v + eps).sqrt())?;
            let b = beta.zip(&mean.mul(&a)?, |bt, ma| bt - ma)?;
            // reshape per-channel params to broadcast along axis 1
            let pshape: Vec<usize> = if x.rank() == 4 { vec![1, c, 1, 1] } else { vec![1, c] };
            let a4 = a.reshape(&pshape)?;
            let b4 = b.reshape(&pshape)?;
            x.mul(&a4)?.add(&b4)?
        }
        Op::MaxPool { spec } => pool2d(&ins[0], PoolKind::Max, *spec)?,
        Op::AveragePool { spec } => pool2d(&ins[0], PoolKind::Average, *spec)?,
        Op::GlobalAveragePool => {
            let (h, w) = (ins[0].shape()[2], ins[0].shape()[3]);
            pool2d(
                &ins[0],
                PoolKind::Average,
                crate::tensor::Conv2dSpec {
                    kernel: (h, w),
                    stride: (1, 1),
                    pad: (0, 0),
                },
            )?
        }
        Op::Reshape { shape } => {
            let numel = ins[0].numel();
            let mut out: Vec<usize> = Vec::new();
            let mut known = 1usize;
            let mut infer = None;
            for (i, &d) in shape.iter().enumerate() {
                if d == -1 {
                    infer = Some(i);
                    out.push(0);
                } else if d == 0 {
                    out.push(ins[0].shape()[i]);
                    known *= ins[0].shape()[i];
                } else {
                    out.push(d as usize);
                    known *= d as usize;
                }
            }
            if let Some(i) = infer {
                out[i] = numel / known;
            }
            ins[0].reshape(&out)?
        }
        Op::Flatten { axis } => {
            let outer: usize = ins[0].shape()[..*axis].iter().product();
            let inner: usize = ins[0].shape()[*axis..].iter().product();
            ins[0].reshape(&[outer, inner])?
        }
        Op::Transpose { perm } => ins[0].permute(perm)?,
        Op::Concat { axis } => {
            let refs: Vec<&Tensor> = ins.iter().collect();
            Tensor::concat(&refs, *axis)?
        }
        Op::Identity => ins[0].clone(),
        Op::MultiThreshold {
            out_scale,
            out_bias,
        } => multithreshold(&ins[0], &ins[1], *out_scale, *out_bias)?,
    };
    Ok(vec![out])
}

/// QONNX Quant execution:
/// `y = s * (clip(round(x/s + z), qmin, qmax) - z)`.
fn quant(ins: &[Tensor], signed: bool, narrow: bool, rounding: RoundMode) -> Result<Tensor> {
    let (x, s, z) = (&ins[0], &ins[1], &ins[2]);
    let bits = ins[3].first() as u32;
    let (qmin, qmax) = quant_bounds(bits, signed, narrow);
    let pre = x.div(s)?.add(z)?;
    let rounded = match rounding {
        RoundMode::RoundEven => pre.round_even(),
        RoundMode::Floor => pre.floor(),
        RoundMode::Ceil => pre.ceil(),
    };
    let q = rounded.clip(qmin, qmax);
    q.sub(z)?.mul(s)
}

/// Integer output of the Quant operator (before dequantization): the value
/// the streamlined integer datapath carries.
pub fn quant_int(ins: &[Tensor], signed: bool, narrow: bool, rounding: RoundMode) -> Result<Tensor> {
    let (x, s, z) = (&ins[0], &ins[1], &ins[2]);
    let bits = ins[3].first() as u32;
    let (qmin, qmax) = quant_bounds(bits, signed, narrow);
    let pre = x.div(s)?.add(z)?;
    let rounded = match rounding {
        RoundMode::RoundEven => pre.round_even(),
        RoundMode::Floor => pre.floor(),
        RoundMode::Ceil => pre.ceil(),
    };
    Ok(rounded.clip(qmin, qmax))
}

/// MultiThreshold execution: per-channel comparison count
/// `y = out_bias + out_scale * Σ_i (x >= Θ_i)` (Eq. 1 of the paper).
/// Thresholds have shape (C, N); C must match the channel axis (axis 1)
/// of the input or be 1 (per-tensor).
fn multithreshold(x: &Tensor, th: &Tensor, out_scale: f64, out_bias: f64) -> Result<Tensor> {
    if th.rank() != 2 {
        bail!("thresholds must be rank-2 (C, N), got {:?}", th.shape());
    }
    let (c_th, n) = (th.shape()[0], th.shape()[1]);
    let channels = if x.rank() >= 2 { x.shape()[1] } else { 1 };
    if c_th != 1 && c_th != channels {
        bail!(
            "threshold channels {c_th} incompatible with data channels {channels}"
        );
    }
    let ch_stride: usize = if x.rank() >= 2 {
        x.shape()[2..].iter().product()
    } else {
        1
    };
    let mut out = Vec::with_capacity(x.numel());
    for (flat, &v) in x.data().iter().enumerate() {
        let ch = if c_th == 1 { 0 } else { (flat / ch_stride) % channels };
        let row = &th.data()[ch * n..(ch + 1) * n];
        let cnt = row.iter().filter(|&&t| v >= t).count() as f64;
        out.push(out_bias + out_scale * cnt);
    }
    Tensor::new(x.shape(), out)
}

/// Number of multiply-accumulate operations performed by a MAC op (used
/// for workload statistics and folding decisions).
pub fn mac_count(op: &Op, in_shapes: &[Vec<usize>]) -> Result<u64> {
    Ok(match op {
        Op::MatMul | Op::Gemm => {
            let (a, b) = (&in_shapes[0], &in_shapes[1]);
            (a[0] * a[1] * b[1]) as u64
        }
        Op::Conv { spec, group } => {
            let (x, w) = (&in_shapes[0], &in_shapes[1]);
            let (oh, ow) = spec.out_hw(x[2], x[3]);
            let _ = group;
            (x[0] * w[0] * oh * ow * w[1] * w[2] * w[3]) as u64
        }
        _ => 0,
    })
}

/// Dot-product length K of a MAC op (drives the datatype accumulator
/// bound of §4.2).
pub fn dot_length(op: &Op, in_shapes: &[Vec<usize>]) -> Result<u64> {
    Ok(match op {
        Op::MatMul | Op::Gemm => in_shapes[0][1] as u64,
        Op::Conv { spec, .. } => {
            let w = &in_shapes[1];
            (w[1] * spec.kernel.0 * spec.kernel.1) as u64
        }
        _ => bail!("dot_length on non-MAC op"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Conv2dSpec;

    #[test]
    fn quant_roundtrip_4bit() {
        let x = Tensor::from_vec(vec![-5.1, 0.0, 0.34, 5.1]);
        let ins = [
            x,
            Tensor::scalar(0.7),
            Tensor::scalar(0.0),
            Tensor::scalar(4.0),
        ];
        let y = quant(&ins, true, false, RoundMode::RoundEven).unwrap();
        // -5.1/0.7 = -7.29 -> -7 -> -4.9 ; 0.34/0.7 = 0.486 -> 0
        assert!((y.data()[0] + 4.9).abs() < 1e-12);
        assert_eq!(y.data()[1], 0.0);
        assert_eq!(y.data()[2], 0.0);
        assert!((y.data()[3] - 4.9).abs() < 1e-12);
    }

    #[test]
    fn quant_saturates() {
        let x = Tensor::from_vec(vec![-100.0, 100.0]);
        let ins = [
            x,
            Tensor::scalar(1.0),
            Tensor::scalar(0.0),
            Tensor::scalar(4.0),
        ];
        let y = quant(&ins, true, false, RoundMode::RoundEven).unwrap();
        assert_eq!(y.data(), &[-8.0, 7.0]);
        let yn = quant(&ins, true, true, RoundMode::RoundEven).unwrap();
        assert_eq!(yn.data(), &[-7.0, 7.0]); // narrow range
    }

    #[test]
    fn quant_zero_point() {
        // z = -8 maps unsigned-looking data onto signed grid
        let x = Tensor::from_vec(vec![0.0, 15.0]);
        let ins = [
            x,
            Tensor::scalar(1.0),
            Tensor::scalar(-8.0),
            Tensor::scalar(4.0),
        ];
        let y = quant(&ins, true, false, RoundMode::RoundEven).unwrap();
        assert_eq!(y.data(), &[0.0, 15.0]);
    }

    #[test]
    fn multithreshold_per_tensor() {
        let x = Tensor::from_vec(vec![-1.0, 0.5, 2.0, 10.0]).reshape(&[1, 4]).unwrap();
        let th = Tensor::new(&[1, 3], vec![0.0, 1.0, 5.0]).unwrap();
        let y = multithreshold(&x, &th, 1.0, 0.0).unwrap();
        assert_eq!(y.data(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn multithreshold_per_channel_nchw() {
        // 2 channels with different thresholds
        let x = Tensor::new(&[1, 2, 1, 2], vec![1.0, 3.0, 1.0, 3.0]).unwrap();
        let th = Tensor::new(&[2, 2], vec![0.0, 2.0, 2.5, 2.8]).unwrap();
        let y = multithreshold(&x, &th, 1.0, 0.0).unwrap();
        assert_eq!(y.data(), &[1.0, 2.0, 0.0, 2.0]);
    }

    #[test]
    fn multithreshold_bias_scale() {
        let x = Tensor::from_vec(vec![5.0]).reshape(&[1, 1]).unwrap();
        let th = Tensor::new(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        // sign bias -4 and scale 2: y = -4 + 2*3 = 2
        let y = multithreshold(&x, &th, 2.0, -4.0).unwrap();
        assert_eq!(y.data(), &[2.0]);
    }

    #[test]
    fn bn_matches_manual() {
        let x = Tensor::new(&[1, 2, 1, 1], vec![1.0, 2.0]).unwrap();
        let ins = [
            x,
            Tensor::from_vec(vec![2.0, 1.0]),  // gamma
            Tensor::from_vec(vec![0.5, -1.0]), // beta
            Tensor::from_vec(vec![1.0, 0.0]),  // mean
            Tensor::from_vec(vec![3.0, 0.0]),  // var
        ];
        let y = execute_op(&Op::BatchNorm { eps: 1.0 }, &ins).unwrap();
        // ch0: 2*(1-1)/sqrt(4) + 0.5 = 0.5 ; ch1: 1*(2-0)/1 - 1 = 1
        assert!((y[0].data()[0] - 0.5).abs() < 1e-12);
        assert!((y[0].data()[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mac_count_conv() {
        let op = Op::Conv {
            spec: Conv2dSpec {
                kernel: (3, 3),
                stride: (1, 1),
                pad: (1, 1),
            },
            group: 1,
        };
        let macs = mac_count(&op, &[vec![1, 3, 32, 32], vec![16, 3, 3, 3]]).unwrap();
        assert_eq!(macs, 16 * 32 * 32 * 3 * 9);
        assert_eq!(
            dot_length(&op, &[vec![1, 3, 32, 32], vec![16, 3, 3, 3]]).unwrap(),
            27
        );
    }

    #[test]
    fn gemm_bias() {
        let a = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2, 1], vec![3.0, 4.0]).unwrap();
        let c = Tensor::new(&[1, 1], vec![10.0]).unwrap();
        let y = execute_op(&Op::Gemm, &[a, b, c]).unwrap();
        assert_eq!(y[0].data(), &[21.0]);
    }

    #[test]
    fn flatten_reshape_exec() {
        let x = Tensor::zeros(&[2, 3, 4]);
        let y = execute_op(&Op::Flatten { axis: 1 }, std::slice::from_ref(&x)).unwrap();
        assert_eq!(y[0].shape(), &[2, 12]);
        let z = execute_op(
            &Op::Reshape {
                shape: vec![0, -1, 2],
            },
            &[x],
        )
        .unwrap();
        assert_eq!(z[0].shape(), &[2, 6, 2]);
    }
}
