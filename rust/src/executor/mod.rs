//! Graph interpreter: reference execution of QNN graphs (float and
//! streamlined-integer forms), with optional per-channel min/max
//! instrumentation (the empirical verification data of §6.1 / Fig 20) and
//! datatype conformance checking (overflow detection for accumulator
//! width failure-injection tests).

pub mod ops;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

pub use ops::{dot_length, execute_op, mac_count};

use crate::graph::Graph;
use crate::tensor::Tensor;

/// Running per-channel min/max observations per tensor.
#[derive(Clone, Debug, Default)]
pub struct Instrumentation {
    /// tensor -> (per-channel min, per-channel max); channel = axis 1.
    pub observed: BTreeMap<String, (Tensor, Tensor)>,
    /// number of samples folded in
    pub samples: usize,
}

impl Instrumentation {
    fn record(&mut self, name: &str, t: &Tensor) {
        let (mins, maxs) = per_channel_minmax(t);
        match self.observed.get_mut(name) {
            None => {
                self.observed.insert(name.to_string(), (mins, maxs));
            }
            Some((lo, hi)) => {
                *lo = lo.minimum(&mins).expect("instr shape drift");
                *hi = hi.maximum(&maxs).expect("instr shape drift");
            }
        }
    }
}

/// Per-channel (axis 1) min and max of a tensor; rank<2 uses one channel.
pub fn per_channel_minmax(t: &Tensor) -> (Tensor, Tensor) {
    if t.rank() < 2 {
        return (Tensor::scalar(t.min()), Tensor::scalar(t.max()));
    }
    (
        t.reduce_except(1, f64::INFINITY, f64::min),
        t.reduce_except(1, f64::NEG_INFINITY, f64::max),
    )
}

/// Options controlling execution.
#[derive(Clone, Debug, Default)]
pub struct ExecOptions {
    /// Record per-channel min/max for every intermediate tensor.
    pub instrument: bool,
    /// Verify tensors against their `graph.dtypes` annotations (integer
    /// integrality + width bounds). Catches accumulator overflow.
    pub verify_dtypes: bool,
}

/// A prepared executor for one graph (topological order cached).
pub struct Executor<'g> {
    pub graph: &'g Graph,
    order: Vec<usize>,
    pub options: ExecOptions,
    pub instrumentation: Instrumentation,
}

impl<'g> Executor<'g> {
    pub fn new(graph: &'g Graph) -> Result<Executor<'g>> {
        Ok(Executor {
            graph,
            order: graph.topo_order()?,
            options: ExecOptions::default(),
            instrumentation: Instrumentation::default(),
        })
    }

    pub fn with_options(graph: &'g Graph, options: ExecOptions) -> Result<Executor<'g>> {
        Ok(Executor {
            options,
            ..Executor::new(graph)?
        })
    }

    /// Execute the graph; returns the graph outputs in declaration order.
    pub fn run(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<Vec<Tensor>> {
        let env = self.run_env(inputs)?;
        self.graph
            .outputs
            .iter()
            .map(|o| {
                env.get(o)
                    .cloned()
                    .with_context(|| format!("output '{o}' not produced"))
            })
            .collect()
    }

    /// Convenience: run with a single input tensor.
    pub fn run_single(&mut self, x: &Tensor) -> Result<Vec<Tensor>> {
        let mut inputs = BTreeMap::new();
        inputs.insert(self.graph.inputs[0].clone(), x.clone());
        self.run(&inputs)
    }

    /// Execute and return the full tensor environment (all intermediates).
    pub fn run_env(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<BTreeMap<String, Tensor>> {
        let mut env: BTreeMap<String, Tensor> = BTreeMap::new();
        for name in &self.graph.inputs {
            let t = inputs
                .get(name)
                .with_context(|| format!("missing graph input '{name}'"))?;
            let want = &self.graph.shapes[name];
            if t.shape() != &want[..] {
                bail!(
                    "input '{name}': shape {:?} does not match declared {:?}",
                    t.shape(),
                    want
                );
            }
            env.insert(name.clone(), t.clone());
        }
        for (name, t) in &self.graph.initializers {
            env.insert(name.clone(), t.clone());
        }
        for &idx in &self.order {
            let node = &self.graph.nodes[idx];
            let ins: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|i| {
                    env.get(i)
                        .cloned()
                        .with_context(|| format!("node '{}' reads undefined '{i}'", node.name))
                })
                .collect::<Result<_>>()?;
            let outs = execute_op(&node.op, &ins)
                .with_context(|| format!("executing node '{}' ({})", node.name, node.op.name()))?;
            for (oname, t) in node.outputs.iter().zip(outs) {
                if self.options.verify_dtypes {
                    if let Some(dt) = self.graph.dtypes.get(oname) {
                        verify_dtype(oname, &t, *dt)?;
                    }
                }
                if self.options.instrument {
                    self.instrumentation.record(oname, &t);
                }
                env.insert(oname.clone(), t);
            }
        }
        if self.options.instrument {
            self.instrumentation.samples += 1;
        }
        Ok(env)
    }
}

/// Check every element of `t` against datatype `dt`.
pub fn verify_dtype(name: &str, t: &Tensor, dt: crate::graph::DataType) -> Result<()> {
    for &v in t.data() {
        if !dt.allows(v) {
            bail!("tensor '{name}': value {v} outside datatype {dt} — possible overflow");
        }
    }
    Ok(())
}

/// Top-1 accuracy of a classifier graph over a labeled dataset.
/// `data` is a list of (input, label) pairs; the single graph input and
/// single output (logits, shape (1, classes)) are assumed.
pub fn top1_accuracy(g: &Graph, data: &[(Tensor, usize)]) -> Result<f64> {
    let mut exec = Executor::new(g)?;
    let mut correct = 0usize;
    for (x, label) in data {
        let out = exec.run_single(x)?;
        let pred = out[0].argmax_rows()?[0];
        if pred == *label {
            correct += 1;
        }
    }
    Ok(correct as f64 / data.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataType, Node, Op};

    fn relu_graph() -> Graph {
        let mut g = Graph::new("t");
        g.add_input("x", &[1, 3]);
        g.add_node(Node::new("r", Op::Relu, &["x"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        g
    }

    #[test]
    fn runs_simple_graph() {
        let g = relu_graph();
        let mut e = Executor::new(&g).unwrap();
        let out = e
            .run_single(&Tensor::new(&[1, 3], vec![-1.0, 0.0, 2.0]).unwrap())
            .unwrap();
        assert_eq!(out[0].data(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn rejects_bad_input_shape() {
        let g = relu_graph();
        let mut e = Executor::new(&g).unwrap();
        assert!(e.run_single(&Tensor::zeros(&[1, 4])).is_err());
    }

    #[test]
    fn instrumentation_accumulates() {
        let g = relu_graph();
        let mut e = Executor::with_options(
            &g,
            ExecOptions {
                instrument: true,
                verify_dtypes: false,
            },
        )
        .unwrap();
        for vals in [vec![-1.0, 5.0, 0.0], vec![2.0, -3.0, 1.0]] {
            e.run_single(&Tensor::new(&[1, 3], vals).unwrap()).unwrap();
        }
        let (lo, hi) = &e.instrumentation.observed["y"];
        assert_eq!(lo.data(), &[0.0, 0.0, 0.0]);
        assert_eq!(hi.data(), &[2.0, 5.0, 1.0]);
        assert_eq!(e.instrumentation.samples, 2);
    }

    #[test]
    fn dtype_verification_catches_overflow() {
        let mut g = relu_graph();
        g.dtypes.insert("y".to_string(), DataType::UInt(2));
        let mut e = Executor::with_options(
            &g,
            ExecOptions {
                instrument: false,
                verify_dtypes: true,
            },
        )
        .unwrap();
        let err = e
            .run_single(&Tensor::new(&[1, 3], vec![0.0, 1.0, 7.0]).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
    }

    #[test]
    fn per_channel_minmax_nchw() {
        let t = Tensor::new(&[1, 2, 1, 2], vec![1.0, -2.0, 5.0, 3.0]).unwrap();
        let (lo, hi) = per_channel_minmax(&t);
        assert_eq!(lo.data(), &[-2.0, 3.0]);
        assert_eq!(hi.data(), &[1.0, 5.0]);
    }
}
