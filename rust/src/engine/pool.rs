//! Persistent worker pool for the plan engine: long-lived execution
//! threads plus a checkout pool of per-worker run-time state
//! ([`WorkerState`]: buffer arena + conversion scratch).
//!
//! PR 2 parallelised `Plan::run_batch` with scoped `std::thread`s spawned
//! inside every call. That made the sharded paths bit-exact, but each
//! call paid thread spawn + join (tens of microseconds) — more than the
//! entire inference for the small zoo models, and the reason
//! `min_kernel_work` had to stay high. This module replaces the scoped
//! spawns with a pool that persists across `run_batch` calls (and across
//! `Plan` clones, which share it through an `Arc`): submitting a work
//! item is a queue push + condvar wake, so even small kernels can shard.
//!
//! # Execution model
//!
//! [`WorkerPool::scope`] mirrors `std::thread::scope`: work items may
//! borrow from the caller's stack, and `scope` does not return until
//! every spawned item has run. Waiting callers *help*: while their scope
//! is incomplete they pop and run queued items (their own or another
//! scope's), so a work item that itself opens a nested scope — e.g. a
//! sample shard sharding a large MVU kernel — can never deadlock the
//! pool, and the submitting thread always contributes a full worker's
//! throughput. A `Plan` with a thread budget of `N` therefore backs
//! itself with a pool of `N - 1` workers.
//!
//! A panic inside a work item is caught on the worker (workers are
//! never lost to panics), recorded on the owning scope, and re-raised
//! from that scope's `wait` — the same observable behaviour as a panic
//! under `std::thread::scope`.
//!
//! # Worker state
//!
//! Mutable run-time state never crosses threads mid-task: a work item
//! that needs an arena checks one out of the shared state pool for the
//! duration of the item ([`WorkerPool::with_state`]) and returns it
//! afterwards, so states are reused across calls and across plans (the
//! arena is grown on demand and every kernel fully overwrites its output
//! region before any reader touches it, so stale contents are
//! unobservable — the same invariant the buffer arena itself relies on).
//! At steady state the pool holds at most one state per executing
//! thread; [`WorkerPool::pooled_states`] exposes the count so tests can
//! assert reuse instead of growth.

use std::any::Any;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Per-worker conversion scratch (f64 activations gathered/converted to
/// the MAC's accumulator width, plus the im2col buffer), grown on demand
/// and reused across calls. Lives beside the buffer arena in
/// [`WorkerState`] so no scratch ever crosses a thread mid-task.
#[derive(Clone, Debug, Default)]
pub(crate) struct Scratch {
    pub(crate) cols: Vec<f64>,
    pub(crate) i32v: Vec<i32>,
    pub(crate) i64v: Vec<i64>,
}

/// One execution thread's run-time state: a private instance of the
/// liveness-managed buffer arena (see [`super::arena`]) plus conversion
/// scratch. Every sample shard, pipeline stage, and serial run owns
/// exactly one of these for its duration, which is the whole
/// thread-safety argument: steps are immutable, constants are shared
/// read-only, and everything mutable is task-private.
#[derive(Clone, Debug, Default)]
pub(crate) struct WorkerState {
    pub(crate) bufs: Vec<Vec<f64>>,
    pub(crate) scratch: Scratch,
}

impl WorkerState {
    pub(crate) fn new(n_phys: usize) -> WorkerState {
        WorkerState {
            bufs: vec![Vec::new(); n_phys],
            scratch: Scratch::default(),
        }
    }

    /// Grow the arena to at least `n_phys` buffers (plans of different
    /// sizes share pooled states).
    pub(crate) fn ensure(&mut self, n_phys: usize) {
        if self.bufs.len() < n_phys {
            self.bufs.resize(n_phys, Vec::new());
        }
    }
}

/// Recover the guard even if a previous holder panicked: none of the
/// pool's critical sections leave shared state inconsistent on unwind.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Chunk length for splitting `total` units across at most `parts` work
/// items with chunk boundaries aligned to `align` units: tiled MAC
/// shards align their column/channel ranges to the register-panel width
/// (`kernels::tile::NR`) so no two work items stream the same weight
/// panel; `align = 1` reproduces the plain `div_ceil` split. The last
/// chunk may be short; every unit is covered exactly once either way.
pub(crate) fn chunk_len(total: usize, parts: usize, align: usize) -> usize {
    let align = align.max(1);
    let per = total.div_ceil(parts.max(1));
    per.div_ceil(align) * align
}

/// Completion latch of one scope: counts outstanding work items and
/// records the first panic any of them raised.
struct Latch {
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

impl Latch {
    fn new() -> Latch {
        Latch {
            remaining: AtomicUsize::new(0),
            panic: Mutex::new(None),
        }
    }

    fn done(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) == 0
    }
}

/// A queued work item: the erased closure plus the scope it reports to.
struct Task {
    run: Box<dyn FnOnce() + Send + 'static>,
    latch: Arc<Latch>,
}

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cond: Condvar,
    shutdown: AtomicBool,
    states: Mutex<Vec<WorkerState>>,
    tasks_executed: AtomicUsize,
}

impl Shared {
    /// Run one task (on a worker or a helping waiter), recording panics
    /// on its latch and waking waiters when its scope completes.
    fn run_task(&self, task: Task) {
        let Task { run, latch } = task;
        if let Err(p) = catch_unwind(AssertUnwindSafe(run)) {
            let mut slot = lock(&latch.panic);
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        self.tasks_executed.fetch_add(1, Ordering::Relaxed);
        if latch.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            // completion: take the queue lock before notifying so a
            // waiter is either still holding it (and will observe
            // `done()`) or already parked (and receives the wake)
            let _guard = lock(&self.queue);
            self.cond.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = lock(&shared.queue);
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.cond.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        shared.run_task(task);
    }
}

/// The persistent worker pool. See the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("tasks_executed", &self.tasks_executed())
            .field("queued_tasks", &self.queued_tasks())
            .finish()
    }
}

impl WorkerPool {
    /// Start a pool of `workers` long-lived threads (at least 1).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
            states: Mutex::new(Vec::new()),
            tasks_executed: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sira-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            workers,
        }
    }

    /// Number of pool threads (the submitting thread adds one more
    /// executor on top during `scope`).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total work items executed over the pool's lifetime (workers and
    /// helping waiters combined) — the observable tests use to assert
    /// that sharding did or did not engage.
    pub fn tasks_executed(&self) -> usize {
        self.shared.tasks_executed.load(Ordering::Relaxed)
    }

    /// Worker states currently parked in the checkout pool. Bounded by
    /// the number of threads that ever executed state-holding items
    /// concurrently — the leak observable.
    pub fn pooled_states(&self) -> usize {
        lock(&self.shared.states).len()
    }

    /// Work items queued but not yet picked up by any executor — the
    /// instantaneous backlog observable behind the serving metrics
    /// gauges (0 whenever the pool is keeping up).
    pub fn queued_tasks(&self) -> usize {
        lock(&self.shared.queue).len()
    }

    /// Check a [`WorkerState`] out of the pool (creating one if none is
    /// parked), grown to `n_phys` buffers, for the duration of `f`. The
    /// state is returned to the pool afterwards, panic or not.
    pub(crate) fn with_state<R>(&self, n_phys: usize, f: impl FnOnce(&mut WorkerState) -> R) -> R {
        struct Return<'a> {
            shared: &'a Shared,
            state: Option<WorkerState>,
        }
        impl Drop for Return<'_> {
            fn drop(&mut self) {
                if let Some(st) = self.state.take() {
                    lock(&self.shared.states).push(st);
                }
            }
        }
        let mut st = lock(&self.shared.states).pop().unwrap_or_default();
        st.ensure(n_phys);
        let mut guard = Return {
            shared: &self.shared,
            state: Some(st),
        };
        f(guard.state.as_mut().expect("state present until drop"))
    }

    /// Run `f` with a [`Scope`] on which borrowed work items can be
    /// spawned; returns only after every spawned item has executed.
    /// Panics from work items (and from `f` itself) propagate to the
    /// caller, after the wait — exactly the `std::thread::scope`
    /// contract this replaces.
    pub fn scope<'env, R>(&self, f: impl FnOnce(&Scope<'_, 'env>) -> R) -> R {
        let latch = Arc::new(Latch::new());
        let scope = Scope {
            pool: self,
            latch: Arc::clone(&latch),
            _env: PhantomData,
        };
        // `f` may panic after spawning items that borrow the caller's
        // stack: the wait must happen on that path too, before unwinding
        // out of the borrowed frame.
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        self.wait(&latch);
        if let Some(p) = lock(&latch.panic).take() {
            resume_unwind(p);
        }
        match result {
            Ok(r) => r,
            Err(p) => resume_unwind(p),
        }
    }

    /// Block until `latch` completes, executing queued work items (of
    /// any scope) while waiting.
    fn wait(&self, latch: &Latch) {
        if latch.done() {
            return;
        }
        let shared = &self.shared;
        let mut q = lock(&shared.queue);
        loop {
            if latch.done() {
                return;
            }
            if let Some(task) = q.pop_front() {
                drop(q);
                shared.run_task(task);
                q = lock(&shared.queue);
            } else {
                q = shared.cond.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _guard = lock(&self.shared.queue);
            self.shared.cond.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn handle of one [`WorkerPool::scope`] call. Invariant over `'env`
/// like `std::thread::Scope`.
pub struct Scope<'pool, 'env> {
    pool: &'pool WorkerPool,
    latch: Arc<Latch>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Queue a work item that may borrow from `'env`.
    pub fn spawn<F: FnOnce() + Send + 'env>(&self, f: F) {
        self.latch.remaining.fetch_add(1, Ordering::SeqCst);
        let run: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: `WorkerPool::scope` waits for this item to finish (on
        // the normal and the panicking path) before returning, so every
        // `'env` borrow the closure captures outlives its execution.
        let run: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(run) };
        lock(&self.pool.shared.queue).push_back(Task {
            run,
            latch: Arc::clone(&self.latch),
        });
        // one task, one wakeup: any single woken thread (worker or
        // helping waiter) pops it; progress never depends on this
        // notification because every scope's waiter drains the queue
        // itself before parking
        self.pool.shared.cond.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_len_aligns_and_covers() {
        // plain split
        assert_eq!(chunk_len(10, 3, 1), 4);
        // aligned split: boundaries land on multiples of 8
        assert_eq!(chunk_len(16, 8, 8), 8);
        assert_eq!(chunk_len(9, 2, 8), 8); // chunks 8 + 1, both covered
        assert_eq!(chunk_len(7, 4, 8), 8); // one short chunk
        for (total, parts, align) in [(1usize, 1usize, 8usize), (100, 7, 8), (64, 9, 4)] {
            let per = chunk_len(total, parts, align);
            assert_eq!(per % align, 0);
            // walking in `per` steps covers every unit exactly once
            let mut covered = 0usize;
            let mut chunks = 0usize;
            while covered < total {
                covered += per.min(total - covered);
                chunks += 1;
            }
            assert_eq!(covered, total);
            assert!(chunks <= parts.max(1));
        }
    }

    #[test]
    fn scope_runs_borrowed_tasks_to_completion() {
        let pool = WorkerPool::new(3);
        let mut parts = vec![0u64; 8];
        pool.scope(|sc| {
            for (i, p) in parts.iter_mut().enumerate() {
                sc.spawn(move || *p = (i as u64 + 1) * 10);
            }
        });
        assert_eq!(parts.iter().sum::<u64>(), 360);
        assert!(pool.tasks_executed() >= 8);
        // a completed scope leaves no backlog behind
        assert_eq!(pool.queued_tasks(), 0);
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // more nested waits than workers: progress relies on waiters
        // helping with queued items
        let pool = WorkerPool::new(1);
        let total = AtomicUsize::new(0);
        pool.scope(|outer| {
            for _ in 0..4 {
                let (pool, total) = (&pool, &total);
                outer.spawn(move || {
                    pool.scope(|inner| {
                        for _ in 0..4 {
                            inner.spawn(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|sc| {
                sc.spawn(|| panic!("kernel shard exploded"));
                sc.spawn(|| {});
            });
        }));
        assert!(r.is_err(), "task panic must propagate out of scope");
        // the pool keeps working after a propagated panic
        let ran = AtomicUsize::new(0);
        pool.scope(|sc| {
            for _ in 0..4 {
                let ran = &ran;
                sc.spawn(move || {
                    ran.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(ran.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn states_are_reused_not_leaked() {
        let pool = WorkerPool::new(2);
        for _ in 0..10 {
            pool.with_state(5, |st| {
                assert!(st.bufs.len() >= 5);
                st.bufs[0].resize(16, 1.0);
            });
        }
        // serial checkouts always reuse the same parked state
        assert_eq!(pool.pooled_states(), 1);
        // a state checked out under a panic is still returned
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.with_state(5, |_| panic!("mid-task"));
        }));
        assert!(r.is_err());
        assert_eq!(pool.pooled_states(), 1);
    }
}
