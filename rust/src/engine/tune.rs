//! Per-machine MAC tiling autotuner.
//!
//! The tiled kernels in [`super::kernels::tile`] expose three geometry
//! knobs — the row-block height `mr`, the number of `NR`-wide column
//! panels swept per row block (`nr_panels`), and the k-dimension cache
//! block `kc`. The best setting depends on the host's cache hierarchy
//! and on the kernel shape, so instead of freezing one geometry at
//! compile time the engine consults a **tuning table**: a per-machine
//! JSON file mapping kernel shapes (`k`×`n`) to the measured-fastest
//! [`TilingScheme`].
//!
//! * `sira-finn tune [--quick]` measures the candidate grid on this
//!   machine and writes the table next to the perf-gate baseline
//!   (`target/SIRA_tuning.local.json`, override with `SIRA_TUNING_FILE`).
//! * Plan compilation ([`super::compile`]) and snapshot decode
//!   ([`super::snapshot::from_bytes`]) both resolve schemes against the
//!   *local* table at load time — machine-specific geometry is never
//!   baked into a plan sidecar.
//! * A missing table simply means the default scheme (the fixed
//!   `MR`×`NR` single-pass geometry) everywhere. A corrupt, truncated,
//!   or stale-version table is *ignored with a warning* — tuning is an
//!   optimization, never a correctness input, so a bad file must never
//!   fail a plan.
//! * The default scheme is always in the measured candidate set and the
//!   argmin includes it, so a tuned table is never slower than the
//!   fixed geometry it replaces (up to measurement noise).
//!
//! Correctness does not depend on the table at all: every candidate is
//! checked bit-exact against the scalar oracle during tuning, and at
//! run time a KC-blocked scheme only engages on steps whose SIRA bound
//! proves the reassociated partial sums cannot wrap (see
//! [`super::kernels::tile`] module docs).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::OnceLock;

use anyhow::{anyhow, Result};

use crate::bench::Bencher;
use crate::util::json::Json;

use super::kernels::tile::{self, PackedWeights};
use super::kernels::MacElem;

/// File-format discriminator and version for the tuning JSON.
pub const TUNING_KIND: &str = "sira-tiling";
pub const TUNING_VERSION: u64 = 1;

/// One tiled-MAC loop geometry: `mr` rows per register block,
/// `nr_panels` `NR`-wide column panels swept per row block, and the
/// k-dimension cache block `kc` (`0` = no k blocking, single pass).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilingScheme {
    pub mr: usize,
    pub nr_panels: usize,
    pub kc: usize,
}

impl Default for TilingScheme {
    /// The fixed geometry the kernels shipped with before tuning
    /// existed: `MR` rows, one panel at a time, no k blocking.
    fn default() -> Self {
        TilingScheme {
            mr: tile::MR,
            nr_panels: 1,
            kc: 0,
        }
    }
}

impl TilingScheme {
    /// Whether this scheme requires the KC-blocked kernel (any deviation
    /// from the default single-pass geometry). Default schemes run the
    /// original `mac_rows_tiled` path and need no overflow proof.
    pub fn is_blocked(&self) -> bool {
        *self != TilingScheme::default()
    }

    /// Reject geometries outside the range the kernels support, so a
    /// hand-edited tuning file cannot push the loop nest into a corner
    /// the dispatch clamps were never written for.
    pub fn sane(&self) -> bool {
        (1..=8).contains(&self.mr) && (1..=64).contains(&self.nr_panels) && self.kc <= (1 << 20)
    }

    fn to_json(self, ns: f64) -> Json {
        Json::obj(vec![
            ("mr", Json::Num(self.mr as f64)),
            ("nr_panels", Json::Num(self.nr_panels as f64)),
            ("kc", Json::Num(self.kc as f64)),
            ("ns", Json::Num(ns)),
        ])
    }
}

/// One tuned entry: the winning scheme and its measured time (kept for
/// the report; not consulted at plan compile).
#[derive(Clone, Copy, Debug)]
pub struct TuneEntry {
    pub scheme: TilingScheme,
    pub ns: f64,
}

/// The per-machine shape→scheme map.
#[derive(Clone, Debug, Default)]
pub struct TuningTable {
    pub entries: BTreeMap<String, TuneEntry>,
}

/// Key under which a MAC kernel shape is tuned: the effective dot
/// length `k` (after stuck-row elision) and the output width `n`.
pub fn shape_key(k: usize, n: usize) -> String {
    format!("k{k}n{n}")
}

impl TuningTable {
    /// Scheme for a kernel shape; default when the shape was never
    /// tuned on this machine.
    pub fn scheme_for(&self, k: usize, n: usize) -> TilingScheme {
        self.entries
            .get(&shape_key(k, n))
            .map(|e| e.scheme)
            .unwrap_or_default()
    }

    /// Serialize as the versioned tuning JSON document.
    pub fn to_json(&self) -> Json {
        let mut entries = BTreeMap::new();
        for (key, e) in &self.entries {
            entries.insert(key.clone(), e.scheme.to_json(e.ns));
        }
        Json::obj(vec![
            ("tuning", Json::Str(TUNING_KIND.to_string())),
            ("version", Json::Num(TUNING_VERSION as f64)),
            ("entries", Json::Obj(entries)),
        ])
    }

    /// Parse a tuning document, validating kind, version, and every
    /// scheme. Any malformed entry fails the whole parse — the caller
    /// ([`global`]) degrades to the default table with a warning.
    pub fn parse(text: &str) -> Result<TuningTable> {
        let doc = Json::parse(text)?;
        let kind = doc.get("tuning")?.as_str()?;
        if kind != TUNING_KIND {
            return Err(anyhow!("not a tuning file (kind '{kind}')"));
        }
        let version = doc.get("version")?.as_i64()?;
        if version != TUNING_VERSION as i64 {
            return Err(anyhow!(
                "tuning file version {version} != supported {TUNING_VERSION}"
            ));
        }
        let mut entries = BTreeMap::new();
        for (key, v) in doc.get("entries")?.as_obj()? {
            let scheme = TilingScheme {
                mr: v.get("mr")?.as_usize()?,
                nr_panels: v.get("nr_panels")?.as_usize()?,
                kc: v.get("kc")?.as_usize()?,
            };
            if !scheme.sane() {
                return Err(anyhow!("entry '{key}' has out-of-range scheme {scheme:?}"));
            }
            let ns = v.opt("ns").and_then(|n| n.as_f64().ok()).unwrap_or(0.0);
            entries.insert(key.clone(), TuneEntry { scheme, ns });
        }
        Ok(TuningTable { entries })
    }

    /// Load from a file. `Ok(None)` when the file does not exist (the
    /// untuned-machine case); `Err` on unreadable or invalid content.
    pub fn load(path: &std::path::Path) -> Result<Option<TuningTable>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        Ok(Some(TuningTable::parse(&text)?))
    }

    /// Write the table (atomic tmp + rename, like the snapshot sidecar).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{}\n", self.to_json()))?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

/// Where the per-machine tuning table lives: `SIRA_TUNING_FILE` if set,
/// else next to the perf-gate baseline under `target/`.
pub fn default_path() -> PathBuf {
    match std::env::var_os("SIRA_TUNING_FILE") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from("target/SIRA_tuning.local.json"),
    }
}

/// The process-wide tuning table, loaded once from [`default_path`].
/// Missing file → default table (silently). Invalid file → default
/// table with one warning on stderr; never an error, never a changed
/// result (schemes only steer loop order, which is proven
/// result-invariant before it is allowed to engage).
pub fn global() -> &'static TuningTable {
    static TABLE: OnceLock<TuningTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        let path = default_path();
        match TuningTable::load(&path) {
            Ok(Some(t)) => t,
            Ok(None) => TuningTable::default(),
            Err(e) => {
                eprintln!(
                    "warning: ignoring tuning file {}: {e}; using default tiling scheme",
                    path.display()
                );
                TuningTable::default()
            }
        }
    })
}

/// The candidate geometries measured per shape: the default (always —
/// this is what makes tuned tables never-slower), then the cross of
/// row-block heights, panel-group widths, and k blocks. Candidates
/// whose `kc` is at least the shape's `k` are skipped (blocking past
/// the whole dot length is the default single pass with extra spill
/// traffic).
fn candidate_schemes(k: usize) -> Vec<TilingScheme> {
    let mut out = vec![TilingScheme::default()];
    for mr in [4usize, 8] {
        for nr_panels in [1usize, 2, 4] {
            for kc in [0usize, 64, 256, 1024] {
                let s = TilingScheme { mr, nr_panels, kc };
                if kc > 0 && kc >= k {
                    continue;
                }
                if s != TilingScheme::default() && !out.contains(&s) {
                    out.push(s);
                }
            }
        }
    }
    out
}

/// The shapes tuned by default: the zoo's FC layers (784/256-deep),
/// its im2col conv frames, and the deep-K class the KC block targets.
pub fn default_shapes() -> Vec<(usize, usize)> {
    vec![
        (784, 256),
        (256, 256),
        (256, 10),
        (576, 64),
        (1152, 128),
        (4096, 256),
    ]
}

/// Measure one shape across the candidate grid and return the winner.
/// Every candidate is verified bit-exact against the scalar oracle on
/// the benchmark data before it is timed — a kernel that cannot
/// reproduce the scalar result is disqualified, not just slow.
fn tune_shape(b: &Bencher, k: usize, n: usize) -> TuneEntry {
    const ROWS: usize = 8;
    let mut seed = 0x70_17E5u64 ^ ((k as u64) << 20) ^ n as u64;
    let mut next = move || {
        // xorshift — deterministic synthetic int8-ish operands
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        (seed % 17) as i64 - 8
    };
    let a: Vec<i32> = (0..ROWS * k).map(|_| next() as i32).collect();
    let flat: Vec<i32> = (0..k * n).map(|_| next() as i32).collect();
    let packed = PackedWeights::pack(&flat, k, n);

    // scalar oracle for the correctness screen
    let mut want = vec![0i32; ROWS * n];
    for r in 0..ROWS {
        i32::mac_row(&a[r * k..(r + 1) * k], &flat, n, 0..n, &mut want[r * n..(r + 1) * n]);
    }

    let mut acc = vec![0i32; ROWS * n];
    let mut best: Option<TuneEntry> = None;
    for s in candidate_schemes(k) {
        acc.iter_mut().for_each(|v| *v = 0);
        if s.is_blocked() {
            tile::mac_rows_blocked(&a, ROWS, &packed, 0..n, s.mr, s.nr_panels, s.kc, &mut acc);
        } else {
            tile::mac_rows_tiled(&a, ROWS, &packed, 0..n, &mut acc);
        }
        if acc != want {
            eprintln!("tune: scheme {s:?} is not bit-exact on k{k}n{n}; disqualified");
            continue;
        }
        let r = b.run(
            &format!("tune k{k}n{n} mr{} np{} kc{}", s.mr, s.nr_panels, s.kc),
            || {
                acc.iter_mut().for_each(|v| *v = 0);
                if s.is_blocked() {
                    tile::mac_rows_blocked(
                        &a,
                        ROWS,
                        &packed,
                        0..n,
                        s.mr,
                        s.nr_panels,
                        s.kc,
                        &mut acc,
                    );
                } else {
                    tile::mac_rows_tiled(&a, ROWS, &packed, 0..n, &mut acc);
                }
                acc[0]
            },
        );
        let ns = r.mean.as_nanos() as f64;
        let better = match &best {
            None => true,
            Some(prev) => ns < prev.ns,
        };
        if better {
            best = Some(TuneEntry { scheme: s, ns });
        }
    }
    best.expect("default scheme always measures")
}

/// Tune the given shapes on this machine. `quick` trades measurement
/// time for noise (the verify-script smoke uses it); the full run is
/// what `sira-finn tune` ships by default.
pub fn tune(shapes: &[(usize, usize)], quick: bool) -> TuningTable {
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut table = TuningTable::default();
    for &(k, n) in shapes {
        let e = tune_shape(&b, k, n);
        println!(
            "tuned k{k}n{n}: mr={} nr_panels={} kc={} ({:.0} ns)",
            e.scheme.mr, e.scheme.nr_panels, e.scheme.kc, e.ns
        );
        table.entries.insert(shape_key(k, n), e);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scheme_is_not_blocked_and_sane() {
        let d = TilingScheme::default();
        assert!(!d.is_blocked());
        assert!(d.sane());
        assert!(TilingScheme { kc: 64, ..d }.is_blocked());
        assert!(!TilingScheme { mr: 0, ..d }.sane());
        assert!(!TilingScheme { nr_panels: 65, ..d }.sane());
    }

    #[test]
    fn json_roundtrip_preserves_entries() {
        let mut t = TuningTable::default();
        t.entries.insert(
            shape_key(784, 256),
            TuneEntry {
                scheme: TilingScheme {
                    mr: 8,
                    nr_panels: 2,
                    kc: 256,
                },
                ns: 1234.0,
            },
        );
        let text = t.to_json().to_string();
        let back = TuningTable::parse(&text).unwrap();
        assert_eq!(back.scheme_for(784, 256), t.scheme_for(784, 256));
        // untuned shape resolves to default
        assert_eq!(back.scheme_for(3, 3), TilingScheme::default());
    }

    #[test]
    fn parse_rejects_wrong_kind_version_and_insane_schemes() {
        assert!(TuningTable::parse("{").is_err());
        assert!(TuningTable::parse("{\"tuning\":\"other\",\"version\":1,\"entries\":{}}").is_err());
        assert!(
            TuningTable::parse("{\"tuning\":\"sira-tiling\",\"version\":99,\"entries\":{}}")
                .is_err()
        );
        assert!(TuningTable::parse(
            "{\"tuning\":\"sira-tiling\",\"version\":1,\
             \"entries\":{\"k4n4\":{\"mr\":0,\"nr_panels\":1,\"kc\":0}}}"
        )
        .is_err());
    }

    #[test]
    fn candidates_always_include_default_and_respect_k() {
        for k in [1usize, 63, 64, 256, 4096] {
            let cs = candidate_schemes(k);
            assert_eq!(cs[0], TilingScheme::default());
            for s in &cs {
                assert!(s.sane());
                assert!(s.kc == 0 || s.kc < k, "kc {} vs k {k}", s.kc);
            }
        }
    }

    #[test]
    fn quick_tune_on_tiny_shape_is_exact_and_never_slower_shaped() {
        // tiny shape so the test stays fast; correctness screen plus the
        // argmin-over-candidates-including-default property
        let b = Bencher {
            warmup: std::time::Duration::from_millis(1),
            measure: std::time::Duration::from_millis(2),
            max_iters: 64,
        };
        let e = super::tune_shape(&b, 96, 32);
        assert!(e.scheme.sane());
        assert!(e.ns > 0.0);
    }
}
