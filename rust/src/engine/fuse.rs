//! The plan compiler: lowers a graph plus its SIRA [`Analysis`] into a
//! flat [`Plan`] of fused kernels.
//!
//! Compile-time specialisation performed here, all driven by facts SIRA
//! proves (§4 of the paper):
//!
//! * **Constant folding** — any node whose inputs are all constants
//!   (weight quantizers above all) is evaluated once at compile time; the
//!   interpreter re-quantizes every weight tensor on every inference.
//! * **Elementwise chain fusion** — runs of single-consumer elementwise
//!   nodes (aggregated scales/biases of §4.1.2, quantizers, activations,
//!   batch-norm affines, thresholds) collapse into one per-element pass.
//! * **MAC + threshold fusion** — a MatMul/Conv whose only consumer is a
//!   MultiThreshold (§4.1.3) thresholds its accumulators directly,
//!   never materialising the wide intermediate.
//! * **Accumulator narrowing** — when SIRA proves MAC operands are pure
//!   integers ([`IntComponent::is_pure_integer`]) and a conservative
//!   worst-case partial-sum bound fits, the kernel runs on i32 (or i64)
//!   accumulators instead of f64 (§4.2; cf. the A2Q guaranteed-width
//!   argument).
//! * **Stuck-channel elision** (§7.1) — input channels SIRA proves stuck
//!   at a constant ([`crate::passes::stuck`]) are removed from integer
//!   MAC kernels entirely; their constant contribution is folded into a
//!   bias that seeds the accumulator. Integer accumulation is exact, so
//!   the elision is bit-invisible; f64 kernels are never elided (the
//!   fold would reorder float additions).
//! * **Movement elision** — contiguous Reshape/Flatten/Identity become
//!   buffer aliases; no copy.
//! * **Weight pre-packing** — every MAC weight matrix (elision-compacted
//!   form included) is additionally packed tile-major at compile time
//!   ([`MacMat::new`] → [`super::kernels::tile::PackedWeights`]) so the
//!   register-blocked kernels stream contiguous panels at run time; the
//!   extra copy is counted in `PlanStats::packed_weight_elems` (the
//!   packed-weights memory trade-off).
//!
//! Anything else falls back to a per-sample [`crate::executor`] call, so
//! every graph the interpreter runs, the plan runs — bit-exactly.
//!
//! [`IntComponent::is_pure_integer`]: crate::sira::IntComponent::is_pure_integer

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::executor::execute_op;
use crate::graph::{Graph, Node, Op, RoundMode};
use crate::passes::accmin::sira_int_bounds;
use crate::passes::stuck;
use crate::sira::{quant_bounds, Analysis};
use crate::tensor::{Conv2dSpec, PoolKind, Tensor};

use super::arena::{assign, StepUse};
use super::kernels::{MacMat, MicroOp, Param, ThresholdTable, WeightMat};
use super::plan::{
    BinKind, BinaryStep, ConvStep, DepthwiseStep, DwTaps, EwChainStep, GSrc, GenericStep,
    MacElide, MatMulStep, Plan, PlanStats, PoolStep, Step,
};
use super::tune::TilingScheme;

/// Conservative headroom limits for integer accumulation: the worst-case
/// partial-sum magnitude bound must stay below these for the narrowed
/// kernels to be selected. Shared with the plan runner, which re-checks
/// the recorded bound (`kc_bound`) against the accumulator width before
/// allowing the KC-blocked k-order onto a step.
pub(crate) const I32_LIMIT: f64 = 2_147_000_000.0;
pub(crate) const I64_LIMIT: f64 = 4.0e18;

/// A chosen-width weight matrix still in flat `(rows, n)` row-major
/// form, before the tile-major pre-pack. Elision compaction and bias
/// folding operate on this form; [`FlatMat::into_weight_mat`] performs
/// the (single) pack once the final matrix is settled, so elided steps
/// never pay for packing the full-size matrix they are about to discard.
enum FlatMat {
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl FlatMat {
    fn is_integer(&self) -> bool {
        !matches!(self, FlatMat::F64(_))
    }

    /// Pack the settled `(rows, n)` matrix into its dual-layout
    /// [`WeightMat`] form (this is the one pack per MAC step).
    fn into_weight_mat(self, rows: usize, n: usize) -> WeightMat {
        match self {
            FlatMat::F64(v) => WeightMat::F64(MacMat::new(v, rows, n)),
            FlatMat::I32(v) => WeightMat::I32(MacMat::new(v, rows, n)),
            FlatMat::I64(v) => WeightMat::I64(MacMat::new(v, rows, n)),
        }
    }
}

/// Split an integer `(k, n)` weight matrix into its live rows plus a
/// per-column bias folding the contribution of rows whose input is stuck
/// at a constant (`stuck[r] = Some(v)`). Returns None when nothing is
/// stuck, a stuck value is non-integral, or the matrix is f64 (elision
/// would reorder float additions; integer addition is order-free, and
/// the bias magnitude is covered by the same worst-case partial-sum
/// bound that selected the accumulator width).
fn elide_stuck_rows(
    wmat: &FlatMat,
    k: usize,
    n: usize,
    stuck: &[Option<f64>],
) -> Option<(FlatMat, Vec<usize>, Vec<i64>)> {
    if stuck.len() != k || stuck.iter().all(|s| s.is_none()) {
        return None;
    }
    if stuck
        .iter()
        .flatten()
        .any(|v| !v.is_finite() || v.fract() != 0.0)
    {
        return None;
    }
    fn split<T: Copy>(
        w: &[T],
        n: usize,
        stuck: &[Option<f64>],
        to_i64: impl Fn(T) -> i64,
    ) -> (Vec<T>, Vec<usize>, Vec<i64>) {
        let mut live = Vec::new();
        let mut compact = Vec::new();
        let mut bias = vec![0i64; n];
        for (r, s) in stuck.iter().enumerate() {
            let row = &w[r * n..(r + 1) * n];
            match s {
                None => {
                    live.push(r);
                    compact.extend_from_slice(row);
                }
                Some(v) => {
                    let v = *v as i64;
                    if v != 0 {
                        for (b, &wv) in bias.iter_mut().zip(row.iter()) {
                            *b += v * to_i64(wv);
                        }
                    }
                }
            }
        }
        (compact, live, bias)
    }
    match wmat {
        FlatMat::I32(w) => {
            let (c, live, bias) = split(w, n, stuck, |v| v as i64);
            Some((FlatMat::I32(c), live, bias))
        }
        FlatMat::I64(w) => {
            let (c, live, bias) = split(w, n, stuck, |v| v);
            Some((FlatMat::I64(c), live, bias))
        }
        FlatMat::F64(_) => None,
    }
}

/// Per-output-position accumulator bias for a *padded* elided conv
/// (ROADMAP §7.1 leftover): at output position `(oy, ox)` a stuck
/// channel contributes its value through exactly the kernel taps that
/// land in-bounds — out-of-bounds taps read the pad zero and contribute
/// nothing, which is why a single per-column bias is wrong at the
/// borders. Returns an `oh * ow * oc` position-major table whose row
/// `rp` seeds the accumulators at that position. Magnitudes stay inside
/// the accumulator-width bound: every row is a sub-sum of the worst-case
/// partial-sum estimate that selected the integer kernel. Only called
/// for integer matrices with integral stuck values (validated by
/// [`elide_stuck_rows`]).
fn conv_pos_bias(
    wmat: &FlatMat,
    ch_stuck: &[Option<f64>],
    spec: Conv2dSpec,
    h: usize,
    w: usize,
    oc: usize,
) -> Vec<i64> {
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, w);
    let at = |r: usize, j: usize| -> i64 {
        match wmat {
            FlatMat::I32(v) => v[r * oc + j] as i64,
            FlatMat::I64(v) => v[r * oc + j],
            FlatMat::F64(_) => unreachable!("elision is integer-only"),
        }
    };
    let mut bias = vec![0i64; oh * ow * oc];
    for (ch, s) in ch_stuck.iter().enumerate() {
        let Some(v) = *s else { continue };
        let v = v as i64;
        if v == 0 {
            continue;
        }
        for oy in 0..oh {
            for ox in 0..ow {
                let rp = oy * ow + ox;
                for ky in 0..kh {
                    for kx in 0..kw {
                        let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                        let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue;
                        }
                        let r = (ch * kh + ky) * kw + kx;
                        let row = &mut bias[rp * oc..(rp + 1) * oc];
                        for (j, b) in row.iter_mut().enumerate() {
                            *b += v * at(r, j);
                        }
                    }
                }
            }
        }
    }
    bias
}

/// Compile `g` (shapes inferred, per-sample tensors with leading dim 1)
/// and its SIRA `analysis` into an executable [`Plan`]. The analysis is
/// consulted opportunistically — missing or float-only ranges simply
/// disable the integer fast paths, never fail the compile.
pub fn compile(g: &Graph, analysis: &Analysis) -> Result<Plan> {
    if g.inputs.len() != 1 {
        bail!("engine: exactly one graph input required, got {}", g.inputs.len());
    }
    if g.outputs.len() != 1 {
        bail!("engine: exactly one graph output required, got {}", g.outputs.len());
    }
    let mut c = Compiler {
        g,
        analysis,
        consts: g.initializers.clone(),
        slot_of: BTreeMap::new(),
        slot_count: 0,
        steps: Vec::new(),
        stats: PlanStats::default(),
    };
    let input_name = g.inputs[0].clone();
    let input_slot = c.new_slot(&input_name)?;
    let order = g.topo_order()?;
    let mut consumed = vec![false; g.nodes.len()];

    for &ni in &order {
        if consumed[ni] {
            continue;
        }
        consumed[ni] = true;
        let node = g.nodes[ni].clone();

        // 1) whole node is constant: fold at compile time
        if node.inputs.iter().all(|i| c.consts.contains_key(i)) {
            let ins: Vec<Tensor> = node.inputs.iter().map(|i| c.consts[i].clone()).collect();
            let outs = execute_op(&node.op, &ins)
                .with_context(|| format!("constant-folding node '{}'", node.name))?;
            for (o, t) in node.outputs.iter().zip(outs) {
                c.consts.insert(o.clone(), t);
            }
            c.stats.folded_nodes += 1;
            continue;
        }

        // 2) contiguous data movement: alias the buffer, no step
        if matches!(node.op, Op::Reshape { .. } | Op::Flatten { .. } | Op::Identity)
            && node.outputs.len() == 1
            && !c.consts.contains_key(&node.inputs[0])
        {
            let src = &node.inputs[0];
            let dst = &node.outputs[0];
            let in_numel = c.sample_numel(src)?;
            let out_numel = c.sample_numel(dst)?;
            if in_numel == out_numel {
                let sid = c.slot_for_read(src)?;
                c.slot_of.insert(dst.clone(), sid);
                continue;
            }
            // numel change (cannot happen for these ops): fall through
        }

        // 3) fused elementwise chain
        if let Some((di, mut ops)) = c.node_micro_ops(&node)? {
            let start = node.inputs[di].clone();
            let in_slot = c.slot_for_read(&start)?;
            let numel = c.sample_numel(&start)?;
            let mut cur = ni;
            loop {
                let out_name = g.nodes[cur].outputs[0].clone();
                if g.outputs.iter().any(|o| *o == out_name) {
                    break;
                }
                let cons = g.consumers(&out_name);
                if cons.len() != 1 {
                    break;
                }
                let next = cons[0];
                match c.node_micro_ops(&g.nodes[next])? {
                    Some((ndi, nops)) if g.nodes[next].inputs[ndi] == out_name => {
                        ops.extend(nops);
                        consumed[next] = true;
                        cur = next;
                    }
                    _ => break,
                }
            }
            let end = g.nodes[cur].outputs[0].clone();
            let out_slot = c.new_slot(&end)?;
            c.stats.ew_chains += 1;
            c.stats.fused_micro_ops += ops.len();
            c.steps.push(Step::Ew(EwChainStep {
                input: in_slot,
                out: out_slot,
                numel,
                ops,
            }));
            continue;
        }

        // 4) MAC against constant weights
        if let Op::MatMul = node.op {
            if c.consts.contains_key(&node.inputs[1]) && !c.consts.contains_key(&node.inputs[0]) {
                let a_shape = c.sample_shape(&node.inputs[0])?.to_vec();
                let w = c.consts[&node.inputs[1]].clone();
                if a_shape.len() == 2 && w.rank() == 2 && w.shape()[0] == a_shape[1] {
                    c.emit_matmul(&node, &a_shape, &w, &mut consumed)?;
                    continue;
                }
            }
        }
        if let Op::Conv { spec, group } = &node.op {
            let (spec, group) = (*spec, *group);
            if c.consts.contains_key(&node.inputs[1]) && !c.consts.contains_key(&node.inputs[0]) {
                let x_shape = c.sample_shape(&node.inputs[0])?.to_vec();
                let w = c.consts[&node.inputs[1]].clone();
                if x_shape.len() == 4
                    && w.rank() == 4
                    && w.shape()[2] == spec.kernel.0
                    && w.shape()[3] == spec.kernel.1
                {
                    let ch = x_shape[1];
                    if group == 1 && w.shape()[1] == ch {
                        c.emit_conv(&node, &x_shape, &w, spec, &mut consumed)?;
                        continue;
                    }
                    if group == ch && w.shape()[1] == 1 && w.shape()[0] == ch {
                        c.emit_depthwise(&node, &x_shape, &w, spec, &mut consumed)?;
                        continue;
                    }
                }
            }
        }

        // 5) elementwise binary over two dynamic same-shape tensors
        if matches!(node.op, Op::Add | Op::Sub | Op::Mul | Op::Div)
            && node.inputs.len() == 2
            && !c.consts.contains_key(&node.inputs[0])
            && !c.consts.contains_key(&node.inputs[1])
            && c.sample_shape(&node.inputs[0])? == c.sample_shape(&node.inputs[1])?
        {
            let numel = c.sample_numel(&node.inputs[0])?;
            let a = c.slot_for_read(&node.inputs[0])?;
            let b = c.slot_for_read(&node.inputs[1])?;
            let out = c.new_slot(&node.outputs[0])?;
            let kind = match node.op {
                Op::Add => BinKind::Add,
                Op::Sub => BinKind::Sub,
                Op::Mul => BinKind::Mul,
                _ => BinKind::Div,
            };
            c.stats.binary += 1;
            c.steps.push(Step::Binary(BinaryStep {
                a,
                b,
                out,
                numel,
                kind,
            }));
            continue;
        }

        // 6) pooling
        let pool = match &node.op {
            Op::MaxPool { spec } => Some((PoolKind::Max, *spec)),
            Op::AveragePool { spec } => Some((PoolKind::Average, *spec)),
            Op::GlobalAveragePool => {
                let xs = c.sample_shape(&node.inputs[0])?;
                if xs.len() == 4 {
                    Some((
                        PoolKind::Average,
                        Conv2dSpec {
                            kernel: (xs[2], xs[3]),
                            stride: (1, 1),
                            pad: (0, 0),
                        },
                    ))
                } else {
                    None
                }
            }
            _ => None,
        };
        if let Some((kind, spec)) = pool {
            let xs = c.sample_shape(&node.inputs[0])?.to_vec();
            if xs.len() == 4 && !c.consts.contains_key(&node.inputs[0]) {
                let (oh, ow) = spec.out_hw(xs[2], xs[3]);
                let x = c.slot_for_read(&node.inputs[0])?;
                let out = c.new_slot(&node.outputs[0])?;
                c.stats.pool += 1;
                c.steps.push(Step::Pool(PoolStep {
                    x,
                    out,
                    kind,
                    c: xs[1],
                    h: xs[2],
                    w: xs[3],
                    oh,
                    ow,
                    spec,
                }));
                continue;
            }
        }

        // 7) fully general fallback: reference semantics per sample
        c.emit_generic(&node)?;
    }

    c.finish(&input_name, input_slot)
}

struct Compiler<'g> {
    g: &'g Graph,
    analysis: &'g Analysis,
    consts: BTreeMap<String, Tensor>,
    slot_of: BTreeMap<String, usize>,
    slot_count: usize,
    steps: Vec<Step>,
    stats: PlanStats,
}

impl<'g> Compiler<'g> {
    fn sample_shape(&self, name: &str) -> Result<&[usize]> {
        self.g
            .shapes
            .get(name)
            .map(|s| s.as_slice())
            .with_context(|| format!("engine: no shape for tensor '{name}' (run infer_shapes)"))
    }

    fn sample_numel(&self, name: &str) -> Result<usize> {
        Ok(self.sample_shape(name)?.iter().product())
    }

    fn slot_for_read(&self, name: &str) -> Result<usize> {
        self.slot_of
            .get(name)
            .copied()
            .with_context(|| format!("engine internal: tensor '{name}' has no slot"))
    }

    fn new_slot(&mut self, name: &str) -> Result<usize> {
        let shape = self.sample_shape(name)?;
        if shape.is_empty() || shape[0] != 1 {
            bail!(
                "engine: tensor '{name}' has shape {:?}; per-sample tensors must have a leading \
                 batch dim of 1",
                shape
            );
        }
        let id = self.slot_count;
        self.slot_count += 1;
        self.slot_of.insert(name.to_string(), id);
        Ok(id)
    }

    /// Broadcast-materialise a constant against a per-sample shape.
    fn param(&self, t: &Tensor, shape: &[usize]) -> Option<Param> {
        if t.numel() == 1 {
            return Some(Param::Scalar(t.first()));
        }
        let b = t.broadcast_to(shape).ok()?;
        Some(Param::PerElem(b.into_data()))
    }

    /// Sorted threshold table for `Op::MultiThreshold` over data of the
    /// given per-sample shape; None when the shapes are incompatible.
    fn threshold_table(
        &self,
        th: &Tensor,
        data_shape: &[usize],
        out_scale: f64,
        out_bias: f64,
    ) -> Option<ThresholdTable> {
        if th.rank() != 2 {
            return None;
        }
        let (c_th, n) = (th.shape()[0], th.shape()[1]);
        let channels = if data_shape.len() >= 2 { data_shape[1] } else { 1 };
        if c_th != 1 && c_th != channels {
            return None;
        }
        let ch_stride: usize = if data_shape.len() >= 2 {
            data_shape[2..].iter().product()
        } else {
            1
        };
        let mut rows = th.data().to_vec();
        if rows.iter().any(|v| v.is_nan()) {
            return None;
        }
        for ch in 0..c_th {
            rows[ch * n..(ch + 1) * n].sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        Some(ThresholdTable {
            rows,
            n,
            channels: c_th,
            ch_stride,
            out_scale,
            out_bias,
        })
    }

    /// Micro-op lowering for a chain-eligible node: returns the dynamic
    /// input index and the per-element op sequence, or None when the node
    /// is not elementwise-fusable.
    fn node_micro_ops(&self, node: &Node) -> Result<Option<(usize, Vec<MicroOp>)>> {
        if node.outputs.len() != 1 {
            return Ok(None);
        }
        let dyn_idx: Vec<usize> = (0..node.inputs.len())
            .filter(|&i| !self.consts.contains_key(&node.inputs[i]))
            .collect();
        if dyn_idx.len() != 1 {
            return Ok(None);
        }
        let di = dyn_idx[0];
        let in_shape = match self.g.shapes.get(&node.inputs[di]) {
            Some(s) => s.clone(),
            None => return Ok(None),
        };
        let out_shape = match self.g.shapes.get(&node.outputs[0]) {
            Some(s) => s.clone(),
            None => return Ok(None),
        };
        if in_shape != out_shape {
            return Ok(None); // shape-changing broadcast: not chain-fusable
        }
        let ops = match &node.op {
            Op::Relu => vec![MicroOp::Relu],
            Op::Sigmoid => vec![MicroOp::Sigmoid],
            Op::Floor => vec![MicroOp::Floor],
            Op::Identity => vec![],
            Op::Clip { lo, hi } => vec![MicroOp::Clip { lo: *lo, hi: *hi }],
            Op::Mul | Op::Add | Op::Sub | Op::Div => {
                if node.inputs.len() != 2 || di > 1 {
                    return Ok(None);
                }
                let ci = 1 - di;
                let Some(p) = self.param(&self.consts[&node.inputs[ci]], &out_shape) else {
                    return Ok(None);
                };
                let op = match (&node.op, di) {
                    (Op::Mul, _) => MicroOp::Mul(p),
                    (Op::Add, _) => MicroOp::Add(p),
                    (Op::Sub, 0) => MicroOp::Sub(p),
                    (Op::Sub, _) => MicroOp::Rsub(p),
                    (Op::Div, 0) => MicroOp::Div(p),
                    _ => MicroOp::Rdiv(p),
                };
                vec![op]
            }
            Op::Quant {
                signed,
                narrow,
                rounding,
            } => {
                if di != 0 || node.inputs.len() != 4 {
                    return Ok(None);
                }
                let (Some(s), Some(z), Some(b)) = (
                    self.consts.get(&node.inputs[1]),
                    self.consts.get(&node.inputs[2]),
                    self.consts.get(&node.inputs[3]),
                ) else {
                    return Ok(None);
                };
                let bits = b.first() as u32;
                let (qmin, qmax) = quant_bounds(bits, *signed, *narrow);
                let (Some(sp), Some(zp)) =
                    (self.param(s, &out_shape), self.param(z, &out_shape))
                else {
                    return Ok(None);
                };
                let round = match rounding {
                    RoundMode::RoundEven => MicroOp::RoundEven,
                    RoundMode::Floor => MicroOp::Floor,
                    RoundMode::Ceil => MicroOp::Ceil,
                };
                // y = s * (clip(round(x/s + z), qmin, qmax) - z), exactly
                // the executor's operation order
                vec![
                    MicroOp::Div(sp.clone()),
                    MicroOp::Add(zp.clone()),
                    round,
                    MicroOp::Clip { lo: qmin, hi: qmax },
                    MicroOp::Sub(zp),
                    MicroOp::Mul(sp),
                ]
            }
            Op::BatchNorm { eps } => {
                if di != 0 || node.inputs.len() != 5 {
                    return Ok(None);
                }
                let (Some(gamma), Some(beta), Some(mean), Some(var)) = (
                    self.consts.get(&node.inputs[1]),
                    self.consts.get(&node.inputs[2]),
                    self.consts.get(&node.inputs[3]),
                    self.consts.get(&node.inputs[4]),
                ) else {
                    return Ok(None);
                };
                // identical arithmetic to the executor's BatchNorm lowering
                let ch = gamma.numel();
                let eps = *eps;
                let a = gamma.zip(var, |g_, v| g_ / (v + eps).sqrt()).ok();
                let Some(a) = a else { return Ok(None) };
                let Some(b) = mean
                    .mul(&a)
                    .ok()
                    .and_then(|ma| beta.zip(&ma, |bt, m| bt - m).ok())
                else {
                    return Ok(None);
                };
                let pshape: Vec<usize> = if out_shape.len() == 4 {
                    vec![1, ch, 1, 1]
                } else {
                    vec![1, ch]
                };
                let (Ok(a), Ok(b)) = (a.reshape(&pshape), b.reshape(&pshape)) else {
                    return Ok(None);
                };
                let (Some(ap), Some(bp)) =
                    (self.param(&a, &out_shape), self.param(&b, &out_shape))
                else {
                    return Ok(None);
                };
                vec![MicroOp::Mul(ap), MicroOp::Add(bp)]
            }
            Op::MultiThreshold {
                out_scale,
                out_bias,
            } => {
                if di != 0 || node.inputs.len() != 2 {
                    return Ok(None);
                }
                let Some(th) = self.consts.get(&node.inputs[1]) else {
                    return Ok(None);
                };
                let Some(t) = self.threshold_table(th, &in_shape, *out_scale, *out_bias) else {
                    return Ok(None);
                };
                vec![MicroOp::Threshold(t)]
            }
            _ => return Ok(None),
        };
        Ok(Some((di, ops)))
    }

    /// If the single consumer of `out_name` is a fusable MultiThreshold,
    /// consume it and return its table plus the new output tensor.
    fn fusable_threshold(
        &self,
        out_name: &str,
        out_shape: &[usize],
        consumed: &mut [bool],
    ) -> Option<(ThresholdTable, String)> {
        if self.g.outputs.iter().any(|o| o == out_name) {
            return None;
        }
        let cons = self.g.consumers(out_name);
        if cons.len() != 1 {
            return None;
        }
        let mi = cons[0];
        let mnode = &self.g.nodes[mi];
        let (os, ob) = match &mnode.op {
            Op::MultiThreshold {
                out_scale,
                out_bias,
            } => (*out_scale, *out_bias),
            _ => return None,
        };
        if mnode.inputs.len() != 2
            || mnode.inputs[0] != out_name
            || mnode.outputs.len() != 1
        {
            return None;
        }
        let th = self.consts.get(&mnode.inputs[1])?;
        let table = self.threshold_table(th, out_shape, os, ob)?;
        consumed[mi] = true;
        Some((table, mnode.outputs[0].clone()))
    }

    /// Per-element |value| upper bound for a SIRA-proven pure-integer
    /// activation, broadcast to its per-sample shape.
    fn activation_amax(&self, name: &str, sample_shape: &[usize]) -> Option<Vec<f64>> {
        let r = self.analysis.get(name).ok()?;
        let ic = r.int.as_ref()?;
        if !ic.is_pure_integer() {
            return None;
        }
        let lo = ic.lo.broadcast_to(sample_shape).ok()?;
        let hi = ic.hi.broadcast_to(sample_shape).ok()?;
        let v: Vec<f64> = lo
            .data()
            .iter()
            .zip(hi.data())
            .map(|(&l, &h)| l.abs().max(h.abs()))
            .collect();
        if v.iter().all(|x| x.is_finite()) {
            Some(v)
        } else {
            None
        }
    }

    /// Pick the weight representation: integer (i32/i64 accumulators)
    /// when SIRA proves the operands integer and the worst-case
    /// partial-sum magnitude `max_j Σ_k amax_k*|w_kj|` fits; f64
    /// otherwise. `wdata` is `(k, n)` row-major. Returns the flat form —
    /// the tile-major pack happens once, after elision settles the final
    /// matrix ([`FlatMat::into_weight_mat`]) — plus the proven `peak`
    /// bound (`0.0` for the f64 fallback, where no bound was proven).
    ///
    /// The bound doubles as the KC-blocking proof recorded on the step
    /// (`kc_bound`): under k blocking every intermediate is either a
    /// zero-seeded chunk partial (`|·| ≤ Σ_chunk amax·|w|`) or the bias
    /// seed plus a prefix of whole chunks — both bounded by `peak`, so
    /// `peak` under the width limit means no intermediate wraps, integer
    /// addition stays associative, and the reordered sum is
    /// bit-identical to the single-pass one. Elision only shrinks the
    /// row set the bound sums over, so the pre-elision `peak` remains an
    /// upper bound for the compacted kernel (bias included).
    fn choose_weight_mat(
        &self,
        out_name: &str,
        amax_per_k: Option<Vec<f64>>,
        wdata: &[f64],
        k: usize,
        n: usize,
    ) -> (FlatMat, f64) {
        let fallback = || (FlatMat::F64(wdata.to_vec()), 0.0);
        // cheap reject via the shared SIRA metadata: no integer output
        // interval means the operands cannot both be pure integers
        if sira_int_bounds(self.analysis, out_name).is_none() {
            return fallback();
        }
        let Some(amax) = amax_per_k else {
            return fallback();
        };
        if amax.len() != k || !wdata.iter().all(|v| v.fract() == 0.0 && v.is_finite()) {
            return fallback();
        }
        let mut worst = 0.0f64;
        for j in 0..n {
            let mut s = 0.0;
            for (kk, &a) in amax.iter().enumerate() {
                s += a * wdata[kk * n + j].abs();
            }
            worst = worst.max(s);
        }
        let amax_all = amax.iter().cloned().fold(0.0f64, f64::max);
        let wmax = wdata.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        let peak = worst.max(amax_all).max(wmax);
        if peak < I32_LIMIT {
            (FlatMat::I32(wdata.iter().map(|&v| v as i32).collect()), peak)
        } else if peak < I64_LIMIT {
            (FlatMat::I64(wdata.iter().map(|&v| v as i64).collect()), peak)
        } else {
            fallback()
        }
    }

    fn emit_matmul(
        &mut self,
        node: &Node,
        a_shape: &[usize],
        w: &Tensor,
        consumed: &mut [bool],
    ) -> Result<()> {
        let (m, k) = (a_shape[0], a_shape[1]);
        let n = w.shape()[1];
        let amax = self.activation_amax(&node.inputs[0], a_shape).map(|full| {
            // per-k max over the m rows
            let mut per_k = vec![0.0f64; k];
            for r in 0..m {
                for kk in 0..k {
                    per_k[kk] = per_k[kk].max(full[r * k + kk]);
                }
            }
            per_k
        });
        let out_name = node.outputs[0].clone();
        let (mut flat, kc_bound) = self.choose_weight_mat(&out_name, amax, w.data(), k, n);
        // §7.1 stuck-channel elision: input positions proven constant
        // never enter the MAC; their contribution seeds the accumulator.
        // m == 1 keeps the per-row gather trivial (all zoo layers).
        let mut elide = None;
        let mut k_rows = k;
        if flat.is_integer() && m == 1 {
            if let Ok(stuck) = stuck::stuck_elements(self.analysis, &node.inputs[0], a_shape) {
                if let Some((compact, live, bias)) = elide_stuck_rows(&flat, k, n, &stuck) {
                    self.stats.elided_mac_steps += 1;
                    self.stats.elided_mac_channels += k - live.len();
                    k_rows = live.len();
                    flat = compact;
                    elide = Some(MacElide {
                        live,
                        bias,
                        pos_stride: 0,
                    });
                }
            }
        }
        // single tile-major pack, after elision settled the matrix
        let wmat = flat.into_weight_mat(k_rows, n);
        let out_shape = self.sample_shape(&out_name)?.to_vec();
        let fused = self.fusable_threshold(&out_name, &out_shape, consumed);
        let (table, final_out) = match fused {
            Some((t, mt_out)) => (Some(t), mt_out),
            None => (None, out_name),
        };
        match &wmat {
            WeightMat::F64(_) => self.stats.matmul_f64 += 1,
            WeightMat::I32(_) => self.stats.matmul_i32 += 1,
            WeightMat::I64(_) => self.stats.matmul_i64 += 1,
        }
        self.stats.packed_weight_elems += wmat.packed_elems();
        self.stats.flat_weight_elems += wmat.flat_elems();
        if table.is_some() {
            self.stats.fused_thresholds += 1;
        }
        let a = self.slot_for_read(&node.inputs[0])?;
        let out = self.new_slot(&final_out)?;
        self.steps.push(Step::MatMul(MatMulStep {
            a,
            out,
            m,
            k,
            n,
            w: wmat,
            fused: table,
            elide,
            kc_bound,
            scheme: TilingScheme::default(),
        }));
        Ok(())
    }

    fn emit_conv(
        &mut self,
        node: &Node,
        x_shape: &[usize],
        w: &Tensor,
        spec: Conv2dSpec,
        consumed: &mut [bool],
    ) -> Result<()> {
        let (ch, h, wd) = (x_shape[1], x_shape[2], x_shape[3]);
        let (kh, kw) = spec.kernel;
        let oc = w.shape()[0];
        let k = ch * kh * kw;
        let (oh, ow) = spec.out_hw(h, wd);
        // (oc, c*kh*kw) -> transpose -> (k, oc), exactly the executor's
        // weight lowering
        let wmat_t = w.reshape(&[oc, k])?.t()?;
        let amax = self.activation_amax(&node.inputs[0], x_shape).map(|full| {
            // per-channel max over spatial positions, expanded to im2col k
            let mut chmax = vec![0.0f64; ch];
            for (i, &v) in full.iter().enumerate() {
                chmax[i / (h * wd)] = chmax[i / (h * wd)].max(v);
            }
            (0..k).map(|kk| chmax[kk / (kh * kw)]).collect::<Vec<f64>>()
        });
        let out_name = node.outputs[0].clone();
        let (mut flat, kc_bound) = self.choose_weight_mat(&out_name, amax, wmat_t.data(), k, oc);
        // §7.1 stuck-channel elision: a channel whose every spatial
        // element is stuck at one value leaves the im2col + MAC entirely.
        // With pad 0 the contribution is the same at every output
        // position (one bias per output column); with padding, border
        // taps read the pad zero instead of the stuck value, so the
        // pad/stuck interaction folds into per-output-position biases.
        let mut elide = None;
        let mut k_rows = k;
        if flat.is_integer() {
            if let Ok(stuck) = stuck::stuck_elements(self.analysis, &node.inputs[0], x_shape) {
                let hw = h * wd;
                let ch_stuck: Vec<Option<f64>> = (0..ch)
                    .map(|c| match stuck[c * hw] {
                        Some(v) if stuck[c * hw..(c + 1) * hw].iter().all(|&e| e == Some(v)) => {
                            Some(v)
                        }
                        _ => None,
                    })
                    .collect();
                let per_ch = kh * kw;
                let stuck_rows: Vec<Option<f64>> = (0..k).map(|r| ch_stuck[r / per_ch]).collect();
                let elided = elide_stuck_rows(&flat, k, oc, &stuck_rows);
                if let Some((compact, live_rows, col_bias)) = elided {
                    let live: Vec<usize> = (0..ch).filter(|&c| ch_stuck[c].is_none()).collect();
                    let (bias, pos_stride) = if spec.pad == (0, 0) {
                        (col_bias, 0)
                    } else {
                        self.stats.elided_padded_convs += 1;
                        (conv_pos_bias(&flat, &ch_stuck, spec, h, wd, oc), oc)
                    };
                    self.stats.elided_mac_steps += 1;
                    self.stats.elided_mac_channels += ch - live.len();
                    k_rows = live_rows.len();
                    flat = compact;
                    elide = Some(MacElide {
                        live,
                        bias,
                        pos_stride,
                    });
                }
            }
        }
        // single tile-major pack, after elision settled the matrix
        let wmat = flat.into_weight_mat(k_rows, oc);
        let out_shape = self.sample_shape(&out_name)?.to_vec();
        let fused = self.fusable_threshold(&out_name, &out_shape, consumed);
        let (table, final_out) = match fused {
            Some((t, mt_out)) => (Some(t), mt_out),
            None => (None, out_name),
        };
        match &wmat {
            WeightMat::F64(_) => self.stats.conv_f64 += 1,
            WeightMat::I32(_) => self.stats.conv_i32 += 1,
            WeightMat::I64(_) => self.stats.conv_i64 += 1,
        }
        self.stats.packed_weight_elems += wmat.packed_elems();
        self.stats.flat_weight_elems += wmat.flat_elems();
        if table.is_some() {
            self.stats.fused_thresholds += 1;
        }
        let x = self.slot_for_read(&node.inputs[0])?;
        let out = self.new_slot(&final_out)?;
        self.steps.push(Step::Conv(ConvStep {
            x,
            out,
            c: ch,
            h,
            w: wd,
            oc,
            oh,
            ow,
            spec,
            wmat,
            fused: table,
            elide,
            kc_bound,
            scheme: TilingScheme::default(),
        }));
        Ok(())
    }

    fn emit_depthwise(
        &mut self,
        node: &Node,
        x_shape: &[usize],
        w: &Tensor,
        spec: Conv2dSpec,
        consumed: &mut [bool],
    ) -> Result<()> {
        let (ch, h, wd) = (x_shape[1], x_shape[2], x_shape[3]);
        let (kh, kw) = spec.kernel;
        let (oh, ow) = spec.out_hw(h, wd);
        let out_name = node.outputs[0].clone();
        let out_shape = self.sample_shape(&out_name)?.to_vec();
        let fused = self.fusable_threshold(&out_name, &out_shape, consumed);
        let (table, final_out) = match fused {
            Some((t, mt_out)) => (Some(t), mt_out),
            None => (None, out_name.clone()),
        };
        let weights = w.data().to_vec();

        // Per-channel SIRA bound — the depthwise analogue of
        // choose_weight_mat's `peak`: output channel `c` only ever sums
        // its own channel's taps, so `worst = max_c amax_c * Σ_taps|w_c|`
        // bounds every (prefix of the) per-element accumulation. The
        // row-sweep kernel applies taps in the exact scalar order, so
        // the bound gates accumulator *width* only, not a reorder.
        let mut kc_bound = 0.0f64;
        if sira_int_bounds(self.analysis, &out_name).is_some()
            && weights.iter().all(|v| v.fract() == 0.0 && v.is_finite())
        {
            if let Some(full) = self.activation_amax(&node.inputs[0], x_shape) {
                let hw = h * wd;
                let mut chmax = vec![0.0f64; ch];
                for (i, &v) in full.iter().enumerate() {
                    chmax[i / hw] = chmax[i / hw].max(v);
                }
                let per_ch = kh * kw;
                let mut worst = 0.0f64;
                for (c, &cm) in chmax.iter().enumerate() {
                    let wsum: f64 =
                        weights[c * per_ch..(c + 1) * per_ch].iter().map(|t| t.abs()).sum();
                    worst = worst.max(cm * wsum);
                }
                let amax_all = chmax.iter().cloned().fold(0.0f64, f64::max);
                let wmax = weights.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
                kc_bound = worst.max(amax_all).max(wmax);
            }
        }
        let taps = if kc_bound > 0.0 && kc_bound < I32_LIMIT {
            DwTaps::I32(weights.iter().map(|&v| v as i32).collect())
        } else if kc_bound > 0.0 && kc_bound < I64_LIMIT {
            DwTaps::I64(weights.iter().map(|&v| v as i64).collect())
        } else {
            kc_bound = 0.0;
            DwTaps::F64
        };

        // §7.1 stuck-channel elision, depthwise form: a channel whose
        // every input element is stuck contributes a compile-time
        // constant output plane. The plane is precomputed with the exact
        // scalar f64 tap order (pad taps skipped) and finished through
        // the fused threshold — so the run-time copy is bit-identical to
        // recomputing, on every accumulator width, which is why (unlike
        // the matmul/conv form) this needs no integrality restriction.
        let mut elided: Vec<(usize, Vec<f64>)> = Vec::new();
        if let Ok(stuck) = stuck::stuck_elements(self.analysis, &node.inputs[0], x_shape) {
            let hw = h * wd;
            for c in 0..ch {
                let v0 = match stuck[c * hw] {
                    Some(v) if stuck[c * hw..(c + 1) * hw].iter().all(|&e| e == Some(v)) => v,
                    _ => continue,
                };
                let mut plane = vec![0.0f64; oh * ow];
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0f64;
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                                let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                    continue;
                                }
                                acc += v0 * weights[(c * kh + ky) * kw + kx];
                            }
                        }
                        plane[oy * ow + ox] = match &table {
                            Some(t) => t.apply_channel(acc, c),
                            None => acc,
                        };
                    }
                }
                elided.push((c, plane));
            }
            if !elided.is_empty() {
                self.stats.elided_mac_steps += 1;
                self.stats.elided_mac_channels += elided.len();
            }
        }

        self.stats.depthwise += 1;
        if table.is_some() {
            self.stats.fused_thresholds += 1;
        }
        let x = self.slot_for_read(&node.inputs[0])?;
        let out = self.new_slot(&final_out)?;
        self.steps.push(Step::Depthwise(DepthwiseStep {
            x,
            out,
            c: ch,
            h,
            w: wd,
            oh,
            ow,
            spec,
            weights,
            fused: table,
            taps,
            kc_bound,
            elided,
        }));
        Ok(())
    }

    fn emit_generic(&mut self, node: &Node) -> Result<()> {
        if node.outputs.len() != 1 {
            bail!(
                "engine: multi-output node '{}' ({}) is unsupported",
                node.name,
                node.op.name()
            );
        }
        let mut ins = Vec::with_capacity(node.inputs.len());
        for i in &node.inputs {
            if let Some(t) = self.consts.get(i) {
                ins.push(GSrc::Const(t.clone()));
            } else {
                let shape = self.sample_shape(i)?.to_vec();
                ins.push(GSrc::Slot(self.slot_for_read(i)?, shape));
            }
        }
        let out_shape = self.sample_shape(&node.outputs[0])?.to_vec();
        let out_numel = out_shape.iter().product();
        let out = self.new_slot(&node.outputs[0])?;
        self.stats.generic += 1;
        self.steps.push(Step::Generic(GenericStep {
            op: node.op.clone(),
            ins,
            out,
            out_shape,
            out_numel,
        }));
        Ok(())
    }

    fn finish(mut self, input_name: &str, input_slot: usize) -> Result<Plan> {
        let out_name = self.g.outputs[0].clone();
        let input_shape = self.sample_shape(input_name)?.to_vec();

        if let Some(t) = self.consts.get(&out_name) {
            // degenerate: the whole graph constant-folded
            return Ok(Plan::new(
                self.g.name.clone(),
                Vec::new(),
                1,
                0,
                input_shape,
                0,
                t.shape().to_vec(),
                t.numel(),
                Some(t.clone()),
                self.stats,
            ));
        }
        let output_shape = self.sample_shape(&out_name)?.to_vec();
        let output_numel: usize = output_shape.iter().product();

        let out_slot = self.slot_for_read(&out_name)?;
        let uses: Vec<StepUse> = self
            .steps
            .iter()
            .map(|s| StepUse {
                reads: s.reads(),
                writes: s.writes(),
            })
            .collect();
        let layout = assign(self.slot_count, &uses, &[input_slot, out_slot]);
        for step in &mut self.steps {
            step.remap(&layout.phys);
        }
        self.stats.steps = self.steps.len();
        self.stats.logical_slots = self.slot_count;
        self.stats.physical_buffers = layout.n_phys;
        Ok(Plan::new(
            self.g.name.clone(),
            self.steps,
            layout.n_phys,
            layout.phys[input_slot],
            input_shape,
            layout.phys[out_slot],
            output_shape,
            output_numel,
            None,
            self.stats,
        ))
    }
}
