//! Plan segmentation for pipeline-parallel serving: split a compiled
//! [`Plan`]'s step list into consecutive **segments** at boundaries where
//! the set of live buffers — the data one pipeline stage must hand the
//! next — is minimal, so the coordinator can run batch *k+1* through
//! segment 0 while batch *k* runs segment 1 (FINN-R's per-layer stream
//! overlap, lifted to the plan level).
//!
//! # Boundary analysis
//!
//! A boundary sits *between* two steps, so no kernel is ever split and
//! segmented execution is bit-exact by construction: each segment runs
//! the same [`Step`]s on the same physical buffers as the monolithic
//! runner. For every candidate boundary the analysis computes the live
//! set — buffers written before the cut and read at-or-after it
//! (including the packed input and the plan output) — and its per-sample
//! element count. Cuts are chosen to balance per-segment MAC/elementwise
//! work (pipeline throughput is set by the slowest stage) and, within a
//! half-segment tolerance of the balanced point, to minimise the carry
//! cost.
//!
//! # Stage hand-off
//!
//! Pipeline stages own private worker states; between stages only the
//! carry buffers move (`take_carry` / `put_carry`, a `Vec` move per
//! buffer — no copies). Every other buffer a segment touches is fully
//! overwritten before it is read (the arena invariant), so stale
//! contents from a previous batch in a stage-owned state are
//! unobservable — this is the same argument that lets pooled worker
//! states be shared across plans.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::tensor::Tensor;

use super::plan::{ExecCtx, Plan, Step};
use super::pool::WorkerState;

/// A plan split into pipeline segments. Construct with
/// [`SegmentedPlan::new`]; serve with
/// [`crate::coordinator::Coordinator::start_pipelined`] or run inline
/// with [`SegmentedPlan::run_batch`] (bit-identical to
/// [`Plan::run_batch`]).
pub struct SegmentedPlan {
    plan: Plan,
    /// Ascending cut step indices; segment `s` runs steps
    /// `[bounds[s-1], bounds[s])` (with virtual bounds 0 and `n`).
    bounds: Vec<usize>,
    /// `carries[i]`: physical buffers live across `bounds[i]`, ascending.
    carries: Vec<Vec<usize>>,
}

/// For every candidate boundary `i` in `1..n` (index `i - 1` in the
/// returned vec): the buffers live across it and their summed per-sample
/// element count.
fn boundary_liveness(plan: &Plan) -> Vec<(Vec<usize>, u64)> {
    let n = plan.steps.len();
    if n < 2 {
        return Vec::new();
    }
    // write times: the input pack is step -1, step j is j; a write at
    // step w supplying a read at step r is live across boundaries i with
    // w < i <= r (boundaries are 1..=n-1; the plan output is read at n)
    fn mark(live: &mut [BTreeMap<usize, usize>], w: isize, r: usize, p: usize, e: usize) {
        let n_bounds = live.len();
        let lo = (w + 1).max(1) as usize;
        let hi = r.min(n_bounds);
        for i in lo..=hi {
            live[i - 1].insert(p, e);
        }
    }
    let mut last_write: Vec<Option<(isize, usize)>> = vec![None; plan.n_phys];
    last_write[plan.input_phys] = Some((-1, plan.input_numel));
    let mut live: Vec<BTreeMap<usize, usize>> = vec![BTreeMap::new(); n - 1];
    for (j, step) in plan.steps.iter().enumerate() {
        for p in step.reads() {
            if let Some((w, e)) = last_write[p] {
                mark(&mut live, w, j, p, e);
            }
        }
        for p in step.writes() {
            last_write[p] = Some((j as isize, step.out_numel()));
        }
    }
    if let Some((w, e)) = last_write[plan.output_phys] {
        mark(&mut live, w, n, plan.output_phys, e);
    }
    live.into_iter()
        .map(|m| {
            let cost = m.values().map(|&e| e as u64).sum();
            (m.into_keys().collect(), cost)
        })
        .collect()
}

/// Pick `want - 1` ascending cut indices over `n = work.len()` steps:
/// for each cut, candidates within a half-segment of the work-balanced
/// point compete on carry cost; outside the window, on balance alone.
fn choose_bounds(work: &[u64], carry_cost: &[u64], want: usize) -> Vec<usize> {
    let n = work.len();
    let total: u64 = work.iter().sum();
    let mut cum = vec![0u64; n + 1];
    for (j, w) in work.iter().enumerate() {
        cum[j + 1] = cum[j] + w;
    }
    let window = (total / (2 * want as u64)).max(1);
    let mut bounds = Vec::with_capacity(want - 1);
    let mut prev = 0usize;
    for k in 1..want {
        let lo = prev + 1;
        let hi = n - (want - k); // leave >= 1 step per remaining segment
        if lo > hi {
            break;
        }
        let ideal = total * k as u64 / want as u64;
        let mut best: Option<(u64, u64, u64, usize)> = None;
        for i in lo..=hi {
            let dev = cum[i].abs_diff(ideal);
            let in_window = dev <= window;
            let cand = (
                u64::from(!in_window),
                if in_window { carry_cost[i - 1] } else { dev },
                dev,
            );
            let better = match best {
                None => true,
                Some((f, key, d, _)) => cand < (f, key, d),
            };
            if better {
                best = Some((cand.0, cand.1, cand.2, i));
            }
        }
        let (_, _, _, cut) = best.expect("non-empty candidate range");
        bounds.push(cut);
        prev = cut;
    }
    bounds
}

impl SegmentedPlan {
    /// Split `plan` into up to `segments` pipeline segments (clamped to
    /// the step count; degenerate plans stay single-segment). The plan's
    /// thread budget and `min_kernel_work` gate keep applying *within*
    /// each segment (intra-kernel sharding through the shared pool);
    /// sample sharding is left to the pipeline, which overlaps whole
    /// batches instead.
    pub fn new(plan: Plan, segments: usize) -> SegmentedPlan {
        let n = plan.steps.len();
        let want = segments.max(1).min(n.max(1));
        if want <= 1 || plan.const_output.is_some() {
            return SegmentedPlan {
                plan,
                bounds: Vec::new(),
                carries: Vec::new(),
            };
        }
        let livec = boundary_liveness(&plan);
        let carry_cost: Vec<u64> = livec.iter().map(|(_, c)| *c).collect();
        let work: Vec<u64> = plan.steps.iter().map(Step::work).collect();
        let bounds = choose_bounds(&work, &carry_cost, want);
        let carries = bounds.iter().map(|&i| livec[i - 1].0.clone()).collect();
        SegmentedPlan {
            plan,
            bounds,
            carries,
        }
    }

    /// Number of segments (1 when the plan was too small to cut).
    pub fn segments(&self) -> usize {
        self.bounds.len() + 1
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn into_plan(self) -> Plan {
        self.plan
    }

    /// Carried-buffer count per cut (the minimality observable).
    pub fn carry_counts(&self) -> Vec<usize> {
        self.carries.iter().map(Vec::len).collect()
    }

    /// Human-readable summary for serve banners.
    pub fn describe(&self) -> String {
        format!(
            "{} segment(s) over {} steps, cuts {:?}, carry buffers {:?}",
            self.segments(),
            self.plan.steps.len(),
            self.bounds,
            self.carry_counts(),
        )
    }

    fn seg_range(&self, s: usize) -> core::ops::Range<usize> {
        let start = if s == 0 { 0 } else { self.bounds[s - 1] };
        let end = if s + 1 == self.segments() {
            self.plan.steps.len()
        } else {
            self.bounds[s]
        };
        start..end
    }

    /// Validate and pack a batch into `ws` (stage 0 of the pipeline).
    pub(crate) fn pack(&self, ws: &mut WorkerState, inputs: &[Tensor]) -> Result<()> {
        self.plan.validate(inputs)?;
        ws.ensure(self.plan.n_phys);
        self.plan.view().pack(ws, inputs);
        Ok(())
    }

    /// Run one segment over the `b`-sample batch resident in `ws`.
    pub(crate) fn run_segment(&self, s: usize, ws: &mut WorkerState, b: usize) -> Result<()> {
        ws.ensure(self.plan.n_phys);
        let ctx = ExecCtx {
            pool: self.plan.pool.as_deref(),
            kt: self.plan.threads,
            min_work: self.plan.min_kernel_work,
            min_tile: self.plan.min_tile_work,
            prof: self.plan.prof.as_deref(),
        };
        self.plan.view().run_steps(ws, b, self.seg_range(s), &ctx)
    }

    /// Extract the batch outputs after the final segment.
    pub(crate) fn extract(&self, ws: &WorkerState, b: usize) -> Result<Vec<Tensor>> {
        self.plan.view().extract(ws, b)
    }

    /// Move the buffers live across cut `bound` out of `ws` (sender
    /// side of the stage hand-off).
    pub(crate) fn take_carry(&self, bound: usize, ws: &mut WorkerState) -> Vec<Vec<f64>> {
        self.carries[bound]
            .iter()
            .map(|&p| std::mem::take(&mut ws.bufs[p]))
            .collect()
    }

    /// Install carried buffers into the next stage's state (receiver
    /// side; order matches [`SegmentedPlan::take_carry`]). Returns the
    /// displaced buffers (same slots, previous batch's allocations) so
    /// the coordinator can recycle them back to the sender — steady-state
    /// pipelining then moves carries without ever allocating.
    #[must_use = "displaced buffers should be recycled to the sender (or explicitly dropped)"]
    pub(crate) fn put_carry(
        &self,
        bound: usize,
        ws: &mut WorkerState,
        bufs: Vec<Vec<f64>>,
    ) -> Vec<Vec<f64>> {
        ws.ensure(self.plan.n_phys);
        self.carries[bound]
            .iter()
            .zip(bufs)
            .map(|(&p, v)| std::mem::replace(&mut ws.bufs[p], v))
            .collect()
    }

    /// Re-install recycled buffers into the sender's state (the reverse
    /// hop of the carry loop). Capacity is what matters — the next
    /// `run_segment` overwrites contents — so this is best-effort: any
    /// shape mismatch is simply absorbed by `ensure`/`resize` later.
    pub(crate) fn restore_carry(&self, bound: usize, ws: &mut WorkerState, bufs: Vec<Vec<f64>>) {
        ws.ensure(self.plan.n_phys);
        for (&p, v) in self.carries[bound].iter().zip(bufs) {
            // only fill empty slots: take_carry left them empty, and a
            // non-empty slot means the stage already re-allocated
            if ws.bufs[p].is_empty() {
                ws.bufs[p] = v;
            }
        }
    }

    /// Whether the compile-time degenerate constant-output path applies.
    pub(crate) fn const_output(&self) -> Option<&Tensor> {
        self.plan.const_output.as_ref()
    }

    /// Run a batch through every segment in order on one state —
    /// bit-identical to [`Plan::run_batch`] (same steps, same buffers),
    /// used by tests and non-pipelined callers.
    pub fn run_batch(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.plan.validate(inputs)?;
        let b = inputs.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        if let Some(t) = &self.plan.const_output {
            return Ok(vec![t.clone(); b]);
        }
        let mut ws = std::mem::take(&mut self.plan.serial);
        ws.ensure(self.plan.n_phys);
        self.plan.view().pack(&mut ws, inputs);
        let mut run = Ok(());
        for s in 0..self.segments() {
            run = self.run_segment(s, &mut ws, b);
            if run.is_err() {
                break;
            }
        }
        let out = match run {
            Ok(()) => self.extract(&ws, b),
            Err(e) => Err(e),
        };
        self.plan.serial = ws;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::compile;
    use crate::models::{Granularity, QnnBuilder};
    use crate::sira::analyze;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap as Map;

    fn deep_mlp() -> (crate::graph::Graph, Map<String, crate::sira::SiRange>) {
        let mut b = QnnBuilder::new("seg", 7);
        b.input("x", &[1, 12]);
        for _ in 0..4 {
            b.quant_act(8, false, Granularity::PerTensor, 255.0);
            b.linear(10, 3, Granularity::PerTensor, true);
            b.relu();
        }
        b.linear(4, 4, Granularity::PerTensor, true);
        let g = b.finish().unwrap();
        let mut inputs = Map::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        (g, inputs)
    }

    fn batch(shape: &[usize], n: usize, seed: u64) -> Vec<Tensor> {
        let numel: usize = shape.iter().product();
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                Tensor::new(shape, (0..numel).map(|_| rng.int_in(0, 255) as f64).collect())
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn segments_cover_all_steps_in_order() {
        let (g, inputs) = deep_mlp();
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        let n = plan.stats().steps;
        let sp = SegmentedPlan::new(plan, 3);
        assert!(sp.segments() >= 2, "deep chain should split: {}", sp.describe());
        let mut covered = 0usize;
        for s in 0..sp.segments() {
            let r = sp.seg_range(s);
            assert_eq!(r.start, covered, "segments must tile the step list");
            assert!(r.end > r.start, "empty segment");
            covered = r.end;
        }
        assert_eq!(covered, n);
    }

    #[test]
    fn linear_chain_carries_single_buffer_per_cut() {
        let (g, inputs) = deep_mlp();
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        let sp = SegmentedPlan::new(plan, 4);
        for (i, c) in sp.carry_counts().iter().enumerate() {
            assert_eq!(*c, 1, "cut {i} of a linear chain should carry one buffer");
        }
    }

    #[test]
    fn segmented_run_matches_monolithic_bits() {
        let (g, inputs) = deep_mlp();
        let analysis = analyze(&g, &inputs).unwrap();
        let mut mono = compile(&g, &analysis).unwrap();
        let xs = batch(&[1, 12], 5, 0x5E6);
        let want = mono.run_batch(&xs).unwrap();
        for segs in [1usize, 2, 3, 8] {
            let mut sp = SegmentedPlan::new(compile(&g, &analysis).unwrap(), segs);
            let got = sp.run_batch(&xs).unwrap();
            for (w, y) in want.iter().zip(&got) {
                assert_eq!(w.data(), y.data(), "segments={segs} diverged");
            }
        }
    }

    /// Staged execution with per-stage states and explicit carry moves —
    /// exactly what the pipelined coordinator does — must equal the
    /// monolithic runner even though non-carry buffers hold stale data
    /// from other batches.
    #[test]
    fn staged_states_with_carry_handoff_are_bit_exact() {
        let (g, inputs) = deep_mlp();
        let analysis = analyze(&g, &inputs).unwrap();
        let mut mono = compile(&g, &analysis).unwrap();
        let sp = SegmentedPlan::new(compile(&g, &analysis).unwrap(), 3);
        let nseg = sp.segments();
        let mut stage_states = vec![WorkerState::default(); nseg];
        // two different batches pushed through the same stage states, so
        // the second run sees the first run's leftovers
        for seed in [1u64, 2] {
            let xs = batch(&[1, 12], 3, seed);
            let want = mono.run_batch(&xs).unwrap();
            sp.pack(&mut stage_states[0], &xs).unwrap();
            for s in 0..nseg {
                sp.run_segment(s, &mut stage_states[s], xs.len()).unwrap();
                if s + 1 < nseg {
                    let carry = sp.take_carry(s, &mut stage_states[s]);
                    let displaced = sp.put_carry(s, &mut stage_states[s + 1], carry);
                    sp.restore_carry(s, &mut stage_states[s], displaced);
                }
            }
            let got = sp.extract(&stage_states[nseg - 1], xs.len()).unwrap();
            for (w, y) in want.iter().zip(&got) {
                assert_eq!(w.data(), y.data(), "staged hand-off diverged (seed {seed})");
            }
        }
    }

    /// The recycle loop: `put_carry` hands back the receiver's displaced
    /// previous-batch buffers, `restore_carry` refills the sender's
    /// emptied slots — so in steady state the carry hand-off allocates
    /// nothing.
    #[test]
    fn put_carry_returns_displaced_buffers_for_recycling() {
        let (g, inputs) = deep_mlp();
        let analysis = analyze(&g, &inputs).unwrap();
        let sp = SegmentedPlan::new(compile(&g, &analysis).unwrap(), 2);
        assert_eq!(sp.segments(), 2, "{}", sp.describe());
        let mut tx = WorkerState::default();
        let mut rx = WorkerState::default();
        let xs = batch(&[1, 12], 2, 3);
        // round 1: a fresh receiver has nothing to hand back
        sp.pack(&mut tx, &xs).unwrap();
        sp.run_segment(0, &mut tx, xs.len()).unwrap();
        let carry = sp.take_carry(0, &mut tx);
        let displaced = sp.put_carry(0, &mut rx, carry);
        assert!(
            displaced.iter().all(Vec::is_empty),
            "fresh receiver should displace only empty buffers"
        );
        sp.restore_carry(0, &mut tx, displaced);
        sp.run_segment(1, &mut rx, xs.len()).unwrap();
        // round 2 (steady state): the receiver displaces the previous
        // batch's real allocations, and the sender absorbs them
        sp.pack(&mut tx, &xs).unwrap();
        sp.run_segment(0, &mut tx, xs.len()).unwrap();
        let carry = sp.take_carry(0, &mut tx);
        let displaced = sp.put_carry(0, &mut rx, carry);
        assert_eq!(displaced.len(), sp.carry_counts()[0]);
        assert!(
            displaced.iter().any(|v| !v.is_empty()),
            "steady-state hand-off must recycle real buffers"
        );
        sp.restore_carry(0, &mut tx, displaced);
    }

    #[test]
    fn tiny_plans_stay_single_segment() {
        let mut b = QnnBuilder::new("tiny", 9);
        b.input("x", &[1, 4]);
        b.relu();
        let g = b.finish().unwrap();
        let mut inputs = Map::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(-1.0, 1.0));
        let analysis = analyze(&g, &inputs).unwrap();
        let sp = SegmentedPlan::new(compile(&g, &analysis).unwrap(), 8);
        assert_eq!(sp.segments(), 1);
        assert!(sp.carry_counts().is_empty());
    }
}
