//! Low-level compute primitives for the plan engine: fused elementwise
//! micro-ops, sorted-threshold tables (binary-search MultiThreshold, the
//! software twin of the §4.1.3 hardware kernel), weight matrices with
//! SIRA-narrowed integer accumulation (§4.2), and a batched im2col.
//!
//! Every routine is arithmetic-identical to the reference
//! [`crate::executor`] semantics: identical per-element operation order
//! for elementwise chains, identical k-order (zero-skipping) accumulation
//! for matrix products, and order-independent threshold counting — this
//! is what makes the engine bit-exact against the interpreter (enforced
//! by `rust/tests/engine_equivalence.rs`).

use crate::tensor::{round_half_even, Conv2dSpec};

/// A per-element constant parameter, broadcast-materialised at compile
/// time to the (per-sample) shape of the tensor it applies to.
#[derive(Clone, Debug)]
pub enum Param {
    Scalar(f64),
    PerElem(Vec<f64>),
}

impl Param {
    #[inline(always)]
    pub fn get(&self, i: usize) -> f64 {
        match self {
            Param::Scalar(v) => *v,
            Param::PerElem(v) => v[i],
        }
    }
}

/// A sorted per-channel threshold table: the engine form of
/// `Op::MultiThreshold`. Rows are sorted ascending so the comparison
/// count (`Σ_i x >= Θ_i`, order-independent) becomes a binary search.
#[derive(Clone, Debug)]
pub struct ThresholdTable {
    /// `channels * n` thresholds, each row ascending.
    pub rows: Vec<f64>,
    pub n: usize,
    /// Threshold channels: 1 (per-tensor) or the data channel count.
    pub channels: usize,
    /// Intra-sample stride of the channel axis (product of dims after it).
    pub ch_stride: usize,
    pub out_scale: f64,
    pub out_bias: f64,
}

/// Number of elements of ascending `row` that are <= x — equal to the
/// linear count `Σ_i (x >= row[i])` the executor computes.
#[inline]
pub fn count_ge(row: &[f64], x: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = row.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x >= row[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

impl ThresholdTable {
    #[inline]
    fn channel_of(&self, i: usize) -> usize {
        if self.channels == 1 {
            0
        } else {
            (i / self.ch_stride) % self.channels
        }
    }

    /// Threshold a value at intra-sample flat index `i`.
    #[inline]
    pub fn apply(&self, v: f64, i: usize) -> f64 {
        self.apply_channel(v, self.channel_of(i))
    }

    /// Threshold a value whose channel is already known (fused MAC tails).
    #[inline]
    pub fn apply_channel(&self, v: f64, ch: usize) -> f64 {
        let ch = if self.channels == 1 { 0 } else { ch };
        let row = &self.rows[ch * self.n..(ch + 1) * self.n];
        self.out_bias + self.out_scale * count_ge(row, v) as f64
    }
}

/// One fused elementwise operation, applied per element. `i` is the
/// intra-sample flat index (for per-element parameters and thresholds).
#[derive(Clone, Debug)]
pub enum MicroOp {
    Mul(Param),
    Add(Param),
    Sub(Param),
    /// `param - x` (constant on the left of a Sub).
    Rsub(Param),
    Div(Param),
    /// `param / x` (constant on the left of a Div).
    Rdiv(Param),
    Relu,
    Sigmoid,
    Floor,
    Ceil,
    RoundEven,
    Clip { lo: f64, hi: f64 },
    Threshold(ThresholdTable),
}

impl MicroOp {
    #[inline(always)]
    pub fn apply(&self, v: f64, i: usize) -> f64 {
        match self {
            MicroOp::Mul(p) => v * p.get(i),
            MicroOp::Add(p) => v + p.get(i),
            MicroOp::Sub(p) => v - p.get(i),
            MicroOp::Rsub(p) => p.get(i) - v,
            MicroOp::Div(p) => v / p.get(i),
            MicroOp::Rdiv(p) => p.get(i) / v,
            MicroOp::Relu => v.max(0.0),
            MicroOp::Sigmoid => 1.0 / (1.0 + (-v).exp()),
            MicroOp::Floor => v.floor(),
            MicroOp::Ceil => v.ceil(),
            MicroOp::RoundEven => round_half_even(v),
            MicroOp::Clip { lo, hi } => v.clamp(*lo, *hi),
            MicroOp::Threshold(t) => t.apply(v, i),
        }
    }
}

/// Constant weight matrix of a MAC step, laid out `(k, n)` row-major
/// (already transposed for row-times-matrix products). The integer
/// variants carry SIRA-proven-width accumulation: `I32` when the
/// compile-time worst-case partial-sum bound fits a 32-bit accumulator,
/// `I64` when it needs up to 63 bits.
#[derive(Clone, Debug)]
pub enum WeightMat {
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl WeightMat {
    pub fn is_integer(&self) -> bool {
        !matches!(self, WeightMat::F64(_))
    }
}

/// `acc += a_row · W` over `(k, n)` weights, accumulating in increasing
/// k order with the same zero-skip as [`crate::tensor::Tensor::matmul`]
/// (exact: skipped terms contribute +0.0). `acc` must be zeroed, len n.
#[inline]
pub fn mac_row_f64(a_row: &[f64], w: &[f64], n: usize, acc: &mut [f64]) {
    for (kk, &a) in a_row.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        let w_row = &w[kk * n..(kk + 1) * n];
        for (j, &b) in w_row.iter().enumerate() {
            acc[j] += a * b;
        }
    }
}

/// Integer variant, 32-bit accumulators (no overflow by the compile-time
/// bound in [`super::fuse`]).
#[inline]
pub fn mac_row_i32(a_row: &[i32], w: &[i32], n: usize, acc: &mut [i32]) {
    for (kk, &a) in a_row.iter().enumerate() {
        if a == 0 {
            continue;
        }
        let w_row = &w[kk * n..(kk + 1) * n];
        for (j, &b) in w_row.iter().enumerate() {
            acc[j] += a * b;
        }
    }
}

/// Integer variant, 64-bit accumulators.
#[inline]
pub fn mac_row_i64(a_row: &[i64], w: &[i64], n: usize, acc: &mut [i64]) {
    for (kk, &a) in a_row.iter().enumerate() {
        if a == 0 {
            continue;
        }
        let w_row = &w[kk * n..(kk + 1) * n];
        for (j, &b) in w_row.iter().enumerate() {
            acc[j] += a * b;
        }
    }
}

/// Batched im2col into a caller-provided buffer: lowers `(B,C,H,W)` input
/// data to a `(B*OH*OW, C*KH*KW)` matrix, padding with 0.0 — identical
/// loop order and padding semantics to [`crate::tensor::im2col`].
/// `cols` is resized to fit.
pub fn im2col_batched(
    x: &[f64],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    spec: Conv2dSpec,
    cols: &mut Vec<f64>,
) -> (usize, usize) {
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, w);
    let k = c * kh * kw;
    let rows = b * oh * ow;
    if cols.len() < rows * k {
        cols.resize(rows * k, 0.0);
    }
    let mut idx = 0usize;
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                            let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                            let v = if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                0.0
                            } else {
                                x[((bi * c + ch) * h + iy as usize) * w + ix as usize]
                            };
                            cols[idx] = v;
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    (rows, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn count_ge_matches_linear_scan() {
        let row = [-3.0, -1.0, 0.0, 0.0, 2.5, 7.0];
        for x in [-10.0, -3.0, -2.0, 0.0, 0.1, 2.5, 6.9, 7.0, 100.0] {
            let linear = row.iter().filter(|&&t| x >= t).count();
            assert_eq!(count_ge(&row, x), linear, "x = {x}");
        }
        assert_eq!(count_ge(&[], 1.0), 0);
    }

    #[test]
    fn threshold_table_matches_executor_op() {
        use crate::executor::execute_op;
        use crate::graph::Op;
        // 2 channels x 3 thresholds over a (1,2,1,2) NCHW tensor
        let th = Tensor::new(&[2, 3], vec![0.0, 2.0, 5.0, -1.0, 1.0, 4.0]).unwrap();
        let x = Tensor::new(&[1, 2, 1, 2], vec![1.0, 6.0, -2.0, 3.5]).unwrap();
        let want = execute_op(
            &Op::MultiThreshold {
                out_scale: 2.0,
                out_bias: -4.0,
            },
            &[x.clone(), th.clone()],
        )
        .unwrap();
        let mut rows = th.data().to_vec();
        for ch in 0..2 {
            rows[ch * 3..(ch + 1) * 3].sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        let table = ThresholdTable {
            rows,
            n: 3,
            channels: 2,
            ch_stride: 2, // product of dims after the channel axis
            out_scale: 2.0,
            out_bias: -4.0,
        };
        let got: Vec<f64> = x
            .data()
            .iter()
            .enumerate()
            .map(|(i, &v)| table.apply(v, i))
            .collect();
        assert_eq!(got, want[0].data());
    }

    #[test]
    fn mac_rows_agree_across_widths() {
        let a = [3.0, 0.0, -2.0, 7.0];
        let w = [1.0, -1.0, 2.0, 0.5, -3.0, 4.0, 1.0, 1.0]; // (4,2)
        let mut acc_f = vec![0.0; 2];
        mac_row_f64(&a, &w, 2, &mut acc_f);
        let ai: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        // use integer weights for the integer comparison
        let wi = [1i32, -1, 2, 1, -3, 4, 1, 1];
        let wf: Vec<f64> = wi.iter().map(|&v| v as f64).collect();
        let mut acc_ref = vec![0.0; 2];
        mac_row_f64(&a, &wf, 2, &mut acc_ref);
        let mut acc32 = vec![0i32; 2];
        mac_row_i32(&ai, &wi, 2, &mut acc32);
        let ai64: Vec<i64> = a.iter().map(|&v| v as i64).collect();
        let wi64: Vec<i64> = wi.iter().map(|&v| v as i64).collect();
        let mut acc64 = vec![0i64; 2];
        mac_row_i64(&ai64, &wi64, 2, &mut acc64);
        for j in 0..2 {
            assert_eq!(acc32[j] as f64, acc_ref[j]);
            assert_eq!(acc64[j] as f64, acc_ref[j]);
        }
        let _ = acc_f;
    }

    #[test]
    fn im2col_batched_matches_tensor_im2col() {
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (2, 2),
            pad: (1, 1),
        };
        let x = Tensor::new(&[2, 2, 5, 5], (0..100).map(|i| i as f64 - 30.0).collect()).unwrap();
        let (want, _, _) = crate::tensor::im2col(&x, spec, 0.0).unwrap();
        let mut cols = Vec::new();
        let (rows, k) = im2col_batched(x.data(), 2, 2, 5, 5, spec, &mut cols);
        assert_eq!(&cols[..rows * k], want.data());
    }

    #[test]
    fn micro_ops_match_executor_elementwise() {
        let ops = [
            MicroOp::Mul(Param::Scalar(0.3)),
            MicroOp::Add(Param::PerElem(vec![1.0, -2.0, 0.5])),
            MicroOp::Relu,
            MicroOp::RoundEven,
            MicroOp::Clip { lo: -1.0, hi: 4.0 },
        ];
        let xs = [-3.7, 0.0, 9.9];
        for (i, &x) in xs.iter().enumerate() {
            let mut v = x;
            for op in &ops {
                v = op.apply(v, i);
            }
            // manual reference, same order
            let p = [1.0, -2.0, 0.5][i];
            let want = round_half_even((x * 0.3 + p).max(0.0)).clamp(-1.0, 4.0);
            assert_eq!(v, want);
        }
    }
}
