//! Low-level compute primitives for the plan engine: fused elementwise
//! micro-ops, sorted-threshold tables (binary-search MultiThreshold, the
//! software twin of the §4.1.3 hardware kernel), weight matrices with
//! SIRA-narrowed integer accumulation (§4.2), and a batched im2col.
//!
//! Every routine is arithmetic-identical to the reference
//! [`crate::executor`] semantics: identical per-element operation order
//! for elementwise chains, identical k-order (zero-skipping) accumulation
//! for matrix products, and order-independent threshold counting — this
//! is what makes the engine bit-exact against the interpreter (enforced
//! by `rust/tests/engine_equivalence.rs`).
//!
//! The MAC core comes in two interchangeable, bit-identical forms: the
//! scalar generic [`MacElem::mac_row`] (the oracle) and the tiled,
//! register-blocked kernels in [`tile`] that the plan dispatches to for
//! kernels above `Plan::set_min_tile_work` — see
//! `rust/tests/kernel_properties.rs` for the property/fuzz suite that
//! pins the two together.

pub mod tile;

use std::sync::Arc;

use crate::tensor::{round_half_even, Conv2dSpec};

/// A per-element constant parameter, broadcast-materialised at compile
/// time to the (per-sample) shape of the tensor it applies to.
#[derive(Clone, Debug)]
pub enum Param {
    Scalar(f64),
    PerElem(Vec<f64>),
}

impl Param {
    #[inline(always)]
    pub fn get(&self, i: usize) -> f64 {
        match self {
            Param::Scalar(v) => *v,
            Param::PerElem(v) => v[i],
        }
    }
}

/// A sorted per-channel threshold table: the engine form of
/// `Op::MultiThreshold`. Rows are sorted ascending so the comparison
/// count (`Σ_i x >= Θ_i`, order-independent) becomes a binary search.
#[derive(Clone, Debug)]
pub struct ThresholdTable {
    /// `channels * n` thresholds, each row ascending.
    pub rows: Vec<f64>,
    pub n: usize,
    /// Threshold channels: 1 (per-tensor) or the data channel count.
    pub channels: usize,
    /// Intra-sample stride of the channel axis (product of dims after it).
    pub ch_stride: usize,
    pub out_scale: f64,
    pub out_bias: f64,
}

/// Number of elements of ascending `row` that are <= x — equal to the
/// linear count `Σ_i (x >= row[i])` the executor computes.
#[inline]
pub fn count_ge(row: &[f64], x: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = row.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x >= row[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

impl ThresholdTable {
    #[inline]
    fn channel_of(&self, i: usize) -> usize {
        if self.channels == 1 {
            0
        } else {
            (i / self.ch_stride) % self.channels
        }
    }

    /// Threshold a value at intra-sample flat index `i`.
    #[inline]
    pub fn apply(&self, v: f64, i: usize) -> f64 {
        self.apply_channel(v, self.channel_of(i))
    }

    /// Threshold a value whose channel is already known (fused MAC tails).
    #[inline]
    pub fn apply_channel(&self, v: f64, ch: usize) -> f64 {
        let ch = if self.channels == 1 { 0 } else { ch };
        let row = &self.rows[ch * self.n..(ch + 1) * self.n];
        self.out_bias + self.out_scale * count_ge(row, v) as f64
    }
}

/// One fused elementwise operation, applied per element. `i` is the
/// intra-sample flat index (for per-element parameters and thresholds).
#[derive(Clone, Debug)]
pub enum MicroOp {
    Mul(Param),
    Add(Param),
    Sub(Param),
    /// `param - x` (constant on the left of a Sub).
    Rsub(Param),
    Div(Param),
    /// `param / x` (constant on the left of a Div).
    Rdiv(Param),
    Relu,
    Sigmoid,
    Floor,
    Ceil,
    RoundEven,
    Clip { lo: f64, hi: f64 },
    Threshold(ThresholdTable),
}

impl MicroOp {
    #[inline(always)]
    pub fn apply(&self, v: f64, i: usize) -> f64 {
        match self {
            MicroOp::Mul(p) => v * p.get(i),
            MicroOp::Add(p) => v + p.get(i),
            MicroOp::Sub(p) => v - p.get(i),
            MicroOp::Rsub(p) => p.get(i) - v,
            MicroOp::Div(p) => v / p.get(i),
            MicroOp::Rdiv(p) => p.get(i) / v,
            MicroOp::Relu => v.max(0.0),
            MicroOp::Sigmoid => 1.0 / (1.0 + (-v).exp()),
            MicroOp::Floor => v.floor(),
            MicroOp::Ceil => v.ceil(),
            MicroOp::RoundEven => round_half_even(v),
            MicroOp::Clip { lo, hi } => v.clamp(*lo, *hi),
            MicroOp::Threshold(t) => t.apply(v, i),
        }
    }
}

/// Borrowed view of an elided-channel accumulator bias (§7.1): one
/// value per output column when `pos_stride == 0`, else `pos_stride`
/// (= output-channel count) wide rows per output position. Shared by
/// the scalar and the tiled MAC cores so both seed identically.
#[derive(Clone, Copy)]
pub struct BiasRef<'a> {
    pub(crate) bias: &'a [i64],
    pub(crate) pos_stride: usize,
}

/// One MAC weight matrix in both layouts the engine keeps: `flat` is the
/// `(k, n)` row-major form (the scalar-oracle path; also what elision
/// compaction and bias folding index), `packed` the tile-major form the
/// register-blocked kernels stream (see [`tile`]). The packed copy costs
/// `k * round_up(n, tile::NR)` extra elements per MAC step — the
/// documented packed-weights memory trade-off, surfaced through
/// `PlanStats::packed_weight_elems`.
///
/// Both layouts live behind shared immutable `Arc` storage: cloning a
/// `MacMat` (and therefore a whole `Plan`, e.g. one per coordinator
/// replica) bumps two reference counts instead of copying weights, so N
/// replicas of one model cost one weight allocation. The flat oracle is
/// additionally droppable at serve time ([`MacMat::drop_flat`]) — the
/// tiled kernels are bit-identical to the scalar path, so a plan without
/// the flat copy forces tiled dispatch and produces the same bits.
#[derive(Clone, Debug)]
pub struct MacMat<T: MacElem> {
    flat: Option<Arc<Vec<T>>>,
    k: usize,
    n: usize,
    packed: Arc<tile::PackedWeights<T>>,
}

impl<T: MacElem> MacMat<T> {
    /// Build both layouts from a `(k, n)` row-major matrix (packing
    /// happens once, at plan-compile time).
    pub fn new(flat: Vec<T>, k: usize, n: usize) -> MacMat<T> {
        let packed = Arc::new(tile::PackedWeights::pack(&flat, k, n));
        MacMat {
            flat: Some(Arc::new(flat)),
            k,
            n,
            packed,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The `(k, n)` row-major form, `None` after [`MacMat::drop_flat`].
    pub fn flat(&self) -> Option<&[T]> {
        self.flat.as_deref().map(Vec::as_slice)
    }

    /// The tile-packed form.
    pub fn packed(&self) -> &tile::PackedWeights<T> {
        &self.packed
    }

    /// The `(k, n)` row-major matrix, recovered from the panels when the
    /// flat copy has been dropped (what plan serialization stores).
    pub fn flat_data(&self) -> Vec<T> {
        match &self.flat {
            Some(f) => f.as_ref().clone(),
            None => self.packed.unpack(),
        }
    }

    /// Release the flat scalar-oracle copy (this handle's reference to
    /// it — other clones keep theirs). MACs over a flat-less matrix
    /// dispatch to the bit-identical tiled kernels unconditionally.
    pub fn drop_flat(&mut self) {
        self.flat = None;
    }

    /// Elements held by this handle's flat copy (0 once dropped).
    pub fn flat_elems(&self) -> usize {
        self.flat.as_ref().map_or(0, |f| f.len())
    }

    /// Reference count of the shared packed storage — the observable
    /// that N plan clones really share one weight allocation.
    pub fn packed_refs(&self) -> usize {
        Arc::strong_count(&self.packed)
    }
}

/// Constant weight matrix of a MAC step, laid out `(k, n)` row-major
/// (already transposed for row-times-matrix products) plus its
/// tile-packed twin ([`MacMat`]). The integer variants carry
/// SIRA-proven-width accumulation: `I32` when the compile-time
/// worst-case partial-sum bound fits a 32-bit accumulator, `I64` when it
/// needs up to 63 bits.
#[derive(Clone, Debug)]
pub enum WeightMat {
    F64(MacMat<f64>),
    I32(MacMat<i32>),
    I64(MacMat<i64>),
}

impl WeightMat {
    pub fn is_integer(&self) -> bool {
        !matches!(self, WeightMat::F64(_))
    }

    /// Padded element count of the tile-packed copy (the memory-overhead
    /// observable).
    pub fn packed_elems(&self) -> usize {
        match self {
            WeightMat::F64(m) => m.packed().padded_len(),
            WeightMat::I32(m) => m.packed().padded_len(),
            WeightMat::I64(m) => m.packed().padded_len(),
        }
    }

    /// Elements held by the flat scalar-oracle copy (0 once dropped).
    pub fn flat_elems(&self) -> usize {
        match self {
            WeightMat::F64(m) => m.flat_elems(),
            WeightMat::I32(m) => m.flat_elems(),
            WeightMat::I64(m) => m.flat_elems(),
        }
    }

    /// Whether the flat scalar-oracle copy is still attached.
    pub fn has_flat(&self) -> bool {
        self.flat_elems() > 0
    }

    /// Release the flat copy; see [`MacMat::drop_flat`].
    pub fn drop_flat(&mut self) {
        match self {
            WeightMat::F64(m) => m.drop_flat(),
            WeightMat::I32(m) => m.drop_flat(),
            WeightMat::I64(m) => m.drop_flat(),
        }
    }

    /// Reference count of the shared packed storage; see
    /// [`MacMat::packed_refs`].
    pub fn packed_refs(&self) -> usize {
        match self {
            WeightMat::F64(m) => m.packed_refs(),
            WeightMat::I32(m) => m.packed_refs(),
            WeightMat::I64(m) => m.packed_refs(),
        }
    }
}

/// A MAC accumulator element: the one abstraction over the three
/// accumulation widths (f64, SIRA-narrowed i32/i64) so the plan runner
/// has a single row-times-matrix implementation for the serial, the
/// row-sharded and the channel-sharded execution paths. Integer addition
/// is exact and order-free, which is what makes both re-sharding and
/// stuck-channel bias folding bit-exact for the integer variants; the
/// f64 variant keeps the reference accumulation order because sharding
/// only ever splits *between* output elements, never within one dot
/// product.
pub trait MacElem: Copy + Send + Sync + 'static {
    const ZERO: Self;
    /// Whether the tiled kernels must reproduce the scalar zero-skip
    /// exactly: true for f64, where `acc + 0.0 * w` can differ from
    /// skipping (signed zeros, non-finite weights); false for the
    /// integer widths, where a zero activation contributes an exact
    /// zero either way and the branch-free form is SIMD-friendlier.
    const EXACT_SKIP: bool;
    fn from_f64(v: f64) -> Self;
    fn from_i64(v: i64) -> Self;
    fn to_f64(self) -> f64;
    fn is_zero(self) -> bool;
    fn mul_acc(self, a: Self, b: Self) -> Self;
    /// Plain addition — what the KC-blocked kernels spill chunk partials
    /// with. Deliberately *not* wrapping for the integer widths: under
    /// the `relcheck` overflow-check profile an unproven reorder panics
    /// instead of silently wrapping back to the right answer, which is
    /// the property the accumulator-edge suite pins.
    fn add(self, other: Self) -> Self;

    /// `acc += a_row · W[:, cols]` over `(k, n)` weights, accumulating in
    /// increasing k order with the same zero-skip as
    /// [`crate::tensor::Tensor::matmul`] (exact: skipped terms contribute
    /// +0.0). `acc` has `cols.len()` elements and is *not* zeroed here —
    /// the caller seeds it (zero, or an elided-channel bias).
    #[inline]
    fn mac_row(
        a_row: &[Self],
        w: &[Self],
        n: usize,
        cols: core::ops::Range<usize>,
        acc: &mut [Self],
    ) {
        for (kk, &a) in a_row.iter().enumerate() {
            if a.is_zero() {
                continue;
            }
            let w_row = &w[kk * n + cols.start..kk * n + cols.end];
            for (j, &b) in w_row.iter().enumerate() {
                acc[j] = acc[j].mul_acc(a, b);
            }
        }
    }
}

impl MacElem for f64 {
    const ZERO: Self = 0.0;
    const EXACT_SKIP: bool = true;
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline(always)]
    fn from_i64(v: i64) -> Self {
        v as f64
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0.0
    }
    #[inline(always)]
    fn mul_acc(self, a: Self, b: Self) -> Self {
        self + a * b
    }
    #[inline(always)]
    fn add(self, other: Self) -> Self {
        self + other
    }
}

impl MacElem for i32 {
    const ZERO: Self = 0;
    const EXACT_SKIP: bool = false;
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as i32
    }
    #[inline(always)]
    fn from_i64(v: i64) -> Self {
        v as i32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }
    #[inline(always)]
    fn mul_acc(self, a: Self, b: Self) -> Self {
        self + a * b
    }
    #[inline(always)]
    fn add(self, other: Self) -> Self {
        self + other
    }
}

impl MacElem for i64 {
    const ZERO: Self = 0;
    const EXACT_SKIP: bool = false;
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as i64
    }
    #[inline(always)]
    fn from_i64(v: i64) -> Self {
        v
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }
    #[inline(always)]
    fn mul_acc(self, a: Self, b: Self) -> Self {
        self + a * b
    }
    #[inline(always)]
    fn add(self, other: Self) -> Self {
        self + other
    }
}

/// `acc += a_row · W` over `(k, n)` weights (all columns). `acc` must be
/// zeroed, len n. Kept as the width-explicit entry points.
#[inline]
pub fn mac_row_f64(a_row: &[f64], w: &[f64], n: usize, acc: &mut [f64]) {
    MacElem::mac_row(a_row, w, n, 0..n, acc);
}

/// Integer variant, 32-bit accumulators (no overflow by the compile-time
/// bound in [`super::fuse`]).
#[inline]
pub fn mac_row_i32(a_row: &[i32], w: &[i32], n: usize, acc: &mut [i32]) {
    MacElem::mac_row(a_row, w, n, 0..n, acc);
}

/// Integer variant, 64-bit accumulators.
#[inline]
pub fn mac_row_i64(a_row: &[i64], w: &[i64], n: usize, acc: &mut [i64]) {
    MacElem::mac_row(a_row, w, n, 0..n, acc);
}

/// Batched im2col into a caller-provided buffer: lowers `(B,C,H,W)` input
/// data to a `(B*OH*OW, C*KH*KW)` matrix, padding with 0.0 — identical
/// loop order and padding semantics to [`crate::tensor::im2col`].
/// `cols` is resized to fit.
pub fn im2col_batched(
    x: &[f64],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    spec: Conv2dSpec,
    cols: &mut Vec<f64>,
) -> (usize, usize) {
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, w);
    let k = c * kh * kw;
    let rows = b * oh * ow;
    if cols.len() < rows * k {
        cols.resize(rows * k, 0.0);
    }
    let mut idx = 0usize;
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                            let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                            let v = if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                0.0
                            } else {
                                x[((bi * c + ch) * h + iy as usize) * w + ix as usize]
                            };
                            cols[idx] = v;
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    (rows, k)
}

/// im2col restricted to a subset of input channels (ascending `live`
/// list): the lowering used by stuck-channel elision (§7.1), where the
/// elided channels' constant contribution is pre-folded into the MAC
/// bias at compile time. Column order matches [`im2col_batched`] with the
/// stuck channels deleted, which is exactly how [`super::fuse`] compacts
/// the weight matrix rows. Padding semantics are identical to the full
/// lowering (out-of-bounds taps read 0.0); for elided channels the
/// compiler accounts for the pad/stuck interaction with per-output-
/// position biases instead.
pub fn im2col_channels(
    x: &[f64],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    spec: Conv2dSpec,
    live: &[usize],
    cols: &mut Vec<f64>,
) -> (usize, usize) {
    let (kh, kw) = spec.kernel;
    let (oh, ow) = spec.out_hw(h, w);
    let k = live.len() * kh * kw;
    let rows = b * oh * ow;
    if cols.len() < rows * k {
        cols.resize(rows * k, 0.0);
    }
    let mut idx = 0usize;
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for &ch in live {
                    debug_assert!(ch < c);
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
                            let ix = (ox * spec.stride.1 + kx) as isize - spec.pad.1 as isize;
                            let v = if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                0.0
                            } else {
                                x[((bi * c + ch) * h + iy as usize) * w + ix as usize]
                            };
                            cols[idx] = v;
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    (rows, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn count_ge_matches_linear_scan() {
        let row = [-3.0, -1.0, 0.0, 0.0, 2.5, 7.0];
        for x in [-10.0, -3.0, -2.0, 0.0, 0.1, 2.5, 6.9, 7.0, 100.0] {
            let linear = row.iter().filter(|&&t| x >= t).count();
            assert_eq!(count_ge(&row, x), linear, "x = {x}");
        }
        assert_eq!(count_ge(&[], 1.0), 0);
    }

    #[test]
    fn threshold_table_matches_executor_op() {
        use crate::executor::execute_op;
        use crate::graph::Op;
        // 2 channels x 3 thresholds over a (1,2,1,2) NCHW tensor
        let th = Tensor::new(&[2, 3], vec![0.0, 2.0, 5.0, -1.0, 1.0, 4.0]).unwrap();
        let x = Tensor::new(&[1, 2, 1, 2], vec![1.0, 6.0, -2.0, 3.5]).unwrap();
        let want = execute_op(
            &Op::MultiThreshold {
                out_scale: 2.0,
                out_bias: -4.0,
            },
            &[x.clone(), th.clone()],
        )
        .unwrap();
        let mut rows = th.data().to_vec();
        for ch in 0..2 {
            rows[ch * 3..(ch + 1) * 3].sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        let table = ThresholdTable {
            rows,
            n: 3,
            channels: 2,
            ch_stride: 2, // product of dims after the channel axis
            out_scale: 2.0,
            out_bias: -4.0,
        };
        let got: Vec<f64> = x
            .data()
            .iter()
            .enumerate()
            .map(|(i, &v)| table.apply(v, i))
            .collect();
        assert_eq!(got, want[0].data());
    }

    #[test]
    fn mac_rows_agree_across_widths() {
        let a = [3.0, 0.0, -2.0, 7.0];
        let w = [1.0, -1.0, 2.0, 0.5, -3.0, 4.0, 1.0, 1.0]; // (4,2)
        let mut acc_f = vec![0.0; 2];
        mac_row_f64(&a, &w, 2, &mut acc_f);
        let ai: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        // use integer weights for the integer comparison
        let wi = [1i32, -1, 2, 1, -3, 4, 1, 1];
        let wf: Vec<f64> = wi.iter().map(|&v| v as f64).collect();
        let mut acc_ref = vec![0.0; 2];
        mac_row_f64(&a, &wf, 2, &mut acc_ref);
        let mut acc32 = vec![0i32; 2];
        mac_row_i32(&ai, &wi, 2, &mut acc32);
        let ai64: Vec<i64> = a.iter().map(|&v| v as i64).collect();
        let wi64: Vec<i64> = wi.iter().map(|&v| v as i64).collect();
        let mut acc64 = vec![0i64; 2];
        mac_row_i64(&ai64, &wi64, 2, &mut acc64);
        for j in 0..2 {
            assert_eq!(acc32[j] as f64, acc_ref[j]);
            assert_eq!(acc64[j] as f64, acc_ref[j]);
        }
        let _ = acc_f;
    }

    #[test]
    fn im2col_batched_matches_tensor_im2col() {
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (2, 2),
            pad: (1, 1),
        };
        let x = Tensor::new(&[2, 2, 5, 5], (0..100).map(|i| i as f64 - 30.0).collect()).unwrap();
        let (want, _, _) = crate::tensor::im2col(&x, spec, 0.0).unwrap();
        let mut cols = Vec::new();
        let (rows, k) = im2col_batched(x.data(), 2, 2, 5, 5, spec, &mut cols);
        assert_eq!(&cols[..rows * k], want.data());
    }

    #[test]
    fn mac_row_column_ranges_tile_the_full_product() {
        // concatenating column-range MACs must equal the full-width MAC
        // (the invariant channel-sharding relies on)
        let a = [3i32, 0, -2, 7, 1];
        let w: Vec<i32> = (0..5 * 6).map(|i| (i as i32 % 11) - 5).collect();
        let mut full = vec![0i32; 6];
        mac_row_i32(&a, &w, 6, &mut full);
        for split in 1..6 {
            let mut lo = vec![0i32; split];
            let mut hi = vec![0i32; 6 - split];
            MacElem::mac_row(&a, &w[..], 6, 0..split, &mut lo);
            MacElem::mac_row(&a, &w[..], 6, split..6, &mut hi);
            lo.extend(hi);
            assert_eq!(lo, full, "split at {split}");
        }
    }

    #[test]
    fn im2col_channels_matches_full_on_live_subset() {
        let spec = Conv2dSpec {
            kernel: (2, 2),
            stride: (1, 1),
            pad: (0, 0),
        };
        let x: Vec<f64> = (0..2 * 3 * 4 * 4).map(|i| i as f64 - 40.0).collect();
        let mut full = Vec::new();
        let (rows, k) = im2col_batched(&x, 2, 3, 4, 4, spec, &mut full);
        assert_eq!(k, 3 * 4);
        let live = [0usize, 2];
        let mut sub = Vec::new();
        let (srows, sk) = im2col_channels(&x, 2, 3, 4, 4, spec, &live, &mut sub);
        assert_eq!(srows, rows);
        assert_eq!(sk, 2 * 4);
        // each subset row = full row with channel 1's 4 columns deleted
        for r in 0..rows {
            let frow = &full[r * k..(r + 1) * k];
            let srow = &sub[r * sk..(r + 1) * sk];
            assert_eq!(&srow[..4], &frow[..4]);
            assert_eq!(&srow[4..], &frow[8..12]);
        }
    }

    #[test]
    fn im2col_channels_pads_like_the_full_lowering() {
        let spec = Conv2dSpec {
            kernel: (3, 3),
            stride: (1, 1),
            pad: (1, 1),
        };
        let x: Vec<f64> = (0..2 * 3 * 4 * 4).map(|i| i as f64 - 40.0).collect();
        let mut full = Vec::new();
        let (rows, k) = im2col_batched(&x, 2, 3, 4, 4, spec, &mut full);
        assert_eq!(k, 3 * 9);
        let live = [0usize, 2];
        let mut sub = Vec::new();
        let (srows, sk) = im2col_channels(&x, 2, 3, 4, 4, spec, &live, &mut sub);
        assert_eq!(srows, rows);
        assert_eq!(sk, 2 * 9);
        // each subset row = full row with channel 1's 9 columns deleted,
        // padded zeros included
        for r in 0..rows {
            let frow = &full[r * k..(r + 1) * k];
            let srow = &sub[r * sk..(r + 1) * sk];
            assert_eq!(&srow[..9], &frow[..9]);
            assert_eq!(&srow[9..], &frow[18..27]);
        }
    }

    #[test]
    fn micro_ops_match_executor_elementwise() {
        let ops = [
            MicroOp::Mul(Param::Scalar(0.3)),
            MicroOp::Add(Param::PerElem(vec![1.0, -2.0, 0.5])),
            MicroOp::Relu,
            MicroOp::RoundEven,
            MicroOp::Clip { lo: -1.0, hi: 4.0 },
        ];
        let xs = [-3.7, 0.0, 9.9];
        for (i, &x) in xs.iter().enumerate() {
            let mut v = x;
            for op in &ops {
                v = op.apply(v, i);
            }
            // manual reference, same order
            let p = [1.0, -2.0, 0.5][i];
            let want = round_half_even((x * 0.3 + p).max(0.0)).clamp(-1.0, 4.0);
            assert_eq!(v, want);
        }
    }
}
