//! The compiled execution plan: a flat, topologically ordered list of
//! fused kernels over physical buffers, plus the batched runner.
//!
//! A [`Plan`] is produced by [`super::fuse::compile`] from a graph and
//! its SIRA [`crate::sira::Analysis`]. All constants (weights, folded
//! quantizers, aggregated scales/biases, threshold tables) are baked into
//! the steps at compile time; at run time the only dynamic state is the
//! buffer arena, sized `batch * per_sample_numel` per buffer and reused
//! across calls — the hot path performs no per-node graph resolution, no
//! name lookups, and no constant-tensor clones (all of which dominate the
//! interpretive [`crate::executor::Executor`]'s per-inference cost).

use anyhow::{bail, Context, Result};

use crate::executor::execute_op;
use crate::graph::Op;
use crate::tensor::{Conv2dSpec, PoolKind, Tensor};

use super::kernels::{
    im2col_batched, mac_row_f64, mac_row_i32, mac_row_i64, MicroOp, ThresholdTable, WeightMat,
};

/// Fused elementwise chain: one pass over the input applying a sequence
/// of micro-ops per element (aggregated scales/biases, quantizers,
/// activations, thresholds).
#[derive(Clone, Debug)]
pub(crate) struct EwChainStep {
    pub input: usize,
    pub out: usize,
    /// per-sample element count (input and output shapes agree)
    pub numel: usize,
    pub ops: Vec<MicroOp>,
}

/// Batched matrix multiply against a constant weight matrix, optionally
/// finishing each output element through a fused threshold table.
#[derive(Clone, Debug)]
pub(crate) struct MatMulStep {
    pub a: usize,
    pub out: usize,
    /// per-sample rows of the left operand (1 for the zoo workloads)
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub w: WeightMat,
    pub fused: Option<ThresholdTable>,
    // run-time scratch, reused across calls
    pub a32: Vec<i32>,
    pub a64: Vec<i64>,
}

/// Dense convolution as batched im2col + matrix multiply, scattering
/// results straight into NCHW layout (the `permute` the interpreter
/// performs is folded into the output indexing), with optional fused
/// per-channel thresholding.
#[derive(Clone, Debug)]
pub(crate) struct ConvStep {
    pub x: usize,
    pub out: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oc: usize,
    pub oh: usize,
    pub ow: usize,
    pub spec: Conv2dSpec,
    /// `(c*kh*kw, oc)` weight matrix
    pub wmat: WeightMat,
    pub fused: Option<ThresholdTable>,
    pub cols: Vec<f64>,
    pub cols32: Vec<i32>,
    pub cols64: Vec<i64>,
}

/// Depthwise convolution (per-channel kernels), optional fused threshold.
#[derive(Clone, Debug)]
pub(crate) struct DepthwiseStep {
    pub x: usize,
    pub out: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oh: usize,
    pub ow: usize,
    pub spec: Conv2dSpec,
    /// `(c, kh, kw)` flattened
    pub weights: Vec<f64>,
    pub fused: Option<ThresholdTable>,
}

/// Max/average pooling over NCHW (count_include_pad = false, identical
/// to [`crate::tensor::pool2d`]).
#[derive(Clone, Debug)]
pub(crate) struct PoolStep {
    pub x: usize,
    pub out: usize,
    pub kind: PoolKind,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oh: usize,
    pub ow: usize,
    pub spec: Conv2dSpec,
}

#[derive(Clone, Copy, Debug)]
pub(crate) enum BinKind {
    Add,
    Sub,
    Mul,
    Div,
}

/// Elementwise binary op over two same-shape dynamic tensors (residual
/// adds and friends).
#[derive(Clone, Debug)]
pub(crate) struct BinaryStep {
    pub a: usize,
    pub b: usize,
    pub out: usize,
    pub numel: usize,
    pub kind: BinKind,
}

/// Source of a generic-step operand.
#[derive(Clone, Debug)]
pub(crate) enum GSrc {
    /// dynamic tensor: (slot, per-sample shape)
    Slot(usize, Vec<usize>),
    Const(Tensor),
}

/// Fallback: execute the reference operator per sample via
/// [`crate::executor::execute_op`]. Slow but exact and fully general —
/// anything the interpreter runs, the plan runs.
#[derive(Clone, Debug)]
pub(crate) struct GenericStep {
    pub op: Op,
    pub ins: Vec<GSrc>,
    pub out: usize,
    pub out_shape: Vec<usize>,
    pub out_numel: usize,
}

#[derive(Clone, Debug)]
pub(crate) enum Step {
    Ew(EwChainStep),
    MatMul(MatMulStep),
    Conv(ConvStep),
    Depthwise(DepthwiseStep),
    Pool(PoolStep),
    Binary(BinaryStep),
    Generic(GenericStep),
}

impl Step {
    /// Logical slots this step reads.
    pub(crate) fn reads(&self) -> Vec<usize> {
        match self {
            Step::Ew(s) => vec![s.input],
            Step::MatMul(s) => vec![s.a],
            Step::Conv(s) => vec![s.x],
            Step::Depthwise(s) => vec![s.x],
            Step::Pool(s) => vec![s.x],
            Step::Binary(s) => vec![s.a, s.b],
            Step::Generic(s) => s
                .ins
                .iter()
                .filter_map(|src| match src {
                    GSrc::Slot(id, _) => Some(*id),
                    GSrc::Const(_) => None,
                })
                .collect(),
        }
    }

    /// Logical slots this step writes.
    pub(crate) fn writes(&self) -> Vec<usize> {
        match self {
            Step::Ew(s) => vec![s.out],
            Step::MatMul(s) => vec![s.out],
            Step::Conv(s) => vec![s.out],
            Step::Depthwise(s) => vec![s.out],
            Step::Pool(s) => vec![s.out],
            Step::Binary(s) => vec![s.out],
            Step::Generic(s) => vec![s.out],
        }
    }

    /// Rewrite logical slot ids to physical buffer ids.
    pub(crate) fn remap(&mut self, phys: &[usize]) {
        match self {
            Step::Ew(s) => {
                s.input = phys[s.input];
                s.out = phys[s.out];
            }
            Step::MatMul(s) => {
                s.a = phys[s.a];
                s.out = phys[s.out];
            }
            Step::Conv(s) => {
                s.x = phys[s.x];
                s.out = phys[s.out];
            }
            Step::Depthwise(s) => {
                s.x = phys[s.x];
                s.out = phys[s.out];
            }
            Step::Pool(s) => {
                s.x = phys[s.x];
                s.out = phys[s.out];
            }
            Step::Binary(s) => {
                s.a = phys[s.a];
                s.b = phys[s.b];
                s.out = phys[s.out];
            }
            Step::Generic(s) => {
                for src in &mut s.ins {
                    if let GSrc::Slot(id, _) = src {
                        *id = phys[*id];
                    }
                }
                s.out = phys[s.out];
            }
        }
    }
}

/// Take a physical output buffer out of the arena, grown to `need`.
/// The buffer is detached so input buffers can be borrowed immutably
/// while it is written; the caller puts it back when done.
#[inline]
fn take_out(bufs: &mut [Vec<f64>], phys: usize, need: usize) -> Vec<f64> {
    let mut v = std::mem::take(&mut bufs[phys]);
    if v.len() < need {
        v.resize(need, 0.0);
    }
    v
}

impl Step {
    fn run(&mut self, bufs: &mut [Vec<f64>], b: usize) -> Result<()> {
        match self {
            Step::Ew(s) => {
                let need = b * s.numel;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.input][..need];
                let numel = s.numel;
                for (i, (&v0, o)) in x.iter().zip(out[..need].iter_mut()).enumerate() {
                    let si = i % numel;
                    let mut v = v0;
                    for op in &s.ops {
                        v = op.apply(v, si);
                    }
                    *o = v;
                }
                bufs[s.out] = out;
            }
            Step::MatMul(s) => {
                let rows = b * s.m;
                let need = rows * s.n;
                let mut out = take_out(bufs, s.out, need);
                let a = &bufs[s.a][..rows * s.k];
                match &s.w {
                    WeightMat::F64(w) => {
                        let mut acc = vec![0.0f64; s.n];
                        for r in 0..rows {
                            acc.iter_mut().for_each(|v| *v = 0.0);
                            mac_row_f64(&a[r * s.k..(r + 1) * s.k], w, s.n, &mut acc);
                            write_row(&mut out[r * s.n..(r + 1) * s.n], &acc, &s.fused);
                        }
                    }
                    WeightMat::I32(w) => {
                        if s.a32.len() < a.len() {
                            s.a32.resize(a.len(), 0);
                        }
                        for (d, &v) in s.a32.iter_mut().zip(a.iter()) {
                            *d = v as i32;
                        }
                        let mut acc = vec![0i32; s.n];
                        for r in 0..rows {
                            acc.iter_mut().for_each(|v| *v = 0);
                            mac_row_i32(&s.a32[r * s.k..(r + 1) * s.k], w, s.n, &mut acc);
                            write_row_i(&mut out[r * s.n..(r + 1) * s.n], &acc, &s.fused);
                        }
                    }
                    WeightMat::I64(w) => {
                        if s.a64.len() < a.len() {
                            s.a64.resize(a.len(), 0);
                        }
                        for (d, &v) in s.a64.iter_mut().zip(a.iter()) {
                            *d = v as i64;
                        }
                        let mut acc = vec![0i64; s.n];
                        for r in 0..rows {
                            acc.iter_mut().for_each(|v| *v = 0);
                            mac_row_i64(&s.a64[r * s.k..(r + 1) * s.k], w, s.n, &mut acc);
                            write_row_i(&mut out[r * s.n..(r + 1) * s.n], &acc, &s.fused);
                        }
                    }
                }
                bufs[s.out] = out;
            }
            Step::Conv(s) => {
                let per_out = s.oc * s.oh * s.ow;
                let need = b * per_out;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.x][..b * s.c * s.h * s.w];
                let mut cols = std::mem::take(&mut s.cols);
                let (rows, k) = im2col_batched(x, b, s.c, s.h, s.w, s.spec, &mut cols);
                let frame = s.oh * s.ow;
                match &s.wmat {
                    WeightMat::F64(w) => {
                        let mut acc = vec![0.0f64; s.oc];
                        for r in 0..rows {
                            acc.iter_mut().for_each(|v| *v = 0.0);
                            mac_row_f64(&cols[r * k..(r + 1) * k], w, s.oc, &mut acc);
                            scatter_row(&mut out, &acc, r, frame, s.ow, per_out, &s.fused);
                        }
                    }
                    WeightMat::I32(w) => {
                        if s.cols32.len() < rows * k {
                            s.cols32.resize(rows * k, 0);
                        }
                        for (d, &v) in s.cols32.iter_mut().zip(cols[..rows * k].iter()) {
                            *d = v as i32;
                        }
                        let mut acc = vec![0i32; s.oc];
                        for r in 0..rows {
                            acc.iter_mut().for_each(|v| *v = 0);
                            mac_row_i32(&s.cols32[r * k..(r + 1) * k], w, s.oc, &mut acc);
                            scatter_row_i(&mut out, &acc, r, frame, s.ow, per_out, &s.fused);
                        }
                    }
                    WeightMat::I64(w) => {
                        if s.cols64.len() < rows * k {
                            s.cols64.resize(rows * k, 0);
                        }
                        for (d, &v) in s.cols64.iter_mut().zip(cols[..rows * k].iter()) {
                            *d = v as i64;
                        }
                        let mut acc = vec![0i64; s.oc];
                        for r in 0..rows {
                            acc.iter_mut().for_each(|v| *v = 0);
                            mac_row_i64(&s.cols64[r * k..(r + 1) * k], w, s.oc, &mut acc);
                            scatter_row_i(&mut out, &acc, r, frame, s.ow, per_out, &s.fused);
                        }
                    }
                }
                s.cols = cols;
                bufs[s.out] = out;
            }
            Step::Depthwise(s) => {
                let per_out = s.c * s.oh * s.ow;
                let need = b * per_out;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.x][..b * s.c * s.h * s.w];
                let (kh, kw) = s.spec.kernel;
                for bi in 0..b {
                    for ch in 0..s.c {
                        for oy in 0..s.oh {
                            for ox in 0..s.ow {
                                let mut acc = 0.0f64;
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let iy = (oy * s.spec.stride.0 + ky) as isize
                                            - s.spec.pad.0 as isize;
                                        let ix = (ox * s.spec.stride.1 + kx) as isize
                                            - s.spec.pad.1 as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= s.h as isize
                                            || ix >= s.w as isize
                                        {
                                            continue;
                                        }
                                        acc += x[((bi * s.c + ch) * s.h + iy as usize) * s.w
                                            + ix as usize]
                                            * s.weights[(ch * kh + ky) * kw + kx];
                                    }
                                }
                                let v = match &s.fused {
                                    Some(t) => t.apply_channel(acc, ch),
                                    None => acc,
                                };
                                out[((bi * s.c + ch) * s.oh + oy) * s.ow + ox] = v;
                            }
                        }
                    }
                }
                bufs[s.out] = out;
            }
            Step::Pool(s) => {
                let per_out = s.c * s.oh * s.ow;
                let need = b * per_out;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.x][..b * s.c * s.h * s.w];
                let (kh, kw) = s.spec.kernel;
                for bi in 0..b {
                    for ch in 0..s.c {
                        for oy in 0..s.oh {
                            for ox in 0..s.ow {
                                let mut acc = match s.kind {
                                    PoolKind::Max => f64::NEG_INFINITY,
                                    PoolKind::Average => 0.0,
                                };
                                let mut count = 0usize;
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let iy = (oy * s.spec.stride.0 + ky) as isize
                                            - s.spec.pad.0 as isize;
                                        let ix = (ox * s.spec.stride.1 + kx) as isize
                                            - s.spec.pad.1 as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= s.h as isize
                                            || ix >= s.w as isize
                                        {
                                            continue;
                                        }
                                        let v = x[((bi * s.c + ch) * s.h + iy as usize) * s.w
                                            + ix as usize];
                                        match s.kind {
                                            PoolKind::Max => acc = acc.max(v),
                                            PoolKind::Average => acc += v,
                                        }
                                        count += 1;
                                    }
                                }
                                out[((bi * s.c + ch) * s.oh + oy) * s.ow + ox] = match s.kind {
                                    PoolKind::Max => acc,
                                    PoolKind::Average => acc / count.max(1) as f64,
                                };
                            }
                        }
                    }
                }
                bufs[s.out] = out;
            }
            Step::Binary(s) => {
                let need = b * s.numel;
                let mut out = take_out(bufs, s.out, need);
                let xa = &bufs[s.a][..need];
                let xb = &bufs[s.b][..need];
                match s.kind {
                    BinKind::Add => ew2(xa, xb, &mut out[..need], |a, c| a + c),
                    BinKind::Sub => ew2(xa, xb, &mut out[..need], |a, c| a - c),
                    BinKind::Mul => ew2(xa, xb, &mut out[..need], |a, c| a * c),
                    BinKind::Div => ew2(xa, xb, &mut out[..need], |a, c| a / c),
                }
                bufs[s.out] = out;
            }
            Step::Generic(s) => {
                let need = b * s.out_numel;
                let mut out = take_out(bufs, s.out, need);
                for bi in 0..b {
                    let ins: Vec<Tensor> = s
                        .ins
                        .iter()
                        .map(|src| match src {
                            GSrc::Const(t) => Ok(t.clone()),
                            GSrc::Slot(id, shape) => {
                                let numel: usize = shape.iter().product();
                                Tensor::new(shape, bufs[*id][bi * numel..(bi + 1) * numel].to_vec())
                            }
                        })
                        .collect::<Result<_>>()?;
                    let y = execute_op(&s.op, &ins)
                        .with_context(|| format!("generic step {:?}", s.op.name()))?
                        .remove(0);
                    if y.numel() != s.out_numel {
                        bail!(
                            "generic step {} produced {} elements, expected {}",
                            s.op.name(),
                            y.numel(),
                            s.out_numel
                        );
                    }
                    out[bi * s.out_numel..(bi + 1) * s.out_numel].copy_from_slice(y.data());
                }
                bufs[s.out] = out;
            }
        }
        Ok(())
    }
}

#[inline]
fn ew2(a: &[f64], b: &[f64], out: &mut [f64], f: impl Fn(f64, f64) -> f64) {
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = f(x, y);
    }
}

/// Write one matmul output row, column channel = j.
#[inline]
fn write_row(out_row: &mut [f64], acc: &[f64], fused: &Option<ThresholdTable>) {
    match fused {
        None => out_row.copy_from_slice(acc),
        Some(t) => {
            for (j, (&v, o)) in acc.iter().zip(out_row.iter_mut()).enumerate() {
                *o = t.apply_channel(v, j);
            }
        }
    }
}

#[inline]
fn write_row_i<T: Copy + Into<i64>>(out_row: &mut [f64], acc: &[T], fused: &Option<ThresholdTable>) {
    match fused {
        None => {
            for (o, &v) in out_row.iter_mut().zip(acc.iter()) {
                *o = Into::<i64>::into(v) as f64;
            }
        }
        Some(t) => {
            for (j, (&v, o)) in acc.iter().zip(out_row.iter_mut()).enumerate() {
                *o = t.apply_channel(Into::<i64>::into(v) as f64, j);
            }
        }
    }
}

/// Scatter one conv row (output position `r`, all output channels) into
/// NCHW layout — the fold of the interpreter's final `permute`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn scatter_row(
    out: &mut [f64],
    acc: &[f64],
    r: usize,
    frame: usize,
    ow: usize,
    per_out: usize,
    fused: &Option<ThresholdTable>,
) {
    let bi = r / frame;
    let rem = r % frame;
    let oy = rem / ow;
    let ox = rem % ow;
    let oh = frame / ow;
    let base = bi * per_out + oy * ow + ox;
    for (j, &v) in acc.iter().enumerate() {
        let val = match fused {
            Some(t) => t.apply_channel(v, j),
            None => v,
        };
        out[base + j * oh * ow] = val;
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn scatter_row_i<T: Copy + Into<i64>>(
    out: &mut [f64],
    acc: &[T],
    r: usize,
    frame: usize,
    ow: usize,
    per_out: usize,
    fused: &Option<ThresholdTable>,
) {
    let bi = r / frame;
    let rem = r % frame;
    let oy = rem / ow;
    let ox = rem % ow;
    let oh = frame / ow;
    let base = bi * per_out + oy * ow + ox;
    for (j, &v) in acc.iter().enumerate() {
        let f = Into::<i64>::into(v) as f64;
        let val = match fused {
            Some(t) => t.apply_channel(f, j),
            None => f,
        };
        out[base + j * oh * ow] = val;
    }
}

/// Composition statistics of a compiled plan (also the observable for the
/// equivalence tests asserting the integer fast paths actually engage).
#[derive(Clone, Debug, Default)]
pub struct PlanStats {
    pub steps: usize,
    pub ew_chains: usize,
    pub fused_micro_ops: usize,
    pub matmul_f64: usize,
    pub matmul_i32: usize,
    pub matmul_i64: usize,
    pub conv_f64: usize,
    pub conv_i32: usize,
    pub conv_i64: usize,
    pub depthwise: usize,
    pub pool: usize,
    pub binary: usize,
    pub generic: usize,
    pub fused_thresholds: usize,
    pub folded_nodes: usize,
    pub logical_slots: usize,
    pub physical_buffers: usize,
}

impl PlanStats {
    /// MAC steps running on narrowed integer accumulators.
    pub fn integer_macs(&self) -> usize {
        self.matmul_i32 + self.matmul_i64 + self.conv_i32 + self.conv_i64
    }
}

impl std::fmt::Display for PlanStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} steps (ew {} / mm {}+{}i32+{}i64 / conv {}+{}i32+{}i64 / dw {} / pool {} / bin {} / gen {}), \
             {} fused thresholds, {} folded nodes, {} buffers for {} tensors",
            self.steps,
            self.ew_chains,
            self.matmul_f64,
            self.matmul_i32,
            self.matmul_i64,
            self.conv_f64,
            self.conv_i32,
            self.conv_i64,
            self.depthwise,
            self.pool,
            self.binary,
            self.generic,
            self.fused_thresholds,
            self.folded_nodes,
            self.physical_buffers,
            self.logical_slots,
        )
    }
}

/// A compiled, batched execution plan. See the module docs.
#[derive(Clone, Debug)]
pub struct Plan {
    pub(crate) name: String,
    pub(crate) steps: Vec<Step>,
    pub(crate) bufs: Vec<Vec<f64>>,
    pub(crate) input_phys: usize,
    pub(crate) input_shape: Vec<usize>,
    pub(crate) input_numel: usize,
    pub(crate) output_phys: usize,
    pub(crate) output_shape: Vec<usize>,
    pub(crate) output_numel: usize,
    /// Set when the whole graph constant-folds (degenerate but legal).
    pub(crate) const_output: Option<Tensor>,
    pub(crate) stats: PlanStats,
}

impl Plan {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn stats(&self) -> &PlanStats {
        &self.stats
    }

    /// Per-sample input shape the plan expects (leading dim 1).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Per-sample output shape (leading dim 1).
    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    /// Execute the plan over a batch of per-sample inputs; returns one
    /// output tensor per input, in order.
    pub fn run_batch(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let b = inputs.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        if let Some(t) = &self.const_output {
            return Ok(vec![t.clone(); b]);
        }
        for t in inputs {
            if t.shape() != &self.input_shape[..] {
                bail!(
                    "plan '{}': input shape {:?} does not match expected {:?}",
                    self.name,
                    t.shape(),
                    self.input_shape
                );
            }
        }
        // pack the batch into the input buffer
        {
            let need = b * self.input_numel;
            let ib = &mut self.bufs[self.input_phys];
            if ib.len() < need {
                ib.resize(need, 0.0);
            }
            for (i, t) in inputs.iter().enumerate() {
                ib[i * self.input_numel..(i + 1) * self.input_numel].copy_from_slice(t.data());
            }
        }
        let (steps, bufs) = (&mut self.steps, &mut self.bufs);
        for step in steps.iter_mut() {
            step.run(bufs, b)?;
        }
        let ob = &self.bufs[self.output_phys];
        (0..b)
            .map(|i| {
                Tensor::new(
                    &self.output_shape,
                    ob[i * self.output_numel..(i + 1) * self.output_numel].to_vec(),
                )
            })
            .collect()
    }

    /// Single-sample convenience wrapper.
    pub fn run_one(&mut self, x: &Tensor) -> Result<Tensor> {
        let mut out = self.run_batch(std::slice::from_ref(x))?;
        Ok(out.remove(0))
    }
}
