//! The compiled execution plan: a flat, topologically ordered list of
//! fused kernels over physical buffers, plus the batched multi-threaded
//! runner.
//!
//! A [`Plan`] is produced by [`super::fuse::compile`] from a graph and
//! its SIRA [`crate::sira::Analysis`]. All constants (weights, folded
//! quantizers, aggregated scales/biases, threshold tables, elided-channel
//! biases) are baked into the steps at compile time; at run time the only
//! dynamic state lives in per-task worker states (a liveness-managed
//! buffer arena plus conversion scratch, see [`super::pool`]), reused
//! across calls — the hot path performs no per-node graph resolution, no
//! name lookups, and no constant-tensor clones (all of which dominate the
//! interpretive [`crate::executor::Executor`]'s per-inference cost).
//!
//! # Parallel execution
//!
//! `Plan::run_batch` honours a thread budget ([`Plan::set_threads`])
//! backed by a persistent [`super::pool::WorkerPool`] shared by every
//! clone of the plan — work items are queue pushes, not thread spawns,
//! so parallelism no longer pays a per-call spawn cost. Two composable
//! sharding strategies, both bit-exact:
//!
//! * **Sample sharding** — the batch is split into contiguous chunks,
//!   one pool work item per chunk (the submitting thread runs the tail
//!   chunk itself), each checking out a private worker state so buffers
//!   never cross threads mid-task. Samples are independent in every
//!   kernel, so per-shard results are the bits the serial runner would
//!   produce.
//! * **Row/channel sharding inside MVU kernels** — leftover budget
//!   (notably at batch 1) splits large MatMul steps across output rows
//!   (or output columns when there is only one row) and large Conv steps
//!   across output channels, again as pool work items. Shard boundaries
//!   always fall *between* output elements — no dot product is ever
//!   split — so each output element is accumulated in exactly the
//!   reference order. [`Plan::set_min_kernel_work`] tunes the MAC volume
//!   below which a kernel stays serial.
//!
//! Orthogonally to sharding, MAC kernels above [`Plan::set_min_tile_work`]
//! execute on the tiled, register-blocked cores in
//! [`super::kernels::tile`] (pre-packed weights, `MR × NR` accumulator
//! grids the compiler keeps in SIMD registers); smaller kernels stay on
//! the scalar [`super::kernels::MacElem::mac_row`] oracle. The two are
//! bit-identical — locked by `rust/tests/kernel_properties.rs` and the
//! differential harness — and tiled column/channel shards align to the
//! panel width so work items never stream the same weight panel twice.
//!
//! # Segmented execution
//!
//! [`super::segment::SegmentedPlan`] additionally splits the step list
//! at minimal-live-buffer boundaries so the serving coordinator can
//! pipeline consecutive batches across segments; the per-segment runner
//! here ([`PlanView::run_steps`]) executes exactly the same steps on the
//! same buffers, which is why segmentation is bit-exact by construction.

use anyhow::{bail, Context, Result};

use crate::executor::execute_op;
use crate::graph::Op;
use crate::obs::profile::PlanProfiler;
use crate::tensor::{Conv2dSpec, PoolKind, Tensor};

use super::fuse::{I32_LIMIT, I64_LIMIT};
use super::kernels::{
    im2col_batched, im2col_channels, tile, BiasRef, MacElem, MacMat, MicroOp, ThresholdTable,
    WeightMat,
};
use super::pool::{chunk_len, Scratch, WorkerPool, WorkerState};
use super::tune::{TilingScheme, TuningTable};

use std::sync::Arc;

/// Below this many MAC operations (`rows * k * n`) a kernel is run on one
/// thread regardless of the budget. With the persistent pool a work item
/// costs a queue push rather than a thread spawn, so the default sits an
/// order of magnitude below the PR 2 spawn-amortising threshold; tune per
/// deployment via [`Plan::set_min_kernel_work`] /
/// [`Plan::with_min_kernel_work`] (0 forces sharding, `usize::MAX`
/// disables it).
const DEFAULT_MIN_KERNEL_WORK: usize = 1 << 12;

/// Below this many MAC operations a kernel runs on the scalar
/// [`MacElem::mac_row`] oracle instead of the register-blocked tiled
/// kernels ([`tile`]): on micro shapes the blocked form's lane setup
/// outweighs its throughput, and the scalar path costs nothing to keep
/// (both are bit-identical, so this is purely a performance knob). Tune
/// per deployment via [`Plan::set_min_tile_work`] /
/// [`Plan::with_min_tile_work`] (0 forces the tiled path everywhere,
/// `usize::MAX` keeps every kernel on the scalar oracle).
const DEFAULT_MIN_TILE_WORK: usize = 1 << 10;

/// Stuck-channel elision (§7.1) applied to an integer MAC step: `live`
/// lists the input positions (MatMul) or input channels (Conv) still fed
/// to the kernel; the constant contribution of the elided positions is
/// folded into `bias`, which seeds the accumulator. For MatMul and
/// unpadded Conv the bias is one value per output column
/// (`pos_stride == 0`); for padded Conv the border taps of a stuck
/// channel fall on pad zeros instead of the stuck value, so the folded
/// contribution varies by output position and `bias` holds
/// `oh * ow * oc` values with `pos_stride == oc` (position-major).
/// Integer accumulation is exact and order-free, so seeding with the
/// elided partial sum is bit-identical to accumulating it in-place —
/// which is why elision is only ever applied to I32/I64 kernels, never
/// F64.
#[derive(Clone, Debug)]
pub(crate) struct MacElide {
    pub live: Vec<usize>,
    pub bias: Vec<i64>,
    /// 0 = one bias per output column; `oc` = per-output-position rows.
    pub pos_stride: usize,
}

impl MacElide {
    fn bias_ref(&self) -> BiasRef<'_> {
        BiasRef {
            bias: &self.bias,
            pos_stride: self.pos_stride,
        }
    }
}

/// Fused elementwise chain: one pass over the input applying a sequence
/// of micro-ops per element (aggregated scales/biases, quantizers,
/// activations, thresholds).
#[derive(Clone, Debug)]
pub(crate) struct EwChainStep {
    pub input: usize,
    pub out: usize,
    /// per-sample element count (input and output shapes agree)
    pub numel: usize,
    pub ops: Vec<MicroOp>,
}

/// Batched matrix multiply against a constant weight matrix, optionally
/// finishing each output element through a fused threshold table.
#[derive(Clone, Debug)]
pub(crate) struct MatMulStep {
    pub a: usize,
    pub out: usize,
    /// per-sample rows of the left operand (1 for the zoo workloads)
    pub m: usize,
    /// logical dot length of the input row (gather source width)
    pub k: usize,
    pub n: usize,
    /// `(k_eff, n)` where `k_eff = elide.live.len()` when elided
    pub w: WeightMat,
    pub fused: Option<ThresholdTable>,
    pub elide: Option<MacElide>,
    /// SIRA worst-case partial-sum magnitude bound (the `peak` that
    /// selected the accumulator width), or `0.0` when no bound was
    /// proven. KC-blocked execution reorders the k accumulation, which
    /// is only bit-exact when every intermediate is wrap-free — so a
    /// blocked scheme engages only when this bound also clears the
    /// accumulator width's limit (see [`kc_safe`]).
    pub kc_bound: f64,
    /// Tiling geometry resolved against the machine-local tuning table
    /// at compile/load time ([`Plan::apply_tuning`]); never serialized.
    pub scheme: TilingScheme,
}

impl MatMulStep {
    fn k_eff(&self) -> usize {
        self.elide.as_ref().map_or(self.k, |e| e.live.len())
    }
}

/// Dense convolution as batched im2col + matrix multiply, scattering
/// results straight into NCHW layout (the `permute` the interpreter
/// performs is folded into the output indexing), with optional fused
/// per-channel thresholding.
#[derive(Clone, Debug)]
pub(crate) struct ConvStep {
    pub x: usize,
    pub out: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oc: usize,
    pub oh: usize,
    pub ow: usize,
    pub spec: Conv2dSpec,
    /// `(k_eff, oc)` weight matrix, `k_eff = live_channels * kh * kw`
    pub wmat: WeightMat,
    pub fused: Option<ThresholdTable>,
    /// `live` holds input *channel* indices here
    pub elide: Option<MacElide>,
    /// SIRA partial-sum bound gating KC-blocked reordering (see
    /// [`MatMulStep::kc_bound`]); `0.0` = unproven.
    pub kc_bound: f64,
    /// Machine-local tiling geometry ([`Plan::apply_tuning`]).
    pub scheme: TilingScheme,
}

/// Accumulator-width view of a depthwise step's taps: integer copies
/// when SIRA proved the per-channel dot bound fits (`kc_bound` against
/// the same limits MatMul/Conv use), f64 otherwise. Derived from
/// `weights` at compile *and* at snapshot decode — never serialized.
#[derive(Clone, Debug)]
pub(crate) enum DwTaps {
    F64,
    I32(Vec<i32>),
    I64(Vec<i64>),
}

/// Depthwise convolution (per-channel kernels), optional fused threshold.
#[derive(Clone, Debug)]
pub(crate) struct DepthwiseStep {
    pub x: usize,
    pub out: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oh: usize,
    pub ow: usize,
    pub spec: Conv2dSpec,
    /// `(c, kh, kw)` flattened — the reference f64 taps
    pub weights: Vec<f64>,
    pub fused: Option<ThresholdTable>,
    /// integer tap copies when the SIRA per-channel bound allows
    pub taps: DwTaps,
    /// worst per-channel `amax_c * Σ|w_c|` bound (`0.0` = unproven)
    pub kc_bound: f64,
    /// §7.1 stuck-channel elision for the depthwise path: channels whose
    /// every input element is SIRA-stuck never run the kernel — their
    /// finished (thresholded) `oh*ow` output plane is precomputed at
    /// compile time with the exact scalar f64 tap order, so copying it
    /// is bit-identical to recomputing on any width. Sorted by channel.
    pub elided: Vec<(usize, Vec<f64>)>,
}

impl DepthwiseStep {
    fn elided_plane(&self, ch: usize) -> Option<&[f64]> {
        self.elided
            .iter()
            .find(|(c, _)| *c == ch)
            .map(|(_, p)| p.as_slice())
    }
}

/// Max/average pooling over NCHW (count_include_pad = false, identical
/// to [`crate::tensor::pool2d`]).
#[derive(Clone, Debug)]
pub(crate) struct PoolStep {
    pub x: usize,
    pub out: usize,
    pub kind: PoolKind,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oh: usize,
    pub ow: usize,
    pub spec: Conv2dSpec,
}

#[derive(Clone, Copy, Debug)]
pub(crate) enum BinKind {
    Add,
    Sub,
    Mul,
    Div,
}

/// Elementwise binary op over two same-shape dynamic tensors (residual
/// adds and friends).
#[derive(Clone, Debug)]
pub(crate) struct BinaryStep {
    pub a: usize,
    pub b: usize,
    pub out: usize,
    pub numel: usize,
    pub kind: BinKind,
}

/// Source of a generic-step operand.
#[derive(Clone, Debug)]
pub(crate) enum GSrc {
    /// dynamic tensor: (slot, per-sample shape)
    Slot(usize, Vec<usize>),
    Const(Tensor),
}

/// Fallback: execute the reference operator per sample via
/// [`crate::executor::execute_op`]. Slow but exact and fully general —
/// anything the interpreter runs, the plan runs.
#[derive(Clone, Debug)]
pub(crate) struct GenericStep {
    pub op: Op,
    pub ins: Vec<GSrc>,
    pub out: usize,
    pub out_shape: Vec<usize>,
    pub out_numel: usize,
}

#[derive(Clone, Debug)]
pub(crate) enum Step {
    Ew(EwChainStep),
    MatMul(MatMulStep),
    Conv(ConvStep),
    Depthwise(DepthwiseStep),
    Pool(PoolStep),
    Binary(BinaryStep),
    Generic(GenericStep),
}

impl Step {
    /// Logical slots this step reads.
    pub(crate) fn reads(&self) -> Vec<usize> {
        match self {
            Step::Ew(s) => vec![s.input],
            Step::MatMul(s) => vec![s.a],
            Step::Conv(s) => vec![s.x],
            Step::Depthwise(s) => vec![s.x],
            Step::Pool(s) => vec![s.x],
            Step::Binary(s) => vec![s.a, s.b],
            Step::Generic(s) => s
                .ins
                .iter()
                .filter_map(|src| match src {
                    GSrc::Slot(id, _) => Some(*id),
                    GSrc::Const(_) => None,
                })
                .collect(),
        }
    }

    /// Logical slots this step writes.
    pub(crate) fn writes(&self) -> Vec<usize> {
        match self {
            Step::Ew(s) => vec![s.out],
            Step::MatMul(s) => vec![s.out],
            Step::Conv(s) => vec![s.out],
            Step::Depthwise(s) => vec![s.out],
            Step::Pool(s) => vec![s.out],
            Step::Binary(s) => vec![s.out],
            Step::Generic(s) => vec![s.out],
        }
    }

    /// Per-sample element count of the output this step writes (the
    /// live-buffer transfer unit for segment boundary analysis).
    pub(crate) fn out_numel(&self) -> usize {
        match self {
            Step::Ew(s) => s.numel,
            Step::MatMul(s) => s.m * s.n,
            Step::Conv(s) => s.oc * s.oh * s.ow,
            Step::Depthwise(s) => s.c * s.oh * s.ow,
            Step::Pool(s) => s.c * s.oh * s.ow,
            Step::Binary(s) => s.numel,
            Step::Generic(s) => s.out_numel,
        }
    }

    /// Rough per-sample operation count — the load-balancing weight for
    /// segment boundary placement. Only relative magnitudes matter.
    pub(crate) fn work(&self) -> u64 {
        let w = match self {
            Step::Ew(s) => s.numel * s.ops.len().max(1),
            Step::MatMul(s) => s.m * s.k_eff() * s.n,
            Step::Conv(s) => {
                let k_eff = match &s.elide {
                    Some(e) => e.live.len() * s.spec.kernel.0 * s.spec.kernel.1,
                    None => s.c * s.spec.kernel.0 * s.spec.kernel.1,
                };
                s.oh * s.ow * k_eff * s.oc
            }
            Step::Depthwise(s) => s.c * s.oh * s.ow * s.spec.kernel.0 * s.spec.kernel.1,
            Step::Pool(s) => s.c * s.oh * s.ow * s.spec.kernel.0 * s.spec.kernel.1,
            Step::Binary(s) => s.numel,
            // interpreter round trip: charge a healthy constant factor
            Step::Generic(s) => s.out_numel * 16,
        };
        w as u64
    }

    /// Rewrite logical slot ids to physical buffer ids.
    pub(crate) fn remap(&mut self, phys: &[usize]) {
        match self {
            Step::Ew(s) => {
                s.input = phys[s.input];
                s.out = phys[s.out];
            }
            Step::MatMul(s) => {
                s.a = phys[s.a];
                s.out = phys[s.out];
            }
            Step::Conv(s) => {
                s.x = phys[s.x];
                s.out = phys[s.out];
            }
            Step::Depthwise(s) => {
                s.x = phys[s.x];
                s.out = phys[s.out];
            }
            Step::Pool(s) => {
                s.x = phys[s.x];
                s.out = phys[s.out];
            }
            Step::Binary(s) => {
                s.a = phys[s.a];
                s.b = phys[s.b];
                s.out = phys[s.out];
            }
            Step::Generic(s) => {
                for src in &mut s.ins {
                    if let GSrc::Slot(id, _) = src {
                        *id = phys[*id];
                    }
                }
                s.out = phys[s.out];
            }
        }
    }
}

/// Immutable execution parameters threaded through a step run: the pool
/// to submit intra-kernel work items to (None = fully serial), the
/// intra-kernel thread budget, the sharding gate, the tiled-kernel
/// gate, and the optional step profiler (None = zero-cost).
#[derive(Clone, Copy)]
pub(crate) struct ExecCtx<'a> {
    pub pool: Option<&'a WorkerPool>,
    pub kt: usize,
    pub min_work: usize,
    pub min_tile: usize,
    pub prof: Option<&'a PlanProfiler>,
}

impl ExecCtx<'_> {
    /// Effective intra-kernel budget for a MAC of `work` volume: the full
    /// budget when it clears the gate (and a pool exists), else serial.
    fn kernel_threads(&self, work: usize) -> usize {
        if self.pool.is_some() && work >= self.min_work {
            self.kt
        } else {
            1
        }
    }

    /// Whether a MAC of `work` volume runs on the tiled kernels.
    fn tiled(&self, work: usize) -> bool {
        work >= self.min_tile
    }
}

/// Take a physical output buffer out of the arena, grown to `need`.
/// The buffer is detached so input buffers can be borrowed immutably
/// while it is written; the caller puts it back when done.
#[inline]
fn take_out(bufs: &mut [Vec<f64>], phys: usize, need: usize) -> Vec<f64> {
    let mut v = std::mem::take(&mut bufs[phys]);
    if v.len() < need {
        v.resize(need, 0.0);
    }
    v
}

/// Convert (and, under elision, gather the live positions of) `rows`
/// activation rows of logical width `k` into `dst` at the accumulator
/// width; returns the effective row width.
fn gather_rows<T: MacElem>(
    a: &[f64],
    rows: usize,
    k: usize,
    live: Option<&[usize]>,
    dst: &mut Vec<T>,
) -> usize {
    match live {
        None => {
            if dst.len() < rows * k {
                dst.resize(rows * k, T::ZERO);
            }
            for (d, &v) in dst.iter_mut().zip(a.iter()) {
                *d = T::from_f64(v);
            }
            k
        }
        Some(idx) => {
            let ke = idx.len();
            if dst.len() < rows * ke {
                dst.resize(rows * ke, T::ZERO);
            }
            for r in 0..rows {
                let src = &a[r * k..(r + 1) * k];
                let row = &mut dst[r * ke..(r + 1) * ke];
                for (d, &kk) in row.iter_mut().zip(idx.iter()) {
                    *d = T::from_f64(src[kk]);
                }
            }
            ke
        }
    }
}

/// Seed an accumulator span for output columns `j0..j0+acc.len()` at
/// output position `rp`: the elided-channel bias when present (uniform
/// across positions when `pos_stride == 0`), zero otherwise.
#[inline]
fn seed_acc<T: MacElem>(acc: &mut [T], bias: Option<BiasRef<'_>>, j0: usize, rp: usize) {
    match bias {
        None => acc.iter_mut().for_each(|v| *v = T::ZERO),
        Some(b) => {
            let base = rp * b.pos_stride + j0;
            for (jj, v) in acc.iter_mut().enumerate() {
                *v = T::from_i64(b.bias[base + jj]);
            }
        }
    }
}

/// MAC a block of rows over output columns `cols`, writing finished
/// values (optionally thresholded) row-major into `out` (row stride
/// `cols.len()`). The single compute core behind the serial, row-sharded
/// and column-sharded matmul paths. MatMul rows are batch samples, so
/// the bias (when present) is always per-column (`pos_stride == 0`).
fn mm_block<T: MacElem>(
    a: &[T],
    w: &[T],
    rows: usize,
    k: usize,
    n: usize,
    cols: core::ops::Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
) {
    let width = cols.len();
    let mut acc = vec![T::ZERO; width];
    for r in 0..rows {
        seed_acc(&mut acc, bias, cols.start, 0);
        T::mac_row(&a[r * k..(r + 1) * k], w, n, cols.clone(), &mut acc);
        let out_row = &mut out[r * width..(r + 1) * width];
        for (jj, (&v, o)) in acc.iter().zip(out_row.iter_mut()).enumerate() {
            let f = v.to_f64();
            *o = match fused {
                Some(t) => t.apply_channel(f, cols.start + jj),
                None => f,
            };
        }
    }
}

/// Resolved parallelism of one MAC step: the intra-kernel work-item
/// budget (already gated on `min_kernel_work`), the pool to submit to,
/// whether the kernel cleared the tiled gate (`min_tile_work`), and the
/// tuned tiling geometry plus whether the KC-blocked kernel may engage
/// (tuned scheme deviates from default *and* the step's SIRA bound
/// proves the reordered partial sums wrap-free at the accumulator
/// width).
#[derive(Clone, Copy)]
struct MacPar<'a> {
    kt: usize,
    pool: Option<&'a WorkerPool>,
    tiled: bool,
    scheme: TilingScheme,
    blocked: bool,
}

/// Whether a step's SIRA partial-sum bound proves the KC-blocked
/// k-order safe at the chosen accumulator width. `0.0` is the
/// no-proof sentinel; f64 accumulators are never blocked (float
/// addition is not associative, so reordering would change bits).
fn kc_safe(kc_bound: f64, w: &WeightMat) -> bool {
    match w {
        WeightMat::F64(_) => false,
        WeightMat::I32(_) => kc_bound > 0.0 && kc_bound < I32_LIMIT,
        WeightMat::I64(_) => kc_bound > 0.0 && kc_bound < I64_LIMIT,
    }
}

/// One matmul chunk on one of the three MAC cores: KC-blocked (tuned
/// geometry, proven-safe steps only), tiled register blocks, or the
/// scalar oracle — all bit-identical by the kernel property suite, so
/// the dispatch is purely a performance decision.
#[allow(clippy::too_many_arguments)]
fn mm_chunk<T: MacElem>(
    a: &[T],
    w: &MacMat<T>,
    rows: usize,
    k: usize,
    n: usize,
    cols: core::ops::Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    par: MacPar<'_>,
) {
    if par.blocked {
        // chunks may run as pool work items, so the spill accumulator is
        // a call-local allocation (same precedent as mm_block's)
        let mut acc = Vec::new();
        let s = par.scheme;
        tile::mac_block_blocked(
            a,
            w.packed(),
            rows,
            cols,
            bias,
            fused,
            out,
            tile::TiledOut::RowMajor,
            s.mr,
            s.nr_panels,
            s.kc,
            &mut acc,
        );
        return;
    }
    match w.flat() {
        // the scalar oracle needs the flat copy; once it is dropped
        // (serve-time memory trim) every MAC dispatches tiled — same
        // bits either way, so only memory and speed change
        Some(flat) if !par.tiled => mm_block(a, flat, rows, k, n, cols, bias, fused, out),
        _ => {
            let layout = tile::TiledOut::RowMajor;
            tile::mac_block_tiled(a, w.packed(), rows, cols, bias, fused, out, layout);
        }
    }
}

/// Batched matmul over `rows * k` activations: serial, or sharded across
/// rows (batch/m parallelism), or across output columns when only one
/// row exists (the single-sample large-layer case). Sharded work items
/// are submitted to the persistent pool; the submitting thread computes
/// the tail chunk itself. Column shards of a tiled kernel align to the
/// [`tile::NR`] panel width so no two work items touch the same weight
/// panel (shard boundaries still never split a dot product either way).
#[allow(clippy::too_many_arguments)]
fn run_mm<T: MacElem>(
    a: &[T],
    w: &MacMat<T>,
    rows: usize,
    k: usize,
    n: usize,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    par: MacPar<'_>,
) {
    debug_assert_eq!(w.k(), k, "weight rows must match the gathered row width");
    debug_assert_eq!(w.n(), n);
    let tiled = par.tiled;
    let out = &mut out[..rows * n];
    let kt = par.kt;
    let pool = if kt > 1 { par.pool } else { None };
    if let Some(pool) = pool {
        if rows >= 2 {
            let per = rows.div_ceil(kt);
            pool.scope(|sc| {
                let mut rest = out;
                let mut r0 = 0usize;
                while r0 < rows {
                    let r1 = (r0 + per).min(rows);
                    let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
                    rest = tail;
                    let a_block = &a[r0 * k..r1 * k];
                    if r1 == rows {
                        mm_chunk(a_block, w, r1 - r0, k, n, 0..n, bias, fused, chunk, par);
                    } else {
                        sc.spawn(move || {
                            mm_chunk(a_block, w, r1 - r0, k, n, 0..n, bias, fused, chunk, par)
                        });
                    }
                    r0 = r1;
                }
            });
            return;
        }
        if rows == 1 && n >= 2 * kt {
            let per = chunk_len(n, kt, if tiled { tile::NR } else { 1 });
            pool.scope(|sc| {
                let mut rest = out;
                let mut j0 = 0usize;
                while j0 < n {
                    let j1 = (j0 + per).min(n);
                    let (chunk, tail) = rest.split_at_mut(j1 - j0);
                    rest = tail;
                    if j1 == n {
                        mm_chunk(a, w, 1, k, n, j0..j1, bias, fused, chunk, par);
                    } else {
                        sc.spawn(move || {
                            mm_chunk(a, w, 1, k, n, j0..j1, bias, fused, chunk, par)
                        });
                    }
                    j0 = j1;
                }
            });
            return;
        }
    }
    mm_chunk(a, w, rows, k, n, 0..n, bias, fused, out, par);
}

/// One sample's conv MAC over output channels `jr`: for every output
/// position `rp` accumulate the im2col row against the weight columns and
/// scatter into the channel-major chunk (`chunk[(j - jr.start) * frame +
/// rp]`), folding the interpreter's final permute into the indexing.
#[allow(clippy::too_many_arguments)]
fn conv_block<T: MacElem>(
    cols: &[T],
    w: &[T],
    frame: usize,
    k: usize,
    n: usize,
    jr: core::ops::Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    chunk: &mut [f64],
) {
    let mut acc = vec![T::ZERO; jr.len()];
    for rp in 0..frame {
        seed_acc(&mut acc, bias, jr.start, rp);
        T::mac_row(&cols[rp * k..(rp + 1) * k], w, n, jr.clone(), &mut acc);
        for (jj, &v) in acc.iter().enumerate() {
            let f = v.to_f64();
            chunk[jj * frame + rp] = match fused {
                Some(t) => t.apply_channel(f, jr.start + jj),
                None => f,
            };
        }
    }
}

/// One conv output-channel chunk on one of the three MAC cores
/// (KC-blocked on proven-safe steps, tiled register blocks over the
/// output positions, or the scalar oracle) — same bits every way.
#[allow(clippy::too_many_arguments)]
fn conv_chunk<T: MacElem>(
    cols: &[T],
    w: &MacMat<T>,
    frame: usize,
    k: usize,
    oc: usize,
    jr: core::ops::Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    chunk: &mut [f64],
    par: MacPar<'_>,
) {
    if par.blocked {
        let mut acc = Vec::new();
        let s = par.scheme;
        tile::mac_block_blocked(
            cols,
            w.packed(),
            frame,
            jr,
            bias,
            fused,
            chunk,
            tile::TiledOut::ChannelMajor { frame },
            s.mr,
            s.nr_panels,
            s.kc,
            &mut acc,
        );
        return;
    }
    match w.flat() {
        Some(flat) if !par.tiled => conv_block(cols, flat, frame, k, oc, jr, bias, fused, chunk),
        _ => tile::mac_block_tiled(
            cols,
            w.packed(),
            frame,
            jr,
            bias,
            fused,
            chunk,
            tile::TiledOut::ChannelMajor { frame },
        ),
    }
}

/// Batched conv MAC: per sample, optionally sharding the output-channel
/// axis across pool work items (each shard's NCHW output region is
/// contiguous, so no two tasks ever share a cache line, let alone an
/// element); the submitting thread computes the tail shard itself.
/// Channel shards of a tiled kernel align to the [`tile::NR`] panel
/// width so no two work items recompute the same weight panel.
#[allow(clippy::too_many_arguments)]
fn run_conv<T: MacElem>(
    cols: &[T],
    w: &MacMat<T>,
    b: usize,
    frame: usize,
    k: usize,
    oc: usize,
    per_out: usize,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    par: MacPar<'_>,
) {
    debug_assert_eq!(w.k(), k, "weight rows must match the im2col row width");
    debug_assert_eq!(w.n(), oc);
    let tiled = par.tiled;
    let kt = par.kt;
    let pool = if kt > 1 && oc >= 2 { par.pool } else { None };
    for bi in 0..b {
        let sample_cols = &cols[bi * frame * k..(bi + 1) * frame * k];
        let sample_out = &mut out[bi * per_out..(bi + 1) * per_out];
        match pool {
            Some(pool) => {
                let per = chunk_len(oc, kt, if tiled { tile::NR } else { 1 });
                pool.scope(|sc| {
                    let mut rest = sample_out;
                    let mut j0 = 0usize;
                    while j0 < oc {
                        let j1 = (j0 + per).min(oc);
                        let (chunk, tail) = rest.split_at_mut((j1 - j0) * frame);
                        rest = tail;
                        if j1 == oc {
                            let jr = j0..j1;
                            conv_chunk(sample_cols, w, frame, k, oc, jr, bias, fused, chunk, par);
                        } else {
                            sc.spawn(move || {
                                conv_chunk(
                                    sample_cols,
                                    w,
                                    frame,
                                    k,
                                    oc,
                                    j0..j1,
                                    bias,
                                    fused,
                                    chunk,
                                    par,
                                )
                            });
                        }
                        j0 = j1;
                    }
                });
            }
            None => {
                conv_chunk(sample_cols, w, frame, k, oc, 0..oc, bias, fused, sample_out, par)
            }
        }
    }
}

/// Tiled depthwise runner: per channel, copy the precomputed plane for
/// elided (stuck) channels, otherwise convert the channel's input plane
/// to the accumulator width and sweep it through the row-tiled AXPY
/// kernel ([`tile::dw_channel_rows`]) — same tap order as the scalar
/// loop, so bit-exact on every width the SIRA bound admits.
fn run_dw_tiled<T: MacElem>(
    s: &DepthwiseStep,
    taps: &[T],
    x: &[f64],
    b: usize,
    out: &mut [f64],
    xbuf: &mut Vec<T>,
) {
    let (kh, kw) = s.spec.kernel;
    let hw = s.h * s.w;
    let ohw = s.oh * s.ow;
    let mut rowacc: Vec<T> = Vec::new();
    for bi in 0..b {
        for ch in 0..s.c {
            let out_plane = &mut out[(bi * s.c + ch) * ohw..(bi * s.c + ch + 1) * ohw];
            if let Some(plane) = s.elided_plane(ch) {
                out_plane.copy_from_slice(plane);
                continue;
            }
            let xin = &x[(bi * s.c + ch) * hw..(bi * s.c + ch + 1) * hw];
            xbuf.clear();
            xbuf.extend(xin.iter().map(|&v| T::from_f64(v)));
            tile::dw_channel_rows(
                xbuf,
                s.h,
                s.w,
                s.oh,
                s.ow,
                s.spec,
                &taps[ch * kh * kw..(ch + 1) * kh * kw],
                ch,
                &s.fused,
                out_plane,
                &mut rowacc,
            );
        }
    }
}

/// Scalar depthwise reference loop (per-position tap accumulation in
/// f64), with the same elided-plane copies as the tiled path.
fn run_dw_scalar(s: &DepthwiseStep, x: &[f64], b: usize, out: &mut [f64]) {
    let (kh, kw) = s.spec.kernel;
    let ohw = s.oh * s.ow;
    for bi in 0..b {
        for ch in 0..s.c {
            if let Some(plane) = s.elided_plane(ch) {
                out[(bi * s.c + ch) * ohw..(bi * s.c + ch + 1) * ohw].copy_from_slice(plane);
                continue;
            }
            for oy in 0..s.oh {
                for ox in 0..s.ow {
                    let mut acc = 0.0f64;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * s.spec.stride.0 + ky) as isize - s.spec.pad.0 as isize;
                            let ix = (ox * s.spec.stride.1 + kx) as isize - s.spec.pad.1 as isize;
                            if iy < 0 || ix < 0 || iy >= s.h as isize || ix >= s.w as isize {
                                continue;
                            }
                            acc += x[((bi * s.c + ch) * s.h + iy as usize) * s.w + ix as usize]
                                * s.weights[(ch * kh + ky) * kw + kx];
                        }
                    }
                    let v = match &s.fused {
                        Some(t) => t.apply_channel(acc, ch),
                        None => acc,
                    };
                    out[((bi * s.c + ch) * s.oh + oy) * s.ow + ox] = v;
                }
            }
        }
    }
}

impl Step {
    /// Short kind label for profiling reports: the step family plus the
    /// accumulator width for MAC steps (`matmul(i32)`, `conv(i64)`), the
    /// fused micro-op count for elementwise chains (`ew[3]`), the op
    /// name for interpreter fallbacks.
    pub(crate) fn kind_label(&self) -> String {
        fn width(w: &WeightMat) -> &'static str {
            match w {
                WeightMat::F64(_) => "f64",
                WeightMat::I32(_) => "i32",
                WeightMat::I64(_) => "i64",
            }
        }
        match self {
            Step::Ew(s) => format!("ew[{}]", s.ops.len()),
            Step::MatMul(s) => format!("matmul({})", width(&s.w)),
            Step::Conv(s) => format!("conv({})", width(&s.wmat)),
            Step::Depthwise(s) => {
                let w = match &s.taps {
                    DwTaps::F64 => "f64",
                    DwTaps::I32(_) => "i32",
                    DwTaps::I64(_) => "i64",
                };
                format!("depthwise({w})")
            }
            Step::Pool(s) => match s.kind {
                PoolKind::Max => "pool(max)".to_string(),
                PoolKind::Average => "pool(avg)".to_string(),
            },
            Step::Binary(_) => "binary".to_string(),
            Step::Generic(s) => format!("generic({})", s.op.name()),
        }
    }

    /// Execute one step over a `b`-sample shard under `ctx` (intra-kernel
    /// budget, sharding gate, pool).
    fn run(
        &self,
        bufs: &mut [Vec<f64>],
        scratch: &mut Scratch,
        b: usize,
        ctx: &ExecCtx,
    ) -> Result<()> {
        match self {
            Step::Ew(s) => {
                let need = b * s.numel;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.input][..need];
                let numel = s.numel;
                for (i, (&v0, o)) in x.iter().zip(out[..need].iter_mut()).enumerate() {
                    let si = i % numel;
                    let mut v = v0;
                    for op in &s.ops {
                        v = op.apply(v, si);
                    }
                    *o = v;
                }
                bufs[s.out] = out;
            }
            Step::MatMul(s) => {
                let rows = b * s.m;
                let need = rows * s.n;
                let mut out = take_out(bufs, s.out, need);
                let a = &bufs[s.a][..rows * s.k];
                let k_eff = s.k_eff();
                let live = s.elide.as_ref().map(|e| e.live.as_slice());
                let bias = s.elide.as_ref().map(|e| e.bias_ref());
                let work = rows * k_eff * s.n;
                let tiled = ctx.tiled(work) || !s.w.has_flat();
                let par = MacPar {
                    kt: ctx.kernel_threads(work),
                    pool: ctx.pool,
                    // no flat oracle (dropped at serve time) forces the
                    // bit-identical tiled path regardless of the gate
                    tiled,
                    scheme: s.scheme,
                    // the tuned KC-blocked geometry engages only on
                    // tiled-eligible steps whose SIRA bound proves the
                    // reordered k accumulation wrap-free at this width
                    blocked: tiled && s.scheme.is_blocked() && kc_safe(s.kc_bound, &s.w),
                };
                if let Some(p) = ctx.prof {
                    p.note_mac(par.tiled);
                }
                let fused = &s.fused;
                match &s.w {
                    WeightMat::F64(w) => {
                        debug_assert!(s.elide.is_none(), "elision is integer-only");
                        run_mm(a, w, rows, s.k, s.n, None, fused, &mut out, par);
                    }
                    WeightMat::I32(w) => {
                        gather_rows(a, rows, s.k, live, &mut scratch.i32v);
                        let at = &scratch.i32v[..rows * k_eff];
                        run_mm(at, w, rows, k_eff, s.n, bias, fused, &mut out, par);
                    }
                    WeightMat::I64(w) => {
                        gather_rows(a, rows, s.k, live, &mut scratch.i64v);
                        let at = &scratch.i64v[..rows * k_eff];
                        run_mm(at, w, rows, k_eff, s.n, bias, fused, &mut out, par);
                    }
                }
                bufs[s.out] = out;
            }
            Step::Conv(s) => {
                let per_out = s.oc * s.oh * s.ow;
                let need = b * per_out;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.x][..b * s.c * s.h * s.w];
                let frame = s.oh * s.ow;
                let cols = &mut scratch.cols;
                let (rows, k_eff) = match &s.elide {
                    Some(e) => im2col_channels(x, b, s.c, s.h, s.w, s.spec, &e.live, cols),
                    None => im2col_batched(x, b, s.c, s.h, s.w, s.spec, cols),
                };
                let bias = s.elide.as_ref().map(|e| e.bias_ref());
                let work = rows * k_eff * s.oc;
                let tiled = ctx.tiled(work) || !s.wmat.has_flat();
                let par = MacPar {
                    kt: ctx.kernel_threads(work),
                    pool: ctx.pool,
                    tiled,
                    scheme: s.scheme,
                    blocked: tiled && s.scheme.is_blocked() && kc_safe(s.kc_bound, &s.wmat),
                };
                if let Some(p) = ctx.prof {
                    p.note_mac(par.tiled);
                }
                let fused = &s.fused;
                let oc = s.oc;
                match &s.wmat {
                    WeightMat::F64(w) => {
                        debug_assert!(s.elide.is_none(), "elision is integer-only");
                        let ct = &cols[..rows * k_eff];
                        run_conv(ct, w, b, frame, k_eff, oc, per_out, None, fused, &mut out, par);
                    }
                    WeightMat::I32(w) => {
                        gather_rows(&cols[..rows * k_eff], rows, k_eff, None, &mut scratch.i32v);
                        let ct = &scratch.i32v[..rows * k_eff];
                        run_conv(ct, w, b, frame, k_eff, oc, per_out, bias, fused, &mut out, par);
                    }
                    WeightMat::I64(w) => {
                        gather_rows(&cols[..rows * k_eff], rows, k_eff, None, &mut scratch.i64v);
                        let ct = &scratch.i64v[..rows * k_eff];
                        run_conv(ct, w, b, frame, k_eff, oc, per_out, bias, fused, &mut out, par);
                    }
                }
                bufs[s.out] = out;
            }
            Step::Depthwise(s) => {
                let per_out = s.c * s.oh * s.ow;
                let need = b * per_out;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.x][..b * s.c * s.h * s.w];
                let work = b * s.c * s.oh * s.ow * s.spec.kernel.0 * s.spec.kernel.1;
                let tiled = ctx.tiled(work);
                if let Some(p) = ctx.prof {
                    p.note_mac(tiled);
                }
                if tiled {
                    // row-sweep AXPY kernel at the SIRA-chosen width
                    // (same tap order as the scalar loop — bit-exact)
                    match &s.taps {
                        DwTaps::I32(taps) => {
                            run_dw_tiled(s, taps, x, b, &mut out, &mut scratch.i32v)
                        }
                        DwTaps::I64(taps) => {
                            run_dw_tiled(s, taps, x, b, &mut out, &mut scratch.i64v)
                        }
                        DwTaps::F64 => {
                            run_dw_tiled(s, &s.weights, x, b, &mut out, &mut scratch.cols)
                        }
                    }
                } else {
                    run_dw_scalar(s, x, b, &mut out);
                }
                bufs[s.out] = out;
            }
            Step::Pool(s) => {
                let per_out = s.c * s.oh * s.ow;
                let need = b * per_out;
                let mut out = take_out(bufs, s.out, need);
                let x = &bufs[s.x][..b * s.c * s.h * s.w];
                let (kh, kw) = s.spec.kernel;
                for bi in 0..b {
                    for ch in 0..s.c {
                        for oy in 0..s.oh {
                            for ox in 0..s.ow {
                                let mut acc = match s.kind {
                                    PoolKind::Max => f64::NEG_INFINITY,
                                    PoolKind::Average => 0.0,
                                };
                                let mut count = 0usize;
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let iy = (oy * s.spec.stride.0 + ky) as isize
                                            - s.spec.pad.0 as isize;
                                        let ix = (ox * s.spec.stride.1 + kx) as isize
                                            - s.spec.pad.1 as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= s.h as isize
                                            || ix >= s.w as isize
                                        {
                                            continue;
                                        }
                                        let v = x[((bi * s.c + ch) * s.h + iy as usize) * s.w
                                            + ix as usize];
                                        match s.kind {
                                            PoolKind::Max => acc = acc.max(v),
                                            PoolKind::Average => acc += v,
                                        }
                                        count += 1;
                                    }
                                }
                                out[((bi * s.c + ch) * s.oh + oy) * s.ow + ox] = match s.kind {
                                    PoolKind::Max => acc,
                                    PoolKind::Average => acc / count.max(1) as f64,
                                };
                            }
                        }
                    }
                }
                bufs[s.out] = out;
            }
            Step::Binary(s) => {
                let need = b * s.numel;
                let mut out = take_out(bufs, s.out, need);
                let xa = &bufs[s.a][..need];
                let xb = &bufs[s.b][..need];
                match s.kind {
                    BinKind::Add => ew2(xa, xb, &mut out[..need], |a, c| a + c),
                    BinKind::Sub => ew2(xa, xb, &mut out[..need], |a, c| a - c),
                    BinKind::Mul => ew2(xa, xb, &mut out[..need], |a, c| a * c),
                    BinKind::Div => ew2(xa, xb, &mut out[..need], |a, c| a / c),
                }
                bufs[s.out] = out;
            }
            Step::Generic(s) => {
                let need = b * s.out_numel;
                let mut out = take_out(bufs, s.out, need);
                for bi in 0..b {
                    let ins: Vec<Tensor> = s
                        .ins
                        .iter()
                        .map(|src| match src {
                            GSrc::Const(t) => Ok(t.clone()),
                            GSrc::Slot(id, shape) => {
                                let numel: usize = shape.iter().product();
                                Tensor::new(shape, bufs[*id][bi * numel..(bi + 1) * numel].to_vec())
                            }
                        })
                        .collect::<Result<_>>()?;
                    let y = execute_op(&s.op, &ins)
                        .with_context(|| format!("generic step {:?}", s.op.name()))?
                        .remove(0);
                    if y.numel() != s.out_numel {
                        bail!(
                            "generic step {} produced {} elements, expected {}",
                            s.op.name(),
                            y.numel(),
                            s.out_numel
                        );
                    }
                    out[bi * s.out_numel..(bi + 1) * s.out_numel].copy_from_slice(y.data());
                }
                bufs[s.out] = out;
            }
        }
        Ok(())
    }
}

#[inline]
fn ew2(a: &[f64], b: &[f64], out: &mut [f64], f: impl Fn(f64, f64) -> f64) {
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = f(x, y);
    }
}

/// Composition statistics of a compiled plan (also the observable for the
/// equivalence tests asserting the integer fast paths actually engage).
#[derive(Clone, Debug, Default)]
pub struct PlanStats {
    pub steps: usize,
    pub ew_chains: usize,
    pub fused_micro_ops: usize,
    pub matmul_f64: usize,
    pub matmul_i32: usize,
    pub matmul_i64: usize,
    pub conv_f64: usize,
    pub conv_i32: usize,
    pub conv_i64: usize,
    pub depthwise: usize,
    pub pool: usize,
    pub binary: usize,
    pub generic: usize,
    pub fused_thresholds: usize,
    pub folded_nodes: usize,
    /// MAC steps with at least one stuck channel elided (§7.1)
    pub elided_mac_steps: usize,
    /// total stuck input channels removed from MAC kernels, their
    /// constant contribution folded into the accumulator-seeding bias
    pub elided_mac_channels: usize,
    /// elided Conv steps with nonzero padding, where the stuck/pad
    /// interaction folds into per-output-position biases
    pub elided_padded_convs: usize,
    /// total elements held by the tile-packed weight copies (padding
    /// included) — the packed-weights memory trade-off: ≈ one extra copy
    /// of every MAC weight matrix, rounded up to the `tile::NR` panel
    /// width (see README)
    pub packed_weight_elems: usize,
    /// total elements held by the flat scalar-oracle weight copies —
    /// zeroed by [`Plan::drop_flat_oracles`] at serve time, when every
    /// MAC runs the bit-identical tiled kernels from packed storage only
    pub flat_weight_elems: usize,
    pub logical_slots: usize,
    pub physical_buffers: usize,
}

impl PlanStats {
    /// MAC steps running on narrowed integer accumulators.
    pub fn integer_macs(&self) -> usize {
        self.matmul_i32 + self.matmul_i64 + self.conv_i32 + self.conv_i64
    }
}

impl std::fmt::Display for PlanStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} steps (ew {} / mm {}+{}i32+{}i64 / conv {}+{}i32+{}i64 / dw {} / pool {} / bin {} / gen {}), \
             {} fused thresholds, {} folded nodes, {} elided stuck channels ({} MACs, {} padded), \
             {} packed + {} flat weight elems, {} buffers for {} tensors",
            self.steps,
            self.ew_chains,
            self.matmul_f64,
            self.matmul_i32,
            self.matmul_i64,
            self.conv_f64,
            self.conv_i32,
            self.conv_i64,
            self.depthwise,
            self.pool,
            self.binary,
            self.generic,
            self.fused_thresholds,
            self.folded_nodes,
            self.elided_mac_channels,
            self.elided_mac_steps,
            self.elided_padded_convs,
            self.packed_weight_elems,
            self.flat_weight_elems,
            self.physical_buffers,
            self.logical_slots,
        )
    }
}

/// A compiled, batched execution plan. See the module docs.
#[derive(Clone, Debug)]
pub struct Plan {
    pub(crate) name: String,
    pub(crate) steps: Vec<Step>,
    pub(crate) n_phys: usize,
    /// Caller-side worker state: the serial path and the submitting
    /// thread's own sample shard run here; pool work items check states
    /// out of the shared pool instead.
    pub(crate) serial: WorkerState,
    /// Persistent execution pool, shared by every clone of this plan
    /// (created by [`Plan::set_threads`], absent at budget 1).
    pub(crate) pool: Option<Arc<WorkerPool>>,
    pub(crate) input_phys: usize,
    pub(crate) input_shape: Vec<usize>,
    pub(crate) input_numel: usize,
    pub(crate) output_phys: usize,
    pub(crate) output_shape: Vec<usize>,
    pub(crate) output_numel: usize,
    /// Set when the whole graph constant-folds (degenerate but legal).
    pub(crate) const_output: Option<Tensor>,
    pub(crate) stats: PlanStats,
    pub(crate) threads: usize,
    pub(crate) min_kernel_work: usize,
    pub(crate) min_tile_work: usize,
    /// Optional step profiler, shared by every clone of this plan
    /// (attached by [`Plan::enable_profiling`], absent by default).
    pub(crate) prof: Option<Arc<PlanProfiler>>,
}

/// Borrowed, `Copy` view of the immutable parts of a plan needed to run
/// steps — what sample shards, segments and pipeline stages share.
#[derive(Clone, Copy)]
pub(crate) struct PlanView<'a> {
    pub steps: &'a [Step],
    pub input_phys: usize,
    pub input_numel: usize,
    pub output_phys: usize,
    pub output_shape: &'a [usize],
    pub output_numel: usize,
}

impl PlanView<'_> {
    /// Pack a batch of validated per-sample inputs into the input buffer.
    pub(crate) fn pack(&self, ws: &mut WorkerState, inputs: &[Tensor]) {
        let need = inputs.len() * self.input_numel;
        let ib = &mut ws.bufs[self.input_phys];
        if ib.len() < need {
            ib.resize(need, 0.0);
        }
        for (i, t) in inputs.iter().enumerate() {
            ib[i * self.input_numel..(i + 1) * self.input_numel].copy_from_slice(t.data());
        }
    }

    /// Run steps `range` over a `b`-sample batch resident in `ws`.
    /// When `ctx.prof` is attached, each step bumps its always-on call
    /// counter and (1-in-`sample_every` calls) a timing sample —
    /// indexed by *absolute* step position so segmented execution
    /// attributes to the same slots as the monolithic runner.
    pub(crate) fn run_steps(
        &self,
        ws: &mut WorkerState,
        b: usize,
        range: core::ops::Range<usize>,
        ctx: &ExecCtx,
    ) -> Result<()> {
        let base = range.start;
        for (i, step) in self.steps[range].iter().enumerate() {
            let t0 = match ctx.prof {
                Some(p) => p.begin(base + i),
                None => None,
            };
            step.run(&mut ws.bufs, &mut ws.scratch, b, ctx)?;
            if let Some(p) = ctx.prof {
                p.end(base + i, t0, b);
            }
        }
        Ok(())
    }

    /// Copy the output buffer back out into one tensor per sample.
    pub(crate) fn extract(&self, ws: &WorkerState, b: usize) -> Result<Vec<Tensor>> {
        let ob = &ws.bufs[self.output_phys];
        (0..b)
            .map(|i| {
                Tensor::new(
                    self.output_shape,
                    ob[i * self.output_numel..(i + 1) * self.output_numel].to_vec(),
                )
            })
            .collect()
    }

    /// Run every step over one contiguous sample shard on one worker
    /// state: pack, execute, extract.
    pub(crate) fn run_shard(
        &self,
        ws: &mut WorkerState,
        inputs: &[Tensor],
        ctx: &ExecCtx,
    ) -> Result<Vec<Tensor>> {
        self.pack(ws, inputs);
        self.run_steps(ws, inputs.len(), 0..self.steps.len(), ctx)?;
        self.extract(ws, inputs.len())
    }
}

impl Plan {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        name: String,
        steps: Vec<Step>,
        n_phys: usize,
        input_phys: usize,
        input_shape: Vec<usize>,
        output_phys: usize,
        output_shape: Vec<usize>,
        output_numel: usize,
        const_output: Option<Tensor>,
        stats: PlanStats,
    ) -> Plan {
        let input_numel = input_shape.iter().product();
        Plan {
            name,
            steps,
            n_phys,
            serial: WorkerState::new(n_phys),
            pool: None,
            input_phys,
            input_shape,
            input_numel,
            output_phys,
            output_shape,
            output_numel,
            const_output,
            stats,
            threads: 1,
            min_kernel_work: DEFAULT_MIN_KERNEL_WORK,
            min_tile_work: DEFAULT_MIN_TILE_WORK,
            prof: None,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn stats(&self) -> &PlanStats {
        &self.stats
    }

    /// Per-sample input shape the plan expects (leading dim 1).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Per-sample output shape (leading dim 1).
    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    /// Thread budget for `run_batch` (1 = fully serial, the default).
    /// A budget of `n > 1` attaches a persistent [`WorkerPool`] of
    /// `n - 1` workers (the submitting thread is the n-th executor),
    /// shared by every subsequent clone of this plan: up to `n` threads
    /// cooperate per call, first sharding the batch across samples and
    /// then sharding rows/channels inside large MVU kernels with any
    /// leftover budget.
    pub fn set_threads(&mut self, n: usize) {
        let n = n.max(1);
        self.threads = n;
        if n == 1 {
            self.pool = None;
        } else {
            let have = self.pool.as_ref().map(|p| p.workers());
            if have != Some(n - 1) {
                self.pool = Some(Arc::new(WorkerPool::new(n - 1)));
            }
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The persistent execution pool backing this plan's thread budget
    /// (None at budget 1). Exposed for observability: worker count,
    /// executed work items, parked states.
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_deref()
    }

    /// Attach a [`PlanProfiler`] shared by every *subsequent* clone of
    /// this plan: always-on per-step call counters, plus sampled
    /// timing on 1-in-`sample_every` calls per step (`0` keeps only
    /// the counters, `1` times everything). Step labels and per-sample
    /// work estimates are derived from the compiled steps.
    pub fn enable_profiling(&mut self, sample_every: u64) {
        let labels = self
            .steps
            .iter()
            .map(|s| (s.kind_label(), s.work()))
            .collect();
        self.prof = Some(Arc::new(PlanProfiler::new(&self.name, labels, sample_every)));
    }

    /// The attached profiler, if any (shared with every clone made
    /// after [`Plan::enable_profiling`]).
    pub fn profiler(&self) -> Option<&Arc<PlanProfiler>> {
        self.prof.as_ref()
    }

    /// Minimum `rows * k * n` MAC volume before intra-kernel sharding
    /// engages. The default amortises the pool's submit/wake cost on
    /// mid-sized kernels; set 0 to force the sharded code paths
    /// (deterministic by construction, so this is safe anywhere), or
    /// `usize::MAX` to keep every kernel serial while still sample-
    /// sharding batches.
    pub fn set_min_kernel_work(&mut self, min_work: usize) {
        self.min_kernel_work = min_work;
    }

    /// Builder-style [`Plan::set_min_kernel_work`].
    pub fn with_min_kernel_work(mut self, min_work: usize) -> Plan {
        self.min_kernel_work = min_work;
        self
    }

    /// Current intra-kernel sharding gate.
    pub fn min_kernel_work(&self) -> usize {
        self.min_kernel_work
    }

    /// Minimum `rows * k * n` MAC volume before a kernel runs on the
    /// tiled, register-blocked cores ([`super::kernels::tile`]) instead
    /// of the scalar oracle. The two are bit-identical (locked by
    /// `rust/tests/kernel_properties.rs`), so this is purely a
    /// performance knob: 0 forces the tiled path onto every kernel
    /// (what the differential harness does), `usize::MAX` keeps every
    /// kernel on the scalar oracle.
    pub fn set_min_tile_work(&mut self, min_work: usize) {
        self.min_tile_work = min_work;
    }

    /// Builder-style [`Plan::set_min_tile_work`].
    pub fn with_min_tile_work(mut self, min_work: usize) -> Plan {
        self.min_tile_work = min_work;
        self
    }

    /// Current tiled-kernel gate.
    pub fn min_tile_work(&self) -> usize {
        self.min_tile_work
    }

    /// Release every MAC weight's flat scalar-oracle copy (this plan's
    /// references — other clones keep theirs): the serve-time memory
    /// trim from ROADMAP item 5. All MACs then dispatch to the tiled
    /// kernels, which are bit-identical to the scalar oracle, so outputs
    /// are unchanged. `stats().flat_weight_elems` drops to 0.
    pub fn drop_flat_oracles(&mut self) {
        for step in &mut self.steps {
            match step {
                Step::MatMul(s) => s.w.drop_flat(),
                Step::Conv(s) => s.wmat.drop_flat(),
                _ => {}
            }
        }
        self.stats.flat_weight_elems = 0;
    }

    /// Resolve every MAC step's tiling scheme against a machine-local
    /// tuning table, keyed by effective kernel shape (`k_eff`, `n`).
    /// Called at plan compile ([`super::compile`]) and after snapshot
    /// decode — the scheme is a per-machine performance decision, so it
    /// is never serialized into plans or sidecars. Results are
    /// unaffected: the KC-blocked path a non-default scheme selects is
    /// proof-gated per step and bit-identical where it engages.
    pub(crate) fn apply_tuning(&mut self, table: &TuningTable) {
        for step in &mut self.steps {
            match step {
                Step::MatMul(s) => s.scheme = table.scheme_for(s.k_eff(), s.n),
                Step::Conv(s) => {
                    let k_eff = match &s.elide {
                        Some(e) => e.live.len() * s.spec.kernel.0 * s.spec.kernel.1,
                        None => s.c * s.spec.kernel.0 * s.spec.kernel.1,
                    };
                    s.scheme = table.scheme_for(k_eff, s.oc);
                }
                _ => {}
            }
        }
    }

    /// `Arc` reference count of the first MAC step's packed weights
    /// (None for plans without MAC steps) — the observable that N plan
    /// clones (replicas) share one weight allocation rather than
    /// holding N copies.
    pub fn packed_share_count(&self) -> Option<usize> {
        self.steps.iter().find_map(|s| match s {
            Step::MatMul(st) => Some(st.w.packed_refs()),
            Step::Conv(st) => Some(st.wmat.packed_refs()),
            _ => None,
        })
    }

    pub(crate) fn view(&self) -> PlanView<'_> {
        PlanView {
            steps: &self.steps,
            input_phys: self.input_phys,
            input_numel: self.input_numel,
            output_phys: self.output_phys,
            output_shape: &self.output_shape,
            output_numel: self.output_numel,
        }
    }

    /// Validate a batch against the expected per-sample shape without
    /// touching any run-time state (a rejected call never perturbs an
    /// arena).
    pub(crate) fn validate(&self, inputs: &[Tensor]) -> Result<()> {
        for t in inputs {
            if t.shape() != &self.input_shape[..] {
                bail!(
                    "plan '{}': input shape {:?} does not match expected {:?}",
                    self.name,
                    t.shape(),
                    self.input_shape
                );
            }
        }
        Ok(())
    }

    /// Execute the plan over a batch of per-sample inputs; returns one
    /// output tensor per input, in order.
    pub fn run_batch(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // All validation (including the empty-batch early return) happens
        // before any arena is touched, so a rejected call never perturbs
        // worker state.
        self.validate(inputs)?;
        let b = inputs.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        if let Some(t) = &self.const_output {
            return Ok(vec![t.clone(); b]);
        }
        self.serial.ensure(self.n_phys);
        let view = PlanView {
            steps: &self.steps,
            input_phys: self.input_phys,
            input_numel: self.input_numel,
            output_phys: self.output_phys,
            output_shape: &self.output_shape,
            output_numel: self.output_numel,
        };
        let pool = self.pool.clone();
        let shards = if pool.is_some() { self.threads.min(b) } else { 1 };
        if shards <= 1 {
            // one sample shard on the caller; the whole budget (if any)
            // goes to intra-kernel sharding
            let ctx = ExecCtx {
                pool: pool.as_deref(),
                kt: self.threads,
                min_work: self.min_kernel_work,
                min_tile: self.min_tile_work,
                prof: self.prof.as_deref(),
            };
            return view.run_shard(&mut self.serial, inputs, &ctx);
        }
        // Sample sharding: contiguous chunks, one pool work item per
        // chunk with a checked-out worker state — except the tail chunk,
        // which the submitting thread runs itself on the plan's own
        // state. Leftover thread budget goes to intra-kernel sharding.
        let pool = pool.expect("shards > 1 implies a pool");
        let pool = &*pool;
        let chunk = b.div_ceil(shards);
        let n_chunks = b.div_ceil(chunk);
        let ctx = ExecCtx {
            pool: Some(pool),
            kt: (self.threads / shards).max(1),
            min_work: self.min_kernel_work,
            min_tile: self.min_tile_work,
            prof: self.prof.as_deref(),
        };
        let n_phys = self.n_phys;
        let serial = &mut self.serial;
        let mut results: Vec<Option<Result<Vec<Tensor>>>> = Vec::new();
        results.resize_with(n_chunks, || None);
        pool.scope(|sc| {
            let mut slots = &mut results[..];
            for (ci, xs) in inputs.chunks(chunk).enumerate() {
                let (slot, rest) = slots.split_first_mut().expect("one slot per chunk");
                slots = rest;
                if ci + 1 == n_chunks {
                    *slot = Some(view.run_shard(serial, xs, &ctx));
                } else {
                    sc.spawn(move || {
                        *slot = Some(pool.with_state(n_phys, |ws| view.run_shard(ws, xs, &ctx)));
                    });
                }
            }
        });
        let mut out = Vec::with_capacity(b);
        for r in results {
            out.extend(r.expect("pool scope completed every shard")?);
        }
        Ok(out)
    }

    /// Single-sample convenience wrapper.
    pub fn run_one(&mut self, x: &Tensor) -> Result<Tensor> {
        let mut out = self.run_batch(std::slice::from_ref(x))?;
        Ok(out.remove(0))
    }
}
