//! Serialized plan snapshots: the fleet-scale cold-start path
//! (ROADMAP item 5). `sira-finn serve` normally pays
//! streamline → SIRA analysis → plan compilation on every process
//! start; a snapshot makes that a file read plus weight re-packing —
//! `save` writes a compiled [`Plan`] to a compact versioned binary
//! sidecar, `load` rebuilds a plan that is **bit-exact** against the
//! freshly compiled one (locked by `rust/tests/engine_equivalence.rs`).
//!
//! # Why a binary sidecar (and not the crate's JSON)
//!
//! The hand-rolled `util::json` stores every number as `f64`; i64 MAC
//! weights and elision biases can exceed 2^53 and would silently lose
//! bits through a JSON round trip. The snapshot instead stores integers
//! as little-endian fixed-width words and floats as IEEE-754 bit
//! patterns, so a round trip is exact by construction.
//!
//! # Format
//!
//! ```text
//! magic    8 bytes   b"SIRAPLAN"
//! version  u32 LE    bumped on any layout change; mismatch = clean error
//! len      u64 LE    payload byte length
//! checksum u64 LE    FNV-1a-64 over the payload
//! payload  len bytes the serialized plan
//! ```
//!
//! A corrupted, truncated or version-mismatched snapshot is always a
//! clean `Err` — every length is bounds-checked against the remaining
//! bytes before allocation, and the checksum is verified before any
//! decoding — never a wrong answer.
//!
//! Only compile-time, machine-independent state is stored: steps
//! (weights in their flat `(k, n)` form — packing is deterministic, so
//! panels are rebuilt on load), SIRA accumulation bounds (`kc_bound`),
//! buffer wiring, shapes and [`PlanStats`]. Runtime knobs (thread
//! budget, work gates, profiler) stay at their defaults, and tiling
//! schemes are deliberately **not** serialized — they describe the
//! machine that tuned them, not the model — so decode re-resolves them
//! against this host's tuning table ([`super::tune::global`]), same as
//! a freshly compiled plan.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::{Op, RoundMode};
use crate::tensor::{Conv2dSpec, PoolKind, Tensor};

use super::kernels::{MacMat, MicroOp, Param, ThresholdTable, WeightMat};
use super::plan::{
    BinKind, BinaryStep, ConvStep, DepthwiseStep, DwTaps, EwChainStep, GSrc, GenericStep,
    MacElide, MatMulStep, Plan, PlanStats, PoolStep, Step,
};
use super::tune::TilingScheme;

/// File magic, first 8 bytes of every snapshot.
pub const MAGIC: &[u8; 8] = b"SIRAPLAN";

/// Format version; bumped on any layout change. A mismatch is a clean
/// load error (old readers never misinterpret new layouts or vice
/// versa). v2 added the per-step `kc_bound` and the depthwise tap
/// width / elided-plane fields.
pub const VERSION: u32 = 2;

/// FNV-1a 64-bit over `bytes` — the integrity checksum. Not
/// cryptographic; it catches torn writes and bit rot, which is the
/// failure model for a local sidecar file.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// little-endian writer / bounds-checked reader

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn i64(&mut self, v: i64) {
        self.u64(v as u64);
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn usizes(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }
    fn i64s(&mut self, v: &[i64]) {
        self.usize(v.len());
        for &x in v {
            self.i64(x);
        }
    }
    fn f64s(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "snapshot truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("snapshot corrupt: bool byte {v}"),
        }
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow!("snapshot corrupt: oversized count {v}"))
    }

    /// An element count about to drive a `Vec` allocation: must be
    /// coverable by the remaining bytes (elements are ≥ `elem_size`
    /// bytes), so a corrupted length can never trigger a huge
    /// allocation or a misdecode — it fails here, cleanly.
    fn count(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.usize()?;
        match n.checked_mul(elem_size) {
            Some(total) if total <= self.remaining() => Ok(n),
            _ => bail!(
                "snapshot corrupt: count {n} x {elem_size} bytes exceeds the {} remaining",
                self.remaining()
            ),
        }
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let s = std::str::from_utf8(self.bytes(n)?)
            .map_err(|e| anyhow!("snapshot corrupt: non-UTF-8 string: {e}"))?;
        Ok(s.to_string())
    }
    fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.usize()).collect()
    }
    fn i64s(&mut self) -> Result<Vec<i64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.i64()).collect()
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64()).collect()
    }
}

// ---------------------------------------------------------------------------
// component encoders/decoders

fn enc_spec(e: &mut Enc, s: Conv2dSpec) {
    e.usize(s.kernel.0);
    e.usize(s.kernel.1);
    e.usize(s.stride.0);
    e.usize(s.stride.1);
    e.usize(s.pad.0);
    e.usize(s.pad.1);
}

fn dec_spec(d: &mut Dec) -> Result<Conv2dSpec> {
    Ok(Conv2dSpec {
        kernel: (d.usize()?, d.usize()?),
        stride: (d.usize()?, d.usize()?),
        pad: (d.usize()?, d.usize()?),
    })
}

fn enc_tensor(e: &mut Enc, t: &Tensor) {
    e.usizes(t.shape());
    e.f64s(t.data());
}

fn dec_tensor(d: &mut Dec) -> Result<Tensor> {
    let shape = d.usizes()?;
    let data = d.f64s()?;
    Tensor::new(&shape, data).context("snapshot corrupt: tensor shape/data mismatch")
}

fn enc_param(e: &mut Enc, p: &Param) {
    match p {
        Param::Scalar(v) => {
            e.u8(0);
            e.f64(*v);
        }
        Param::PerElem(v) => {
            e.u8(1);
            e.f64s(v);
        }
    }
}

fn dec_param(d: &mut Dec) -> Result<Param> {
    match d.u8()? {
        0 => Ok(Param::Scalar(d.f64()?)),
        1 => Ok(Param::PerElem(d.f64s()?)),
        t => bail!("snapshot corrupt: param tag {t}"),
    }
}

fn enc_table(e: &mut Enc, t: &ThresholdTable) {
    e.f64s(&t.rows);
    e.usize(t.n);
    e.usize(t.channels);
    e.usize(t.ch_stride);
    e.f64(t.out_scale);
    e.f64(t.out_bias);
}

fn dec_table(d: &mut Dec) -> Result<ThresholdTable> {
    let rows = d.f64s()?;
    let n = d.usize()?;
    let channels = d.usize()?;
    if n.checked_mul(channels) != Some(rows.len()) {
        bail!(
            "snapshot corrupt: threshold table {} rows != {channels} channels x {n}",
            rows.len()
        );
    }
    Ok(ThresholdTable {
        rows,
        n,
        channels,
        ch_stride: d.usize()?,
        out_scale: d.f64()?,
        out_bias: d.f64()?,
    })
}

fn enc_opt_table(e: &mut Enc, t: &Option<ThresholdTable>) {
    match t {
        None => e.u8(0),
        Some(t) => {
            e.u8(1);
            enc_table(e, t);
        }
    }
}

fn dec_opt_table(d: &mut Dec) -> Result<Option<ThresholdTable>> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(dec_table(d)?)),
        t => bail!("snapshot corrupt: option tag {t}"),
    }
}

fn enc_micro_op(e: &mut Enc, op: &MicroOp) {
    match op {
        MicroOp::Mul(p) => {
            e.u8(0);
            enc_param(e, p);
        }
        MicroOp::Add(p) => {
            e.u8(1);
            enc_param(e, p);
        }
        MicroOp::Sub(p) => {
            e.u8(2);
            enc_param(e, p);
        }
        MicroOp::Rsub(p) => {
            e.u8(3);
            enc_param(e, p);
        }
        MicroOp::Div(p) => {
            e.u8(4);
            enc_param(e, p);
        }
        MicroOp::Rdiv(p) => {
            e.u8(5);
            enc_param(e, p);
        }
        MicroOp::Relu => e.u8(6),
        MicroOp::Sigmoid => e.u8(7),
        MicroOp::Floor => e.u8(8),
        MicroOp::Ceil => e.u8(9),
        MicroOp::RoundEven => e.u8(10),
        MicroOp::Clip { lo, hi } => {
            e.u8(11);
            e.f64(*lo);
            e.f64(*hi);
        }
        MicroOp::Threshold(t) => {
            e.u8(12);
            enc_table(e, t);
        }
    }
}

fn dec_micro_op(d: &mut Dec) -> Result<MicroOp> {
    Ok(match d.u8()? {
        0 => MicroOp::Mul(dec_param(d)?),
        1 => MicroOp::Add(dec_param(d)?),
        2 => MicroOp::Sub(dec_param(d)?),
        3 => MicroOp::Rsub(dec_param(d)?),
        4 => MicroOp::Div(dec_param(d)?),
        5 => MicroOp::Rdiv(dec_param(d)?),
        6 => MicroOp::Relu,
        7 => MicroOp::Sigmoid,
        8 => MicroOp::Floor,
        9 => MicroOp::Ceil,
        10 => MicroOp::RoundEven,
        11 => MicroOp::Clip {
            lo: d.f64()?,
            hi: d.f64()?,
        },
        12 => MicroOp::Threshold(dec_table(d)?),
        t => bail!("snapshot corrupt: micro-op tag {t}"),
    })
}

/// Weights are stored flat `(k, n)` at their accumulator width (i32 as
/// 4-byte words, so a CNV snapshot stays compact); the tile-packed
/// panels are rebuilt on load — `PackedWeights::pack` is deterministic,
/// so the loaded plan's panels are byte-identical to the compiled
/// plan's. When the flat oracle was dropped before saving,
/// `MacMat::flat_data` recovers it from the panels exactly.
fn enc_weight_mat(e: &mut Enc, w: &WeightMat) {
    match w {
        WeightMat::F64(m) => {
            e.u8(0);
            e.usize(m.k());
            e.usize(m.n());
            e.f64s(&m.flat_data());
        }
        WeightMat::I32(m) => {
            e.u8(1);
            e.usize(m.k());
            e.usize(m.n());
            let flat = m.flat_data();
            e.usize(flat.len());
            for v in flat {
                e.buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        WeightMat::I64(m) => {
            e.u8(2);
            e.usize(m.k());
            e.usize(m.n());
            e.i64s(&m.flat_data());
        }
    }
}

fn dec_weight_mat(d: &mut Dec) -> Result<WeightMat> {
    let tag = d.u8()?;
    let k = d.usize()?;
    let n = d.usize()?;
    let check = |len: usize| -> Result<()> {
        if k.checked_mul(n) != Some(len) {
            bail!("snapshot corrupt: weight matrix {len} elems != ({k}, {n})");
        }
        Ok(())
    };
    Ok(match tag {
        0 => {
            let flat = d.f64s()?;
            check(flat.len())?;
            WeightMat::F64(MacMat::new(flat, k, n))
        }
        1 => {
            let len = d.count(4)?;
            let mut flat = Vec::with_capacity(len);
            for _ in 0..len {
                flat.push(i32::from_le_bytes(d.bytes(4)?.try_into().unwrap()));
            }
            check(flat.len())?;
            WeightMat::I32(MacMat::new(flat, k, n))
        }
        2 => {
            let flat = d.i64s()?;
            check(flat.len())?;
            WeightMat::I64(MacMat::new(flat, k, n))
        }
        t => bail!("snapshot corrupt: weight-mat tag {t}"),
    })
}

fn enc_elide(e: &mut Enc, el: &Option<MacElide>) {
    match el {
        None => e.u8(0),
        Some(el) => {
            e.u8(1);
            e.usizes(&el.live);
            e.i64s(&el.bias);
            e.usize(el.pos_stride);
        }
    }
}

fn dec_elide(d: &mut Dec) -> Result<Option<MacElide>> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(MacElide {
            live: d.usizes()?,
            bias: d.i64s()?,
            pos_stride: d.usize()?,
        })),
        t => bail!("snapshot corrupt: elide tag {t}"),
    }
}

fn enc_op(e: &mut Enc, op: &Op) {
    match op {
        Op::Quant {
            signed,
            narrow,
            rounding,
        } => {
            e.u8(0);
            e.bool(*signed);
            e.bool(*narrow);
            e.u8(match rounding {
                RoundMode::RoundEven => 0,
                RoundMode::Floor => 1,
                RoundMode::Ceil => 2,
            });
        }
        Op::MatMul => e.u8(1),
        Op::Gemm => e.u8(2),
        Op::Conv { spec, group } => {
            e.u8(3);
            enc_spec(e, *spec);
            e.usize(*group);
        }
        Op::Add => e.u8(4),
        Op::Sub => e.u8(5),
        Op::Mul => e.u8(6),
        Op::Div => e.u8(7),
        Op::Relu => e.u8(8),
        Op::Sigmoid => e.u8(9),
        Op::BatchNorm { eps } => {
            e.u8(10);
            e.f64(*eps);
        }
        Op::MaxPool { spec } => {
            e.u8(11);
            enc_spec(e, *spec);
        }
        Op::AveragePool { spec } => {
            e.u8(12);
            enc_spec(e, *spec);
        }
        Op::GlobalAveragePool => e.u8(13),
        Op::Reshape { shape } => {
            e.u8(14);
            e.usize(shape.len());
            for &v in shape {
                e.i64(v);
            }
        }
        Op::Flatten { axis } => {
            e.u8(15);
            e.usize(*axis);
        }
        Op::Transpose { perm } => {
            e.u8(16);
            e.usizes(perm);
        }
        Op::Concat { axis } => {
            e.u8(17);
            e.usize(*axis);
        }
        Op::Identity => e.u8(18),
        Op::Floor => e.u8(19),
        Op::Clip { lo, hi } => {
            e.u8(20);
            e.f64(*lo);
            e.f64(*hi);
        }
        Op::MultiThreshold {
            out_scale,
            out_bias,
        } => {
            e.u8(21);
            e.f64(*out_scale);
            e.f64(*out_bias);
        }
    }
}

fn dec_op(d: &mut Dec) -> Result<Op> {
    Ok(match d.u8()? {
        0 => Op::Quant {
            signed: d.bool()?,
            narrow: d.bool()?,
            rounding: match d.u8()? {
                0 => RoundMode::RoundEven,
                1 => RoundMode::Floor,
                2 => RoundMode::Ceil,
                t => bail!("snapshot corrupt: round-mode tag {t}"),
            },
        },
        1 => Op::MatMul,
        2 => Op::Gemm,
        3 => Op::Conv {
            spec: dec_spec(d)?,
            group: d.usize()?,
        },
        4 => Op::Add,
        5 => Op::Sub,
        6 => Op::Mul,
        7 => Op::Div,
        8 => Op::Relu,
        9 => Op::Sigmoid,
        10 => Op::BatchNorm { eps: d.f64()? },
        11 => Op::MaxPool { spec: dec_spec(d)? },
        12 => Op::AveragePool { spec: dec_spec(d)? },
        13 => Op::GlobalAveragePool,
        14 => {
            let n = d.count(8)?;
            Op::Reshape {
                shape: (0..n).map(|_| d.i64()).collect::<Result<_>>()?,
            }
        }
        15 => Op::Flatten { axis: d.usize()? },
        16 => Op::Transpose { perm: d.usizes()? },
        17 => Op::Concat { axis: d.usize()? },
        18 => Op::Identity,
        19 => Op::Floor,
        20 => Op::Clip {
            lo: d.f64()?,
            hi: d.f64()?,
        },
        21 => Op::MultiThreshold {
            out_scale: d.f64()?,
            out_bias: d.f64()?,
        },
        t => bail!("snapshot corrupt: op tag {t}"),
    })
}

fn enc_step(e: &mut Enc, step: &Step) {
    match step {
        Step::Ew(s) => {
            e.u8(0);
            e.usize(s.input);
            e.usize(s.out);
            e.usize(s.numel);
            e.usize(s.ops.len());
            for op in &s.ops {
                enc_micro_op(e, op);
            }
        }
        Step::MatMul(s) => {
            e.u8(1);
            e.usize(s.a);
            e.usize(s.out);
            e.usize(s.m);
            e.usize(s.k);
            e.usize(s.n);
            enc_weight_mat(e, &s.w);
            enc_opt_table(e, &s.fused);
            enc_elide(e, &s.elide);
            e.f64(s.kc_bound);
        }
        Step::Conv(s) => {
            e.u8(2);
            e.usize(s.x);
            e.usize(s.out);
            e.usize(s.c);
            e.usize(s.h);
            e.usize(s.w);
            e.usize(s.oc);
            e.usize(s.oh);
            e.usize(s.ow);
            enc_spec(e, s.spec);
            enc_weight_mat(e, &s.wmat);
            enc_opt_table(e, &s.fused);
            enc_elide(e, &s.elide);
            e.f64(s.kc_bound);
        }
        Step::Depthwise(s) => {
            e.u8(3);
            e.usize(s.x);
            e.usize(s.out);
            e.usize(s.c);
            e.usize(s.h);
            e.usize(s.w);
            e.usize(s.oh);
            e.usize(s.ow);
            enc_spec(e, s.spec);
            e.f64s(&s.weights);
            enc_opt_table(e, &s.fused);
            // the tap width alone is stored; the casted taps are
            // re-derived from the f64 weights at decode (the cast is
            // deterministic, so the single source of truth stays the
            // f64 vector)
            e.u8(match &s.taps {
                DwTaps::F64 => 0,
                DwTaps::I32(_) => 1,
                DwTaps::I64(_) => 2,
            });
            e.f64(s.kc_bound);
            e.usize(s.elided.len());
            for (ch, plane) in &s.elided {
                e.usize(*ch);
                e.f64s(plane);
            }
        }
        Step::Pool(s) => {
            e.u8(4);
            e.usize(s.x);
            e.usize(s.out);
            e.u8(match s.kind {
                PoolKind::Max => 0,
                PoolKind::Average => 1,
            });
            e.usize(s.c);
            e.usize(s.h);
            e.usize(s.w);
            e.usize(s.oh);
            e.usize(s.ow);
            enc_spec(e, s.spec);
        }
        Step::Binary(s) => {
            e.u8(5);
            e.usize(s.a);
            e.usize(s.b);
            e.usize(s.out);
            e.usize(s.numel);
            e.u8(match s.kind {
                BinKind::Add => 0,
                BinKind::Sub => 1,
                BinKind::Mul => 2,
                BinKind::Div => 3,
            });
        }
        Step::Generic(s) => {
            e.u8(6);
            enc_op(e, &s.op);
            e.usize(s.ins.len());
            for src in &s.ins {
                match src {
                    GSrc::Slot(id, shape) => {
                        e.u8(0);
                        e.usize(*id);
                        e.usizes(shape);
                    }
                    GSrc::Const(t) => {
                        e.u8(1);
                        enc_tensor(e, t);
                    }
                }
            }
            e.usize(s.out);
            e.usizes(&s.out_shape);
            e.usize(s.out_numel);
        }
    }
}

fn dec_step(d: &mut Dec) -> Result<Step> {
    Ok(match d.u8()? {
        0 => {
            let input = d.usize()?;
            let out = d.usize()?;
            let numel = d.usize()?;
            let n_ops = d.count(1)?;
            let ops = (0..n_ops).map(|_| dec_micro_op(d)).collect::<Result<_>>()?;
            Step::Ew(EwChainStep {
                input,
                out,
                numel,
                ops,
            })
        }
        1 => Step::MatMul(MatMulStep {
            a: d.usize()?,
            out: d.usize()?,
            m: d.usize()?,
            k: d.usize()?,
            n: d.usize()?,
            w: dec_weight_mat(d)?,
            fused: dec_opt_table(d)?,
            elide: dec_elide(d)?,
            kc_bound: d.f64()?,
            scheme: TilingScheme::default(),
        }),
        2 => Step::Conv(ConvStep {
            x: d.usize()?,
            out: d.usize()?,
            c: d.usize()?,
            h: d.usize()?,
            w: d.usize()?,
            oc: d.usize()?,
            oh: d.usize()?,
            ow: d.usize()?,
            spec: dec_spec(d)?,
            wmat: dec_weight_mat(d)?,
            fused: dec_opt_table(d)?,
            elide: dec_elide(d)?,
            kc_bound: d.f64()?,
            scheme: TilingScheme::default(),
        }),
        3 => {
            let x = d.usize()?;
            let out = d.usize()?;
            let c = d.usize()?;
            let h = d.usize()?;
            let w = d.usize()?;
            let oh = d.usize()?;
            let ow = d.usize()?;
            let spec = dec_spec(d)?;
            let weights = d.f64s()?;
            let fused = dec_opt_table(d)?;
            let taps = match d.u8()? {
                0 => DwTaps::F64,
                1 => DwTaps::I32(weights.iter().map(|&v| v as i32).collect()),
                2 => DwTaps::I64(weights.iter().map(|&v| v as i64).collect()),
                t => bail!("snapshot corrupt: depthwise width tag {t}"),
            };
            let kc_bound = d.f64()?;
            let n_elided = d.count(16)?;
            let mut elided = Vec::with_capacity(n_elided);
            for _ in 0..n_elided {
                let ch = d.usize()?;
                let plane = d.f64s()?;
                if ch >= c {
                    bail!("snapshot corrupt: elided channel {ch} out of {c}");
                }
                if plane.len() != oh * ow {
                    bail!(
                        "snapshot corrupt: elided plane {} elems != {oh}x{ow}",
                        plane.len()
                    );
                }
                elided.push((ch, plane));
            }
            Step::Depthwise(DepthwiseStep {
                x,
                out,
                c,
                h,
                w,
                oh,
                ow,
                spec,
                weights,
                fused,
                taps,
                kc_bound,
                elided,
            })
        }
        4 => Step::Pool(PoolStep {
            x: d.usize()?,
            out: d.usize()?,
            kind: match d.u8()? {
                0 => PoolKind::Max,
                1 => PoolKind::Average,
                t => bail!("snapshot corrupt: pool-kind tag {t}"),
            },
            c: d.usize()?,
            h: d.usize()?,
            w: d.usize()?,
            oh: d.usize()?,
            ow: d.usize()?,
            spec: dec_spec(d)?,
        }),
        5 => Step::Binary(BinaryStep {
            a: d.usize()?,
            b: d.usize()?,
            out: d.usize()?,
            numel: d.usize()?,
            kind: match d.u8()? {
                0 => BinKind::Add,
                1 => BinKind::Sub,
                2 => BinKind::Mul,
                3 => BinKind::Div,
                t => bail!("snapshot corrupt: bin-kind tag {t}"),
            },
        }),
        6 => {
            let op = dec_op(d)?;
            let n_ins = d.count(1)?;
            let ins = (0..n_ins)
                .map(|_| {
                    Ok(match d.u8()? {
                        0 => GSrc::Slot(d.usize()?, d.usizes()?),
                        1 => GSrc::Const(dec_tensor(d)?),
                        t => bail!("snapshot corrupt: gsrc tag {t}"),
                    })
                })
                .collect::<Result<_>>()?;
            Step::Generic(GenericStep {
                op,
                ins,
                out: d.usize()?,
                out_shape: d.usizes()?,
                out_numel: d.usize()?,
            })
        }
        t => bail!("snapshot corrupt: step tag {t}"),
    })
}

/// `PlanStats` fields in fixed order (all u64 on the wire). Encoder and
/// decoder must stay in lockstep; any reorder is a `VERSION` bump.
fn enc_stats(e: &mut Enc, s: &PlanStats) {
    for v in [
        s.steps,
        s.ew_chains,
        s.fused_micro_ops,
        s.matmul_f64,
        s.matmul_i32,
        s.matmul_i64,
        s.conv_f64,
        s.conv_i32,
        s.conv_i64,
        s.depthwise,
        s.pool,
        s.binary,
        s.generic,
        s.fused_thresholds,
        s.folded_nodes,
        s.elided_mac_steps,
        s.elided_mac_channels,
        s.elided_padded_convs,
        s.packed_weight_elems,
        s.flat_weight_elems,
        s.logical_slots,
        s.physical_buffers,
    ] {
        e.usize(v);
    }
}

fn dec_stats(d: &mut Dec) -> Result<PlanStats> {
    Ok(PlanStats {
        steps: d.usize()?,
        ew_chains: d.usize()?,
        fused_micro_ops: d.usize()?,
        matmul_f64: d.usize()?,
        matmul_i32: d.usize()?,
        matmul_i64: d.usize()?,
        conv_f64: d.usize()?,
        conv_i32: d.usize()?,
        conv_i64: d.usize()?,
        depthwise: d.usize()?,
        pool: d.usize()?,
        binary: d.usize()?,
        generic: d.usize()?,
        fused_thresholds: d.usize()?,
        folded_nodes: d.usize()?,
        elided_mac_steps: d.usize()?,
        elided_mac_channels: d.usize()?,
        elided_padded_convs: d.usize()?,
        packed_weight_elems: d.usize()?,
        flat_weight_elems: d.usize()?,
        logical_slots: d.usize()?,
        physical_buffers: d.usize()?,
    })
}

// ---------------------------------------------------------------------------
// public API

/// Serialize a compiled plan to the snapshot wire format (header +
/// checksummed payload).
pub fn to_bytes(plan: &Plan) -> Vec<u8> {
    let mut e = Enc::default();
    e.str(&plan.name);
    e.usize(plan.steps.len());
    for step in &plan.steps {
        enc_step(&mut e, step);
    }
    e.usize(plan.n_phys);
    e.usize(plan.input_phys);
    e.usizes(&plan.input_shape);
    e.usize(plan.output_phys);
    e.usizes(&plan.output_shape);
    e.usize(plan.output_numel);
    match &plan.const_output {
        None => e.u8(0),
        Some(t) => {
            e.u8(1);
            enc_tensor(&mut e, t);
        }
    }
    enc_stats(&mut e, &plan.stats);
    let payload = e.buf;

    let mut out = Vec::with_capacity(payload.len() + 28);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Rebuild a plan from snapshot bytes. Bad magic, version mismatch,
/// truncation, checksum failure and any malformed payload are all clean
/// errors — a snapshot never half-loads.
pub fn from_bytes(bytes: &[u8]) -> Result<Plan> {
    if bytes.len() < 28 {
        bail!("snapshot too short ({} bytes) to hold a header", bytes.len());
    }
    if &bytes[..8] != MAGIC {
        bail!("not a plan snapshot (bad magic)");
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION {
        bail!("snapshot format version {version}, this build reads {VERSION}");
    }
    let len = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let want_sum = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    let payload = &bytes[28..];
    if payload.len() as u64 != len {
        bail!(
            "snapshot truncated: header says {len} payload bytes, file has {}",
            payload.len()
        );
    }
    let got_sum = fnv1a64(payload);
    if got_sum != want_sum {
        bail!("snapshot checksum mismatch ({got_sum:#018x} != {want_sum:#018x})");
    }

    let mut d = Dec::new(payload);
    let name = d.str()?;
    let n_steps = d.count(1)?;
    let steps: Vec<Step> = (0..n_steps).map(|_| dec_step(&mut d)).collect::<Result<_>>()?;
    let n_phys = d.usize()?;
    let input_phys = d.usize()?;
    let input_shape = d.usizes()?;
    let output_phys = d.usize()?;
    let output_shape = d.usizes()?;
    let output_numel = d.usize()?;
    let const_output = match d.u8()? {
        0 => None,
        1 => Some(dec_tensor(&mut d)?),
        t => bail!("snapshot corrupt: const-output tag {t}"),
    };
    let mut stats = dec_stats(&mut d)?;
    if d.remaining() != 0 {
        bail!("snapshot corrupt: {} trailing bytes after the plan", d.remaining());
    }
    // the loaded plan always carries the flat oracle (decode rebuilds
    // it), even if it was dropped before saving — keep the stat honest
    stats.flat_weight_elems = steps
        .iter()
        .map(|s| match s {
            Step::MatMul(st) => st.w.flat_elems(),
            Step::Conv(st) => st.wmat.flat_elems(),
            _ => 0,
        })
        .sum();
    let mut plan = Plan::new(
        name,
        steps,
        n_phys,
        input_phys,
        input_shape,
        output_phys,
        output_shape,
        output_numel,
        const_output,
        stats,
    );
    // tiling schemes are per-machine, never per-snapshot: re-resolve
    // against this host's tuning table, exactly like a fresh compile
    plan.apply_tuning(super::tune::global());
    Ok(plan)
}

/// Write a plan snapshot to `path` (atomically: temp file + rename, so
/// a crash mid-write never leaves a torn snapshot behind at the final
/// name).
pub fn save(plan: &Plan, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let bytes = to_bytes(plan);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("writing snapshot to {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming snapshot into {}", path.display()))?;
    Ok(())
}

/// Read a plan snapshot from `path`; see [`from_bytes`] for the failure
/// contract.
pub fn load(path: impl AsRef<Path>) -> Result<Plan> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    from_bytes(&bytes).with_context(|| format!("decoding snapshot {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sira::analyze;
    use crate::util::rng::Rng;

    fn compiled(name: &str) -> Plan {
        let m = models::by_name(name).unwrap();
        let analysis = analyze(&m.graph, &m.input_ranges).unwrap();
        super::super::compile(&m.graph, &analysis).unwrap()
    }

    #[test]
    fn roundtrip_is_bit_exact_on_tfc() {
        let mut fresh = compiled("tfc");
        let bytes = to_bytes(&fresh);
        let mut loaded = from_bytes(&bytes).unwrap();
        assert_eq!(loaded.name(), fresh.name());
        assert_eq!(loaded.stats().steps, fresh.stats().steps);
        assert_eq!(loaded.stats().integer_macs(), fresh.stats().integer_macs());
        let mut rng = Rng::new(0x5A17);
        let shape = fresh.input_shape().to_vec();
        let numel: usize = shape.iter().product();
        let xs: Vec<Tensor> = (0..3)
            .map(|_| {
                Tensor::new(
                    &shape,
                    (0..numel).map(|_| rng.int_in(0, 255) as f64).collect(),
                )
                .unwrap()
            })
            .collect();
        let want = fresh.run_batch(&xs).unwrap();
        let got = loaded.run_batch(&xs).unwrap();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.data(), g.data());
        }
    }

    #[test]
    fn dropped_flat_oracle_still_snapshots_exactly() {
        let fresh = compiled("tfc");
        let mut trimmed = fresh.clone();
        trimmed.drop_flat_oracles();
        assert_eq!(trimmed.stats().flat_weight_elems, 0);
        // unpack-on-save recovers the exact flat matrix
        let a = to_bytes(&fresh);
        let b = to_bytes(&trimmed);
        assert_eq!(a, b, "snapshot bytes must not depend on the flat copy");
    }

    #[test]
    fn corruption_and_version_mismatch_are_clean_errors() {
        let plan = compiled("tfc");
        let good = to_bytes(&plan);
        assert!(from_bytes(&good).is_ok());
        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("magic"));
        // version mismatch
        let mut bad = good.clone();
        bad[8] = 99;
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("version"));
        // flipped payload byte -> checksum
        let mut bad = good.clone();
        let mid = 28 + (bad.len() - 28) / 2;
        bad[mid] ^= 0x01;
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("checksum"));
        // truncations at every region never panic, always Err
        for cut in [0usize, 7, 12, 27, 28, good.len() / 2, good.len() - 1] {
            assert!(from_bytes(&good[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage is rejected too (header length catches it)
        let mut bad = good.clone();
        bad.push(0);
        assert!(from_bytes(&bad).is_err());
    }

    #[test]
    fn file_roundtrip_via_save_and_load() {
        let plan = compiled("tfc");
        let dir = std::env::temp_dir().join(format!("sira_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tfc.plan");
        save(&plan, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.name(), plan.name());
        assert_eq!(to_bytes(&loaded), to_bytes(&plan));
        assert!(load(dir.join("missing.plan")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
