//! The serving hot path: an ahead-of-time **plan compiler** and
//! **batched integer runtime** — the second execution backend next to the
//! interpretive [`crate::executor`].
//!
//! The interpreter is the reference: it re-resolves the graph node by
//! node, clones every input tensor (weights included) on every
//! inference, and allocates fresh output tensors per op. That is the
//! right shape for verification and instrumentation, and the wrong shape
//! for serving. Following the FINN-R observation that end-to-end
//! throughput is set by the compiled dataflow rather than the model
//! math, this module turns SIRA's per-tensor facts into a specialised
//! execution artifact.
//!
//! The example below is a doctest on purpose: it exercises the real
//! [`Plan::set_threads`] / [`Plan::with_min_kernel_work`] /
//! [`Plan::set_min_tile_work`] tuning surface, so the documented API can
//! no longer drift from the implementation (the PR 3 refactor had left a
//! prose copy of this snippet behind).
//!
//! ```
//! use sira_finn::engine;
//! use sira_finn::models::{Granularity, QnnBuilder};
//! use sira_finn::sira::{analyze, SiRange};
//! use sira_finn::tensor::Tensor;
//! # fn main() -> anyhow::Result<()> {
//! let mut b = QnnBuilder::new("doc", 1);
//! b.input("x", &[1, 8]);
//! b.quant_act(8, false, Granularity::PerTensor, 255.0);
//! b.linear(4, 3, Granularity::PerTensor, true);
//! let graph = b.finish()?;
//! let mut input_ranges = std::collections::BTreeMap::new();
//! input_ranges.insert("x".to_string(), SiRange::scalar(0.0, 255.0));
//!
//! let analysis = analyze(&graph, &input_ranges)?;          // SIRA facts
//! let mut plan = engine::compile(&graph, &analysis)?       // AOT compile
//!     .with_min_kernel_work(1 << 12);                      // sharding gate
//! plan.set_threads(4);        // persistent pool, shared by plan clones
//! plan.set_min_tile_work(0);  // force the tiled MAC cores (bit-exact)
//!
//! let inputs = vec![Tensor::zeros(&[1, 8]); 2];
//! let outputs = plan.run_batch(&inputs)?;                  // hot path
//! assert_eq!(outputs.len(), 2);
//! # Ok(()) }
//! ```
//!
//! See [`fuse`] for what the compiler specialises (constant folding,
//! elementwise-chain fusion, im2col+MVU+threshold fusion, SIRA-narrowed
//! i32/i64 accumulators, stuck-channel elision — padded convs included,
//! tile-major weight pre-packing, buffer-arena reuse), [`kernels::tile`]
//! for the register-blocked SIMD-friendly MAC cores (the scalar
//! [`kernels::MacElem::mac_row`] stays on as the bit-exactness oracle,
//! pinned by `rust/tests/kernel_properties.rs`), [`plan`] for the
//! parallel runner (sample sharding across the batch plus tile-aligned
//! row/column/channel sharding inside large MVU kernels), [`pool`] for
//! the persistent worker pool every sharded path executes on (work items
//! instead of per-call thread spawns, worker states checked out per
//! task), [`segment`] for pipeline-parallel plan segmentation
//! ([`SegmentedPlan`], served by
//! [`crate::coordinator::Coordinator::start_pipelined`]), and
//! `rust/tests/engine_equivalence.rs` plus
//! `rust/tests/engine_differential.rs` for the bit-exactness contract
//! against the interpreter — on the zoo workloads and on seeded random
//! graphs, at every tested batch size and thread count, tiled and
//! scalar, monolithic and segmented.

pub mod arena;
pub mod fuse;
pub mod kernels;
pub mod plan;
pub mod pool;
pub mod segment;
pub mod snapshot;
pub mod tune;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::graph::Graph;
use crate::passes::{fold, lower, streamline, thresholds};
use crate::sira::{analyze, Analysis, SiRange};

pub use plan::{Plan, PlanStats};
pub use pool::WorkerPool;
pub use segment::SegmentedPlan;
pub use tune::TilingScheme;

/// Compile a graph to an executable [`Plan`] and resolve each MAC
/// step's tiling scheme against this machine's tuning table
/// ([`tune::global`]). The scheme only steers loop geometry — results
/// are bit-identical with or without a tuning file, so the table is a
/// pure performance input applied at the edge (here and at snapshot
/// decode), never serialized into plans.
pub fn compile(g: &Graph, analysis: &Analysis) -> Result<Plan> {
    let mut plan = fuse::compile(g, analysis)?;
    plan.apply_tuning(tune::global());
    Ok(plan)
}

/// Streamline `g` in place (lower → fold → extract scales → aggregate →
/// threshold-convert, the §4.1 pipeline) and return a fresh SIRA
/// analysis of the streamlined graph. Compiling the result yields plans
/// whose MACs run on pure-integer operands with narrowed accumulators —
/// the configuration the serving benchmarks use.
pub fn prepare_streamlined(
    g: &mut Graph,
    input_ranges: &BTreeMap<String, SiRange>,
) -> Result<Analysis> {
    lower::lower_all(g)?;
    fold::fold_constants(g, false)?;
    streamline::extract_quant_scales(g)?;
    fold::duplicate_shared_initializers(g)?;
    streamline::streamline(g)?;
    thresholds::convert_to_thresholds(g, input_ranges)?;
    analyze(g, input_ranges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executor::Executor;
    use crate::graph::{Node, Op, RoundMode};
    use crate::models::{Granularity, QnnBuilder};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn exact_match(g: &Graph, analysis: &Analysis, xs: &[Tensor]) {
        let mut plan = compile(g, analysis).unwrap();
        let mut exec = Executor::new(g).unwrap();
        let ys = plan.run_batch(xs).unwrap();
        assert_eq!(ys.len(), xs.len());
        for (x, y) in xs.iter().zip(&ys) {
            let want = exec.run_single(x).unwrap().remove(0);
            assert_eq!(want.shape(), y.shape());
            assert_eq!(want.data(), y.data(), "engine output differs");
        }
    }

    fn input_batch(rng: &mut Rng, shape: &[usize], b: usize) -> Vec<Tensor> {
        let numel: usize = shape.iter().product();
        (0..b)
            .map(|_| {
                Tensor::new(shape, (0..numel).map(|_| rng.int_in(0, 255) as f64).collect())
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn mlp_bit_exact_vs_executor() {
        let mut b = QnnBuilder::new("mlp", 11);
        b.input("x", &[1, 12]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.linear(8, 2, Granularity::PerChannel, true);
        b.batchnorm();
        b.relu();
        b.quant_act(2, false, Granularity::PerTensor, 4.0);
        b.linear(5, 4, Granularity::PerTensor, true);
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::sira::SiRange::scalar(0.0, 255.0),
        );
        let analysis = analyze(&m, &inputs).unwrap();
        let mut rng = Rng::new(99);
        exact_match(&m, &analysis, &input_batch(&mut rng, &[1, 12], 5));
    }

    #[test]
    fn cnn_with_pool_and_residual_bit_exact() {
        let mut b = QnnBuilder::new("cnn", 21);
        b.input("x", &[1, 2, 8, 8]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.conv(4, 3, 1, 1, 3, Granularity::PerChannel, false);
        b.batchnorm();
        b.relu();
        b.quant_act(3, true, Granularity::PerTensor, 4.0);
        let tap = b.current().to_string();
        let tap_shape = b.current_shape().to_vec();
        b.conv(4, 3, 1, 1, 3, Granularity::PerChannel, false);
        b.batchnorm();
        b.quant_act(3, true, Granularity::PerTensor, 4.0);
        let main = b.current().to_string();
        let main_shape = b.current_shape().to_vec();
        b.seek(&main, &main_shape);
        b.add_residual(&tap);
        let _ = tap_shape;
        b.relu();
        b.maxpool(2);
        b.global_avgpool();
        b.flatten();
        b.linear(3, 4, Granularity::PerTensor, true);
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut rng = Rng::new(7);
        exact_match(&m, &analysis, &input_batch(&mut rng, &[1, 2, 8, 8], 3));
    }

    #[test]
    fn depthwise_conv_bit_exact() {
        let mut b = QnnBuilder::new("dw", 31);
        b.input("x", &[1, 4, 6, 6]);
        b.quant_act(4, false, Granularity::PerChannel, 8.0);
        b.conv(0, 3, 1, 1, 4, Granularity::PerChannel, true);
        b.relu();
        b.global_avgpool();
        b.flatten();
        b.linear(3, 4, Granularity::PerTensor, false);
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 15.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut rng = Rng::new(13);
        let xs: Vec<Tensor> = (0..3)
            .map(|_| {
                Tensor::new(
                    &[1, 4, 6, 6],
                    (0..144).map(|_| rng.int_in(0, 15) as f64).collect(),
                )
                .unwrap()
            })
            .collect();
        exact_match(&m, &analysis, &xs);
    }

    /// Pure-integer tail → MultiThreshold graph: the MatMul must compile
    /// onto a narrowed integer accumulator and fuse the threshold.
    #[test]
    fn integer_matmul_with_fused_threshold() {
        let mut g = Graph::new("intmm");
        g.add_input("x", &[1, 4]);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("bits", Tensor::scalar(8.0));
        // x is quantized to pure integers by a unit-scale quantizer
        g.add_node(Node::new(
            "q",
            Op::Quant {
                signed: true,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["x", "one", "z", "bits"],
            &["xq"],
        ));
        g.add_initializer(
            "W",
            Tensor::new(&[4, 3], vec![1.0, -2.0, 3.0, 0.0, 5.0, -1.0, 2.0, 2.0, 0.0, -3.0, 1.0, 4.0])
                .unwrap(),
        );
        g.add_node(Node::new("mm", Op::MatMul, &["xq", "W"], &["h"]));
        g.add_initializer(
            "th",
            Tensor::new(&[1, 3], vec![-50.0, 0.0, 75.0]).unwrap(),
        );
        g.add_node(Node::new(
            "mt",
            Op::MultiThreshold {
                out_scale: 1.0,
                out_bias: 0.0,
            },
            &["h", "th"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(-100.0, 100.0));
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        assert_eq!(plan.stats().matmul_i32, 1, "{}", plan.stats());
        assert_eq!(plan.stats().fused_thresholds, 1);
        let mut rng = Rng::new(5);
        let xs: Vec<Tensor> = (0..4)
            .map(|_| {
                Tensor::new(&[1, 4], (0..4).map(|_| rng.int_in(-100, 100) as f64).collect())
                    .unwrap()
            })
            .collect();
        exact_match(&g, &analysis, &xs);
    }

    /// The streamlined pipeline produces integer MACs on a real QNN.
    #[test]
    fn streamlined_mlp_uses_integer_macs() {
        let mut b = QnnBuilder::new("smlp", 41);
        b.input("x", &[1, 10]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.linear(6, 2, Granularity::PerTensor, false);
        b.batchnorm();
        b.relu();
        b.quant_act(2, false, Granularity::PerTensor, 4.0);
        b.linear(4, 4, Granularity::PerTensor, true);
        let mut g = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = prepare_streamlined(&mut g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        assert!(
            plan.stats().integer_macs() >= 1,
            "no integer MACs after streamlining: {}",
            plan.stats()
        );
        let mut rng = Rng::new(3);
        exact_match(&g, &analysis, &input_batch(&mut rng, &[1, 10], 4));
    }

    #[test]
    fn batch_matches_single_runs() {
        let mut b = QnnBuilder::new("bm", 51);
        b.input("x", &[1, 8]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.linear(5, 3, Granularity::PerTensor, true);
        b.relu();
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut plan = compile(&m, &analysis).unwrap();
        let mut rng = Rng::new(17);
        let xs = input_batch(&mut rng, &[1, 8], 6);
        let batched = plan.run_batch(&xs).unwrap();
        for (x, yb) in xs.iter().zip(&batched) {
            let y1 = plan.run_one(x).unwrap();
            assert_eq!(y1.data(), yb.data());
        }
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let mut b = QnnBuilder::new("shape", 61);
        b.input("x", &[1, 8]);
        b.relu();
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(-1.0, 1.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut plan = compile(&m, &analysis).unwrap();
        assert!(plan.run_batch(&[Tensor::zeros(&[1, 9])]).is_err());
        assert!(plan.run_batch(&[]).unwrap().is_empty());
    }

    /// Regression: the empty-batch and shape-mismatch paths must run
    /// their checks before any arena touch — a rejected (or empty) call
    /// leaves every worker buffer exactly as it found it.
    #[test]
    fn empty_and_invalid_batches_never_touch_the_arena() {
        let mut b = QnnBuilder::new("pristine", 62);
        b.input("x", &[1, 8]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.linear(4, 3, Granularity::PerTensor, true);
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut plan = compile(&m, &analysis).unwrap();
        let untouched = |p: &super::Plan| p.serial.bufs.iter().all(|b| b.is_empty());
        assert!(untouched(&plan), "fresh plan must start with empty buffers");
        assert!(plan.run_batch(&[]).unwrap().is_empty());
        assert!(untouched(&plan), "empty batch grew a buffer");
        // a mixed batch where a later sample mismatches must fail before
        // the first sample is packed
        let good = Tensor::zeros(&[1, 8]);
        let bad = Tensor::zeros(&[1, 9]);
        assert!(plan.run_batch(&[good, bad]).is_err());
        assert!(untouched(&plan), "rejected batch perturbed the arena");
    }

    /// Sample sharding and intra-kernel row/channel sharding must be
    /// bit-invisible at every thread count (min work forced to 0 so the
    /// sharded paths engage even on this tiny model).
    #[test]
    fn threaded_execution_is_bit_exact() {
        let mut b = QnnBuilder::new("thr", 63);
        b.input("x", &[1, 2, 8, 8]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.conv(4, 3, 1, 0, 3, Granularity::PerChannel, false);
        b.relu();
        b.quant_act(3, true, Granularity::PerTensor, 4.0);
        b.flatten();
        b.linear(6, 3, Granularity::PerTensor, true);
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut rng = Rng::new(64);
        let xs = input_batch(&mut rng, &[1, 2, 8, 8], 5);
        let mut reference = compile(&m, &analysis).unwrap();
        let want = reference.run_batch(&xs).unwrap();
        for threads in [2usize, 3, 4, 8] {
            let mut plan = compile(&m, &analysis).unwrap();
            plan.set_threads(threads);
            plan.set_min_kernel_work(0);
            for bsz in [1usize, 2, 5] {
                let got = plan.run_batch(&xs[..bsz]).unwrap();
                for (w, g) in want[..bsz].iter().zip(&got) {
                    assert_eq!(
                        w.data(),
                        g.data(),
                        "threads={threads} bsz={bsz} diverged from serial"
                    );
                }
            }
        }
    }

    /// §7.1 stuck-channel elision: input positions with point-interval
    /// ranges leave the integer MAC (their contribution folds into the
    /// accumulator bias), the stats record it, and outputs stay
    /// bit-exact against the executor for in-range inputs.
    #[test]
    fn stuck_channels_are_elided_from_integer_matmul() {
        let mut g = Graph::new("stuckmm");
        g.add_input("x", &[1, 4]);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("bits", Tensor::scalar(8.0));
        g.add_node(Node::new(
            "q",
            Op::Quant {
                signed: true,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["x", "one", "z", "bits"],
            &["xq"],
        ));
        g.add_initializer(
            "W",
            Tensor::new(
                &[4, 3],
                vec![1.0, -2.0, 3.0, 0.0, 5.0, -1.0, 2.0, 2.0, 0.0, -3.0, 1.0, 4.0],
            )
            .unwrap(),
        );
        g.add_node(Node::new("mm", Op::MatMul, &["xq", "W"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        // elements 0 and 3 are stuck (point intervals), 1 and 2 are live
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::sira::SiRange::float(
                Tensor::new(&[1, 4], vec![5.0, -100.0, -100.0, 7.0]).unwrap(),
                Tensor::new(&[1, 4], vec![5.0, 100.0, 100.0, 7.0]).unwrap(),
            )
            .unwrap(),
        );
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        assert_eq!(plan.stats().matmul_i32, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_steps, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_channels, 2, "{}", plan.stats());
        let mut rng = Rng::new(65);
        let xs: Vec<Tensor> = (0..6)
            .map(|_| {
                Tensor::new(
                    &[1, 4],
                    vec![
                        5.0,
                        rng.int_in(-100, 100) as f64,
                        rng.int_in(-100, 100) as f64,
                        7.0,
                    ],
                )
                .unwrap()
            })
            .collect();
        exact_match(&g, &analysis, &xs);
    }

    /// Conv variant of elision: a spatially uniform stuck input channel
    /// (per-channel point interval) is dropped from the im2col and the
    /// weight matrix when pad is 0.
    #[test]
    fn stuck_channels_are_elided_from_integer_conv() {
        let mut g = Graph::new("stuckconv");
        g.add_input("x", &[1, 3, 4, 4]);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("bits", Tensor::scalar(8.0));
        g.add_node(Node::new(
            "q",
            Op::Quant {
                signed: true,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["x", "one", "z", "bits"],
            &["xq"],
        ));
        let mut rng = Rng::new(66);
        g.add_initializer(
            "W",
            Tensor::new(
                &[2, 3, 3, 3],
                (0..2 * 3 * 9).map(|_| rng.int_in(-3, 3) as f64).collect(),
            )
            .unwrap(),
        );
        g.add_node(Node::new(
            "conv",
            Op::Conv {
                spec: crate::tensor::Conv2dSpec {
                    kernel: (3, 3),
                    stride: (1, 1),
                    pad: (0, 0),
                },
                group: 1,
            },
            &["xq", "W"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        // channel 1 stuck at 9, channels 0 and 2 live
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::sira::SiRange::float(
                Tensor::new(&[1, 3, 1, 1], vec![-50.0, 9.0, -50.0]).unwrap(),
                Tensor::new(&[1, 3, 1, 1], vec![50.0, 9.0, 50.0]).unwrap(),
            )
            .unwrap(),
        );
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        assert_eq!(plan.stats().conv_i32, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_steps, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_channels, 1, "{}", plan.stats());
        let xs: Vec<Tensor> = (0..3)
            .map(|_| {
                let mut data = Vec::with_capacity(48);
                for ch in 0..3 {
                    for _ in 0..16 {
                        data.push(if ch == 1 { 9.0 } else { rng.int_in(-50, 50) as f64 });
                    }
                }
                Tensor::new(&[1, 3, 4, 4], data).unwrap()
            })
            .collect();
        exact_match(&g, &analysis, &xs);
    }

    /// The `min_kernel_work` tuning API: `usize::MAX` keeps every kernel
    /// serial even under a thread budget; 0 forces the sharded paths
    /// onto arbitrarily tiny kernels. Observable through the pool's
    /// executed-work-item counter; bits never change either way.
    #[test]
    fn min_kernel_work_gates_intra_kernel_sharding() {
        let mut b = QnnBuilder::new("gate", 81);
        b.input("x", &[1, 8]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.linear(8, 3, Granularity::PerTensor, true);
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let mut rng = Rng::new(82);
        let xs = input_batch(&mut rng, &[1, 8], 1);
        let mut serial = compile(&m, &analysis).unwrap();
        let want = serial.run_batch(&xs).unwrap();

        let mut gated = compile(&m, &analysis)
            .unwrap()
            .with_min_kernel_work(usize::MAX);
        gated.set_threads(2);
        assert_eq!(gated.min_kernel_work(), usize::MAX);
        let got = gated.run_batch(&xs).unwrap();
        assert_eq!(got[0].data(), want[0].data());
        assert_eq!(
            gated.pool().unwrap().tasks_executed(),
            0,
            "min_kernel_work = MAX must keep every kernel serial"
        );

        let mut forced = compile(&m, &analysis).unwrap().with_min_kernel_work(0);
        forced.set_threads(2);
        let got = forced.run_batch(&xs).unwrap();
        assert_eq!(got[0].data(), want[0].data());
        assert!(
            forced.pool().unwrap().tasks_executed() > 0,
            "min_kernel_work = 0 must force sharded work items"
        );
    }

    /// §7.1 extension: a stuck input channel of a *padded* conv is
    /// elided too — border taps read pad zeros instead of the stuck
    /// value, so the folded contribution becomes a per-output-position
    /// bias; outputs stay bit-exact against the executor.
    #[test]
    fn stuck_channels_are_elided_from_padded_integer_conv() {
        let mut g = Graph::new("stuckpad");
        g.add_input("x", &[1, 3, 4, 4]);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("bits", Tensor::scalar(8.0));
        g.add_node(Node::new(
            "q",
            Op::Quant {
                signed: true,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["x", "one", "z", "bits"],
            &["xq"],
        ));
        let mut rng = Rng::new(83);
        g.add_initializer(
            "W",
            Tensor::new(
                &[2, 3, 3, 3],
                (0..2 * 3 * 9).map(|_| rng.int_in(-3, 3) as f64).collect(),
            )
            .unwrap(),
        );
        g.add_node(Node::new(
            "conv",
            Op::Conv {
                spec: crate::tensor::Conv2dSpec {
                    kernel: (3, 3),
                    stride: (1, 1),
                    pad: (1, 1),
                },
                group: 1,
            },
            &["xq", "W"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        // channel 1 stuck at 9, channels 0 and 2 live
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::sira::SiRange::float(
                Tensor::new(&[1, 3, 1, 1], vec![-50.0, 9.0, -50.0]).unwrap(),
                Tensor::new(&[1, 3, 1, 1], vec![50.0, 9.0, 50.0]).unwrap(),
            )
            .unwrap(),
        );
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        assert_eq!(plan.stats().conv_i32, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_steps, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_channels, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_padded_convs, 1, "{}", plan.stats());
        let xs: Vec<Tensor> = (0..3)
            .map(|_| {
                let mut data = Vec::with_capacity(48);
                for ch in 0..3 {
                    for _ in 0..16 {
                        data.push(if ch == 1 { 9.0 } else { rng.int_in(-50, 50) as f64 });
                    }
                }
                Tensor::new(&[1, 3, 4, 4], data).unwrap()
            })
            .collect();
        exact_match(&g, &analysis, &xs);
    }

    #[test]
    fn arena_reuses_buffers_on_deep_chains() {
        let mut b = QnnBuilder::new("deep", 71);
        b.input("x", &[1, 16]);
        for _ in 0..6 {
            b.quant_act(8, true, Granularity::PerTensor, 64.0);
            b.linear(16, 4, Granularity::PerTensor, true);
            b.relu();
        }
        let m = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = analyze(&m, &inputs).unwrap();
        let plan = compile(&m, &analysis).unwrap();
        let st = plan.stats();
        assert!(
            st.physical_buffers < st.logical_slots,
            "no buffer reuse: {st}"
        );
        let mut rng = Rng::new(23);
        exact_match(&m, &analysis, &input_batch(&mut rng, &[1, 16], 2));
    }

    /// Saturate a tuning table so *every* MAC step in `plan` resolves to
    /// `force`, whatever its shape — the test double for a tuning file
    /// that (rightly or wrongly) demands KC blocking everywhere.
    fn force_table(plan: &Plan, force: TilingScheme) -> tune::TuningTable {
        use super::plan::Step;
        let mut t = tune::TuningTable::default();
        for step in &plan.steps {
            let (k_eff, n) = match step {
                Step::MatMul(s) => (s.elide.as_ref().map_or(s.k, |e| e.live.len()), s.n),
                Step::Conv(s) => {
                    let live = s.elide.as_ref().map_or(s.c, |e| e.live.len());
                    (live * s.spec.kernel.0 * s.spec.kernel.1, s.oc)
                }
                _ => continue,
            };
            t.entries.insert(
                tune::shape_key(k_eff, n),
                tune::TuneEntry { scheme: force, ns: 1.0 },
            );
        }
        t
    }

    /// Tentpole safety net, end to end: force a ragged KC-blocked scheme
    /// onto every MAC step through a hand-built tuning table, drop the
    /// tile work gate so the blocked core actually dispatches, and
    /// confirm the plan stays bit-exact vs the interpreter. Then the
    /// unproven side: an f64-weight plan handed the *same* table keeps
    /// `kc_safe` false (kc_bound = 0.0), stays on the single-pass path,
    /// and still matches the interpreter — a tuning table, however
    /// aggressive, can never change results.
    #[test]
    fn forced_kc_blocking_stays_bit_exact_and_unproven_steps_stay_safe() {
        use super::plan::Step;
        let force = TilingScheme { mr: 3, nr_panels: 2, kc: 5 };

        // proven integer MACs: the blocked core engages
        let mut b = QnnBuilder::new("smlp-kc", 41);
        b.input("x", &[1, 10]);
        b.quant_act(8, false, Granularity::PerTensor, 255.0);
        b.linear(6, 2, Granularity::PerTensor, false);
        b.batchnorm();
        b.relu();
        b.quant_act(2, false, Granularity::PerTensor, 4.0);
        b.linear(4, 4, Granularity::PerTensor, true);
        let mut g = b.finish().unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(0.0, 255.0));
        let analysis = prepare_streamlined(&mut g, &inputs).unwrap();
        let mut plan = compile(&g, &analysis).unwrap();
        assert!(plan.stats().integer_macs() >= 1, "{}", plan.stats());
        plan.apply_tuning(&force_table(&plan, force));
        plan.set_min_tile_work(0);
        assert!(
            plan.steps.iter().any(|s| matches!(
                s, Step::MatMul(m) if m.scheme == force && m.kc_bound > 0.0
            )),
            "no proven MatMul picked up the forced blocked scheme"
        );
        let mut rng = Rng::new(77);
        let xs = input_batch(&mut rng, &[1, 10], 4);
        let ys = plan.run_batch(&xs).unwrap();
        let mut exec = Executor::new(&g).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let want = exec.run_single(x).unwrap().remove(0);
            assert_eq!(want.data(), y.data(), "forced-KC integer plan diverged");
        }

        // unproven f64 MAC: same table, blocking must refuse to engage
        let mut g = Graph::new("f64mm-kc");
        g.add_input("x", &[1, 6]);
        g.add_initializer(
            "W",
            Tensor::new(&[6, 4], (0..24).map(|i| i as f64 * 0.37 - 3.1).collect()).unwrap(),
        );
        g.add_node(Node::new("mm", Op::MatMul, &["x", "W"], &["y"]));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), crate::sira::SiRange::scalar(-10.0, 10.0));
        let analysis = analyze(&g, &inputs).unwrap();
        let mut plan = compile(&g, &analysis).unwrap();
        assert_eq!(plan.stats().matmul_f64, 1, "{}", plan.stats());
        plan.apply_tuning(&force_table(&plan, force));
        plan.set_min_tile_work(0);
        assert!(
            plan.steps.iter().any(|s| matches!(
                s, Step::MatMul(m) if m.scheme == force && m.kc_bound == 0.0
            )),
            "f64 step should carry the scheme but no proof"
        );
        let xs: Vec<Tensor> = (0..3)
            .map(|_| {
                Tensor::new(&[1, 6], (0..6).map(|_| rng.int_in(-20, 20) as f64 * 0.5).collect())
                    .unwrap()
            })
            .collect();
        let ys = plan.run_batch(&xs).unwrap();
        let mut exec = Executor::new(&g).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let want = exec.run_single(x).unwrap().remove(0);
            assert_eq!(want.data(), y.data(), "unproven f64 step was reordered");
        }
    }

    /// `Plan::apply_tuning` resolution on the three zoo additions
    /// (VGG12, RN12, DWS), raw and streamlined: a saturated tuning table
    /// must land its scheme on the models' MAC steps through the
    /// `(k_eff, n)` lookup — elided shapes included — and the retiled
    /// plan must stay bit-exact against the interpreter with the tile
    /// work gate dropped.
    #[test]
    fn apply_tuning_resolves_on_new_zoo_models() {
        use super::plan::Step;
        let force = TilingScheme { mr: 2, nr_panels: 2, kc: 7 };
        for m in [
            crate::models::vgg12_w2a2().unwrap(),
            crate::models::rn12_w3a3().unwrap(),
            crate::models::dws_w4a4().unwrap(),
        ] {
            let mut rng = Rng::new(0x7A11);
            let xs = input_batch(&mut rng, &m.input_shape, 1);
            for streamlined in [false, true] {
                let label = if streamlined { "streamlined" } else { "raw" };
                let mut g = m.graph.clone();
                let analysis = if streamlined {
                    prepare_streamlined(&mut g, &m.input_ranges).unwrap()
                } else {
                    analyze(&g, &m.input_ranges).unwrap()
                };
                let mut plan = compile(&g, &analysis).unwrap();
                plan.apply_tuning(&force_table(&plan, force));
                plan.set_min_tile_work(0);
                assert!(
                    plan.steps.iter().any(|s| {
                        matches!(s, Step::MatMul(st) if st.scheme == force)
                            || matches!(s, Step::Conv(st) if st.scheme == force)
                    }),
                    "{} ({label}): no MAC step resolved the forced scheme",
                    m.name
                );
                let ys = plan.run_batch(&xs).unwrap();
                let mut exec = Executor::new(&g).unwrap();
                let want = exec.run_single(&xs[0]).unwrap().remove(0);
                assert_eq!(
                    want.data(),
                    ys[0].data(),
                    "{} ({label}): retiled plan diverged",
                    m.name
                );
            }
        }
    }

    /// Depthwise form of §7.1 stuck-channel elision, second witness
    /// beyond MNv1: a padded depthwise conv shaped like DWS's
    /// stem-output stage with one input channel pinned must compile its
    /// constant output plane away (`DepthwiseStep::elided`), count it in
    /// `elided_mac_channels`, and stay bit-exact on inputs honoring the
    /// stuck channel.
    #[test]
    fn stuck_plane_is_elided_from_padded_depthwise_conv() {
        use super::plan::Step;
        let ch = 8usize;
        let mut g = Graph::new("stuckdw");
        g.add_input("x", &[1, ch, 8, 8]);
        g.add_initializer("one", Tensor::scalar(1.0));
        g.add_initializer("z", Tensor::scalar(0.0));
        g.add_initializer("bits", Tensor::scalar(8.0));
        g.add_node(Node::new(
            "q",
            Op::Quant {
                signed: true,
                narrow: false,
                rounding: RoundMode::RoundEven,
            },
            &["x", "one", "z", "bits"],
            &["xq"],
        ));
        let mut rng = Rng::new(0xD25);
        g.add_initializer(
            "W",
            Tensor::new(
                &[ch, 1, 3, 3],
                (0..ch * 9).map(|_| rng.int_in(-3, 3) as f64).collect(),
            )
            .unwrap(),
        );
        g.add_node(Node::new(
            "dw",
            Op::Conv {
                spec: crate::tensor::Conv2dSpec {
                    kernel: (3, 3),
                    stride: (1, 1),
                    pad: (1, 1),
                },
                group: ch,
            },
            &["xq", "W"],
            &["y"],
        ));
        g.outputs.push("y".into());
        crate::graph::shapes::infer_shapes(&mut g).unwrap();
        // channel 3 stuck at 5, all others live
        let (mut lo, mut hi) = (vec![-50.0; ch], vec![50.0; ch]);
        lo[3] = 5.0;
        hi[3] = 5.0;
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::sira::SiRange::float(
                Tensor::new(&[1, ch, 1, 1], lo).unwrap(),
                Tensor::new(&[1, ch, 1, 1], hi).unwrap(),
            )
            .unwrap(),
        );
        let analysis = analyze(&g, &inputs).unwrap();
        let plan = compile(&g, &analysis).unwrap();
        assert_eq!(plan.stats().depthwise, 1, "{}", plan.stats());
        assert_eq!(plan.stats().elided_mac_channels, 1, "{}", plan.stats());
        let elided_planes: usize = plan
            .steps
            .iter()
            .map(|s| match s {
                Step::Depthwise(d) => d.elided.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(elided_planes, 1, "stuck plane not elided from the dw step");
        let xs: Vec<Tensor> = (0..2)
            .map(|_| {
                let mut data = Vec::with_capacity(ch * 64);
                for c in 0..ch {
                    for _ in 0..64 {
                        data.push(if c == 3 { 5.0 } else { rng.int_in(-50, 50) as f64 });
                    }
                }
                Tensor::new(&[1, ch, 8, 8], data).unwrap()
            })
            .collect();
        exact_match(&g, &analysis, &xs);
    }
}
