//! Tiled, register-blocked MAC microkernels: the SIMD-friendly core
//! behind the engine's MatMul / im2col-Conv steps (elision-compacted
//! variants included), with the scalar [`MacElem::mac_row`] retained as
//! the bit-exactness oracle (see `rust/tests/kernel_properties.rs`).
//!
//! # Layout
//!
//! Weights are pre-packed at plan-compile time ([`PackedWeights::pack`],
//! driven by [`super::MacMat::new`] from `engine::fuse`) into **panels**:
//! the `(k, n)` row-major matrix is cut into column blocks of [`NR`]
//! lanes, and each panel stores its `k × NR` slice contiguously (ragged
//! final panel zero-padded to `NR`). The inner loop then streams one
//! contiguous panel row per `k` step — no strided weight access, no
//! bounds arithmetic the compiler cannot hoist.
//!
//! # Microkernel
//!
//! [`panel_block`] keeps an `MR × NR` accumulator grid in fixed-size
//! arrays — small enough that the compiler promotes every lane to a SIMD
//! register and unrolls both block loops — and streams the panel
//! sequentially over `k`, so each panel is read exactly once per row
//! block while the `MR` activation rows are reused from registers/L1.
//!
//! # KC blocking
//!
//! [`mac_rows_blocked`] adds a cache-blocked loop nest on top of the
//! same panels: the dot length is cut into `kc`-deep chunks, and for
//! each chunk a *group* of panels is swept while the chunk of
//! activation rows stays L1-resident. Each `(chunk, panel)` pair
//! accumulates into a zero-seeded register tile that is then **spilled**
//! (added) into the memory accumulator. This changes the association of
//! every dot product — chunk partials are formed away from the seed —
//! so the blocked kernels are only dispatched on steps whose SIRA bound
//! proves every such partial safe at the step's accumulator width (see
//! `engine::fuse`); see the bit-exactness rules below.
//!
//! # Bit-exactness
//!
//! The single-pass register blocking ([`mac_rows_tiled`]) reorders work
//! only **across** output elements, never within one dot product: each
//! accumulator lane still adds its terms in increasing-`k` order,
//! starting from its seed (zero or the elided-channel bias) — exactly
//! the scalar kernel's order. Two consequences, both locked by the
//! property suite:
//!
//! * **f64** stays bit-identical because the per-element operation
//!   sequence is identical, including the zero-skip (`MacElem::
//!   EXACT_SKIP`): a skipped `a == 0.0` term is skipped here too, so
//!   signed zeros and non-finite weights behave exactly as in the
//!   scalar kernel.
//! * **i32/i64** cannot overflow anywhere the scalar kernel didn't: the
//!   per-element partial sums are the *same* sums in the same order (the
//!   compile-time `Σ|aᵢ·wᵢⱼ|` bound from `engine::fuse` additionally
//!   covers any order, pad lanes contribute exact zeros).
//!
//! The KC-blocked kernels keep integer results element-exact under a
//! stronger precondition: integer addition is associative as long as no
//! intermediate wraps, every blocked intermediate is either a chunk
//! partial (`|·| ≤ Σ|aᵢ·wᵢⱼ|`) or the seed plus a prefix of whole
//! chunks (also `≤ |seed-subset| + Σ|live aᵢ·wᵢⱼ|`), and `engine::fuse`
//! only marks a step KC-safe when that absolute-value bound fits the
//! accumulator width. **f64 never takes the blocked path** — a changed
//! association changes rounding — which is why the blocked entry points
//! are integer-proof-gated at dispatch, not here.
//!
//! # Tuning
//!
//! [`NR`] stays a compile-time constant — it is baked into the
//! [`PackedWeights`] panel layout — but the row-block height, panel
//! group width and k-chunk depth of the blocked kernels are runtime
//! parameters (`TilingScheme { mr, nr_panels, kc }` in `engine::tune`):
//! `sira-finn tune` measures candidate schemes per kernel shape on the
//! local machine and the plan compiler resolves the tuned scheme per
//! step (snapshot loads re-resolve against the same local tuning file).
//! [`MR`] is the default row-block height used when no tuning entry
//! applies.

use core::ops::Range;

use super::{BiasRef, MacElem, ThresholdTable};
use crate::tensor::Conv2dSpec;

/// Register lanes per column panel: 8 accumulators span two 256-bit
/// vectors at f64/i64 width and one at i32 — wide enough to saturate
/// 2×FMA pipes, narrow enough that an `MR×NR` grid still fits the
/// architectural register file.
pub const NR: usize = 8;

/// Activation rows per register block. `MR × NR = 32` accumulator lanes
/// ≤ 8 vector registers at f64 width, leaving room for the broadcast
/// activation values and the streamed panel row. Re-tunable up to 8
/// (the row-block dispatch in this module instantiates every block
/// height 1..=8 and advances by the height actually run, so any
/// `1 ..= 8` value is safe); the compile-time assertion below guards
/// the ceiling.
pub const MR: usize = 4;

const _: () = assert!(MR >= 1 && MR <= 8, "MR must be within the dispatched 1..=8 range");

/// A weight matrix packed tile-major for the register-blocked kernels:
/// `ceil(n / NR)` panels, each holding its `k × NR` column slice
/// contiguously (row `kk` of panel `jb` = columns `jb*NR .. jb*NR+NR` of
/// weight row `kk`), with the ragged final panel zero-padded. Padding is
/// exact: pad lanes multiply-accumulate literal zeros and are never
/// written back.
#[derive(Clone, Debug)]
pub struct PackedWeights<T> {
    data: Vec<T>,
    k: usize,
    n: usize,
}

impl<T: MacElem> PackedWeights<T> {
    /// Pack a `(k, n)` row-major matrix. The packed copy costs
    /// `k * round_up(n, NR)` elements — the documented packed-weights
    /// memory trade-off (≈ one extra copy of every MAC weight matrix).
    pub fn pack(flat: &[T], k: usize, n: usize) -> PackedWeights<T> {
        assert_eq!(flat.len(), k * n, "flat weight matrix is not (k, n)");
        let nb = n.div_ceil(NR);
        let mut data = vec![T::ZERO; nb * k * NR];
        for jb in 0..nb {
            let base = jb * k * NR;
            let j0 = jb * NR;
            let lanes = NR.min(n - j0);
            for kk in 0..k {
                data[base + kk * NR..base + kk * NR + lanes]
                    .copy_from_slice(&flat[kk * n + j0..kk * n + j0 + lanes]);
            }
        }
        PackedWeights { data, k, n }
    }

    /// Dot length (weight rows).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical column count (pre-padding).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total packed elements, padding included (the memory-overhead
    /// observable surfaced through `PlanStats::packed_weight_elems`).
    pub fn padded_len(&self) -> usize {
        self.data.len()
    }

    /// The contiguous `k × NR` slice of column panel `jb`.
    #[inline]
    fn panel(&self, jb: usize) -> &[T] {
        &self.data[jb * self.k * NR..(jb + 1) * self.k * NR]
    }

    /// Recover the `(k, n)` row-major matrix from the panels, dropping
    /// the pad lanes — the exact inverse of [`PackedWeights::pack`]
    /// (packing copies, never transforms, so `pack(unpack()) == self`).
    /// Used by plan serialization when the flat oracle has been dropped.
    pub fn unpack(&self) -> Vec<T> {
        let mut flat = vec![T::ZERO; self.k * self.n];
        for jb in 0..self.n.div_ceil(NR) {
            let panel = self.panel(jb);
            let j0 = jb * NR;
            let lanes = NR.min(self.n - j0);
            for kk in 0..self.k {
                flat[kk * self.n + j0..kk * self.n + j0 + lanes]
                    .copy_from_slice(&panel[kk * NR..kk * NR + lanes]);
            }
        }
        flat
    }
}

/// The `M × NR` register-blocked inner loop over one weight panel:
/// `acc[r][jj] += a[r*stride + kk] * panel[kk*NR + jj]` for `kk` in
/// increasing order over the full dot length. `acc` lives in fixed-size
/// arrays so every lane stays in a SIMD register across the whole `k`
/// loop; the panel row is one contiguous `NR`-wide load per `kk`. The
/// f64 instantiation preserves the scalar kernel's zero-skip per
/// activation element ([`MacElem::EXACT_SKIP`]); integer instantiations
/// are branch-free (a zero activation contributes an exact zero either
/// way).
#[inline]
fn panel_block<T: MacElem, const M: usize>(
    a: &[T],
    stride: usize,
    k: usize,
    panel: &[T],
    acc: &mut [[T; NR]; M],
) {
    for kk in 0..k {
        let w: &[T; NR] = panel[kk * NR..kk * NR + NR]
            .try_into()
            .expect("panel rows are NR-wide");
        for (r, acc_r) in acc.iter_mut().enumerate() {
            let ar = a[r * stride + kk];
            if T::EXACT_SKIP && ar.is_zero() {
                continue;
            }
            for (lane, &wv) in acc_r.iter_mut().zip(w.iter()) {
                *lane = lane.mul_acc(ar, wv);
            }
        }
    }
}

/// Tiled counterpart of [`MacElem::mac_row`], generalised to a row
/// block: `acc[r * cols.len() + (j - cols.start)] += a_row_r · W[:, j]`
/// for every row `r < rows` and column `j` in `cols`, where `a` holds
/// `rows` activation rows of length `w.k()` and `acc` is caller-seeded
/// (zero, or an elided-channel bias) — the same contract as the scalar
/// kernel, element-exactly (the property suite's oracle comparison).
pub fn mac_rows_tiled<T: MacElem>(
    a: &[T],
    rows: usize,
    w: &PackedWeights<T>,
    cols: Range<usize>,
    acc: &mut [T],
) {
    let k = w.k;
    assert!(cols.end <= w.n, "column range beyond the packed matrix");
    let width = cols.len();
    assert!(a.len() >= rows * k, "activation block too short");
    assert!(acc.len() >= rows * width, "accumulator block too short");
    if width == 0 {
        return;
    }
    let mut r0 = 0usize;
    while r0 < rows {
        // dispatch on the block height actually run (`min(remaining, MR)`)
        // and advance by exactly that, so every MR in 1..=8 is safe
        let m = (rows - r0).min(MR);
        match m {
            1 => raw_rows::<T, 1>(a, w, r0, &cols, acc),
            2 => raw_rows::<T, 2>(a, w, r0, &cols, acc),
            3 => raw_rows::<T, 3>(a, w, r0, &cols, acc),
            4 => raw_rows::<T, 4>(a, w, r0, &cols, acc),
            5 => raw_rows::<T, 5>(a, w, r0, &cols, acc),
            6 => raw_rows::<T, 6>(a, w, r0, &cols, acc),
            7 => raw_rows::<T, 7>(a, w, r0, &cols, acc),
            _ => raw_rows::<T, 8>(a, w, r0, &cols, acc),
        }
        r0 += m;
    }
}

/// One `M`-row block of [`mac_rows_tiled`]: load the in-range seeds into
/// the register grid, run the panels, store the in-range lanes back.
/// Lanes outside `cols` (other shards' columns, pad lanes) are computed
/// into discarded registers and never written.
#[inline]
fn raw_rows<T: MacElem, const M: usize>(
    a: &[T],
    w: &PackedWeights<T>,
    r0: usize,
    cols: &Range<usize>,
    acc: &mut [T],
) {
    let k = w.k;
    let width = cols.len();
    for jb in cols.start / NR..cols.end.div_ceil(NR) {
        let j0 = jb * NR;
        let mut regs = [[T::ZERO; NR]; M];
        for (r, regs_r) in regs.iter_mut().enumerate() {
            let row = &acc[(r0 + r) * width..(r0 + r) * width + width];
            for (jj, lane) in regs_r.iter_mut().enumerate() {
                let j = j0 + jj;
                if j >= cols.start && j < cols.end {
                    *lane = row[j - cols.start];
                }
            }
        }
        panel_block::<T, M>(&a[r0 * k..], k, k, w.panel(jb), &mut regs);
        for (r, regs_r) in regs.iter().enumerate() {
            let row = &mut acc[(r0 + r) * width..(r0 + r) * width + width];
            for (jj, lane) in regs_r.iter().enumerate() {
                let j = j0 + jj;
                if j >= cols.start && j < cols.end {
                    row[j - cols.start] = *lane;
                }
            }
        }
    }
}

/// KC-blocked counterpart of [`mac_rows_tiled`]: same accumulate-into
/// contract (`acc` caller-seeded), but the loop nest is
/// `row block → panel group → k chunk → panel`, with each
/// `(chunk, panel)` pair accumulated into a zero-seeded register tile
/// that is then spilled (added) into `acc`. `mr` is the row-block
/// height (clamped to the dispatched `1..=8`), `nr_panels` the number
/// of [`NR`]-wide panels swept per chunk while the activation chunk
/// stays hot, and `kc` the chunk depth (`0` means unblocked: one chunk
/// spanning the whole dot length — still partial-from-zero
/// association).
///
/// Integer-only by contract: the changed association is element-exact
/// for i32/i64 when the caller holds the SIRA proof that no
/// intermediate wraps (see the module docs), and silently changes
/// rounding for f64 — dispatch (`engine::plan`) never routes f64 steps
/// here, and the property suite runs it under overflow checks.
pub fn mac_rows_blocked<T: MacElem>(
    a: &[T],
    rows: usize,
    w: &PackedWeights<T>,
    cols: Range<usize>,
    mr: usize,
    nr_panels: usize,
    kc: usize,
    acc: &mut [T],
) {
    let k = w.k;
    assert!(cols.end <= w.n, "column range beyond the packed matrix");
    let width = cols.len();
    assert!(a.len() >= rows * k, "activation block too short");
    assert!(acc.len() >= rows * width, "accumulator block too short");
    if width == 0 {
        return;
    }
    let mr = mr.clamp(1, 8);
    let group = nr_panels.max(1);
    let kc = if kc == 0 { k.max(1) } else { kc };
    let jb_first = cols.start / NR;
    let jb_last = cols.end.div_ceil(NR);
    let mut r0 = 0usize;
    while r0 < rows {
        let m = (rows - r0).min(mr);
        let mut jb = jb_first;
        while jb < jb_last {
            let jbe = (jb + group).min(jb_last);
            match m {
                1 => blocked_rows::<T, 1>(a, w, r0, &cols, jb..jbe, kc, acc),
                2 => blocked_rows::<T, 2>(a, w, r0, &cols, jb..jbe, kc, acc),
                3 => blocked_rows::<T, 3>(a, w, r0, &cols, jb..jbe, kc, acc),
                4 => blocked_rows::<T, 4>(a, w, r0, &cols, jb..jbe, kc, acc),
                5 => blocked_rows::<T, 5>(a, w, r0, &cols, jb..jbe, kc, acc),
                6 => blocked_rows::<T, 6>(a, w, r0, &cols, jb..jbe, kc, acc),
                7 => blocked_rows::<T, 7>(a, w, r0, &cols, jb..jbe, kc, acc),
                _ => blocked_rows::<T, 8>(a, w, r0, &cols, jb..jbe, kc, acc),
            }
            jb = jbe;
        }
        r0 += m;
    }
}

/// One `M`-row × panel-group block of [`mac_rows_blocked`]: chunks of
/// `kc` weight rows, panels of the group swept per chunk, partials
/// spilled into `acc` after every `(chunk, panel)` microkernel.
#[inline]
fn blocked_rows<T: MacElem, const M: usize>(
    a: &[T],
    w: &PackedWeights<T>,
    r0: usize,
    cols: &Range<usize>,
    panels: Range<usize>,
    kc: usize,
    acc: &mut [T],
) {
    let k = w.k;
    let width = cols.len();
    let mut k0 = 0usize;
    loop {
        let klen = kc.min(k - k0);
        for jb in panels.clone() {
            let j0 = jb * NR;
            let mut part = [[T::ZERO; NR]; M];
            panel_block::<T, M>(
                &a[r0 * k + k0..],
                k,
                klen,
                &w.panel(jb)[k0 * NR..],
                &mut part,
            );
            for (r, part_r) in part.iter().enumerate() {
                let row = &mut acc[(r0 + r) * width..(r0 + r) * width + width];
                for (jj, lane) in part_r.iter().enumerate() {
                    let j = j0 + jj;
                    if j >= cols.start && j < cols.end {
                        row[j - cols.start] = row[j - cols.start].add(*lane);
                    }
                }
            }
        }
        k0 += klen;
        if k0 >= k {
            break;
        }
    }
}

/// Output placement of one tiled MAC block.
#[derive(Clone, Copy)]
pub(crate) enum TiledOut {
    /// MatMul: `out[row * cols.len() + (j - cols.start)]`.
    RowMajor,
    /// Conv NCHW scatter: `out[(j - cols.start) * frame + row]` (row =
    /// output position, `j` = output channel).
    ChannelMajor { frame: usize },
}

/// The plan-facing tiled MAC block: seed the accumulator grid from the
/// elided-channel bias (uniform per column, or per output position when
/// `pos_stride != 0`), run the panels, then finish each in-range value
/// through the optional fused threshold into `out` — the tiled
/// equivalent of `plan::mm_block` / `plan::conv_block`, dispatched
/// behind `Plan::set_min_tile_work`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mac_block_tiled<T: MacElem>(
    a: &[T],
    w: &PackedWeights<T>,
    rows: usize,
    cols: Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    layout: TiledOut,
) {
    if cols.is_empty() {
        return;
    }
    let mut r0 = 0usize;
    while r0 < rows {
        let m = (rows - r0).min(MR);
        match m {
            1 => fused_rows::<T, 1>(a, w, r0, &cols, bias, fused, out, layout),
            2 => fused_rows::<T, 2>(a, w, r0, &cols, bias, fused, out, layout),
            3 => fused_rows::<T, 3>(a, w, r0, &cols, bias, fused, out, layout),
            4 => fused_rows::<T, 4>(a, w, r0, &cols, bias, fused, out, layout),
            5 => fused_rows::<T, 5>(a, w, r0, &cols, bias, fused, out, layout),
            6 => fused_rows::<T, 6>(a, w, r0, &cols, bias, fused, out, layout),
            7 => fused_rows::<T, 7>(a, w, r0, &cols, bias, fused, out, layout),
            _ => fused_rows::<T, 8>(a, w, r0, &cols, bias, fused, out, layout),
        }
        r0 += m;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn fused_rows<T: MacElem, const M: usize>(
    a: &[T],
    w: &PackedWeights<T>,
    r0: usize,
    cols: &Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    layout: TiledOut,
) {
    let k = w.k;
    let width = cols.len();
    for jb in cols.start / NR..cols.end.div_ceil(NR) {
        let j0 = jb * NR;
        let mut regs = [[T::ZERO; NR]; M];
        if let Some(b) = bias {
            for (r, regs_r) in regs.iter_mut().enumerate() {
                let base = (r0 + r) * b.pos_stride;
                for (jj, lane) in regs_r.iter_mut().enumerate() {
                    let j = j0 + jj;
                    if j >= cols.start && j < cols.end {
                        *lane = T::from_i64(b.bias[base + j]);
                    }
                }
            }
        }
        panel_block::<T, M>(&a[r0 * k..], k, k, w.panel(jb), &mut regs);
        for (r, regs_r) in regs.iter().enumerate() {
            for (jj, lane) in regs_r.iter().enumerate() {
                let j = j0 + jj;
                if j < cols.start || j >= cols.end {
                    continue;
                }
                let f = lane.to_f64();
                let v = match fused {
                    Some(t) => t.apply_channel(f, j),
                    None => f,
                };
                match layout {
                    TiledOut::RowMajor => out[(r0 + r) * width + (j - cols.start)] = v,
                    TiledOut::ChannelMajor { frame } => {
                        out[(j - cols.start) * frame + r0 + r] = v
                    }
                }
            }
        }
    }
}

/// The plan-facing KC-blocked MAC block: seed a `T`-typed scratch
/// accumulator from the elided-channel bias, run the blocked loop nest
/// ([`mac_rows_blocked`]), then finish every in-range value through the
/// optional fused threshold into `out`. The memory accumulator is what
/// "spilled partials" spill into; the caller supplies the vector (the
/// sharded chunk paths pass a call-local one, since pool work items
/// cannot share a worker's conversion scratch). Integer-proof-gated at
/// dispatch like the raw blocked kernel — f64 steps never route here.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mac_block_blocked<T: MacElem>(
    a: &[T],
    w: &PackedWeights<T>,
    rows: usize,
    cols: Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    layout: TiledOut,
    mr: usize,
    nr_panels: usize,
    kc: usize,
    scratch: &mut Vec<T>,
) {
    let width = cols.len();
    if width == 0 {
        return;
    }
    scratch.clear();
    scratch.resize(rows * width, T::ZERO);
    if let Some(b) = bias {
        for r in 0..rows {
            let base = r * b.pos_stride;
            for (jj, j) in cols.clone().enumerate() {
                scratch[r * width + jj] = T::from_i64(b.bias[base + j]);
            }
        }
    }
    mac_rows_blocked(a, rows, w, cols.clone(), mr, nr_panels, kc, scratch);
    for r in 0..rows {
        for (jj, j) in cols.clone().enumerate() {
            let f = scratch[r * width + jj].to_f64();
            let v = match fused {
                Some(t) => t.apply_channel(f, j),
                None => f,
            };
            match layout {
                TiledOut::RowMajor => out[r * width + jj] = v,
                TiledOut::ChannelMajor { frame } => out[jj * frame + r] = v,
            }
        }
    }
}

/// Row-tiled depthwise-conv kernel for **one channel**: instead of the
/// scalar per-output-position tap loop, every output row is swept
/// tap-by-tap — for a fixed `(ky, kx)` the inner loop is a contiguous
/// (stride-strided) AXPY over the output row, which vectorizes — with
/// a reusable `T`-typed row accumulator. Taps are applied in the same
/// ascending `(ky, kx)` order as the scalar loop and out-of-bounds
/// (padding) taps are skipped identically, so the per-element operation
/// sequence is *exactly* the scalar one: f64 is bit-identical, and the
/// integer instantiations are exact wherever the scalar order was (the
/// per-channel SIRA bound from `engine::fuse` gates the width). The
/// fused per-channel threshold is applied on the way out.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dw_channel_rows<T: MacElem>(
    xin: &[T],
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    spec: Conv2dSpec,
    taps: &[T],
    channel: usize,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    rowacc: &mut Vec<T>,
) {
    let (kh, kw) = spec.kernel;
    debug_assert!(xin.len() >= h * w);
    debug_assert_eq!(taps.len(), kh * kw);
    debug_assert!(out.len() >= oh * ow);
    rowacc.clear();
    rowacc.resize(ow, T::ZERO);
    for oy in 0..oh {
        let acc = &mut rowacc[..ow];
        for lane in acc.iter_mut() {
            *lane = T::ZERO;
        }
        for ky in 0..kh {
            let iy = (oy * spec.stride.0 + ky) as isize - spec.pad.0 as isize;
            if iy < 0 || iy >= h as isize {
                continue;
            }
            let xrow = &xin[iy as usize * w..iy as usize * w + w];
            for kx in 0..kw {
                let wt = taps[ky * kw + kx];
                // first/last output column whose input stays in-bounds
                // for this kx: 0 <= ox*stride + kx - pad < w. No
                // zero-skip here — the scalar depthwise loop has none,
                // and bit-exactness means mirroring it exactly.
                let off = kx as isize - spec.pad.1 as isize;
                let ox0 = if off >= 0 {
                    0usize
                } else {
                    ((-off) as usize).div_ceil(spec.stride.1)
                };
                let ox1 = if (w as isize) > off {
                    (((w as isize - 1 - off) as usize) / spec.stride.1 + 1).min(ow)
                } else {
                    0
                };
                for (ox, lane) in acc.iter_mut().enumerate().take(ox1).skip(ox0) {
                    let ix = (ox * spec.stride.1) as isize + off;
                    *lane = lane.mul_acc(xrow[ix as usize], wt);
                }
            }
        }
        for (ox, lane) in acc.iter().enumerate() {
            let f = lane.to_f64();
            out[oy * ow + ox] = match fused {
                Some(t) => t.apply_channel(f, channel),
                None => f,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_rows<T: MacElem>(
        a: &[T],
        rows: usize,
        k: usize,
        flat: &[T],
        n: usize,
        cols: Range<usize>,
        acc: &mut [T],
    ) {
        let width = cols.len();
        for r in 0..rows {
            T::mac_row(
                &a[r * k..(r + 1) * k],
                flat,
                n,
                cols.clone(),
                &mut acc[r * width..(r + 1) * width],
            );
        }
    }

    #[test]
    fn pack_layout_is_panelled_and_zero_padded() {
        // (2, 10): two panels, the second padded from 2 lanes to NR
        let flat: Vec<i32> = (0..20).collect();
        let p = PackedWeights::pack(&flat, 2, 10);
        assert_eq!(p.padded_len(), 2 * 2 * NR);
        // panel 0, row 1 = columns 0..8 of weight row 1
        assert_eq!(&p.panel(0)[NR..2 * NR], &flat[10..18]);
        // panel 1, row 0 = columns 8..10 then zeros
        assert_eq!(&p.panel(1)[..2], &flat[8..10]);
        assert!(p.panel(1)[2..NR].iter().all(|&v| v == 0));
    }

    #[test]
    fn tiled_matches_scalar_on_awkward_shapes() {
        // shapes straddling every tile boundary, K = 0 included
        for (rows, k, n) in [
            (1usize, 0usize, 1usize),
            (1, 3, NR - 1),
            (2, 5, NR),
            (3, 8, NR + 1),
            (MR, 16, 2 * NR + 3),
            (MR + 2, 17, 3 * NR - 1),
        ] {
            let a: Vec<i64> = (0..rows * k).map(|i| (i as i64 % 7) - 3).collect();
            let flat: Vec<i64> = (0..k * n).map(|i| (i as i64 % 11) - 5).collect();
            let p = PackedWeights::pack(&flat, k, n);
            let mut want = vec![0i64; rows * n];
            scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
            let mut got = vec![0i64; rows * n];
            mac_rows_tiled(&a, rows, &p, 0..n, &mut got);
            assert_eq!(got, want, "rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn unpack_inverts_pack_exactly() {
        for (k, n) in [(1usize, 1usize), (3, NR - 1), (5, NR), (7, 2 * NR + 3), (2, 10)] {
            let flat: Vec<i64> = (0..k * n).map(|i| (i as i64 % 13) - 6).collect();
            let p = PackedWeights::pack(&flat, k, n);
            assert_eq!(p.unpack(), flat, "k={k} n={n}");
        }
        // f64 round-trips bit-exactly too (copy, never transform)
        let flat: Vec<f64> = vec![-0.0, 1.5, f64::MIN_POSITIVE, -7.25, 0.0, 3.0];
        let p = PackedWeights::pack(&flat, 2, 3);
        let back = p.unpack();
        for (a, b) in flat.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn blocked_matches_scalar_across_schemes_and_shapes() {
        // every (mr, nr_panels, kc) combination over boundary-straddling
        // shapes must reproduce the scalar oracle exactly (integer data
        // far from any overflow bound, so association cannot matter)
        for (rows, k, n) in [
            (1usize, 0usize, 1usize),
            (1, 3, NR - 1),
            (2, 5, NR),
            (3, 8, NR + 1),
            (MR, 16, 2 * NR + 3),
            (MR + 2, 17, 3 * NR - 1),
            (2 * MR + 1, 33, 2 * NR),
        ] {
            let a: Vec<i64> = (0..rows * k).map(|i| (i as i64 % 7) - 3).collect();
            let flat: Vec<i64> = (0..k * n).map(|i| (i as i64 % 11) - 5).collect();
            let p = PackedWeights::pack(&flat, k, n);
            let seed: Vec<i64> = (0..rows * n).map(|i| (i as i64 % 9) - 4).collect();
            let mut want = seed.clone();
            scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
            for mr in [1usize, 3, 4, 8] {
                for np in [1usize, 2, 4] {
                    for kc in [0usize, 1, 5, 16, 64] {
                        let mut got = seed.clone();
                        mac_rows_blocked(&a, rows, &p, 0..n, mr, np, kc, &mut got);
                        assert_eq!(
                            got, want,
                            "rows={rows} k={k} n={n} mr={mr} np={np} kc={kc}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_respects_column_ranges() {
        let (rows, k, n) = (5usize, 21usize, 2 * NR + 5);
        let a: Vec<i32> = (0..rows * k).map(|i| (i as i32 % 5) - 2).collect();
        let flat: Vec<i32> = (0..k * n).map(|i| (i as i32 % 7) - 3).collect();
        let p = PackedWeights::pack(&flat, k, n);
        let mut full = vec![0i32; rows * n];
        mac_rows_blocked(&a, rows, &p, 0..n, 4, 2, 8, &mut full);
        // stitch unaligned sub-ranges back together
        let cuts = [0usize, 3, NR, NR + 5, 2 * NR + 1, n];
        let mut assembled = vec![0i32; rows * n];
        for wpair in cuts.windows(2) {
            let (j0, j1) = (wpair[0], wpair[1]);
            let width = j1 - j0;
            let mut piece = vec![0i32; rows * width];
            mac_rows_blocked(&a, rows, &p, j0..j1, 4, 2, 8, &mut piece);
            for r in 0..rows {
                assembled[r * n + j0..r * n + j1]
                    .copy_from_slice(&piece[r * width..(r + 1) * width]);
            }
        }
        assert_eq!(assembled, full);
    }

    #[test]
    fn f64_zero_skip_is_bit_identical_to_scalar() {
        // signed zeros + a zero activation against a negative weight:
        // the lanes must take the scalar kernel's skip path bit-for-bit
        let a = [0.0f64, -0.0, 2.0, 0.0];
        let n = NR + 1;
        let flat: Vec<f64> = (0..4 * n).map(|i| -(i as f64) - 0.5).collect();
        let p = PackedWeights::pack(&flat, 4, n);
        let mut want = vec![-0.0f64; n];
        scalar_rows(&a, 1, 4, &flat, n, 0..n, &mut want);
        let mut got = vec![-0.0f64; n];
        mac_rows_tiled(&a, 1, &p, 0..n, &mut got);
        for (j, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "lane {j}");
        }
    }
}
