//! Tiled, register-blocked MAC microkernels: the SIMD-friendly core
//! behind the engine's MatMul / im2col-Conv steps (elision-compacted
//! variants included), with the scalar [`MacElem::mac_row`] retained as
//! the bit-exactness oracle (see `rust/tests/kernel_properties.rs`).
//!
//! # Layout
//!
//! Weights are pre-packed at plan-compile time ([`PackedWeights::pack`],
//! driven by [`super::MacMat::new`] from `engine::fuse`) into **panels**:
//! the `(k, n)` row-major matrix is cut into column blocks of [`NR`]
//! lanes, and each panel stores its `k × NR` slice contiguously (ragged
//! final panel zero-padded to `NR`). The inner loop then streams one
//! contiguous panel row per `k` step — no strided weight access, no
//! bounds arithmetic the compiler cannot hoist.
//!
//! # Microkernel
//!
//! [`panel_block`] keeps an `MR × NR` accumulator grid in fixed-size
//! arrays — small enough that the compiler promotes every lane to a SIMD
//! register and unrolls both block loops — and streams the panel
//! sequentially over `k`, so each panel is read exactly once per row
//! block while the `MR` activation rows are reused from registers/L1.
//!
//! # Bit-exactness
//!
//! The register blocking reorders work only **across** output elements,
//! never within one dot product: each accumulator lane still adds its
//! terms in increasing-`k` order, starting from its seed (zero or the
//! elided-channel bias) — exactly the scalar kernel's order. Two
//! consequences, both locked by the property suite:
//!
//! * **f64** stays bit-identical because the per-element operation
//!   sequence is identical, including the zero-skip (`MacElem::
//!   EXACT_SKIP`): a skipped `a == 0.0` term is skipped here too, so
//!   signed zeros and non-finite weights behave exactly as in the
//!   scalar kernel.
//! * **i32/i64** cannot overflow anywhere the scalar kernel didn't: the
//!   per-element partial sums are the *same* sums in the same order (the
//!   compile-time `Σ|aᵢ·wᵢⱼ|` bound from `engine::fuse` additionally
//!   covers any order, pad lanes contribute exact zeros).
//!
//! # Tuning
//!
//! [`NR`]/[`MR`] are compile-time constants chosen for mainstream
//! x86-64/aarch64 SIMD widths; see ROADMAP.md ("Execution backends") for
//! how to re-tune them per target CPU.

use core::ops::Range;

use super::{BiasRef, MacElem, ThresholdTable};

/// Register lanes per column panel: 8 accumulators span two 256-bit
/// vectors at f64/i64 width and one at i32 — wide enough to saturate
/// 2×FMA pipes, narrow enough that an `MR×NR` grid still fits the
/// architectural register file.
pub const NR: usize = 8;

/// Activation rows per register block. `MR × NR = 32` accumulator lanes
/// ≤ 8 vector registers at f64 width, leaving room for the broadcast
/// activation values and the streamed panel row. Re-tunable up to 8
/// (the row-block dispatch in this module instantiates every block
/// height 1..=8 and advances by the height actually run, so any
/// `1 ..= 8` value is safe); the compile-time assertion below guards
/// the ceiling.
pub const MR: usize = 4;

const _: () = assert!(MR >= 1 && MR <= 8, "MR must be within the dispatched 1..=8 range");

/// A weight matrix packed tile-major for the register-blocked kernels:
/// `ceil(n / NR)` panels, each holding its `k × NR` column slice
/// contiguously (row `kk` of panel `jb` = columns `jb*NR .. jb*NR+NR` of
/// weight row `kk`), with the ragged final panel zero-padded. Padding is
/// exact: pad lanes multiply-accumulate literal zeros and are never
/// written back.
#[derive(Clone, Debug)]
pub struct PackedWeights<T> {
    data: Vec<T>,
    k: usize,
    n: usize,
}

impl<T: MacElem> PackedWeights<T> {
    /// Pack a `(k, n)` row-major matrix. The packed copy costs
    /// `k * round_up(n, NR)` elements — the documented packed-weights
    /// memory trade-off (≈ one extra copy of every MAC weight matrix).
    pub fn pack(flat: &[T], k: usize, n: usize) -> PackedWeights<T> {
        assert_eq!(flat.len(), k * n, "flat weight matrix is not (k, n)");
        let nb = n.div_ceil(NR);
        let mut data = vec![T::ZERO; nb * k * NR];
        for jb in 0..nb {
            let base = jb * k * NR;
            let j0 = jb * NR;
            let lanes = NR.min(n - j0);
            for kk in 0..k {
                data[base + kk * NR..base + kk * NR + lanes]
                    .copy_from_slice(&flat[kk * n + j0..kk * n + j0 + lanes]);
            }
        }
        PackedWeights { data, k, n }
    }

    /// Dot length (weight rows).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical column count (pre-padding).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total packed elements, padding included (the memory-overhead
    /// observable surfaced through `PlanStats::packed_weight_elems`).
    pub fn padded_len(&self) -> usize {
        self.data.len()
    }

    /// The contiguous `k × NR` slice of column panel `jb`.
    #[inline]
    fn panel(&self, jb: usize) -> &[T] {
        &self.data[jb * self.k * NR..(jb + 1) * self.k * NR]
    }

    /// Recover the `(k, n)` row-major matrix from the panels, dropping
    /// the pad lanes — the exact inverse of [`PackedWeights::pack`]
    /// (packing copies, never transforms, so `pack(unpack()) == self`).
    /// Used by plan serialization when the flat oracle has been dropped.
    pub fn unpack(&self) -> Vec<T> {
        let mut flat = vec![T::ZERO; self.k * self.n];
        for jb in 0..self.n.div_ceil(NR) {
            let panel = self.panel(jb);
            let j0 = jb * NR;
            let lanes = NR.min(self.n - j0);
            for kk in 0..self.k {
                flat[kk * self.n + j0..kk * self.n + j0 + lanes]
                    .copy_from_slice(&panel[kk * NR..kk * NR + lanes]);
            }
        }
        flat
    }
}

/// The `M × NR` register-blocked inner loop over one weight panel:
/// `acc[r][jj] += a[r*stride + kk] * panel[kk*NR + jj]` for `kk` in
/// increasing order over the full dot length. `acc` lives in fixed-size
/// arrays so every lane stays in a SIMD register across the whole `k`
/// loop; the panel row is one contiguous `NR`-wide load per `kk`. The
/// f64 instantiation preserves the scalar kernel's zero-skip per
/// activation element ([`MacElem::EXACT_SKIP`]); integer instantiations
/// are branch-free (a zero activation contributes an exact zero either
/// way).
#[inline]
fn panel_block<T: MacElem, const M: usize>(
    a: &[T],
    stride: usize,
    k: usize,
    panel: &[T],
    acc: &mut [[T; NR]; M],
) {
    for kk in 0..k {
        let w: &[T; NR] = panel[kk * NR..kk * NR + NR]
            .try_into()
            .expect("panel rows are NR-wide");
        for (r, acc_r) in acc.iter_mut().enumerate() {
            let ar = a[r * stride + kk];
            if T::EXACT_SKIP && ar.is_zero() {
                continue;
            }
            for (lane, &wv) in acc_r.iter_mut().zip(w.iter()) {
                *lane = lane.mul_acc(ar, wv);
            }
        }
    }
}

/// Tiled counterpart of [`MacElem::mac_row`], generalised to a row
/// block: `acc[r * cols.len() + (j - cols.start)] += a_row_r · W[:, j]`
/// for every row `r < rows` and column `j` in `cols`, where `a` holds
/// `rows` activation rows of length `w.k()` and `acc` is caller-seeded
/// (zero, or an elided-channel bias) — the same contract as the scalar
/// kernel, element-exactly (the property suite's oracle comparison).
pub fn mac_rows_tiled<T: MacElem>(
    a: &[T],
    rows: usize,
    w: &PackedWeights<T>,
    cols: Range<usize>,
    acc: &mut [T],
) {
    let k = w.k;
    assert!(cols.end <= w.n, "column range beyond the packed matrix");
    let width = cols.len();
    assert!(a.len() >= rows * k, "activation block too short");
    assert!(acc.len() >= rows * width, "accumulator block too short");
    if width == 0 {
        return;
    }
    let mut r0 = 0usize;
    while r0 < rows {
        // dispatch on the block height actually run (`min(remaining, MR)`)
        // and advance by exactly that, so every MR in 1..=8 is safe
        let m = (rows - r0).min(MR);
        match m {
            1 => raw_rows::<T, 1>(a, w, r0, &cols, acc),
            2 => raw_rows::<T, 2>(a, w, r0, &cols, acc),
            3 => raw_rows::<T, 3>(a, w, r0, &cols, acc),
            4 => raw_rows::<T, 4>(a, w, r0, &cols, acc),
            5 => raw_rows::<T, 5>(a, w, r0, &cols, acc),
            6 => raw_rows::<T, 6>(a, w, r0, &cols, acc),
            7 => raw_rows::<T, 7>(a, w, r0, &cols, acc),
            _ => raw_rows::<T, 8>(a, w, r0, &cols, acc),
        }
        r0 += m;
    }
}

/// One `M`-row block of [`mac_rows_tiled`]: load the in-range seeds into
/// the register grid, run the panels, store the in-range lanes back.
/// Lanes outside `cols` (other shards' columns, pad lanes) are computed
/// into discarded registers and never written.
#[inline]
fn raw_rows<T: MacElem, const M: usize>(
    a: &[T],
    w: &PackedWeights<T>,
    r0: usize,
    cols: &Range<usize>,
    acc: &mut [T],
) {
    let k = w.k;
    let width = cols.len();
    for jb in cols.start / NR..cols.end.div_ceil(NR) {
        let j0 = jb * NR;
        let mut regs = [[T::ZERO; NR]; M];
        for (r, regs_r) in regs.iter_mut().enumerate() {
            let row = &acc[(r0 + r) * width..(r0 + r) * width + width];
            for (jj, lane) in regs_r.iter_mut().enumerate() {
                let j = j0 + jj;
                if j >= cols.start && j < cols.end {
                    *lane = row[j - cols.start];
                }
            }
        }
        panel_block::<T, M>(&a[r0 * k..], k, k, w.panel(jb), &mut regs);
        for (r, regs_r) in regs.iter().enumerate() {
            let row = &mut acc[(r0 + r) * width..(r0 + r) * width + width];
            for (jj, lane) in regs_r.iter().enumerate() {
                let j = j0 + jj;
                if j >= cols.start && j < cols.end {
                    row[j - cols.start] = *lane;
                }
            }
        }
    }
}

/// Output placement of one tiled MAC block.
#[derive(Clone, Copy)]
pub(crate) enum TiledOut {
    /// MatMul: `out[row * cols.len() + (j - cols.start)]`.
    RowMajor,
    /// Conv NCHW scatter: `out[(j - cols.start) * frame + row]` (row =
    /// output position, `j` = output channel).
    ChannelMajor { frame: usize },
}

/// The plan-facing tiled MAC block: seed the accumulator grid from the
/// elided-channel bias (uniform per column, or per output position when
/// `pos_stride != 0`), run the panels, then finish each in-range value
/// through the optional fused threshold into `out` — the tiled
/// equivalent of `plan::mm_block` / `plan::conv_block`, dispatched
/// behind `Plan::set_min_tile_work`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mac_block_tiled<T: MacElem>(
    a: &[T],
    w: &PackedWeights<T>,
    rows: usize,
    cols: Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    layout: TiledOut,
) {
    if cols.is_empty() {
        return;
    }
    let mut r0 = 0usize;
    while r0 < rows {
        let m = (rows - r0).min(MR);
        match m {
            1 => fused_rows::<T, 1>(a, w, r0, &cols, bias, fused, out, layout),
            2 => fused_rows::<T, 2>(a, w, r0, &cols, bias, fused, out, layout),
            3 => fused_rows::<T, 3>(a, w, r0, &cols, bias, fused, out, layout),
            4 => fused_rows::<T, 4>(a, w, r0, &cols, bias, fused, out, layout),
            5 => fused_rows::<T, 5>(a, w, r0, &cols, bias, fused, out, layout),
            6 => fused_rows::<T, 6>(a, w, r0, &cols, bias, fused, out, layout),
            7 => fused_rows::<T, 7>(a, w, r0, &cols, bias, fused, out, layout),
            _ => fused_rows::<T, 8>(a, w, r0, &cols, bias, fused, out, layout),
        }
        r0 += m;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn fused_rows<T: MacElem, const M: usize>(
    a: &[T],
    w: &PackedWeights<T>,
    r0: usize,
    cols: &Range<usize>,
    bias: Option<BiasRef<'_>>,
    fused: &Option<ThresholdTable>,
    out: &mut [f64],
    layout: TiledOut,
) {
    let k = w.k;
    let width = cols.len();
    for jb in cols.start / NR..cols.end.div_ceil(NR) {
        let j0 = jb * NR;
        let mut regs = [[T::ZERO; NR]; M];
        if let Some(b) = bias {
            for (r, regs_r) in regs.iter_mut().enumerate() {
                let base = (r0 + r) * b.pos_stride;
                for (jj, lane) in regs_r.iter_mut().enumerate() {
                    let j = j0 + jj;
                    if j >= cols.start && j < cols.end {
                        *lane = T::from_i64(b.bias[base + j]);
                    }
                }
            }
        }
        panel_block::<T, M>(&a[r0 * k..], k, k, w.panel(jb), &mut regs);
        for (r, regs_r) in regs.iter().enumerate() {
            for (jj, lane) in regs_r.iter().enumerate() {
                let j = j0 + jj;
                if j < cols.start || j >= cols.end {
                    continue;
                }
                let f = lane.to_f64();
                let v = match fused {
                    Some(t) => t.apply_channel(f, j),
                    None => f,
                };
                match layout {
                    TiledOut::RowMajor => out[(r0 + r) * width + (j - cols.start)] = v,
                    TiledOut::ChannelMajor { frame } => {
                        out[(j - cols.start) * frame + r0 + r] = v
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_rows<T: MacElem>(
        a: &[T],
        rows: usize,
        k: usize,
        flat: &[T],
        n: usize,
        cols: Range<usize>,
        acc: &mut [T],
    ) {
        let width = cols.len();
        for r in 0..rows {
            T::mac_row(
                &a[r * k..(r + 1) * k],
                flat,
                n,
                cols.clone(),
                &mut acc[r * width..(r + 1) * width],
            );
        }
    }

    #[test]
    fn pack_layout_is_panelled_and_zero_padded() {
        // (2, 10): two panels, the second padded from 2 lanes to NR
        let flat: Vec<i32> = (0..20).collect();
        let p = PackedWeights::pack(&flat, 2, 10);
        assert_eq!(p.padded_len(), 2 * 2 * NR);
        // panel 0, row 1 = columns 0..8 of weight row 1
        assert_eq!(&p.panel(0)[NR..2 * NR], &flat[10..18]);
        // panel 1, row 0 = columns 8..10 then zeros
        assert_eq!(&p.panel(1)[..2], &flat[8..10]);
        assert!(p.panel(1)[2..NR].iter().all(|&v| v == 0));
    }

    #[test]
    fn tiled_matches_scalar_on_awkward_shapes() {
        // shapes straddling every tile boundary, K = 0 included
        for (rows, k, n) in [
            (1usize, 0usize, 1usize),
            (1, 3, NR - 1),
            (2, 5, NR),
            (3, 8, NR + 1),
            (MR, 16, 2 * NR + 3),
            (MR + 2, 17, 3 * NR - 1),
        ] {
            let a: Vec<i64> = (0..rows * k).map(|i| (i as i64 % 7) - 3).collect();
            let flat: Vec<i64> = (0..k * n).map(|i| (i as i64 % 11) - 5).collect();
            let p = PackedWeights::pack(&flat, k, n);
            let mut want = vec![0i64; rows * n];
            scalar_rows(&a, rows, k, &flat, n, 0..n, &mut want);
            let mut got = vec![0i64; rows * n];
            mac_rows_tiled(&a, rows, &p, 0..n, &mut got);
            assert_eq!(got, want, "rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn unpack_inverts_pack_exactly() {
        for (k, n) in [(1usize, 1usize), (3, NR - 1), (5, NR), (7, 2 * NR + 3), (2, 10)] {
            let flat: Vec<i64> = (0..k * n).map(|i| (i as i64 % 13) - 6).collect();
            let p = PackedWeights::pack(&flat, k, n);
            assert_eq!(p.unpack(), flat, "k={k} n={n}");
        }
        // f64 round-trips bit-exactly too (copy, never transform)
        let flat: Vec<f64> = vec![-0.0, 1.5, f64::MIN_POSITIVE, -7.25, 0.0, 3.0];
        let p = PackedWeights::pack(&flat, 2, 3);
        let back = p.unpack();
        for (a, b) in flat.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f64_zero_skip_is_bit_identical_to_scalar() {
        // signed zeros + a zero activation against a negative weight:
        // the lanes must take the scalar kernel's skip path bit-for-bit
        let a = [0.0f64, -0.0, 2.0, 0.0];
        let n = NR + 1;
        let flat: Vec<f64> = (0..4 * n).map(|i| -(i as f64) - 0.5).collect();
        let p = PackedWeights::pack(&flat, 4, n);
        let mut want = vec![-0.0f64; n];
        scalar_rows(&a, 1, 4, &flat, n, 0..n, &mut want);
        let mut got = vec![-0.0f64; n];
        mac_rows_tiled(&a, 1, &p, 0..n, &mut got);
        for (j, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "lane {j}");
        }
    }
}
